"""Batched serving example: prefill + autoregressive decode with KV/SSM
caches, comparing a full-context cache against the window-sized ring cache
for a local-attention (gemma3-family) model — the paper's fusion idea
("only the group's edges touch DRAM") applied to the serving cache.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import resolve, run_config, scaled_down
from repro.models import model as M


def cache_bytes(cache):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def main():
    cfg = scaled_down(resolve("gemma3"), window_size=16, max_seq_len=96)
    rc = run_config(cfg.name, "decode_32k")
    rc = dataclasses.replace(rc, attn_chunk_kv=32, xent_chunk=32)
    rc_ring = dataclasses.replace(rc, local_ring_cache=True)

    params = M.init_params(jax.random.key(0), cfg)
    B, prompt, gen = 4, 32, 24
    key = jax.random.key(1)
    batch = {"tokens": jax.random.randint(key, (B, prompt), 0, cfg.vocab_size)}

    results = {}
    for name, rc_i, ring in (("full-cache", rc, False), ("ring-cache", rc_ring, True)):
        cache = M.init_cache(cfg, B, prompt + gen + 8, ring=ring)
        cb = cache_bytes(cache)
        prefill = jax.jit(lambda p, c, b: M.prefill(p, cfg, rc_i, b, c),
                          donate_argnums=(1,))
        decode = jax.jit(lambda p, c, t: M.decode(p, cfg, rc_i, t, c),
                         donate_argnums=(1,))
        logits, cache = prefill(params, cache, batch)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        toks = [np.asarray(tok)]
        t0 = time.perf_counter()
        for _ in range(gen - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
            toks.append(np.asarray(tok))
        jax.block_until_ready(tok)
        dt = (time.perf_counter() - t0) / (gen - 1) * 1e3
        results[name] = np.concatenate(toks, axis=1)
        print(f"[serve_lm] {name:10s}: cache {cb/2**10:8.1f} KiB, "
              f"{dt:6.1f} ms/token, sample {results[name][0][:8].tolist()}")

    same = np.array_equal(results["full-cache"], results["ring-cache"])
    print(f"[serve_lm] ring-cache generations identical to full-cache: {same}")
    assert same


if __name__ == "__main__":
    main()
