"""Async planning service demo: serve LM-workload planning requests,
cancel one mid-flight, and drain safely on Ctrl-C.

Real LM graphs (a gemma3-family decoder superblock traced from the model
code, plus a transformer MLP block) are submitted as futures to
:class:`repro.core.service.AsyncPlanningService`.  The sweep runs in
resumable ``hw_chunk`` slices, so a cancellation landing while the fleet
program is running is honoured at the next chunk boundary — demonstrated
here with a deliberately stalled sweep (the same duck-typed fault-hook
idiom the chaos tests use).

The whole session lives inside the service's context manager: a Ctrl-C
(KeyboardInterrupt) unwinds through ``__exit__``, which still drains the
queue — every accepted future resolves with a typed response before the
process exits, and nothing is left half-answered.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import tempfile
import time

from repro.configs import resolve, scaled_down
from repro.core import frontend
from repro.core.arch import paper_config_space
from repro.core.service import AsyncPlanningService, PlanRequest


class SlowChunks:
    """Stretch each sweep chunk so the mid-flight cancel is observable.

    Any object with the right method names works as a service fault hook
    (the duck-typed idiom of repro.runtime.fault_tolerance); a real
    deployment would simply omit it.
    """

    def __init__(self, stall_seconds: float = 0.05):
        self.stall_seconds = stall_seconds
        self.chunks = 0

    def before_chunk(self) -> None:
        self.chunks += 1
        time.sleep(self.stall_seconds)


def main():
    cfg = scaled_down(resolve("gemma3"), window_size=16, max_seq_len=96)
    superblock = frontend.transformer_graph(cfg, seq_len=64, n_sublayers=2)
    mlp = frontend.mlp_block_graph(d_model=256, d_ff=1024, seq_len=64)

    hook = SlowChunks()
    with tempfile.TemporaryDirectory() as journal_dir, AsyncPlanningService(
        config_space=paper_config_space(),
        hw_chunk=2,  # sweep in resumable hardware-axis chunks
        journal_dir=journal_dir,  # WAL: every answer durable before publish
        backoff_seconds=0.0,
        faults=hook,
    ) as svc:
        # A request we will cancel mid-sweep, then the real workload.
        doomed = svc.submit(PlanRequest(graph=superblock))
        served = [
            svc.submit(PlanRequest(graph=g, sram_budget_words=budget))
            for g, budget in [(superblock, 2e6), (mlp, float("inf")),
                              (mlp, 1e6)]
        ]

        # Wait until the doomed request's chunked sweep is provably
        # running, then cancel: the program stops at the next chunk
        # boundary — never mid-kernel, never a silently wasted sweep.
        t0 = time.perf_counter()
        while hook.chunks == 0:
            if time.perf_counter() - t0 > 60:
                raise SystemExit("sweep never started")
            time.sleep(1e-3)
        svc.cancel(doomed)
        resp = doomed.result(timeout=300)
        print(f"[serve_lm] cancelled mid-flight after {hook.chunks} chunks "
              f"-> {resp.error_type} "
              f"({(time.perf_counter() - t0) * 1e3:.0f} ms)")
        assert resp.error_type == "RequestCancelled"

        # Everything else resolves normally (a Ctrl-C here would unwind
        # through __exit__, which drains first — same guarantee).
        for fut in served:
            r = fut.result(timeout=300)
            assert r.ok, r.error_type
            hw = r.plan.best_hw
            print(f"[serve_lm] {r.plan.best_cuts.shape[0]:2d}-edge "
                  f"{'degraded' if r.degraded else 'exact':8s} plan "
                  f"via {r.engine:11s}: "
                  f"({hw.style} {hw.f1},{hw.f2},{hw.f3},{hw.f4})  "
                  f"energy {r.plan.best_metrics.energy_nj / 1e6:8.3f} mJ  "
                  f"latency {r.latency_seconds * 1e3:7.1f} ms")

        stats = svc.stats()
        print(f"[serve_lm] served {stats['counters']['completed']}, "
              f"cancelled {stats['counters']['cancelled_in_sweep']} "
              f"mid-sweep, {stats['ticks']} ticks, "
              f"journal_seq {stats['journal_seq']}")
    print("[serve_lm] drained shutdown: every accepted future resolved")


if __name__ == "__main__":
    main()
