"""Quickstart: the paper's evaluator in five minutes.

1. Reproduce the paper's VGG-16 experiment (Sec. III): find the optimal
   DLA configuration under the published constraints and report the
   fusion-vs-layer-by-layer reductions.
2. Run the same fusion machinery on a modern LM architecture and show the
   planner picking TPU kernel block shapes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import resolve
from repro.core.arch import PAPER_CONSTRAINTS, PAPER_OPTIMAL_CONFIG, paper_config_space
from repro.core.flow import compare_fusion, run_flow
from repro.core.ir import vgg16_ir
from repro.core.planner import plan_model


def main():
    print("=" * 72)
    print("1. Paper reproduction: VGG-16 pre-RTL evaluation (Sec. III)")
    print("=" * 72)
    ir = vgg16_ir(pool_mode="separate")
    res = run_flow(ir, config_space=paper_config_space(),
                   constraints=PAPER_CONSTRAINTS, groupings="pool")
    print(f"optimal hardware under constraints: {res.best_hw.describe()}")
    print(f"  (paper reports (F1,F2,F3,F4) = (4,4,4,4))")
    cmp = compare_fusion(ir, PAPER_OPTIMAL_CONFIG)
    print("\nfusion vs layer-by-layer on the optimal config:")
    print(cmp.describe())
    print("  (paper reports -55.6% BW, -36.7% latency, -49.2% energy)")
    print(f"\nlayer-by-layer meets constraints: {cmp.lbl.meets(PAPER_CONSTRAINTS)}"
          f"  |  fused meets constraints: {cmp.fused.meets(PAPER_CONSTRAINTS)}")

    print("\n" + "=" * 72)
    print("2. Beyond the paper: the evaluator finds better groupings")
    print("=" * 72)
    exh = run_flow(ir, config_space=[PAPER_OPTIMAL_CONFIG],
                   constraints=PAPER_CONSTRAINTS, groupings="exhaustive")
    print(f"best exhaustive grouping: {exh.describe()}")

    print("\n" + "=" * 72)
    print("3. The same flow on TPU: fusion plans for assigned architectures")
    print("=" * 72)
    for arch in ("qwen3", "gemma3", "jamba", "falcon-mamba"):
        cfg = resolve(arch)
        plan = plan_model(cfg, 4096)
        print(plan.describe())


if __name__ == "__main__":
    main()
