"""The paper's workload end to end: evaluate → plan → execute → train VGG.

1. The pre-RTL evaluator picks the fusion grouping for VGG-16 (Sec. III).
2. The planner sizes the fused conv kernel's blocks against VMEM.
3. The fused Pallas conv (+ReLU+pool) forward is checked against XLA ops.
4. A scaled VGG trains for a few steps on synthetic 32x32 data — the same
   fused-conv forward path a TPU deployment would run.

Run:  PYTHONPATH=src python examples/vgg_pipeline.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fusion, metrics as M
from repro.core.arch import PAPER_OPTIMAL_CONFIG, TPU_V5E
from repro.core.ir import vgg16_ir
from repro.kernels.fused_conv import vmem_bytes
from repro.kernels.ops import fused_conv_fn
from repro.models import vgg as VGG


def main():
    # 1. evaluator: grouping + headline numbers
    ir = vgg16_ir(pool_mode="separate")
    cuts = ir.pool_boundary_cuts()
    lbl = M.evaluate_ref(ir, fusion.layer_by_layer_cuts(len(ir)), PAPER_OPTIMAL_CONFIG)
    fus = M.evaluate_ref(ir, cuts, PAPER_OPTIMAL_CONFIG)
    print(f"[vgg] evaluator: fused BW {fus.bandwidth_words/1e6:.1f}M vs "
          f"layer-by-layer {lbl.bandwidth_words/1e6:.1f}M words "
          f"(-{(1-fus.bandwidth_words/lbl.bandwidth_words)*100:.1f}%)")

    # 2. planner-style VMEM feasibility for the fused conv kernel
    for hw, cin in ((224, 64), (56, 256), (14, 512)):
        b = vmem_bytes(hw, hw, cin, block_c=64)
        print(f"[vgg] conv{hw}x{hw}x{cin}: fused working set "
              f"{b/2**20:6.1f} MiB  (VMEM budget {TPU_V5E.vmem_bytes/2**20:.0f} MiB)"
              f"  -> {'fits' if b < TPU_V5E.vmem_bytes else 'needs spatial tiling'}")

    # 3. fused Pallas forward == XLA ops
    params = VGG.init_params(jax.random.key(0), in_hw=32, n_classes=10)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    ref = VGG.forward(params, x)
    fused = VGG.forward(params, x, fused_conv_fn=fused_conv_fn())
    err = float(jnp.abs(ref - fused).max())
    print(f"[vgg] fused-kernel forward max|Δ| vs XLA: {err:.2e}")

    # 4. a few training steps (synthetic data)
    rng = np.random.default_rng(0)
    opt_state = jax.tree.map(lambda p: jnp.zeros_like(p), params)  # momentum
    loss_grad = jax.jit(jax.value_and_grad(VGG.loss_fn))
    losses = []
    for step in range(10):
        batch = {
            "images": jnp.asarray(rng.standard_normal((8, 32, 32, 3)), jnp.float32),
            "labels": jnp.asarray(rng.integers(0, 10, 8)),
        }
        loss, grads = loss_grad(params, batch)
        opt_state = jax.tree.map(lambda m, g: 0.9 * m + g, opt_state, grads)
        params = jax.tree.map(lambda p, m: p - 1e-3 * m, params, opt_state)
        losses.append(float(loss))
    print(f"[vgg] 10 SGD+momentum steps: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
