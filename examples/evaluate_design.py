"""Design-space exploration example: use the pre-RTL evaluator the way a
hardware team would — sweep constraints, compare accelerator styles, and
read the trade-off frontier; then do the same for TPU fusion plans.

Run:  PYTHONPATH=src python examples/evaluate_design.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.arch import Constraints, DLAConfig, default_config_space
from repro.core.flow import run_flow
from repro.core.ir import lm_ir, vgg16_ir
from repro.core import fusion, metrics as M


def main():
    ir = vgg16_ir(pool_mode="separate")

    print("=== constraint sweep: how the optimum moves ===")
    for lat_mcyc in (20, 12, 6, 3):
        c = Constraints(max_latency_cycles=lat_mcyc * 1e6)
        try:
            res = run_flow(ir, constraints=c, groupings="pool")
            print(f"latency <= {lat_mcyc:3d} Mcyc: {res.best_hw.describe():42s}"
                  f" E={res.best_metrics.energy_nj/1e6:6.2f} mJ "
                  f"A={res.best_metrics.area_um2/1e6:5.1f} mm^2")
        except ValueError:
            print(f"latency <= {lat_mcyc:3d} Mcyc: infeasible with default space")

    print("\n=== SRAM budget vs achievable fusion (DP grouping) ===")
    feat = ir.feature_matrix()
    for budget_kwords in (64, 256, 1024, 4096):
        try:
            dp = fusion.optimal_cuts_dp(ir, sram_budget_words=budget_kwords * 1024)
            bw = M.bandwidth_ref(ir, dp.cuts)
            print(f"SRAM {budget_kwords:5d} Kwords: {dp.n_groups:2d} groups, "
                  f"BW {bw/1e6:6.2f} M words")
        except ValueError:
            print(f"SRAM {budget_kwords:5d} Kwords: no feasible grouping")

    print("\n=== the evaluator on a transformer block chain ===")
    ir_lm = lm_ir(name="qwen3ish", n_layers=4, d_model=1024, n_heads=16,
                  n_kv_heads=8, d_ff=3072, seq_len=4096, repeat=2)
    lbl = M.bandwidth_ref(ir_lm, fusion.layer_by_layer_cuts(len(ir_lm)))
    dp = fusion.optimal_cuts_dp(ir_lm)
    print(f"2 transformer blocks, layer-by-layer BW: {lbl/1e6:8.1f} M words")
    print(f"optimal fusion grouping BW:             {dp.group_cost_words/1e6:8.1f}"
          f" M words in-group + weights (groups of "
          f"{[len(g) for g in M.groups_from_cuts(dp.cuts)]} layers)")


if __name__ == "__main__":
    main()
