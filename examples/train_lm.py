"""End-to-end training driver example: a qwen3-family LM for a few hundred
steps on CPU, with checkpointing and an injected failure mid-run to
demonstrate the fault-tolerant restart path.

Default is a ~15M-parameter model sized for this single-core CPU container
(a few seconds/step); ``--large`` selects the ~100M-parameter configuration
(the same code path — use it on real hardware).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
(Use --small for a quick smoke run.)
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax

from repro.configs import resolve, run_config, scaled_down
from repro.data import TokenStream
from repro.optim import AdamWConfig
from repro.runtime.fault_tolerance import ResilientTrainer, flaky
from repro.runtime.steps import make_init, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = resolve("qwen3")
    if args.small:
        cfg = scaled_down(base)
        batch, seq = 8, 64
    elif args.large:
        # ~100M params: qwen3 family at half width/depth.
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
            head_dim=64, d_ff=1536, vocab_size=32_768, dtype="float32",
        )
        batch, seq = 16, 128
    else:
        # ~15M params: single-CPU-core-sized same-family model.
        cfg = dataclasses.replace(
            base, n_layers=6, d_model=384, n_heads=6, n_kv_heads=3,
            head_dim=64, d_ff=1024, vocab_size=8_192, dtype="float32",
        )
        batch, seq = 4, 64

    rc = run_config(cfg.name, "train_4k", microbatches=1, remat="none")
    rc = dataclasses.replace(
        rc, learning_rate=1e-3, warmup_steps=20, xent_chunk=64,
        attn_chunk_kv=64, flash_vjp=True,
    )
    init = make_init(cfg, rc)
    params, opt = init(jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train_lm] {cfg.name}-family, {n/1e6:.1f}M params, "
          f"{args.steps} steps, batch {batch} x seq {seq}")

    stream = TokenStream(cfg, batch, seq, seed=0)
    step = jax.jit(make_train_step(cfg, rc), donate_argnums=(0, 1))
    trainer = ResilientTrainer(
        train_step=step, stream=stream, ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        failure_hook=flaky({args.steps // 2}),  # mid-run node failure
    )
    params, opt = trainer.run(params, opt, args.steps)
    stream.close()
    r = trainer.report
    k = max(len(r.losses) // 6, 1)
    print(f"[train_lm] loss curve: "
          + " -> ".join(f"{l:.3f}" for l in r.losses[::k]))
    print(f"[train_lm] failures={r.failures} restores={r.restores} "
          f"stragglers={r.stragglers} (run survived the injected failure)")
    assert r.last_loss < r.losses[0]


if __name__ == "__main__":
    main()
