"""Hypothesis property tests on the evaluator's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import fusion, metrics as M
from repro.core.arch import DLAConfig
from repro.core.ir import LayerSpec, NetworkIR
from repro.parallel.sharding import repair_spec


def chain_strategy():
    layer = st.tuples(
        st.sampled_from([4, 8, 16]),  # cout
        st.sampled_from([8, 16]),  # hw
    )
    return st.lists(layer, min_size=2, max_size=8)


def build(chain):
    layers = []
    c = 4
    for i, (cout, hw) in enumerate(chain):
        layers.append(LayerSpec(f"l{i}", "conv", c, cout, 16, 16, 3, 3, 1))
        c = cout
    return NetworkIR("h", tuple(layers))


@given(chain_strategy(), st.integers(0, 2**20 - 1))
@settings(max_examples=60, deadline=None)
def test_merging_groups_never_increases_bandwidth(chain, cut_bits):
    """Eq. (1) invariant: removing any cut (fusing two adjacent groups)
    removes one store+load pair — bandwidth is monotone in fusion."""
    ir = build(chain)
    L = len(ir)
    cuts = np.array([(cut_bits >> i) & 1 for i in range(L - 1)], dtype=bool)
    bw = M.bandwidth_ref(ir, cuts)
    for i in range(L - 1):
        if cuts[i]:
            merged = cuts.copy()
            merged[i] = False
            assert M.bandwidth_ref(ir, merged) <= bw


@given(chain_strategy(), st.integers(0, 2**20 - 1))
@settings(max_examples=40, deadline=None)
def test_bandwidth_decomposes_over_groups(chain, cut_bits):
    ir = build(chain)
    L = len(ir)
    cuts = np.array([(cut_bits >> i) & 1 for i in range(L - 1)], dtype=bool)
    groups = M.groups_from_cuts(cuts)
    total = 0.0
    for g in groups:
        sub = NetworkIR("g", tuple(ir.layers[i] for i in g))
        total += M.bandwidth_ref(sub, np.zeros(len(g) - 1, dtype=bool))
    assert total == M.bandwidth_ref(ir, cuts)


@given(chain_strategy())
@settings(max_examples=30, deadline=None)
def test_dp_is_optimal(chain):
    ir = build(chain)
    dp = fusion.optimal_cuts_dp(ir)
    bf = fusion.brute_force_min_bw(ir)
    assert dp.group_cost_words == bf.group_cost_words


@given(chain_strategy(), st.integers(0, 2**20 - 1))
@settings(max_examples=40, deadline=None)
def test_latency_bandwidth_consistency(chain, cut_bits):
    """Eq. (2)'s DRAM terms = Eq. (1) / bus width (same group structure)."""
    ir = build(chain)
    hw = DLAConfig("hsiao", 4, 4, 4, 4)
    L = len(ir)
    cuts = np.array([(cut_bits >> i) & 1 for i in range(L - 1)], dtype=bool)
    lat = M.latency_ref(ir, cuts, hw)
    bw = M.bandwidth_ref(ir, cuts)
    pe = sum(
        hw.pe_busy_cycles(
            macs=l.macs, n_in=l.n_in, n_out=l.n_out, kh=l.kh, kw=l.kw,
            pixels_out=(l.h_in // l.stride) * (l.w_in // l.stride),
        )
        for l in ir.layers
    )
    expected = bw / hw.dram_words_per_cycle + pe + L * hw.pipeline_latency
    assert lat == expected


# ---------------------------------------------------------------------------
# Sharding-spec repair invariants
# ---------------------------------------------------------------------------

AXES = {"pod": 2, "data": 16, "model": 16}


@given(
    st.lists(st.sampled_from([64, 128, 151655, 4096, 8, 1, 24576]),
             min_size=1, max_size=4),
    st.lists(st.sampled_from([None, "pod", "data", "model",
                              ("pod", "data")]), min_size=1, max_size=4),
)
@settings(max_examples=100, deadline=None)
def test_repair_spec_always_divides(shape, spec):
    spec = tuple(spec[: len(shape)])
    fixed = repair_spec(spec, tuple(shape), lambda a: AXES.get(a, 1))
    used = []
    for dim, axis in zip(shape, tuple(fixed) + (None,) * len(shape)):
        if axis is None:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        n = int(np.prod([AXES[a] for a in axes]))
        assert dim % n == 0, (shape, spec, fixed)
        for a in axes:
            assert a not in used  # each mesh axis used at most once
            used.append(a)
