"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fused_attention, fused_conv, fused_mlp, mamba_scan, ref

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def tol(dtype):
    return TOLS[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


@pytest.mark.parametrize("B,Sq,Skv,H,KV,hd", [
    (1, 128, 128, 4, 4, 64),   # MHA
    (2, 256, 256, 8, 2, 64),   # GQA 4:1
    (1, 128, 256, 4, 1, 128),  # MQA, cross-length
    (2, 384, 384, 6, 2, 32),   # non-pow2 heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, Sq, Skv, H, KV, hd, dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (B, Sq, H, hd), dtype)
    k = jax.random.normal(k2, (B, Skv, KV, hd), dtype)
    v = jax.random.normal(k3, (B, Skv, KV, hd), dtype)
    out = fused_attention.flash_attention(q, k, v, block_q=128, block_k=128)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=tol(dtype), rtol=tol(dtype),
    )


@pytest.mark.parametrize("window,chunk", [(0, 0), (64, 0), (0, 128), (32, 0)])
def test_flash_attention_masks(window, chunk):
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(k1, (2, 256, 4, 64))
    k = jax.random.normal(k2, (2, 256, 2, 64))
    v = jax.random.normal(k3, (2, 256, 2, 64))
    out = fused_attention.flash_attention(q, k, v, window=window, chunk=chunk)
    expect = ref.flash_attention_ref(q, k, v, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), atol=2e-5)


@pytest.mark.parametrize("blocks", [(128, 128), (256, 128), (128, 256)])
def test_flash_attention_block_invariance(blocks):
    bq, bk = blocks
    k1, k2, k3 = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(k1, (1, 256, 2, 64))
    k = jax.random.normal(k2, (1, 256, 2, 64))
    v = jax.random.normal(k3, (1, 256, 2, 64))
    base = fused_attention.flash_attention(q, k, v, block_q=128, block_k=128)
    out = fused_attention.flash_attention(q, k, v, block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=2e-5)


@pytest.mark.parametrize("T,d,ff,act", [
    (128, 64, 256, "swiglu"),
    (256, 128, 512, "geglu"),
    (128, 64, 128, "gelu"),
    (384, 96, 384, "relu"),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_mlp_shapes(T, d, ff, act, dtype):
    ks = jax.random.split(jax.random.key(3), 4)
    x = jax.random.normal(ks[0], (T, d), dtype)
    w1 = (jax.random.normal(ks[1], (d, ff)) * 0.1).astype(dtype)
    w2 = (jax.random.normal(ks[2], (ff, d)) * 0.1).astype(dtype)
    w3 = (jax.random.normal(ks[3], (d, ff)) * 0.1).astype(dtype)
    out = fused_mlp.fused_mlp(x, w1, w2, w3, act=act, block_m=128, block_f=128)
    expect = ref.fused_mlp_ref(x, w1, w2, w3, act=act)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=tol(dtype) * 10, rtol=tol(dtype) * 10,
    )


@pytest.mark.parametrize("B,H,W,Cin,Cout,pool", [
    (1, 8, 8, 4, 8, False),
    (2, 16, 16, 8, 16, True),
    (1, 32, 32, 3, 8, True),
    (2, 8, 8, 16, 32, False),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_conv_shapes(B, H, W, Cin, Cout, pool, dtype):
    ks = jax.random.split(jax.random.key(4), 3)
    x = jax.random.normal(ks[0], (B, H, W, Cin), dtype)
    w = (jax.random.normal(ks[1], (3, 3, Cin, Cout)) * 0.2).astype(dtype)
    b = jax.random.normal(ks[2], (Cout,), dtype)
    out = fused_conv.fused_conv3x3(x, w, b, pool=pool, block_c=min(8, Cout))
    expect = ref.fused_conv3x3_ref(x, w, b, pool=pool)
    assert out.shape == expect.shape
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=tol(dtype) * 10, rtol=tol(dtype) * 10,
    )


@pytest.mark.parametrize("B,S,di,ds,chunk,bd", [
    (1, 64, 16, 4, 16, 16),
    (2, 128, 32, 8, 32, 16),
    (1, 64, 64, 16, 64, 32),
])
def test_mamba_scan_shapes(B, S, di, ds, chunk, bd):
    ks = jax.random.split(jax.random.key(5), 3)
    dA = jax.random.uniform(ks[0], (B, S, di, ds), minval=0.3, maxval=0.98)
    dBx = jax.random.normal(ks[1], (B, S, di, ds)) * 0.1
    C = jax.random.normal(ks[2], (B, S, ds))
    out = mamba_scan.selective_scan(dA, dBx, C, chunk=chunk, block_d=bd)
    expect = ref.selective_scan_ref(dA, dBx, C)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)


def test_vmem_budgets():
    """Planner block choices must fit v5e VMEM (128 MiB, /4 headroom)."""
    from repro.core.arch import TPU_V5E
    from repro.core.planner import plan_model
    from repro.configs import REGISTRY

    for cfg in REGISTRY.values():
        plan = plan_model(cfg, 4096)
        assert plan.attn_vmem_bytes <= TPU_V5E.vmem_bytes // 4
        assert plan.mlp_vmem_bytes <= TPU_V5E.vmem_bytes // 4
        assert plan.attn_block_q % 128 == 0 and plan.attn_block_k % 128 == 0
