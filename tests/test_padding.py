"""Shape-bucketed (padded/masked) evaluation and the fleet sweep.

Locks the PR's core claim: zero-padding a graph's evaluator arrays to a
shape bucket and evaluating through the masked kernels is **bit-identical**
to the unpadded batch path and the scalar ``*_ref`` oracles, for all four
metrics and the SRAM feasibility mask — so `run_flow(bucket=True)` and
`run_fleet` change compile economics, never results.
"""
import numpy as np
import pytest

from repro.core import flow, fusion, metrics as M
from repro.core.arch import Constraints, PAPER_OPTIMAL_CONFIG
from repro.core.frontend import mlp_block_graph, mobilenet_graph
from repro.core.ir import (
    LayerSpec,
    NetworkIR,
    as_graph,
    bucket_size,
    encoder_decoder_ir,
    pad_cuts_batch,
    pad_graph,
    resnet18_ir,
    vgg16_ir,
)

RELAXED = Constraints(*[1e15] * 4)
HW = PAPER_OPTIMAL_CONFIG


def _workloads():
    return {
        "vgg16": as_graph(vgg16_ir(pool_mode="separate")),
        "resnet18": resnet18_ir(),
        "mobilenet": mobilenet_graph(),
        "mlp_block": as_graph(mlp_block_graph()),
        "encoder_decoder": encoder_decoder_ir(),
    }


def _rng_cuts(g, rng, C=5):
    return rng.random((C, g.n_edges)) < 0.5


def _eval_unpadded(g, cuts, hw_rows, ac):
    feat = g.node_features()
    esrc, edst, ewords = g.edge_arrays()
    return np.asarray(M.evaluate_batch_graph(
        feat, esrc, edst, ewords, g.source_mask, g.sink_mask, cuts,
        hw_rows, ac,
    ))


def _eval_padded(g, cuts, hw_rows, ac, *, n_nodes=32, n_edges=64, n_rows=8):
    pg = pad_graph(g, n_nodes=n_nodes, n_edges=n_edges)
    pc = pad_cuts_batch(cuts, n_edges, n_rows)
    out = np.asarray(M.evaluate_batch_graph(
        pg.feat, pg.esrc, pg.edst, pg.ewords, pg.src_mask, pg.sink_mask,
        pc, hw_rows, ac, pg.node_mask, pg.edge_mask,
    ))
    return out[:, : cuts.shape[0]]


# ---------------------------------------------------------------------------
# Padding helpers
# ---------------------------------------------------------------------------


def test_bucket_size():
    assert [bucket_size(n) for n in (1, 2, 3, 4, 5, 17, 64)] == [
        1, 2, 4, 4, 8, 32, 64,
    ]
    assert bucket_size(3, floor=32) == 32
    assert bucket_size(33, floor=32) == 64


def test_pad_graph_shapes_and_masks():
    g = resnet18_ir()
    pg = pad_graph(g, n_nodes=32, n_edges=64)
    assert pg.feat.shape == (32, g.node_features().shape[1])
    assert pg.n_nodes == g.n_nodes and pg.n_edges == g.n_edges
    assert pg.node_mask.sum() == g.n_nodes and pg.edge_mask.sum() == g.n_edges
    assert not pg.node_mask[g.n_nodes :].any()
    assert not pg.src_mask[g.n_nodes :].any()
    assert not pg.sink_mask[g.n_nodes :].any()
    assert (pg.feat[g.n_nodes :] == 0).all()
    assert (pg.ewords[g.n_edges :] == 0).all()
    with pytest.raises(ValueError):
        pad_graph(g, n_nodes=8, n_edges=64)
    with pytest.raises(ValueError):
        pad_cuts_batch(np.zeros((3, 5), dtype=bool), 5, 2)


# ---------------------------------------------------------------------------
# Padding invariance — every in-repo workload, all four metrics + SRAM mask
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,g", _workloads().items(), ids=_workloads())
def test_padded_bit_identical_on_workloads(name, g):
    """Acceptance: padded/bucketed == unpadded batch == scalar oracles."""
    rng = np.random.default_rng(7)
    cuts = np.concatenate([
        flow.groupings_batch(g, "pool"), _rng_cuts(g, rng, C=3)
    ])
    hw_rows = np.stack([HW.as_row()])
    ac = M.area_consts_of(HW)
    ref = _eval_unpadded(g, cuts, hw_rows, ac)
    pad = _eval_padded(g, cuts, hw_rows, ac)
    assert np.array_equal(ref, pad)  # bit-identical, not approx
    for i in range(cuts.shape[0]):  # and both == the scalar oracles
        m = M.evaluate_ref(g, cuts[i], HW)
        assert pad[0, i, 0] == m.bandwidth_words
        assert pad[0, i, 1] == m.latency_cycles
        assert pad[0, i, 2] == m.energy_nj
        assert pad[0, i, 3] == m.area_um2

    # SRAM feasibility through the padded prefilter kernel.
    pg = pad_graph(g, n_nodes=32, n_edges=64)
    pc = pad_cuts_batch(cuts, 64)
    max_int = fusion.padded_max_intermediate_batch(pg, pc)
    assert np.array_equal(
        max_int, fusion.graph_max_intermediate_batch(g, cuts)
    )
    assert max_int[0] == fusion.graph_max_intermediate(g, cuts[0])
    budget = float(np.median(max_int))
    assert np.array_equal(
        fusion.padded_feasible_mask_batch(pg, pc, budget),
        fusion.graph_feasible_mask_batch(g, cuts, budget),
    )


def test_run_flow_bucketed_equals_unbucketed():
    for g in (resnet18_ir(), as_graph(mlp_block_graph())):
        b = flow.run_flow(g, config_space=[HW], constraints=RELAXED,
                          groupings="search")
        u = flow.run_flow(g, config_space=[HW], constraints=RELAXED,
                          groupings="search", bucket=False)
        assert b.best_metrics == u.best_metrics
        assert np.array_equal(b.best_cuts, u.best_cuts)
        assert b.n_candidates == u.n_candidates  # padded rows not counted


# ---------------------------------------------------------------------------
# Fleet sweep
# ---------------------------------------------------------------------------


def test_run_fleet_matches_run_flow_with_one_compile():
    works = _workloads()
    del works["encoder_decoder"]  # keep the search cheap; 4 graphs >= 4
    flow.clear_sweep_cache()
    fl = flow.run_fleet(list(works.values()), config_space=[HW],
                        constraints=RELAXED, groupings="search")
    stats = flow.sweep_cache_stats()
    assert stats["misses"] == 1  # ONE executable for the whole fleet
    assert fl.compile_seconds > 0.0
    assert fl.n_graphs == len(works)
    assert fl.n_candidates == sum(r.n_candidates for r in fl.results)
    for g, r in zip(works.values(), fl.results):
        solo = flow.run_flow(g, config_space=[HW], constraints=RELAXED,
                             groupings="search")
        assert r.best_metrics == solo.best_metrics
        assert np.array_equal(r.best_cuts, solo.best_cuts)
        assert r.best_cuts.shape == (g.n_edges,)
    # the per-graph bucketed flows above shared one more executable
    assert flow.sweep_cache_stats()["misses"] == 2


def test_run_fleet_sram_prefilter_and_errors():
    rb = _workloads()["mlp_block"]
    with pytest.raises(ValueError):
        flow.run_fleet([])
    budget = 1.0  # nothing fits: lbl grouping survives (no intermediates)
    fl = flow.run_fleet([rb], config_space=[HW], constraints=RELAXED,
                        groupings="search", sram_budget_words=budget)
    assert fl.results[0].n_pruned > 0
    assert fusion.graph_max_intermediate(rb, fl.results[0].best_cuts) <= budget


# ---------------------------------------------------------------------------
# Satellites: LRU sweep cache, pool dedupe, planner memo
# ---------------------------------------------------------------------------


def test_sweep_cache_is_lru_not_clear(monkeypatch):
    monkeypatch.setattr(flow, "SWEEP_CACHE_CAPACITY", 2)
    monkeypatch.setattr(flow, "_COMPILED_SWEEPS", type(flow._COMPILED_SWEEPS)())
    monkeypatch.setattr(
        flow, "_SWEEP_CACHE_STATS", {"hits": 0, "misses": 0, "evictions": 0}
    )
    flow._sweep_cache_put(("a",), "exe_a")
    flow._sweep_cache_put(("b",), "exe_b")
    assert flow._sweep_cache_get(("a",)) == "exe_a"  # refreshes a's recency
    flow._sweep_cache_put(("c",), "exe_c")  # evicts b (LRU), NOT everything
    assert flow._sweep_cache_get(("b",)) is None
    assert flow._sweep_cache_get(("a",)) == "exe_a"  # hot entry survived
    assert flow._sweep_cache_get(("c",)) == "exe_c"
    stats = flow.sweep_cache_stats()
    assert stats["evictions"] == 1 and stats["size"] == 2


def test_groupings_batch_pool_dedupes_degenerate_policy():
    # Every producer ends a pooling stage -> pool policy == layer-by-layer;
    # the duplicate row must not be scored twice.
    layers = tuple(
        LayerSpec(f"l{i}", "conv", 8, 8, 16, 16, 3, 3, 1, pool_after=2)
        for i in range(4)
    )
    g = as_graph(NetworkIR("allpool", layers))
    cuts = flow.groupings_batch(g, "pool")
    assert cuts.shape[0] == 1
    assert cuts.all()
    # VGG-16 keeps both distinct rows.
    assert flow.groupings_batch(as_graph(vgg16_ir()), "pool").shape[0] == 2


def test_plan_model_memoises_block_evaluation():
    from repro.configs import REGISTRY
    from repro.core import planner

    cfg = REGISTRY[sorted(REGISTRY)[0]]
    planner._block_bandwidths.cache_clear()
    p1 = planner.plan_model(cfg, 4096)
    info = planner._block_bandwidths.cache_info()
    assert info.misses == 1
    p2 = planner.plan_model(cfg, 4096)
    info = planner._block_bandwidths.cache_info()
    assert info.hits == 1 and info.misses == 1
    assert p1 == p2


# The hypothesis property test for padding invariance on random DAGs lives
# in tests/test_padding_property.py: the suite convention puts
# pytest.importorskip("hypothesis") at module top, which skips the WHOLE
# module when hypothesis is absent — the deterministic locks above must
# still run in that environment.
