"""Planning service: typed responses, degradation ladder, bit-identity.

The contract under test (repro.core.service):

* a non-degraded service plan is BIT-IDENTICAL to the offline
  ``run_fleet(groupings="search")`` answer for the same request;
* the deadline ladder's quality bound is monotone non-decreasing down
  exact -> beam -> greedy -> lbl;
* every failure mode — corrupt graph, bad budget/deadline, impossible
  constraints, overload, transient faults — produces a *typed* response,
  never a raw exception;
* micro-batched requests share ONE fleet sweep (and its one compile), and
  one infeasible member cannot poison its batch neighbours.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import flow, frontend, fusion, service
from repro.core.arch import Constraints, DLAConfig, paper_config_space
from repro.core.errors import (
    ConfigValidationError,
    DeadlineExceeded,
    GraphValidationError,
    InfeasibleConstraintsError,
    ServiceOverloaded,
    TransientFailure,
)
from repro.core.ir import as_graph, encoder_decoder_ir, residual_block_ir
from repro.core.service import PlanRequest, PlanningService

SPACE = paper_config_space()


def _graphs():
    return [
        as_graph(frontend.mlp_block_graph()),
        as_graph(residual_block_ir()),
        as_graph(encoder_decoder_ir()),
    ]


def _service(**kw):
    kw.setdefault("config_space", SPACE)
    kw.setdefault("backoff_seconds", 0.0)
    return PlanningService(**kw)


# ---------------------------------------------------------------------------
# bit-identity + provenance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("budget", [float("inf"), 2e6])
def test_plan_matches_offline_fleet_verdict(budget):
    """Service and offline run_fleet agree per graph: bit-identical plans
    when feasible, the same typed verdict when not (at budget=2e6 the
    encoder-decoder violates the default paper constraints offline too)."""
    svc = _service()
    for g in _graphs():
        try:
            ref = flow.run_fleet(
                [g], config_space=SPACE, groupings="search",
                sram_budget_words=budget,
            ).results[0]
        except InfeasibleConstraintsError:
            ref = None
        resp = svc.plan(PlanRequest(graph=g, sram_budget_words=budget))
        if ref is None:
            assert not resp.ok
            assert isinstance(resp.error, InfeasibleConstraintsError)
            continue
        assert resp.ok and not resp.degraded
        assert np.array_equal(resp.plan.best_cuts, ref.best_cuts)
        assert resp.plan.best_metrics == ref.best_metrics
        assert resp.plan.best_hw == ref.best_hw
        # provenance: the ladder's engine replaces run_fleet's "explicit"
        assert resp.engine == ref.search_engine
        assert resp.plan.search_engine == resp.engine
        assert resp.exact == (
            resp.engine in ("chain_dp", "frontier_dp", "exhaustive")
        )


def test_plan_cache_returns_identical_plan():
    svc = _service()
    g = _graphs()[0]
    first = svc.plan(PlanRequest(graph=g))
    again = svc.plan(PlanRequest(graph=g))
    assert not first.from_cache and again.from_cache
    assert np.array_equal(first.plan.best_cuts, again.plan.best_cuts)
    assert first.plan.best_metrics == again.plan.best_metrics
    stats = svc.plan_cache_stats()
    assert stats["hits"] == 1 and stats["size"] == 1


def test_degraded_plans_are_not_cached():
    svc = _service()
    svc._rung_ewma["exact"] = 1e6  # force the ladder below exact
    svc._rung_ewma["beam"] = 1e6
    svc._rung_ewma["greedy"] = 0.0
    g = _graphs()[1]
    r = svc.plan(PlanRequest(graph=g, deadline_seconds=30.0))
    assert r.ok and r.degraded and r.rung == "greedy"
    assert svc.plan_cache_stats()["size"] == 0
    # with the pressure gone, the same request now earns the exact plan
    svc._rung_ewma["exact"] = 0.0
    r2 = svc.plan(PlanRequest(graph=g, deadline_seconds=30.0))
    assert r2.ok and not r2.degraded and not r2.from_cache


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


def test_quality_bound_monotone_down_the_ladder():
    g = as_graph(residual_block_ir())
    bounds = {}
    for rung in service.RUNGS:
        svc = _service()  # fresh per rung: the plan cache must not answer
        for r in service.RUNGS:  # force exactly this rung
            svc._rung_ewma[r] = 0.0 if r == rung else 1e6
        deadline = float("inf") if rung == "exact" else 30.0
        resp = svc.plan(PlanRequest(graph=g, deadline_seconds=deadline))
        assert resp.ok and resp.rung == rung
        assert resp.quality_bound >= 1.0
        bounds[rung] = resp.quality_bound
    assert (
        bounds["exact"] <= bounds["beam"] <= bounds["greedy"]
        <= bounds["lbl"]
    )


def test_ladder_rung_selection_tracks_remaining_deadline():
    svc = _service()
    svc._rung_ewma.update(exact=10.0, beam=1.0, greedy=0.1, lbl=0.0)
    svc._sweep_ewma = 0.0
    assert svc._pick_rung(float("inf")) == "exact"
    assert svc._pick_rung(100.0) == "exact"
    assert svc._pick_rung(5.0) == "beam"
    assert svc._pick_rung(0.5) == "greedy"
    assert svc._pick_rung(0.01) == "lbl"


def test_zero_deadline_is_typed_deadline_exceeded():
    svc = _service()
    r = svc.plan(PlanRequest(graph=_graphs()[0], deadline_seconds=0.0))
    assert not r.ok and isinstance(r.error, DeadlineExceeded)
    assert isinstance(r.error, TimeoutError)  # compat inheritance


# ---------------------------------------------------------------------------
# admission
# ---------------------------------------------------------------------------


def test_admission_rejects_non_graph_payload():
    r = _service().plan(PlanRequest(graph="not a graph"))
    assert not r.ok and isinstance(r.error, GraphValidationError)


def test_admission_rejects_bad_budget():
    svc = _service()
    for budget in (float("nan"), -1.0, 0.0):
        r = svc.plan(PlanRequest(graph=_graphs()[0],
                                 sram_budget_words=budget))
        assert not r.ok and isinstance(r.error, GraphValidationError)


def test_admission_rejects_mixed_area_constants():
    mixed = (
        DLAConfig("hsiao", 4, 4, 4, 4),
        dataclasses.replace(
            DLAConfig("hsiao", 8, 8, 8, 8), area_per_mult_um2=1.0
        ),
    )
    r = _service().plan(PlanRequest(graph=_graphs()[0], config_space=mixed))
    assert not r.ok and isinstance(r.error, ConfigValidationError)


def test_queue_overload_sheds_typed():
    svc = _service(max_queue_depth=2)
    g = _graphs()[0]
    rids = [svc.submit(PlanRequest(graph=g, sram_budget_words=1e5 + i))
            for i in range(5)]
    shed = [rid for rid in rids
            if (resp := svc._responses.get(rid)) is not None
            and isinstance(resp.error, ServiceOverloaded)]
    assert len(shed) == 3
    svc.drain()
    assert all(svc.collect(rid) is not None for rid in rids)


# ---------------------------------------------------------------------------
# micro-batching + isolation
# ---------------------------------------------------------------------------


def test_micro_batch_shares_one_sweep():
    flow.clear_sweep_cache()
    svc = _service(max_batch=8)
    for g in _graphs():
        svc.submit(PlanRequest(graph=g))
    produced = svc.tick()
    assert produced == 3
    stats = flow.sweep_cache_stats()
    assert stats["misses"] == 1  # three graphs, ONE compiled fleet sweep
    assert svc.stats()["counters"]["completed"] == 3


def test_infeasible_member_cannot_poison_its_batch():
    svc = _service(max_batch=8)
    g_ok, g_bad = _graphs()[0], _graphs()[1]
    rid_ok = svc.submit(PlanRequest(graph=g_ok))
    rid_bad = svc.submit(PlanRequest(
        graph=g_bad,
        constraints=Constraints(max_bandwidth_words=0.5,
                                max_latency_cycles=1.0,
                                max_energy_nj=1.0, max_area_um2=1.0),
    ))
    svc.drain()
    ok = svc.collect(rid_ok)
    bad = svc.collect(rid_bad)
    assert ok.ok
    assert not bad.ok and isinstance(bad.error, InfeasibleConstraintsError)


# ---------------------------------------------------------------------------
# transient faults / retry
# ---------------------------------------------------------------------------


class _FlakySweeps:
    """Raise on the first ``n`` before_sweep calls, then heal."""

    def __init__(self, n):
        self.n = n
        self.calls = 0

    def before_sweep(self, group_size):
        self.calls += 1
        if self.calls <= self.n:
            raise RuntimeError("injected transient")


def test_transient_sweep_failures_are_retried():
    svc = _service(faults=_FlakySweeps(2), max_retries=3)
    r = svc.plan(PlanRequest(graph=_graphs()[0]))
    assert r.ok
    assert svc.stats()["counters"]["transient_retries"] == 2


def test_transient_exhaustion_is_typed():
    svc = _service(faults=_FlakySweeps(100), max_retries=2)
    r = svc.plan(PlanRequest(graph=_graphs()[0]))
    assert not r.ok and isinstance(r.error, TransientFailure)
    assert r.error.attempts == 3
    assert isinstance(r.error.cause, RuntimeError)


# ---------------------------------------------------------------------------
# typed boundaries the service builds on
# ---------------------------------------------------------------------------


def test_run_flow_infeasible_budget_carries_min_feasible():
    """Satellite: run_flow names the smallest workable budget instead of
    returning a silently empty sweep."""
    from repro.core.errors import InfeasibleBudgetError

    g = as_graph(frontend.mlp_block_graph())
    fused = np.zeros((1, g.n_edges), dtype=bool)  # only the all-fused row
    need = fusion.graph_max_intermediate_batch(g, fused).min()
    with pytest.raises(InfeasibleBudgetError) as ei:
        flow.run_flow(g, config_space=SPACE, groupings=fused,
                      sram_budget_words=need - 1)
    assert ei.value.min_feasible_budget_words == pytest.approx(float(need))
    assert isinstance(ei.value, ValueError)  # compat inheritance
    # the reported budget is actionable: retrying with it succeeds
    res = flow.run_flow(g, config_space=SPACE, groupings=fused,
                        sram_budget_words=ei.value.min_feasible_budget_words)
    assert res.n_feasible >= 1
