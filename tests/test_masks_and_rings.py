"""Hypothesis properties for attention masks and ring-buffer positions."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.layers import attention_bias, ring_positions


@given(st.integers(2, 48), st.integers(1, 32), st.integers(1, 16),
       st.integers(2, 16))
@settings(max_examples=60, deadline=None)
def test_attention_bias_semantics(S, window, chunk, _):
    """Causal ⊇ local ⊇ nothing; every unmasked (q,k) obeys its rule; each
    causal query row keeps at least its own position."""
    pos = jnp.arange(S)
    causal = np.asarray(attention_bias(pos, pos, mixer="attn", causal=True,
                                       window=0, chunk=0)) == 0
    local = np.asarray(attention_bias(pos, pos, mixer="attn_local",
                                      causal=True, window=window, chunk=0)) == 0
    chunked = np.asarray(attention_bias(pos, pos, mixer="attn_chunked",
                                        causal=True, window=0, chunk=chunk)) == 0
    q = np.arange(S)[:, None]
    k = np.arange(S)[None, :]
    assert (causal == (k <= q)).all()
    assert (local == ((k <= q) & (q - k < window))).all()
    assert (chunked == ((k <= q) & (q // chunk == k // chunk))).all()
    assert local[causal == 0].sum() == 0  # local ⊆ causal
    assert np.diag(causal).all() and np.diag(local).all() and np.diag(chunked).all()


@given(st.integers(1, 64), st.integers(0, 500))
@settings(max_examples=80, deadline=None)
def test_ring_positions_invariants(W, p_last):
    """Slots hold exactly the last min(W, p_last+1) positions, each in its
    position%W slot; unwritten slots are negative."""
    pos = np.asarray(ring_positions(W, p_last))
    valid = pos[pos >= 0]
    expect = np.arange(max(p_last - W + 1, 0), p_last + 1)
    assert sorted(valid.tolist()) == expect.tolist()
    for j, p in enumerate(pos):
        if p >= 0:
            assert p % W == j  # slot invariant
    assert (pos <= p_last).all()


@given(st.integers(1, 16), st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_ring_positions_masked_by_bias(W, p_last):
    """Negative (unwritten) ring slots are always masked by attention_bias."""
    kv_pos = ring_positions(W, p_last)
    bias = np.asarray(attention_bias(jnp.array([p_last]), kv_pos,
                                     mixer="attn_local", causal=True,
                                     window=W, chunk=0))[0]
    kv = np.asarray(kv_pos)
    assert (bias[kv < 0] < -1e29).all()
    assert (bias[kv >= 0] == 0).all()  # every held position is attendable
