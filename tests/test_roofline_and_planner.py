"""Roofline term arithmetic, model FLOPs, planner decisions, evaluator's
TPU entry."""
import json
import pathlib

import pytest

from repro.configs import REGISTRY, SHAPES, resolve
from repro.core import roofline as RL
from repro.core.arch import TPU_V5E
from repro.core.planner import plan_model


def test_tpu_spec_constants():
    assert TPU_V5E.peak_flops == 197e12
    assert TPU_V5E.hbm_bw == 819e9
    assert TPU_V5E.ici_bw == 4 * 50e9


def test_model_flops_train_matches_6nd():
    cfg = resolve("qwen3")
    shape = SHAPES["train_4k"]
    f = RL.model_flops(cfg, shape, kind="train")
    n_active = cfg.param_counts()["active"]
    assert f == 6.0 * n_active * shape.global_batch * shape.seq_len


def test_model_flops_decode_counts_batch_tokens():
    cfg = resolve("qwen3")
    shape = SHAPES["decode_32k"]
    f = RL.model_flops(cfg, shape, kind="decode")
    assert f == 2.0 * cfg.param_counts()["active"] * shape.global_batch


def test_roofline_bound_selection():
    r = RL.Roofline(
        flops=1e12, hbm_bytes=1e12, coll_bytes=1e9, coll_breakdown={},
        compute_s=1e12 / TPU_V5E.peak_flops,
        memory_s=1e12 / TPU_V5E.hbm_bw,
        collective_s=1e9 / TPU_V5E.ici_bw,
        model_flops_per_device=5e11,
    )
    assert r.bound == "memory"
    assert r.step_seconds == r.memory_s
    assert 0 < r.mfu_bound < 1
    assert r.useful_flops_ratio == pytest.approx(0.5)


def test_collective_bytes_regex():
    text = "  %x.1 = bf16[256,1024]{1,0} all-gather-start(%a), dimensions={0}\n" \
           "  %x.2 = bf16[256,1024]{1,0} all-gather-done(%x.1)\n"
    out = RL.collective_bytes(text)
    assert out["all-gather"] == 256 * 1024 * 2  # -start counted once


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_planner_block_bandwidth_savings(arch):
    plan = plan_model(REGISTRY[arch], 4096)
    # fusing a transformer block must save bandwidth vs layer-by-layer
    assert 0.0 < plan.bw_saving < 1.0
    assert plan.attn_vmem_bytes <= TPU_V5E.vmem_bytes // 4
    assert plan.mlp_vmem_bytes <= TPU_V5E.vmem_bytes // 4


def test_dryrun_records_exist_and_are_complete():
    """The sweep artifacts this repo ships must cover every supported cell
    on both meshes (40 assigned cells minus documented long_500k skips)."""
    from repro.configs import all_cells

    droot = pathlib.Path(__file__).resolve().parents[1] / "experiments/dryrun"
    if not droot.exists():
        pytest.skip("dry-run sweep not yet executed")
    cells = all_cells()
    missing = []
    for arch, shape in cells:
        for mesh in ("single", "multi"):
            f = droot / f"{arch}__{shape}__{mesh}.json"
            if not f.exists():
                missing.append((arch, shape, mesh))
    assert not missing, f"missing dry-run cells: {missing[:8]}"
    # spot-check record integrity
    rec = json.loads((droot / "qwen3-0.6b__train_4k__single.json").read_text())
    assert rec["n_chips"] == 256
    assert rec["roofline"]["bound"] in ("compute", "memory", "collective")
    assert rec["memory_analysis"]["temp_size_in_bytes"] > 0
