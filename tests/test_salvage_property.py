"""Hypothesis property: quarantine is exact and selection-neutral.

For ARBITRARY poison placement — any (hw, cut) cell, any poison kind
(NaN / Inf / negative / >2^53) — the finite guard must (a) quarantine
exactly the injected cell with correct provenance, (b) never let it win
the argmin, and (c) leave the selection among clean cells bit-identical
whenever the poisoned cell was not the clean winner.  Deterministic
single-placement locks live in tests/test_salvage.py (this module is
skipped entirely when hypothesis is absent, per suite convention).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import flow
from repro.core.arch import Constraints, config_space_grid
from repro.core.ir import as_graph, residual_block_ir
from repro.testing.faults import FaultInjector

RELAXED = Constraints(*[float("inf")] * 4)
SMALL_GRID = config_space_grid(
    f1s=(2, 4), f2s=(2,), f3s=(2, 4), f4s=(2,),
    bus_widths=(2,), sram_splits=("unified",),
)
GRAPH = as_graph(residual_block_ir())


def _batch():
    rng = np.random.default_rng(5)
    rows = [np.ones(GRAPH.n_edges, bool), np.zeros(GRAPH.n_edges, bool)]
    rows += [rng.random(GRAPH.n_edges) < 0.5 for _ in range(3)]
    return np.unique(np.stack(rows), axis=0)


BATCH = _batch()
CLEAN = flow.run_fleet(
    [GRAPH], config_space=SMALL_GRID, constraints=RELAXED,
    groupings=[BATCH],
)


def _winner(res):
    h = next(
        i for i, cfg in enumerate(SMALL_GRID)
        if np.array_equal(cfg.as_row(), res.best_hw.as_row())
    )
    c = next(
        i for i in range(BATCH.shape[0])
        if np.array_equal(BATCH[i], res.best_cuts)
    )
    return h, c


POISONS = {
    "nan": float("nan"),
    "inf": float("inf"),
    "negative": -3.0,
    "overflow": 2.0 ** 60,
}


@given(
    h=st.integers(0, len(SMALL_GRID) - 1),
    c=st.integers(0, BATCH.shape[0] - 1),
    kind=st.sampled_from(sorted(POISONS)),
)
@settings(max_examples=40, deadline=None)
def test_arbitrary_poison_is_quarantined_and_never_selected(h, c, kind):
    faults = FaultInjector(poison_cell=(0, h, c),
                           poison_value=POISONS[kind])
    r = flow.run_fleet(
        [GRAPH], config_space=SMALL_GRID, constraints=RELAXED,
        groupings=[BATCH], hooks=faults,
    )
    # (a) exactly the injected cell, with exact provenance
    assert faults.counts["poisoned_cells"] == 1
    assert r.quarantine is not None and r.quarantine.n_cells == 1
    cell = r.quarantine.cells[0]
    assert (cell.graph, cell.hw, cell.cut) == (0, h, c)
    assert cell.reason == kind
    # (b) the poisoned cell can never win
    assert _winner(r.results[0]) != (h, c)
    assert r.results[0].n_feasible == CLEAN.results[0].n_feasible - 1
    # (c) a poisoned non-winner leaves the clean argmin bit-identical
    if (h, c) != _winner(CLEAN.results[0]):
        assert r.results[0].best_hw == CLEAN.results[0].best_hw
        assert np.array_equal(
            r.results[0].best_cuts, CLEAN.results[0].best_cuts
        )
        assert r.results[0].best_metrics == CLEAN.results[0].best_metrics
