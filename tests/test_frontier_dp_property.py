"""Hypothesis property test: frontier DP == brute force on random DAGs.

Separate module from ``test_frontier_dp.py`` on purpose: the module-top
importorskip skips this WHOLE file wherever hypothesis is absent (it is not
installed in the dev container), so every deterministic assertion must live
in the sibling module — see the PR 4 note in the repo memory.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import fusion, metrics as M
from repro.core.ir import EdgeSpec, GraphIR, LayerSpec


@st.composite
def dag_strategy(draw):
    """Random connected DAG with at most MAX_EXHAUSTIVE_EDGES edges (so the
    brute-force oracle stays tractable): a random spanning arborescence over
    n nodes plus random extra forward edges."""
    n = draw(st.integers(3, 11))
    nodes = []
    for i in range(n):
        c = draw(st.sampled_from([4, 8, 16]))
        co = draw(st.sampled_from([4, 8, 16]))
        nodes.append(LayerSpec(f"n{i}", "conv", c, co, 16, 16, 3, 3, 1))
    edges = []
    seen = set()
    for i in range(1, n):
        src = draw(st.integers(0, i - 1))
        edges.append(EdgeSpec(src, i, nodes[src].out_words))
        seen.add((src, i))
    n_extra = draw(st.integers(0, min(n, fusion.MAX_EXHAUSTIVE_EDGES - n + 1)))
    for _ in range(n_extra):
        a = draw(st.integers(0, n - 2))
        b = draw(st.integers(a + 1, n - 1))
        if (a, b) not in seen:
            seen.add((a, b))
            edges.append(EdgeSpec(a, b, nodes[a].out_words))
    return GraphIR("hdag", tuple(nodes), tuple(edges))


@given(dag_strategy(), st.booleans())
@settings(max_examples=40, deadline=None)
def test_frontier_dp_bit_identical_min_bandwidth(g, use_budget):
    assert g.n_edges <= fusion.MAX_EXHAUSTIVE_EDGES
    sram = float("inf")
    if use_budget:
        sram = float(np.median(g.node_features()[:, M.F_OUT_PRE]))
    bf = fusion.brute_force_min_bw(g, sram_budget_words=sram)
    dp = fusion.frontier_dp_min_bw(
        g, sram_budget_words=sram, max_width=None, max_states=1 << 22
    )
    # bit-identical minimum (integer-valued words: == not approx), and the
    # DP's own cuts must realise it validly and feasibly
    assert dp.group_cost_words == bf.group_cost_words
    assert fusion.is_valid_cuts(g, dp.cuts)
    assert fusion.graph_max_intermediate(g, dp.cuts) <= sram
    assert fusion._graph_cost(g, dp.cuts) == dp.group_cost_words
