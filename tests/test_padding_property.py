"""Hypothesis property: padding invariance of the evaluator on random DAGs.

Random DAG, random cuts, random pad amounts: padded/masked evaluation is
bit-identical to the unpadded batch path and the scalar ``*_ref`` oracles
for all four metrics and the SRAM feasibility mask.  Deterministic
per-workload locks live in tests/test_padding.py (this module is skipped
entirely when hypothesis is absent, per suite convention).
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import fusion, metrics as M
from repro.core.arch import PAPER_OPTIMAL_CONFIG as HW
from repro.core.ir import pad_cuts_batch, pad_graph
from test_graph_ir import random_dag


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(3, 8),
    node_pad=st.integers(0, 5),
    edge_pad=st.integers(0, 6),
    row_pad=st.integers(0, 3),
)
@settings(max_examples=40, deadline=None)
def test_padding_invariance_property(seed, n, node_pad, edge_pad, row_pad):
    """Uses the eager kernel (``M._evaluate_batch_graph``) so one hypothesis
    run does not pay an XLA compile per drawn shape; the jitted path's
    padded==unpadded==oracle lock is in tests/test_padding.py."""
    rng = np.random.default_rng(seed)
    g = random_dag(rng, n)
    C = int(rng.integers(1, 4))
    cuts = rng.random((C, g.n_edges)) < 0.5
    hw_rows = np.stack([HW.as_row()])
    ac = M.area_consts_of(HW)

    feat = g.node_features()
    esrc, edst, ewords = g.edge_arrays()
    with M.enable_x64():
        ref = M.compose_metrics(M._evaluate_batch_graph(
            feat, esrc, edst, ewords, g.source_mask, g.sink_mask, cuts,
            hw_rows, ac,
        ), hw_rows)
        pg = pad_graph(
            g, n_nodes=g.n_nodes + node_pad, n_edges=g.n_edges + edge_pad
        )
        pc = pad_cuts_batch(cuts, pg.n_edges_padded, C + row_pad)
        pad = M.compose_metrics(M._evaluate_batch_graph(
            pg.feat, pg.esrc, pg.edst, pg.ewords, pg.src_mask, pg.sink_mask,
            pc, hw_rows, ac, pg.node_mask, pg.edge_mask,
        ), hw_rows)[:, :C]
    assert np.array_equal(ref, pad)  # padded == unpadded, bit-identical
    m = M.evaluate_ref(g, cuts[0], HW)  # == the scalar oracles
    assert pad[0, 0, 0] == m.bandwidth_words
    assert pad[0, 0, 1] == m.latency_cycles
    assert pad[0, 0, 2] == m.energy_nj
    assert pad[0, 0, 3] == m.area_um2

    max_int = fusion.padded_max_intermediate_batch(pg, pc)[:C]
    assert np.array_equal(
        max_int, fusion.graph_max_intermediate_batch(g, cuts)
    )
    assert max_int[0] == fusion.graph_max_intermediate(g, cuts[0])
    budget = float(np.median(max_int))
    assert np.array_equal(
        fusion.padded_feasible_mask_batch(pg, pc, budget)[:C],
        fusion.graph_feasible_mask_batch(g, cuts, budget),
    )
