"""HLO cost walker: exactness on loop-free modules, trip-count awareness,
fusion-group byte model sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hlo_cost as HC


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_exact_loop_free():
    a = jnp.ones((256, 512))
    b = jnp.ones((512, 1024))
    c = jnp.ones((1024, 128))
    w = HC.module_cost(compile_text(lambda a, b, c: jnp.tanh(a @ b) @ c, a, b, c))
    assert w.dot_flops == 2 * 256 * 512 * 1024 + 2 * 256 * 1024 * 128


def test_scan_multiplies_by_trip_count():
    def g(x, ws):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    x = jnp.ones((128, 128))
    ws = jnp.ones((10, 128, 128))
    w = HC.module_cost(compile_text(g, x, ws))
    assert w.dot_flops == 10 * 2 * 128 ** 3
    ca = jax.jit(g).lower(x, ws).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax <= 0.4.x: one dict per device
        ca = ca[0]
    assert ca["flops"] < w.dot_flops / 5  # cost_analysis is loop-blind


def test_nested_scan_trip_counts():
    def g(x, ws):
        def outer(x, wi):
            def inner(x, _):
                return jnp.tanh(x @ wi), None
            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, None
        x, _ = jax.lax.scan(outer, x, ws)
        return x

    x = jnp.ones((64, 64))
    ws = jnp.ones((5, 64, 64))
    w = HC.module_cost(compile_text(g, x, ws))
    assert w.dot_flops == 5 * 3 * 2 * 64 ** 3


def test_fusion_group_bytes_below_unfused_sum():
    """A long elementwise chain must be billed ~ inputs + outputs, not per op
    (the Eq. (1) fusion-group model applied to HLO)."""
    def chain(x):
        for _ in range(12):
            x = jnp.tanh(x) * 1.01 + 0.1
        return x

    x = jnp.ones((1024, 1024))
    w = HC.module_cost(compile_text(chain, x))
    nbytes = 1024 * 1024 * 4
    # unfused accounting would be >= 24x; grouped must stay within ~6x
    assert w.bytes <= 6 * nbytes, w.bytes


def test_bytes_scale_with_scan_length():
    def g(ws):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, jnp.ones((64, 64)), ws)
        return x

    w5 = HC.module_cost(compile_text(g, jnp.ones((5, 64, 64))))
    w10 = HC.module_cost(compile_text(g, jnp.ones((10, 64, 64))))
    assert w10.bytes > 1.5 * w5.bytes


def test_collective_parse_from_synthetic_hlo():
    hlo = """
HloModule m

ENTRY %main (p0: f32[16,128]) -> f32[16,128] {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = f32[32,128]{1,0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[16,128]{1,0} slice(%ag), slice={[0:16], [0:128]}
}
"""
    w = HC.module_cost(hlo)
    assert w.coll["all-reduce"] == 16 * 128 * 4
    assert w.coll["all-gather"] == 32 * 128 * 4


def test_shape_parser():
    assert HC._total_bytes("bf16[4,8]{1,0}") == 64
    assert HC._total_bytes("(f32[2,2], s8[4])") == 20
    assert HC._total_bytes("f32[]") == 4
    assert HC._dims_of("f32[3,5,7]{2,1,0}") == [3, 5, 7]
