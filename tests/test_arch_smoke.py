"""Per assigned architecture: reduced same-family config, one forward +
train step on CPU, output shapes + finiteness.  Full configs are exercised
only via the dry-run (ShapeDtypeStruct; launch/dryrun.py)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, resolve, scaled_down
from repro.configs.base import RunConfig
from repro.data import make_batch
from repro.models import model as M
from repro.runtime.steps import make_init, make_train_step

RC = RunConfig(xent_chunk=16, attn_chunk_kv=16, mamba_chunk=8,
               microbatches=2, learning_rate=1e-3, warmup_steps=1)

ARCHS = sorted(REGISTRY)


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_config_preserves_structure(arch):
    full = resolve(arch)
    small = scaled_down(full)
    assert small.family == full.family
    assert small.is_encoder_decoder == full.is_encoder_decoder
    assert bool(small.frontend) == bool(full.frontend)
    assert (small.n_experts > 1) == (full.n_experts > 1)
    assert small.layer_pattern == full.layer_pattern
    assert (small.d_ff == 0) == (full.d_ff == 0)
    # GQA ratio preserved
    if full.n_heads > 1:
        assert small.n_heads // small.n_kv_heads == full.n_heads // full.n_kv_heads


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = scaled_down(resolve(arch))
    init = make_init(cfg, RC)
    params, opt = init(jax.random.key(0))
    B, S = 4, 32
    batch = make_batch(cfg, B, S, seed=1, step=0)
    batch = jax.tree.map(jnp.asarray, batch)
    step = jax.jit(make_train_step(cfg, RC))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved (after warmup step lr > 0 at step 2)
    params3, _, m3 = step(params2, opt2, batch)
    assert np.isfinite(float(m3["loss"]))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params3)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_smoke(arch):
    cfg = scaled_down(resolve(arch))
    params = M.init_params(jax.random.key(1), cfg)
    B, S = 2, 16
    key = jax.random.key(2)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))
    cache = M.init_cache(cfg, B, 32)
    logits, cache = M.prefill(params, cfg, RC, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    logits, cache = M.decode(params, cfg, RC, tok, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_param_counts_match_materialised(arch):
    """Analytic param_counts (used for MODEL_FLOPS) vs actual leaf sizes of
    the reduced config — exact for total params."""
    cfg = scaled_down(resolve(arch))
    params = M.init_params(jax.random.key(0), cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    analytic = cfg.param_counts()["total"]
    assert actual == pytest.approx(analytic, rel=0.06), (actual, analytic)


def test_full_config_param_counts():
    """Total parameter counts of the full configs land near their names."""
    expect = {
        "llama4-maverick-400b-a17b": (370e9, 440e9),
        "arctic-480b": (450e9, 510e9),
        "jamba-1.5-large-398b": (350e9, 420e9),
        "granite-34b": (30e9, 38e9),
        "gemma3-27b": (24e9, 30e9),
        "phi3-mini-3.8b": (3.4e9, 4.2e9),
        "falcon-mamba-7b": (6.5e9, 8e9),
        "qwen3-0.6b": (0.5e9, 0.8e9),
        # ~0.5 B backbone; the published 0.9 B includes the ViT frontend we
        # stub per spec.
        "internvl2-1b": (0.4e9, 1.2e9),
        # relu FFN (no gate) puts the backbone-only count at ~1.4 B; the
        # published 2.3 B includes the speech frontend we stub per spec.
        "seamless-m4t-large-v2": (1.2e9, 2.6e9),
    }
    for name, (lo, hi) in expect.items():
        total = REGISTRY[name].param_counts()["total"]
        assert lo <= total <= hi, (name, total)
    # MoE active < 10% of total for the top-1/128 model
    l4 = REGISTRY["llama4-maverick-400b-a17b"].param_counts()
    assert l4["active"] < 0.1 * l4["total"]
