"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device tests spawn subprocesses with their own flags."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig, RunConfig


@pytest.fixture(scope="session")
def rc_small():
    return RunConfig(xent_chunk=16, attn_chunk_kv=16, mamba_chunk=8,
                     learning_rate=1e-3, warmup_steps=2)


def tiny_config(**kw) -> ModelConfig:
    base = dict(
        name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


@pytest.fixture
def tiny_dense():
    return tiny_config()
