"""Hypothesis properties over the PR 8 frontend lowerings (config zoo).

Fuzz the model shapes the new lowerings depend on — ``seq_len`` for the
attention actmul pair, ``d_state``/chunking for the SSM scan node,
``top_k``/``n_experts`` for the MoE expert fan-out — and assert, on every
traced graph:

* the vectorised batch evaluator is **bit-identical** to the scalar
  ``*_ref`` oracle on random cut vectors (the lock-step contract extended
  to graphs carrying ``state_words``);
* padded/masked evaluation is bit-identical to unpadded (padded rows are
  inert in the new feature column too);
* the structural claims of docs/OP_COVERAGE.md hold (scan nodes carry
  ``d_inner x d_state`` words, MoE expands to ``n_experts`` branches).

Skipped entirely when hypothesis is absent, per suite convention.
"""
import dataclasses

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import frontend as F, fusion, metrics as M
from repro.core.arch import PAPER_OPTIMAL_CONFIG as HW
from repro.core.ir import pad_cuts_batch, pad_graph
from repro.configs import REGISTRY, scaled_down


def _assert_lockstep_and_padding_inert(g, seed: int) -> None:
    """Batched == oracle (bit-identical, all four metrics + feasibility)
    and padded == unpadded, on random cuts of ``g``."""
    rng = np.random.default_rng(seed)
    C = 3
    cuts = rng.random((C, g.n_edges)) < 0.5
    hw_rows = np.stack([HW.as_row()])
    ac = M.area_consts_of(HW)
    feat = g.node_features()
    esrc, edst, ewords = g.edge_arrays()
    with M.enable_x64():
        batch = M.compose_metrics(M._evaluate_batch_graph(
            feat, esrc, edst, ewords, g.source_mask, g.sink_mask, cuts,
            hw_rows, ac,
        ), hw_rows)
        pg = pad_graph(g, n_nodes=g.n_nodes + 3, n_edges=g.n_edges + 5)
        pc = pad_cuts_batch(cuts, pg.n_edges_padded, C + 2)
        padded = M.compose_metrics(M._evaluate_batch_graph(
            pg.feat, pg.esrc, pg.edst, pg.ewords, pg.src_mask, pg.sink_mask,
            pc, hw_rows, ac, pg.node_mask, pg.edge_mask,
        ), hw_rows)[:, :C]
    assert np.array_equal(batch, padded)
    for c in range(C):
        m = M.evaluate_ref(g, cuts[c], HW)
        assert batch[0, c, 0] == m.bandwidth_words
        assert batch[0, c, 1] == m.latency_cycles
        assert batch[0, c, 2] == m.energy_nj
        assert batch[0, c, 3] == m.area_um2
    # Feasibility: batched graph mask == scalar oracle, at a budget that
    # actually bites (the median intermediate), so state_words is load-
    # bearing on both sides of the comparison.
    budget = float(np.median([
        fusion.graph_max_intermediate(g, cuts[c]) for c in range(C)
    ])) or 1.0
    mask = fusion.graph_feasible_mask_batch(g, cuts, budget)
    for c in range(C):
        assert mask[c] == (
            fusion.graph_max_intermediate(g, cuts[c]) <= budget
        )


@given(
    seed=st.integers(0, 2**31 - 1),
    seq_pow=st.integers(4, 7),  # seq_len in {16, 32, 64, 128}
)
@settings(max_examples=8, deadline=None)
def test_attention_actmul_lockstep(seed, seq_pow):
    """The QK^T/PV actmul pair at fuzzed seq_len: O(S^2) edge present,
    evaluator lock-step holds."""
    cfg = scaled_down(REGISTRY["qwen3-0.6b"])
    S = 2 ** seq_pow
    g = F.transformer_graph(cfg, seq_len=S, n_sublayers=1)
    actmuls = [n for n in g.nodes if n.kind == "actmul"]
    assert len(actmuls) == 2  # QK^T and PV
    score_words = cfg.n_heads * S * S
    assert any(e.words == score_words for e in g.edges)  # the S^2 matrix
    _assert_lockstep_and_padding_inert(g, seed)


@given(
    seed=st.integers(0, 2**31 - 1),
    d_state=st.sampled_from([2, 4, 8]),
    chunks=st.sampled_from([1, 2, 4]),
)
@settings(max_examples=8, deadline=None)
def test_mamba_scan_lockstep(seed, d_state, chunks):
    """The scan node at fuzzed d_state/chunking: state_words is exactly
    the carry size, one scan node per chunk, lock-step holds."""
    cfg = dataclasses.replace(
        scaled_down(REGISTRY["falcon-mamba-7b"]), ssm_state=d_state
    )
    g = F.mamba_graph(cfg, seq_len=64, chunks=chunks)
    scans = [n for n in g.nodes if n.kind == "scan"]
    assert len(scans) == chunks
    for n in scans:
        assert n.state_words == cfg.d_inner * d_state
        assert n.macs == 0  # weightless recurrent node
    if chunks > 1:
        # The carry hand-off between consecutive chunks is a real edge.
        ids = [i for i, n in enumerate(g.nodes) if n.kind == "scan"]
        carry = {(e.src, e.dst): e.words for e in g.edges}
        for a, b in zip(ids, ids[1:]):
            assert carry[(a, b)] == cfg.d_inner * d_state
    _assert_lockstep_and_padding_inert(g, seed)


@given(
    seed=st.integers(0, 2**31 - 1),
    n_experts=st.sampled_from([2, 4]),
    top_k=st.integers(1, 2),
)
@settings(max_examples=8, deadline=None)
def test_moe_fanout_lockstep(seed, n_experts, top_k):
    """The expert fan-out at fuzzed top_k/n_experts: E w1-branches with
    routed-capacity edge words, lock-step holds."""
    from repro.models.moe import _capacity

    cfg = dataclasses.replace(
        scaled_down(REGISTRY["mixtral-8x7b"]),
        n_experts=n_experts, top_k=min(top_k, n_experts),
    )
    S = 32
    g = F.moe_block_graph(cfg, seq_len=S)
    # w1 + w3 (swiglu) + w2 stacks each expand to n_experts branches.
    matmuls = [n for n in g.nodes if n.kind in ("matmul", "fc")]
    assert len(matmuls) == 1 + 3 * n_experts  # router + 3 stacks
    # Routed-capacity edge words: the dispatch actmul fans out
    # G*C*d words per expert branch (C = capacity_factor-scaled slots).
    G = S // min(cfg.moe_group_size, S)
    C = _capacity(cfg, min(cfg.moe_group_size, S))
    branch_words = G * C * cfg.d_model
    fanout = [e.words for e in g.edges if e.words == branch_words]
    assert len(fanout) >= 2 * n_experts  # into each expert's w1 and w3
    _assert_lockstep_and_padding_inert(g, seed)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_zero_state_graph_unchanged_by_state_column(seed):
    """state_words == 0 everywhere => zeroing the F_STATE column is a
    no-op: the new feature is exactly inert on pre-scan workloads."""
    cfg = scaled_down(REGISTRY["qwen3-0.6b"])
    g = F.transformer_graph(cfg, seq_len=32, n_sublayers=1)
    feat = g.node_features()
    assert np.all(feat[:, M.F_STATE] == 0.0)
    rng = np.random.default_rng(seed)
    cuts = rng.random((2, g.n_edges)) < 0.5
    hw_rows = np.stack([HW.as_row()])
    ac = M.area_consts_of(HW)
    esrc, edst, ewords = g.edge_arrays()
    zeroed = feat.copy()
    zeroed[:, M.F_STATE] = 0.0
    with M.enable_x64():
        a = M._evaluate_batch_graph(
            feat, esrc, edst, ewords, g.source_mask, g.sink_mask, cuts,
            hw_rows, ac,
        )
        b = M._evaluate_batch_graph(
            zeroed, esrc, edst, ewords, g.source_mask, g.sink_mask, cuts,
            hw_rows, ac,
        )
    assert np.array_equal(np.asarray(a), np.asarray(b))
