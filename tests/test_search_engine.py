"""Batched search engine: lock-step with the scalar oracles, bit-identical
search results vs the preserved PR 1 scalar implementations.

Every batched kernel (labelling, validity, feasibility, bandwidth, merge
deltas) must equal its scalar oracle exactly — all quantities are
integer-valued words, so equality is ==, not approx.  The search strategies
must return bit-identical cut vectors to the scalar path on the named DAG
builders and on random chains/DAGs, including under SRAM budgets.
"""
import numpy as np
import pytest

from repro.core import fusion, metrics as M
from repro.core.flow import run_flow
from repro.core.arch import Constraints, PAPER_OPTIMAL_CONFIG
from repro.core.ir import (
    as_graph,
    encoder_decoder_ir,
    quotient_acyclic_batch,
    residual_block_ir,
    resnet18_ir,
    uncut_component_labels,
    uncut_component_labels_batch,
)
from test_graph_ir import random_chain, random_dag

RELAXED = Constraints(max_bandwidth_words=1e12, max_latency_cycles=1e12,
                      max_energy_nj=1e12, max_area_um2=1e12)


def _all_patterns(E):
    idx = np.arange(2**E, dtype=np.int64)
    return ((idx[:, None] >> np.arange(E)[None, :]) & 1).astype(bool)


# ---------------------------------------------------------------------------
# Kernel lock-step (batched == scalar oracle, exactly)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_batched_kernels_lockstep_on_random_dags(seed):
    rng = np.random.default_rng(400 + seed)
    g = random_dag(rng, int(rng.integers(3, 9)))
    bits = _all_patterns(g.n_edges)
    # component labelling
    lab = uncut_component_labels_batch(len(g.nodes), g.edges, bits)
    for i in range(bits.shape[0]):
        np.testing.assert_array_equal(
            lab[i], uncut_component_labels(len(g.nodes), g.edges, bits[i])
        )
    # validity (consistency + convexity)
    got = fusion.is_valid_cuts_batch(g, bits)
    want = np.asarray([fusion.is_valid_cuts(g, c) for c in bits])
    np.testing.assert_array_equal(got, want)
    # convexity alone (vectorised Kahn peeling vs scalar SCC check)
    acy = quotient_acyclic_batch(
        len(g.nodes), *g.edge_arrays()[:2], lab
    )
    want_acy = np.asarray([fusion._quotient_is_dag(g, row) for row in lab])
    np.testing.assert_array_equal(acy, want_acy)
    # buffer feasibility
    np.testing.assert_array_equal(
        fusion.graph_max_intermediate_batch(g, bits),
        np.asarray([fusion.graph_max_intermediate(g, c) for c in bits]),
    )
    # Eq. (1) bandwidth
    np.testing.assert_array_equal(
        M.bandwidth_batch_graph(g, bits),
        np.asarray([M.bandwidth_ref(g, c) for c in bits]),
    )


@pytest.mark.parametrize("seed", range(4))
def test_enumeration_identical_to_scalar_filter(seed):
    rng = np.random.default_rng(500 + seed)
    g = random_dag(rng, int(rng.integers(3, 10)))
    np.testing.assert_array_equal(
        fusion.enumerate_valid_edge_cuts(g),
        fusion._enumerate_valid_edge_cuts_scalar(g),
    )


def test_merge_delta_equals_bandwidth_difference():
    rng = np.random.default_rng(42)
    for _ in range(8):
        g = random_dag(rng, int(rng.integers(4, 10)))
        labels = np.arange(len(g.nodes))
        # walk a few random valid merges, checking the delta at each step
        for _ in range(len(g.nodes) - 1):
            ga = M.graph_arrays(g)
            pairs = fusion._valid_merge_pairs(ga, labels)
            if not pairs:
                break
            a, b = pairs[int(rng.integers(len(pairs)))]
            before = M.bandwidth_ref(g, fusion.cuts_from_labels(g, labels))
            merged = np.where(labels == b, a, labels)
            after = M.bandwidth_ref(g, fusion.cuts_from_labels(g, merged))
            assert fusion.merge_bandwidth_delta(g, labels, a, b) == after - before
            labels = merged


def test_valid_merge_pairs_match_scalar_convexity_filter():
    rng = np.random.default_rng(7)
    for _ in range(10):
        g = random_dag(rng, int(rng.integers(4, 10)))
        labels = np.arange(len(g.nodes))
        for _ in range(3):
            ga = M.graph_arrays(g)
            pairs = fusion._merge_pairs(ga.esrc, ga.edst, labels)
            want = [
                (a, b) for a, b in pairs
                if fusion._quotient_is_dag(g, np.where(labels == b, a, labels))
            ]
            assert fusion._valid_merge_pairs(ga, labels) == want
            if not want:
                break
            a, b = want[0]
            labels = np.where(labels == b, a, labels)


# ---------------------------------------------------------------------------
# Search results bit-identical to the PR 1 scalar path
# ---------------------------------------------------------------------------


def _assert_same(a, b):
    np.testing.assert_array_equal(a.cuts, b.cuts)
    assert a.group_cost_words == b.group_cost_words
    assert a.n_groups == b.n_groups


@pytest.mark.parametrize("sram", [float("inf"), 150_000.0])
def test_brute_force_bit_identical_residual_block(sram):
    rb = residual_block_ir()
    _assert_same(
        fusion.brute_force_min_bw(rb, sram_budget_words=sram),
        fusion._brute_force_min_bw_scalar(rb, sram_budget_words=sram),
    )


@pytest.mark.parametrize("seed", range(4))
def test_brute_force_bit_identical_random_chains(seed):
    rng = np.random.default_rng(600 + seed)
    ir = random_chain(rng, n=int(rng.integers(3, 8)))
    budget = float(np.median([l.out_words_prepool for l in ir.layers]))
    for sram in (float("inf"), budget):
        _assert_same(
            fusion.brute_force_min_bw(ir, sram_budget_words=sram),
            fusion._brute_force_min_bw_scalar(ir, sram_budget_words=sram),
        )
    # the dispatch (chain DP) agrees with brute force on cost
    dp = fusion.optimal_cuts(as_graph(ir), sram_budget_words=budget)
    bf = fusion.brute_force_min_bw(ir, sram_budget_words=budget)
    assert dp.group_cost_words == bf.group_cost_words


@pytest.mark.parametrize("seed", range(5))
def test_merge_searches_bit_identical_random_dags(seed):
    rng = np.random.default_rng(700 + seed)
    g = random_dag(rng, int(rng.integers(4, 11)))
    feat = g.node_features()
    budget = float(np.median(feat[:, M.F_OUT_PRE]))
    for sram in (float("inf"), budget):
        _assert_same(
            fusion.greedy_merge_cuts(g, sram_budget_words=sram),
            fusion._greedy_merge_cuts_scalar(g, sram_budget_words=sram),
        )
        _assert_same(
            fusion.beam_merge_cuts(g, sram_budget_words=sram),
            fusion._beam_merge_cuts_scalar(g, sram_budget_words=sram),
        )


def test_beam_bit_identical_resnet18():
    g = resnet18_ir()
    budget = 200_000.0  # forces a non-trivial multi-group grouping
    _assert_same(
        fusion.beam_merge_cuts(g, sram_budget_words=budget),
        fusion._beam_merge_cuts_scalar(g, sram_budget_words=budget),
    )


def test_beam_bit_identical_encoder_decoder():
    ed = encoder_decoder_ir(d_model=256, n_heads=4, d_ff=512, seq_enc=128,
                            seq_dec=64)
    _assert_same(
        fusion.beam_merge_cuts(ed),
        fusion._beam_merge_cuts_scalar(ed),
    )
    # optimal_cuts certifies the optimum via the frontier DP; it can only
    # match or beat the beam, and its minimum must be bit-identical to the
    # exhaustive enumeration (on this graph the cuts agree too).
    opt = fusion.optimal_cuts(ed)
    assert opt.engine == "frontier_dp" and opt.exact
    beam = fusion.beam_merge_cuts(ed)
    assert opt.group_cost_words <= beam.group_cost_words
    bf = fusion.brute_force_min_bw(ed)
    assert opt.group_cost_words == bf.group_cost_words
    np.testing.assert_array_equal(opt.cuts, bf.cuts)


# ---------------------------------------------------------------------------
# Caps + flow integration
# ---------------------------------------------------------------------------


def test_exhaustive_edge_cap_raised():
    assert fusion.MAX_EXHAUSTIVE_EDGES >= 22
    g = resnet18_ir()
    with pytest.raises(ValueError):
        fusion.enumerate_valid_edge_cuts(g)  # 38 edges still out of reach


def test_enumerate_cached_and_readonly():
    rb = residual_block_ir()
    a = fusion.enumerate_valid_edge_cuts(rb)
    b = fusion.enumerate_valid_edge_cuts(rb)
    assert a is b  # memoised per graph
    assert not a.flags.writeable  # cache cannot be poisoned in place
    with pytest.raises(ValueError):
        a[0, 0] = True


def test_run_flow_sram_prefilter():
    rb = residual_block_ir()
    budget = 150_000.0
    res = run_flow(rb, config_space=[PAPER_OPTIMAL_CONFIG],
                   constraints=RELAXED, groupings="exhaustive",
                   sram_budget_words=budget)
    n_valid = fusion.enumerate_valid_edge_cuts(rb).shape[0]
    assert res.n_pruned > 0
    assert res.n_candidates == n_valid - res.n_pruned
    assert fusion.graph_max_intermediate(rb, res.best_cuts) <= budget
    # the surviving optimum == brute force under the same budget
    bf = fusion.brute_force_min_bw(rb, sram_budget_words=budget)
    assert res.best_metrics.bandwidth_words == M.bandwidth_ref(rb, bf.cuts)


def test_run_flow_search_groupings_respect_sram_budget():
    """groupings='search' must search *under* the flow's budget — a
    budget-blind optimum would just be pruned by the prefilter, silently
    degrading the flow result to layer-by-layer / pool cuts."""
    g = resnet18_ir()
    budget = 200_000.0
    res = run_flow(g, config_space=[PAPER_OPTIMAL_CONFIG], constraints=RELAXED,
                   groupings="search", sram_budget_words=budget)
    # the search dispatch answers with the exact frontier DP, which can
    # only match or beat the beam heuristic under the same budget
    want = fusion.frontier_dp_min_bw(g, sram_budget_words=budget)
    assert res.search_engine == "frontier_dp"
    assert res.best_metrics.bandwidth_words == M.bandwidth_ref(g, want.cuts)
    beam = fusion.beam_merge_cuts(g, sram_budget_words=budget)
    assert want.group_cost_words <= beam.group_cost_words
    assert fusion.graph_max_intermediate(g, res.best_cuts) <= budget


def test_run_flow_reports_compile_and_sweep_split():
    from repro.core import flow as flow_mod

    rb = residual_block_ir()
    flow_mod._COMPILED_SWEEPS.clear()
    res = run_flow(rb, config_space=[PAPER_OPTIMAL_CONFIG],
                   constraints=RELAXED, groupings="exhaustive")
    assert res.compile_seconds > 0.0
    assert res.sweep_seconds > 0.0
    assert res.candidates_per_second == pytest.approx(
        res.n_candidates / res.sweep_seconds
    )
    # same shapes again: executable cache hit, no recompilation
    res2 = run_flow(rb, config_space=[PAPER_OPTIMAL_CONFIG],
                    constraints=RELAXED, groupings="exhaustive")
    assert res2.compile_seconds == 0.0
    assert res2.best_metrics == res.best_metrics
