"""Sharded hardware co-search: config-space grid, Pareto extraction,
deterministic argmin tie-breaking, and the mesh-aware executable cache.

Multi-device bit-identity proper (2/8 host devices) lives in
tests/test_multidevice.py (subprocess-per-case); this module covers
everything that is testable in the normal single-device test process,
including the devices=1 sharded path (a real 1-device `hardware` mesh
through shard_map).
"""
import numpy as np
import pytest

from repro.core import flow, metrics as M
from repro.core.arch import (
    SRAM_SPLITS,
    Constraints,
    DLAConfig,
    config_space_grid,
    default_config_space,
)
from repro.core.ir import as_graph, residual_block_ir, resnet18_ir
from repro.parallel.sharding import hardware_mesh, mesh_fingerprint

RELAXED = Constraints(*[float("inf")] * 4)
SMALL_GRID = config_space_grid(
    f1s=(2, 4), f2s=(2, 4), f3s=(2, 4), f4s=(2, 4),
    bus_widths=(2, 4), sram_splits=("unified",),
)


# ---------------------------------------------------------------------------
# Config-space grid
# ---------------------------------------------------------------------------


def test_config_space_grid_default_is_thousands_of_unique_points():
    space = config_space_grid()
    assert len(space) >= 1000  # the co-search scale the sweep shards over
    rows = np.stack([c.as_row() for c in space])
    assert np.unique(rows, axis=0).shape[0] == len(space)  # no duplicates
    assert {c.style for c in space} == {"hsiao", "vwa"}
    assert all(c.f3 == 3 for c in space if c.style == "vwa")
    assert {c.dram_words_per_cycle for c in space} == {2, 4, 8, 16}
    assert {c.e_sram_nj for c in space} == {
        SRAM_SPLITS["unified"], SRAM_SPLITS["banked4"]
    }


def test_config_space_grid_is_flow_compatible():
    # Shared area constants (the sweep requires it) and the default space
    # embeds as the unit-bus/unified slice of the grid.
    M.area_consts_of_space(config_space_grid())  # must not raise
    grid_rows = {tuple(c.as_row()) for c in config_space_grid()}
    for c in default_config_space():
        assert tuple(c.as_row()) in grid_rows


def test_area_consts_of_space_rejects_mixed_calibrations():
    a = DLAConfig("hsiao", 2, 2, 2, 2)
    b = DLAConfig("hsiao", 4, 4, 4, 4, area_controller_um2=1.0)
    with pytest.raises(ValueError, match="area-constant"):
        M.area_consts_of_space([a, b])


# ---------------------------------------------------------------------------
# Pareto-front extraction
# ---------------------------------------------------------------------------


def test_pareto_front_mask_known_cases():
    rows = np.array(
        [
            [1.0, 1.0, 1.0, 1.0],  # front
            [2.0, 2.0, 2.0, 2.0],  # dominated by row 0
            [1.0, 2.0, 0.0, 5.0],  # front (wins on col 2)
            [1.0, 1.0, 1.0, 1.0],  # duplicate of row 0 -> dropped
            [0.5, 3.0, 3.0, 3.0],  # front (wins on col 0)
        ]
    )
    assert M.pareto_front_mask(rows).tolist() == [
        True, False, True, False, True,
    ]
    # degenerate shapes
    assert M.pareto_front_mask(np.empty((0, 4))).shape == (0,)
    assert M.pareto_front_mask(np.array([[3.0, 1.0]])).tolist() == [True]


def test_pareto_front_mask_matches_bruteforce():
    rng = np.random.default_rng(7)
    rows = rng.integers(0, 6, size=(120, 4)).astype(float)  # many ties/dups
    got = M.pareto_front_mask(rows)
    seen: set = set()
    for i, r in enumerate(rows):
        dominated = any(
            np.all(o <= r) and np.any(o < r) for o in rows
        )
        expect = (not dominated) and tuple(r) not in seen
        assert got[i] == expect, (i, r)
        if not dominated:
            seen.add(tuple(r))


def test_flow_pareto_front_is_nondominated_and_holds_best_point():
    g = resnet18_ir()
    r = flow.run_flow(g, config_space=SMALL_GRID, constraints=RELAXED,
                      groupings="pool", pareto=True)
    front = r.pareto
    assert front is not None and front.size >= 1
    assert front.n_feasible == r.n_feasible
    assert front.search_engine == r.search_engine == "pool"
    assert len(front.configs) == front.size
    assert front.cuts.shape == (front.size, g.n_edges)
    # every front point is a real swept candidate
    for i in range(front.size):
        hw = SMALL_GRID[front.hw_indices[i]]
        m = M.evaluate_ref(g, front.cuts[i], hw)
        assert [m.bandwidth_words, m.latency_cycles, m.energy_nj,
                m.area_um2] == front.metrics[i].tolist()
    # pairwise non-domination within the front
    fm = front.metrics
    for i in range(front.size):
        dom = np.all(fm <= fm[i], axis=1) & np.any(fm < fm[i], axis=1)
        assert not dom.any()
    # the min-energy best point can never be dominated -> it is on the front
    best_row = [
        r.best_metrics.bandwidth_words, r.best_metrics.latency_cycles,
        r.best_metrics.energy_nj, r.best_metrics.area_um2,
    ]
    assert any(front.metrics[i].tolist() == best_row
               for i in range(front.size))
    # default stays cheap: no front unless asked
    assert flow.run_flow(g, config_space=SMALL_GRID, constraints=RELAXED,
                         groupings="pool").pareto is None


# ---------------------------------------------------------------------------
# Deterministic argmin tie-breaking
# ---------------------------------------------------------------------------


def _select(out, space):
    g = residual_block_ir()
    cuts = np.ones((out.shape[1], g.n_edges), dtype=bool)
    r = flow._best_flow_result(
        out, cuts, g, space, RELAXED, n_pruned=0, compile_seconds=0.0,
        sweep_seconds=1.0, candidates_per_second=1.0,
    )
    return r


def test_argmin_tie_breaks_to_lowest_index():
    space = [DLAConfig("hsiao", 2, 2, 2, 2), DLAConfig("hsiao", 4, 4, 4, 4)]
    row = [5.0, 5.0, 5.0, 5.0]
    # fully identical candidates -> lowest (h, c) wins
    out = np.array([[row, row], [row, row]])
    r = _select(out, space)
    assert r.best_hw == space[0]
    # equal energy, but (h=1, c=1) has lower bandwidth -> metrics beat index
    out2 = out.copy()
    out2[1, 1] = [4.0, 5.0, 5.0, 5.0]
    r2 = _select(out2, space)
    assert r2.best_hw == space[1]
    assert r2.best_metrics.bandwidth_words == 4.0


def test_best_point_invariant_under_hw_permutation():
    g = resnet18_ir()
    a = flow.run_flow(g, config_space=SMALL_GRID, constraints=RELAXED,
                      groupings="pool", pareto=True)
    b = flow.run_flow(g, config_space=SMALL_GRID[::-1], constraints=RELAXED,
                      groupings="pool", pareto=True)
    # The guarantee: selected *metrics* (and the metric front) are invariant
    # to any permutation of the hardware axis.  The representative *config*
    # is pinned by lowest index only among fully-identical metric rows —
    # e.g. (F1=2,F4=4) and (F1=4,F4=2) tile symmetrically and are
    # metric-identical — so configs may differ only within such classes.
    assert a.best_metrics == b.best_metrics
    assert np.array_equal(a.pareto.metrics, b.pareto.metrics)
    # same design points by metric row: re-evaluate each representative
    for i in range(a.pareto.size):
        ma = M.evaluate_ref(g, a.pareto.cuts[i], a.pareto.configs[i])
        mb = M.evaluate_ref(g, b.pareto.cuts[i], b.pareto.configs[i])
        assert ma == mb


# ---------------------------------------------------------------------------
# Sharded sweep, 1-device mesh (multi-device variants in test_multidevice)
# ---------------------------------------------------------------------------


def test_run_fleet_devices_one_bit_identical_to_plain():
    irs = [resnet18_ir(), residual_block_ir()]
    base = flow.run_fleet(irs, config_space=SMALL_GRID, constraints=RELAXED,
                          groupings="pool", pareto=True)
    sh = flow.run_fleet(irs, config_space=SMALL_GRID, constraints=RELAXED,
                        groupings="pool", devices=1, pareto=True)
    assert base.device_count == 1 and sh.device_count == 1
    assert "hardware mesh" not in base.describe()
    for a, b in zip(base.results, sh.results):
        assert a.best_metrics == b.best_metrics
        assert a.best_hw == b.best_hw
        assert np.array_equal(a.best_cuts, b.best_cuts)
        assert np.array_equal(a.pareto.metrics, b.pareto.metrics)
        assert np.array_equal(a.pareto.hw_indices, b.pareto.hw_indices)


def test_run_fleet_devices_validation():
    irs = [residual_block_ir()]
    with pytest.raises(ValueError, match="only"):
        flow.run_fleet(irs, config_space=SMALL_GRID, devices=4096)
    with pytest.raises(ValueError, match=">= 1"):
        flow.run_fleet(irs, config_space=SMALL_GRID, devices=0)


# ---------------------------------------------------------------------------
# Mesh-aware executable cache keys
# ---------------------------------------------------------------------------


def test_sweep_cache_splits_entries_by_device_layout(monkeypatch):
    monkeypatch.setattr(flow, "_COMPILED_SWEEPS", type(flow._COMPILED_SWEEPS)())
    monkeypatch.setattr(
        flow, "_SWEEP_CACHE_STATS", {"hits": 0, "misses": 0, "evictions": 0}
    )
    irs = [residual_block_ir()]
    flow.run_fleet(irs, config_space=SMALL_GRID, constraints=RELAXED,
                   groupings="pool")
    flow.run_fleet(irs, config_space=SMALL_GRID, constraints=RELAXED,
                   groupings="pool", devices=1)
    stats = flow.sweep_cache_stats()
    # identical argument shapes, but TWO distinct executables: the key
    # carries the device layout, so a 1-device program is never served to
    # a mesh (and vice versa).
    assert stats["misses"] == 2 and stats["size"] == 2
    layouts = [(e["mesh_axis"], e["device_count"]) for e in stats["entries"]]
    assert ("single", 1) in layouts and ("hardware", 1) in layouts
    # repeats hit their own entries
    flow.run_fleet(irs, config_space=SMALL_GRID, constraints=RELAXED,
                   groupings="pool", devices=1)
    assert flow.sweep_cache_stats()["misses"] == 2


def test_mesh_fingerprint_distinguishes_layouts():
    import jax

    m1 = hardware_mesh(1)
    assert mesh_fingerprint(m1)[0] == "hardware"
    assert mesh_fingerprint(m1)[1] == 1
    # None = all visible devices; same devices -> same fingerprint
    n = len(jax.devices())
    assert mesh_fingerprint(hardware_mesh(None)) == mesh_fingerprint(
        hardware_mesh(n)
    )
