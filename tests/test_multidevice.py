"""Multi-device semantics via subprocesses (tests proper see 1 CPU device;
each case spawns a fresh interpreter with xla_force_host_platform_device_count).

Covers: pjit-sharded train step == single-device step; elastic re-mesh
resume; pipeline parallelism vs sequential; compressed cross-pod psum;
and the sharded hardware co-search (run_fleet(devices=...) bit-identical
to the single-device sweep at 2/8 host devices, padded-H masking
included).
"""
import subprocess
import sys
import textwrap

import pytest

REPO_SRC = "src"


def run_py(body: str, n_devices: int = 8, timeout: int = 420) -> str:
    prog = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n_devices}"
    import jax, jax.numpy as jnp, numpy as np
    {textwrap.indent(textwrap.dedent(body), '    ').strip()}
    """)
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=timeout, env={"PYTHONPATH": REPO_SRC, "PATH": "/usr/bin:/bin",
                              "HOME": "/root",
                              # without this, libtpu probes GCP instance
                              # metadata (30 retries per var) before falling
                              # back to CPU -- minutes of nanosleep
                              "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return res.stdout


def test_sharded_train_step_matches_single_device():
    out = run_py("""
    from repro.configs.base import ModelConfig, RunConfig
    from repro.launch.mesh import make_mesh
    from repro.models import model as M
    from repro.optim import AdamWConfig, init_opt_state
    from repro.parallel import sharding as SH
    from repro.runtime.steps import make_train_step

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32")
    rc = RunConfig(xent_chunk=16, attn_chunk_kv=16, learning_rate=1e-3,
                   warmup_steps=1)
    key = jax.random.key(0)
    params = M.init_params(key, cfg)
    opt = init_opt_state(params, AdamWConfig())
    batch = {"tokens": jax.random.randint(key, (8, 32), 0, 256),
             "labels": jax.random.randint(jax.random.key(1), (8, 32), 0, 256)}
    step = make_train_step(cfg, rc)

    # single device
    p1, o1, m1 = jax.jit(step)(params, opt, batch)

    # sharded over (2 data, 4 model)
    mesh = make_mesh((2, 4), ("data", "model"))
    ap = jax.eval_shape(lambda: params)
    pshard = SH.param_shardings(mesh, ap)
    bshard = SH.batch_shardings(mesh, jax.eval_shape(lambda: batch))
    aopt = jax.eval_shape(lambda: opt)
    oshard = SH.opt_state_shardings(mesh, aopt, pshard)
    params_s = jax.device_put(params, pshard)
    opt_s = jax.device_put(opt, oshard)
    batch_s = jax.device_put(batch, bshard)
    with SH.use_mesh(mesh):
        p2, o2, m2 = jax.jit(step, in_shardings=(pshard, oshard, bshard))(
            params_s, opt_s, batch_s)
    print("loss1", float(m1["loss"]), "loss2", float(m2["loss"]))
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print("maxdiff", d)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4
    assert d < 2e-4
    """)
    assert "maxdiff" in out


def test_elastic_remesh_resume(tmp_path):
    out = run_py(f"""
    from repro.configs.base import ModelConfig, RunConfig
    from repro.launch.mesh import make_mesh
    from repro.models import model as M
    from repro.optim import AdamWConfig, init_opt_state
    from repro import checkpoint as CKPT
    from repro.runtime.elastic import resume_on_mesh

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32")
    params = M.init_params(jax.random.key(0), cfg)
    opt = init_opt_state(params, AdamWConfig())
    CKPT.save("{tmp_path}", 3, {{"params": params, "opt": opt}})

    # resume on a "2-pod" mesh, then on a "1-pod" mesh
    for shape, axes in [((2, 2, 2), ("pod", "data", "model")),
                        ((2, 4), ("data", "model"))]:
        mesh = make_mesh(shape, axes)
        p2, o2 = resume_on_mesh("{tmp_path}", 3, cfg, mesh)
        d = max(float(jnp.abs(a - jnp.asarray(b)).max())
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
        print(axes, "diff", d)
        assert d == 0.0
    """)
    assert out.count("diff 0.0") == 2


def test_pipeline_parallel_matches_sequential():
    out = run_py("""
    from functools import partial
    from repro.launch.mesh import make_mesh
    from repro.parallel import sharding as SH
    from repro.parallel.pipeline import pipeline_apply, bubble_fraction

    stages, n_micro, mb, d = 4, 6, 8, 16
    mesh = make_mesh((stages,), ("stage",))
    key = jax.random.key(0)
    ws = jax.random.normal(key, (stages, d, d)) * 0.3

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
    with SH.use_mesh(mesh):
        out = pipeline_apply(stage_fn, ws, x, mesh=mesh)
    # sequential reference
    ref = x
    for s in range(stages):
        ref = jnp.tanh(ref @ ws[s])
    d_ = float(jnp.abs(out - ref).max())
    print("pp maxdiff", d_, "bubble", bubble_fraction(n_micro, stages))
    assert d_ < 1e-5
    """, n_devices=4)
    assert "pp maxdiff" in out


def test_compressed_train_step_learns_with_s8_wire():
    out = run_py("""
    from repro.configs.base import ModelConfig, RunConfig
    from repro.launch.mesh import make_mesh
    from repro.models import model as M
    from repro.optim import AdamWConfig, init_opt_state
    from repro.parallel import sharding as SH
    from repro.runtime.spmd_train import make_compressed_train_step

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      dtype="float32")
    rc = RunConfig(xent_chunk=16, attn_chunk_kv=16, learning_rate=2e-3,
                   warmup_steps=2)
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    params = M.init_params(jax.random.key(0), cfg)
    opt = init_opt_state(params, AdamWConfig())
    step, init_ef = make_compressed_train_step(cfg, rc, mesh)
    ef = init_ef(params)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (8, 32), 0, 128),
             "labels": jax.random.randint(jax.random.key(2), (8, 32), 0, 128)}
    with SH.use_mesh(mesh):
        jstep = jax.jit(step)
        losses = []
        for _ in range(8):
            params, opt, ef, m = jstep(params, opt, ef, batch)
            losses.append(float(m["loss"]))
        txt = jax.jit(step).lower(params, opt, ef, batch).compile().as_text()
    s8 = sum(1 for l in txt.splitlines() if "all-reduce" in l and "s8[" in l)
    print("losses", [round(l, 3) for l in losses], "s8_allreduces", s8)
    assert losses[-1] < losses[0] - 0.2   # converges through int8 sync
    assert s8 >= 5                        # grads really cross pods as int8
    """)
    assert "s8_allreduces" in out


def test_sharded_fleet_bit_identical_vs_single_device():
    # H=37 is not a multiple of 2 or 8, so both meshes exercise the
    # padded-H path (inert copies of config 0, sliced before composition).
    out = run_py("""
    from repro.core import flow
    from repro.core.arch import Constraints, config_space_grid
    from repro.core.ir import residual_block_ir, resnet18_ir

    loose = Constraints(*[float("inf")] * 4)
    space = config_space_grid(
        f1s=(2, 4), f2s=(2, 4), f3s=(2, 4), f4s=(2, 4),
        bus_widths=(2, 4), sram_splits=("unified",),
    )[:37]
    irs = [resnet18_ir(), residual_block_ir()]
    base = flow.run_fleet(irs, config_space=space, constraints=loose,
                          groupings="pool", pareto=True)
    for d in (2, 8):
        fl = flow.run_fleet(irs, config_space=space, constraints=loose,
                            groupings="pool", devices=d, pareto=True)
        assert fl.device_count == d
        assert fl.n_candidates == base.n_candidates  # padded H not counted
        for a, b in zip(base.results, fl.results):
            assert a.best_metrics == b.best_metrics, (d, a, b)
            assert a.best_hw == b.best_hw
            assert np.array_equal(a.best_cuts, b.best_cuts)
            assert a.group_sizes == b.group_sizes
            assert a.n_feasible == b.n_feasible
            # the whole Pareto front, not just the argmin, is bit-identical
            assert np.array_equal(a.pareto.metrics, b.pareto.metrics)
            assert np.array_equal(a.pareto.hw_indices, b.pareto.hw_indices)
            assert np.array_equal(a.pareto.cut_indices, b.pareto.cut_indices)
            assert np.array_equal(a.pareto.cuts, b.pareto.cuts)
        print("devices", d, "ok")
    layouts = {(e["mesh_axis"], e["device_count"])
               for e in flow.sweep_cache_stats()["entries"]}
    assert ("single", 1) in layouts
    assert ("hardware", 2) in layouts and ("hardware", 8) in layouts
    print("sharded fleet ok", len(space))
    """)
    assert "sharded fleet ok 37" in out


def test_sharded_fleet_search_groupings_and_budget_8dev():
    # The sharded path composes with the rest of the flow: frontier-DP
    # groupings + SRAM budget prefilter, best metrics == plain run_flow.
    out = run_py("""
    from repro.core import flow
    from repro.core.arch import Constraints, default_config_space
    from repro.core.ir import residual_block_ir, resnet18_ir

    loose = Constraints(*[float("inf")] * 4)
    budget = 2.0e6
    irs = [resnet18_ir(), residual_block_ir()]
    fl = flow.run_fleet(irs, config_space=default_config_space(),
                        constraints=loose, groupings="search",
                        sram_budget_words=budget, devices=8)
    for g, r in zip(irs, fl.results):
        solo = flow.run_flow(g, config_space=default_config_space(),
                             constraints=loose, groupings="search",
                             sram_budget_words=budget)
        assert r.best_metrics == solo.best_metrics
        assert np.array_equal(r.best_cuts, solo.best_cuts)
        assert r.search_engine == solo.search_engine
    print("sharded search ok", fl.device_count)
    """)
    assert "sharded search ok 8" in out


def test_compressed_psum_accuracy_and_wire_dtype():
    out = run_py("""
    from functools import partial
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_mesh
    from repro.parallel import sharding as SH
    from repro.parallel.compression import compressed_psum

    mesh = make_mesh((2,), ("pod",))

    @partial(SH.shard_map_unchecked, mesh=mesh, in_specs=P("pod"),
             out_specs=P("pod"))
    def sync(x):
        out, err = compressed_psum(x[0], "pod", mean=True)
        return (out + err * 0)[None]

    x = jax.random.normal(jax.random.key(0), (2, 1024)) * 3.0
    with SH.use_mesh(mesh):
        got = sync(x)
        txt = jax.jit(sync).lower(x).compile().as_text()
    expect = x.mean(axis=0)
    rel = float(jnp.abs(got[0] - expect).max() / (jnp.abs(expect).max()))
    n_s8 = sum(1 for l in txt.splitlines() if "all-reduce" in l and "s8[" in l)
    print("rel err", rel, "s8 allreduces", n_s8)
    assert rel < 0.05      # int8 quantisation error bound
    assert n_s8 >= 1       # payload really goes over the wire as int8
    """, n_devices=2)
    assert "s8 allreduces" in out
