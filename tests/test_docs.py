"""docs/OP_COVERAGE.md is a tested contract, not prose: the primitive
matrix must match the frontend's actual ``eqn_*`` handlers, the real
dispatcher, and the real kind vocabulary — so the docs cannot silently rot
when a lowering rule is added or renamed."""
from __future__ import annotations

import pathlib
import re

from repro.core import frontend, ir, metrics

DOC = pathlib.Path(__file__).resolve().parents[1] / "docs" / "OP_COVERAGE.md"


def _matrix_rows() -> list[list[str]]:
    """Cells of every body row of the '## Primitive matrix' table."""
    text = DOC.read_text()
    section = text.split("## Primitive matrix", 1)[1].split("\n## ", 1)[0]
    rows = []
    for line in section.splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if cells[0].startswith("JAX primitive") or set(cells[0]) <= {"-", " "}:
            continue  # header / separator
        rows.append(cells)
    assert rows, "primitive matrix table not found in docs/OP_COVERAGE.md"
    assert all(len(r) == 7 for r in rows), [len(r) for r in rows]
    return rows


def _ticked(cell: str) -> set[str]:
    return set(re.findall(r"`([^`]+)`", cell))


def test_matrix_handlers_match_tracer() -> None:
    documented = set()
    for row in _matrix_rows():
        documented |= _ticked(row[1])
    actual = {m for m in dir(frontend._Tracer) if m.startswith("eqn_")}
    assert documented == actual, (
        f"docs list handlers {sorted(documented)} but _Tracer defines "
        f"{sorted(actual)}"
    )


def test_matrix_primitives_match_dispatcher() -> None:
    dispatched = (
        {"conv_general_dilated", "dot_general", "scan"}
        | set(frontend._REDUCE_WINDOW_PRIMS)
        | set(frontend._SPATIAL_REDUCE_PRIMS)
    )
    documented = set()
    for row in _matrix_rows():
        # The primitive cell may carry qualifiers ("(weight operand)");
        # only the backticked names are primitive claims.
        documented |= {
            p.split(" ")[0] for p in _ticked(row[0]) if not p.startswith("(")
        }
    # Every special-cased primitive is documented, and the docs name no
    # primitive the dispatcher does not special-case.
    assert documented == dispatched, (
        f"docs: {sorted(documented)} vs dispatcher: {sorted(dispatched)}"
    )


def test_matrix_kinds_are_real_and_complete() -> None:
    documented = set()
    for row in _matrix_rows():
        documented |= _ticked(row[3])
    assert documented <= set(ir.KINDS), documented - set(ir.KINDS)
    assert documented == set(ir.KINDS), (
        f"kinds missing from the matrix: {set(ir.KINDS) - documented}"
    )


def test_matrix_support_columns_are_total() -> None:
    # The lock-step tests make support all-or-nothing per kind; the matrix
    # must not claim a partial row that the evaluator cannot distinguish.
    for row in _matrix_rows():
        assert [row[4], row[5], row[6]] == ["yes", "yes", "yes"], row


def test_cost_model_notes_claims() -> None:
    text = DOC.read_text()
    # "13th feature column (metrics.F_STATE)"
    assert "F_STATE" in text
    cols = [getattr(metrics, n) for n in dir(metrics) if n.startswith("F_")]
    assert metrics.F_STATE == max(cols) == 12
    # The builders named in the doc must exist on the frontend.
    for fn in ("transformer_graph", "mamba_graph", "moe_block_graph",
               "vgg16_network", "resnet18_graph", "mobilenet_graph",
               "mlp_block_graph"):
        assert f"`frontend.{fn}`" in text or fn in text
        assert hasattr(frontend, fn), fn


def test_architecture_doc_names_real_paths() -> None:
    arch = DOC.with_name("ARCHITECTURE.md").read_text()
    root = DOC.parents[1]
    for rel in ("benchmarks/bench_search.py", "benchmarks/bench_fleet.py",
                "benchmarks/bench_shard.py", "benchmarks/bench_serve.py",
                "benchmarks/bench_zoo.py", "docs/OP_COVERAGE.md"):
        assert rel.rsplit("/", 1)[-1] in arch, rel
        assert (root / rel).exists(), rel


# ---------------------------------------------------------------------------
# docs/SERVICE.md — the serving/journal/breaker contract
# ---------------------------------------------------------------------------

SERVICE_DOC = DOC.with_name("SERVICE.md")


def _table_rows(section_heading: str) -> list[list[str]]:
    """Body rows of the (single) markdown table under ``section_heading``."""
    text = SERVICE_DOC.read_text()
    section = text.split(section_heading, 1)[1].split("\n## ", 1)[0]
    rows = []
    for line in section.splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if set(cells[1]) <= {"-", " "}:
            continue  # separator
        rows.append(cells)
    header, body = rows[0], rows[1:]
    assert body, f"no table under {section_heading!r} in docs/SERVICE.md"
    return body


def test_service_doc_journal_record_table_matches_code() -> None:
    from repro.core import journal

    documented = [_ticked(row[0]).pop() for row in
                  _table_rows("## Journal record format")]
    # exact vocabulary AND order: the doc table is the WAL's contract
    assert documented == list(journal.RECORD_TYPES), (
        f"docs table: {documented} vs journal.RECORD_TYPES: "
        f"{list(journal.RECORD_TYPES)}"
    )


def test_service_doc_breaker_table_matches_enum() -> None:
    from repro.core import service

    documented = {_ticked(row[0]).pop() for row in
                  _table_rows("## Circuit breaker")}
    actual = {s.name for s in service.BreakerState}
    assert documented == actual, (
        f"docs table: {sorted(documented)} vs BreakerState: {sorted(actual)}"
    )


def test_service_doc_lifecycle_names_real_states_and_errors() -> None:
    text = SERVICE_DOC.read_text()
    for state in ("admitted", "queued", "sweeping", "served", "cancelled",
                  "recovered", "rejected", "expired"):
        assert state in text, f"lifecycle state {state!r} missing"
    from repro.core import errors

    for err in ("GraphValidationError", "ConfigValidationError",
                "DeadlineExceeded", "ServiceOverloaded", "RequestCancelled",
                "AuditMismatch", "TransientFailure", "JournalCorrupt"):
        assert err in text, f"typed error {err!r} missing from the doc"
        assert hasattr(errors, err), err


def test_service_doc_names_real_paths_and_knobs() -> None:
    text = SERVICE_DOC.read_text()
    root = SERVICE_DOC.parents[1]
    for rel in ("tests/test_journal.py", "tests/test_journal_property.py",
                "tests/test_docs.py", "examples/serve_lm.py",
                "benchmarks/bench_serve.py"):
        assert rel in text, rel
        assert (root / rel).exists(), rel
    # every knob the doc mentions is a real constructor parameter
    import inspect

    from repro.core.service import AsyncPlanningService, PlanningService

    params = set(inspect.signature(PlanningService.__init__).parameters)
    params |= set(inspect.signature(AsyncPlanningService.__init__).parameters)
    for knob in ("journal_dir", "hw_chunk", "shadow_audit_rate",
                 "breaker_threshold", "breaker_cooldown_seconds",
                 "watchdog_seconds"):
        assert knob in text, knob
        assert knob in params, knob
