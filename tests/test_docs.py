"""docs/OP_COVERAGE.md is a tested contract, not prose: the primitive
matrix must match the frontend's actual ``eqn_*`` handlers, the real
dispatcher, and the real kind vocabulary — so the docs cannot silently rot
when a lowering rule is added or renamed."""
from __future__ import annotations

import pathlib
import re

from repro.core import frontend, ir, metrics

DOC = pathlib.Path(__file__).resolve().parents[1] / "docs" / "OP_COVERAGE.md"


def _matrix_rows() -> list[list[str]]:
    """Cells of every body row of the '## Primitive matrix' table."""
    text = DOC.read_text()
    section = text.split("## Primitive matrix", 1)[1].split("\n## ", 1)[0]
    rows = []
    for line in section.splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if cells[0].startswith("JAX primitive") or set(cells[0]) <= {"-", " "}:
            continue  # header / separator
        rows.append(cells)
    assert rows, "primitive matrix table not found in docs/OP_COVERAGE.md"
    assert all(len(r) == 7 for r in rows), [len(r) for r in rows]
    return rows


def _ticked(cell: str) -> set[str]:
    return set(re.findall(r"`([^`]+)`", cell))


def test_matrix_handlers_match_tracer() -> None:
    documented = set()
    for row in _matrix_rows():
        documented |= _ticked(row[1])
    actual = {m for m in dir(frontend._Tracer) if m.startswith("eqn_")}
    assert documented == actual, (
        f"docs list handlers {sorted(documented)} but _Tracer defines "
        f"{sorted(actual)}"
    )


def test_matrix_primitives_match_dispatcher() -> None:
    dispatched = (
        {"conv_general_dilated", "dot_general", "scan"}
        | set(frontend._REDUCE_WINDOW_PRIMS)
        | set(frontend._SPATIAL_REDUCE_PRIMS)
    )
    documented = set()
    for row in _matrix_rows():
        # The primitive cell may carry qualifiers ("(weight operand)");
        # only the backticked names are primitive claims.
        documented |= {
            p.split(" ")[0] for p in _ticked(row[0]) if not p.startswith("(")
        }
    # Every special-cased primitive is documented, and the docs name no
    # primitive the dispatcher does not special-case.
    assert documented == dispatched, (
        f"docs: {sorted(documented)} vs dispatcher: {sorted(dispatched)}"
    )


def test_matrix_kinds_are_real_and_complete() -> None:
    documented = set()
    for row in _matrix_rows():
        documented |= _ticked(row[3])
    assert documented <= set(ir.KINDS), documented - set(ir.KINDS)
    assert documented == set(ir.KINDS), (
        f"kinds missing from the matrix: {set(ir.KINDS) - documented}"
    )


def test_matrix_support_columns_are_total() -> None:
    # The lock-step tests make support all-or-nothing per kind; the matrix
    # must not claim a partial row that the evaluator cannot distinguish.
    for row in _matrix_rows():
        assert [row[4], row[5], row[6]] == ["yes", "yes", "yes"], row


def test_cost_model_notes_claims() -> None:
    text = DOC.read_text()
    # "13th feature column (metrics.F_STATE)"
    assert "F_STATE" in text
    cols = [getattr(metrics, n) for n in dir(metrics) if n.startswith("F_")]
    assert metrics.F_STATE == max(cols) == 12
    # The builders named in the doc must exist on the frontend.
    for fn in ("transformer_graph", "mamba_graph", "moe_block_graph",
               "vgg16_network", "resnet18_graph", "mobilenet_graph",
               "mlp_block_graph"):
        assert f"`frontend.{fn}`" in text or fn in text
        assert hasattr(frontend, fn), fn


def test_architecture_doc_names_real_paths() -> None:
    arch = DOC.with_name("ARCHITECTURE.md").read_text()
    root = DOC.parents[1]
    for rel in ("benchmarks/bench_search.py", "benchmarks/bench_fleet.py",
                "benchmarks/bench_shard.py", "benchmarks/bench_serve.py",
                "benchmarks/bench_zoo.py", "docs/OP_COVERAGE.md"):
        assert rel.rsplit("/", 1)[-1] in arch, rel
        assert (root / rel).exists(), rel


# ---------------------------------------------------------------------------
# docs/SERVICE.md — the serving/journal/breaker contract
# ---------------------------------------------------------------------------

SERVICE_DOC = DOC.with_name("SERVICE.md")


def _table_rows(section_heading: str) -> list[list[str]]:
    """Body rows of the (single) markdown table under ``section_heading``."""
    text = SERVICE_DOC.read_text()
    section = text.split(section_heading, 1)[1].split("\n## ", 1)[0]
    rows = []
    for line in section.splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if set(cells[1]) <= {"-", " "}:
            continue  # separator
        rows.append(cells)
    header, body = rows[0], rows[1:]
    assert body, f"no table under {section_heading!r} in docs/SERVICE.md"
    return body


def test_service_doc_journal_record_table_matches_code() -> None:
    from repro.core import journal

    documented = [_ticked(row[0]).pop() for row in
                  _table_rows("## Journal record format")]
    # exact vocabulary AND order: the doc table is the WAL's contract
    assert documented == list(journal.RECORD_TYPES), (
        f"docs table: {documented} vs journal.RECORD_TYPES: "
        f"{list(journal.RECORD_TYPES)}"
    )


def test_service_doc_breaker_table_matches_enum() -> None:
    from repro.core import service

    documented = {_ticked(row[0]).pop() for row in
                  _table_rows("## Circuit breaker")}
    actual = {s.name for s in service.BreakerState}
    assert documented == actual, (
        f"docs table: {sorted(documented)} vs BreakerState: {sorted(actual)}"
    )


def test_service_doc_lifecycle_names_real_states_and_errors() -> None:
    text = SERVICE_DOC.read_text()
    for state in ("admitted", "queued", "sweeping", "served", "cancelled",
                  "recovered", "rejected", "expired"):
        assert state in text, f"lifecycle state {state!r} missing"
    from repro.core import errors

    for err in ("GraphValidationError", "ConfigValidationError",
                "DeadlineExceeded", "ServiceOverloaded", "RequestCancelled",
                "AuditMismatch", "TransientFailure", "JournalCorrupt"):
        assert err in text, f"typed error {err!r} missing from the doc"
        assert hasattr(errors, err), err


def test_service_doc_names_real_paths_and_knobs() -> None:
    text = SERVICE_DOC.read_text()
    root = SERVICE_DOC.parents[1]
    for rel in ("tests/test_journal.py", "tests/test_journal_property.py",
                "tests/test_docs.py", "examples/serve_lm.py",
                "benchmarks/bench_serve.py"):
        assert rel in text, rel
        assert (root / rel).exists(), rel
    # every knob the doc mentions is a real constructor parameter
    import inspect

    from repro.core.service import AsyncPlanningService, PlanningService

    params = set(inspect.signature(PlanningService.__init__).parameters)
    params |= set(inspect.signature(AsyncPlanningService.__init__).parameters)
    for knob in ("journal_dir", "hw_chunk", "shadow_audit_rate",
                 "breaker_threshold", "breaker_cooldown_seconds",
                 "watchdog_seconds"):
        assert knob in text, knob
        assert knob in params, knob


# ---------------------------------------------------------------------------
# docs/RESILIENCE.md — quarantine / salvage / resumable-checkpoint contract
# ---------------------------------------------------------------------------

RESILIENCE_DOC = DOC.with_name("RESILIENCE.md")


def _resilience_rows(section_heading: str) -> list[list[str]]:
    """Body rows of the (single) markdown table under ``section_heading``."""
    text = RESILIENCE_DOC.read_text()
    section = text.split(section_heading, 1)[1].split("\n## ", 1)[0]
    rows = []
    for line in section.splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if set(cells[1]) <= {"-", " "}:
            continue  # separator
        rows.append(cells)
    header, body = rows[0], rows[1:]
    assert body, f"no table under {section_heading!r} in docs/RESILIENCE.md"
    return body


def test_resilience_doc_taxonomy_verdicts_are_real_errors() -> None:
    from repro.core import errors

    documented = set()
    for row in _resilience_rows("## Fault taxonomy"):
        documented |= {
            name for name in _ticked(row[2]) if name.endswith("Error")
            or name in ("TransientFailure", "JournalCorrupt")
        }
    for name in documented:
        assert hasattr(errors, name), name
        assert issubclass(getattr(errors, name), errors.EvaluatorError), name
    for required in ("PoisonedResultError", "TransientFailure",
                     "JournalCorrupt", "GraphValidationError"):
        assert required in documented, required


def test_resilience_doc_checkpoint_record_table_matches_code() -> None:
    from repro import checkpoint

    documented = [_ticked(row[0]).pop() for row in
                  _resilience_rows("## Checkpoint record types")]
    # exact vocabulary AND order: the doc table is the chunk log's contract
    assert documented == list(checkpoint.SWEEP_RECORD_TYPES), (
        f"docs table: {documented} vs SWEEP_RECORD_TYPES: "
        f"{list(checkpoint.SWEEP_RECORD_TYPES)}"
    )


def test_resilience_doc_retry_knob_table_matches_dataclass() -> None:
    import dataclasses as dc

    from repro.core.errors import RetryPolicy

    rows = _resilience_rows("## Retry policy knobs")
    documented = [_ticked(row[0]).pop() for row in rows]
    fields = {f.name: f for f in dc.fields(RetryPolicy)}
    assert documented == list(fields), (
        f"docs table: {documented} vs RetryPolicy fields: {list(fields)}"
    )
    for row in rows:
        name = _ticked(row[0]).pop()
        assert _ticked(row[1]).pop() == repr(fields[name].default), (
            f"{name}: doc default {row[1]} vs code {fields[name].default!r}"
        )


def test_resilience_doc_injector_knobs_and_hooks_are_real() -> None:
    import inspect

    from repro.testing.faults import FaultInjector

    text = RESILIENCE_DOC.read_text()
    params = set(inspect.signature(FaultInjector.__init__).parameters)
    for knob in ("shard_fail_chunks", "shard_fail_every", "mesh_fail_sweeps",
                 "poison_cell", "poison_value", "transient_sweeps",
                 "chunk_stall_seconds"):
        assert knob in text, knob
        assert knob in params, knob
    for hook in ("before_chunk_compute", "poison_plane"):
        assert hook in text, hook
        assert callable(getattr(FaultInjector, hook)), hook


def test_resilience_doc_names_real_symbols_and_paths() -> None:
    text = RESILIENCE_DOC.read_text()
    root = RESILIENCE_DOC.parents[1]
    for rel in ("tests/test_salvage.py", "tests/test_salvage_property.py",
                "tests/test_faults.py", "benchmarks/bench_shard.py"):
        assert rel in text, rel
        assert (root / rel).exists(), rel
    from repro.core import flow, metrics
    from repro.core.service import PlanningService
    from repro.runtime import elastic, fault_tolerance

    assert "poison_mask" in text and hasattr(metrics, "poison_mask")
    assert "assert_exact_f64" in text and hasattr(metrics, "assert_exact_f64")
    assert "MAX_EXACT_WORDS" in text and metrics.MAX_EXACT_WORDS == 2.0 ** 53
    assert "sweep_degradation_ladder" in text
    assert callable(elastic.sweep_degradation_ladder)
    assert "StragglerDetector" in text
    assert callable(fault_tolerance.StragglerDetector)
    for field in ("chunks_restored", "chunks_computed", "straggler_chunks",
                  "mesh_degraded", "quarantine"):
        assert field in text, field
        assert field in {f.name for f in __import__("dataclasses").fields(
            flow.FleetResult)}, field
    import inspect

    params = set(inspect.signature(PlanningService.__init__).parameters)
    run_fleet_params = set(inspect.signature(flow.run_fleet).parameters)
    for knob in ("retry_policy", "checkpoint_dir"):
        assert knob in text, knob
        assert knob in params and knob in run_fleet_params, knob
