"""docs/OP_COVERAGE.md is a tested contract, not prose: the primitive
matrix must match the frontend's actual ``eqn_*`` handlers, the real
dispatcher, and the real kind vocabulary — so the docs cannot silently rot
when a lowering rule is added or renamed."""
from __future__ import annotations

import pathlib
import re

from repro.core import frontend, ir, metrics

DOC = pathlib.Path(__file__).resolve().parents[1] / "docs" / "OP_COVERAGE.md"


def _matrix_rows() -> list[list[str]]:
    """Cells of every body row of the '## Primitive matrix' table."""
    text = DOC.read_text()
    section = text.split("## Primitive matrix", 1)[1].split("\n## ", 1)[0]
    rows = []
    for line in section.splitlines():
        line = line.strip()
        if not line.startswith("|"):
            continue
        cells = [c.strip() for c in line.strip("|").split("|")]
        if cells[0].startswith("JAX primitive") or set(cells[0]) <= {"-", " "}:
            continue  # header / separator
        rows.append(cells)
    assert rows, "primitive matrix table not found in docs/OP_COVERAGE.md"
    assert all(len(r) == 7 for r in rows), [len(r) for r in rows]
    return rows


def _ticked(cell: str) -> set[str]:
    return set(re.findall(r"`([^`]+)`", cell))


def test_matrix_handlers_match_tracer() -> None:
    documented = set()
    for row in _matrix_rows():
        documented |= _ticked(row[1])
    actual = {m for m in dir(frontend._Tracer) if m.startswith("eqn_")}
    assert documented == actual, (
        f"docs list handlers {sorted(documented)} but _Tracer defines "
        f"{sorted(actual)}"
    )


def test_matrix_primitives_match_dispatcher() -> None:
    dispatched = (
        {"conv_general_dilated", "dot_general", "scan"}
        | set(frontend._REDUCE_WINDOW_PRIMS)
        | set(frontend._SPATIAL_REDUCE_PRIMS)
    )
    documented = set()
    for row in _matrix_rows():
        # The primitive cell may carry qualifiers ("(weight operand)");
        # only the backticked names are primitive claims.
        documented |= {
            p.split(" ")[0] for p in _ticked(row[0]) if not p.startswith("(")
        }
    # Every special-cased primitive is documented, and the docs name no
    # primitive the dispatcher does not special-case.
    assert documented == dispatched, (
        f"docs: {sorted(documented)} vs dispatcher: {sorted(dispatched)}"
    )


def test_matrix_kinds_are_real_and_complete() -> None:
    documented = set()
    for row in _matrix_rows():
        documented |= _ticked(row[3])
    assert documented <= set(ir.KINDS), documented - set(ir.KINDS)
    assert documented == set(ir.KINDS), (
        f"kinds missing from the matrix: {set(ir.KINDS) - documented}"
    )


def test_matrix_support_columns_are_total() -> None:
    # The lock-step tests make support all-or-nothing per kind; the matrix
    # must not claim a partial row that the evaluator cannot distinguish.
    for row in _matrix_rows():
        assert [row[4], row[5], row[6]] == ["yes", "yes", "yes"], row


def test_cost_model_notes_claims() -> None:
    text = DOC.read_text()
    # "13th feature column (metrics.F_STATE)"
    assert "F_STATE" in text
    cols = [getattr(metrics, n) for n in dir(metrics) if n.startswith("F_")]
    assert metrics.F_STATE == max(cols) == 12
    # The builders named in the doc must exist on the frontend.
    for fn in ("transformer_graph", "mamba_graph", "moe_block_graph",
               "vgg16_network", "resnet18_graph", "mobilenet_graph",
               "mlp_block_graph"):
        assert f"`frontend.{fn}`" in text or fn in text
        assert hasattr(frontend, fn), fn


def test_architecture_doc_names_real_paths() -> None:
    arch = DOC.with_name("ARCHITECTURE.md").read_text()
    root = DOC.parents[1]
    for rel in ("benchmarks/bench_search.py", "benchmarks/bench_fleet.py",
                "benchmarks/bench_shard.py", "benchmarks/bench_serve.py",
                "benchmarks/bench_zoo.py", "docs/OP_COVERAGE.md"):
        assert rel.rsplit("/", 1)[-1] in arch, rel
        assert (root / rel).exists(), rel
