"""Write-ahead journal + crash recovery: bit-identity at every kill point.

The contract under test (repro.core.journal + PlanningService.recover):

* every codec round-trips bit-exactly (hex floats, raw-byte arrays,
  graphs, configs, requests, responses — success and error);
* the WAL tolerates a torn tail (crash mid-append) but refuses interior
  corruption and sequence gaps with a typed ``JournalCorrupt``;
* snapshots commit atomically, compact the WAL, and verify by digest;
* THE crash property: truncate the journal of a completed 50-request run
  at EVERY record boundary, recover, drain — and the answered set is
  exactly the durably-admitted set, every response bit-identical to the
  uninterrupted run's, no duplicates, no losses (degraded/timing fields
  excluded: they are observations, not answers);
* recovery composes with itself and honours pre-crash cancellations.
"""
import json
import math
import pathlib
import struct

import numpy as np
import pytest

from repro.core import frontend, journal as J
from repro.core.arch import Constraints, paper_config_space
from repro.core.errors import (
    InfeasibleBudgetError,
    JournalCorrupt,
    TransientFailure,
)
from repro.core.ir import as_graph, residual_block_ir
from repro.core.service import PlanRequest, PlanningService

# The paper's 8-point space: small sweeps, one shared compiled executable
# across the whole suite (same space as tests/test_service.py).
SPACE = tuple(paper_config_space())


def _graphs():
    return [as_graph(frontend.mlp_block_graph()), as_graph(residual_block_ir())]


def _service(tmp_path, **kw):
    kw.setdefault("config_space", SPACE)
    kw.setdefault("backoff_seconds", 0.0)
    kw.setdefault("journal_fsync", False)  # replay logic, not disk latency
    kw.setdefault("snapshot_every", 0)
    return PlanningService(journal_dir=tmp_path, **kw)


def _bits(x: float) -> bytes:
    return struct.pack("<d", float(x))


def assert_responses_equivalent(a, b):
    """Bit-identical *answers*: everything except per-run timing."""
    assert a.request_id == b.request_id
    assert a.ok == b.ok
    assert a.error_type == b.error_type
    assert (a.engine, a.rung, a.exact, a.degraded) == (
        b.engine, b.rung, b.exact, b.degraded)
    assert _bits(a.quality_bound) == _bits(b.quality_bound)
    if a.plan is None:
        assert b.plan is None
        return
    pa, pb = a.plan, b.plan
    assert pa.best_hw == pb.best_hw
    assert np.array_equal(pa.best_cuts, pb.best_cuts)
    for f in ("bandwidth_words", "latency_cycles", "energy_nj", "area_um2"):
        assert _bits(getattr(pa.best_metrics, f)) == _bits(
            getattr(pb.best_metrics, f))
    assert pa.group_sizes == pb.group_sizes
    assert (pa.n_candidates, pa.n_feasible, pa.n_pruned) == (
        pb.n_candidates, pb.n_feasible, pb.n_pruned)


# ---------------------------------------------------------------------------
# codecs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("x", [
    0.0, -0.0, 1.5, -3.25e300, 5e-324, float("inf"), float("-inf"),
    float("nan"), 0.1, 1 / 3,
])
def test_float_codec_bit_exact(x):
    y = J.dec_float(J.enc_float(x))
    if math.isnan(x):
        assert math.isnan(y)
    else:
        assert _bits(x) == _bits(y)


def test_array_codec_bit_exact():
    rng = np.random.default_rng(0)
    for a in [
        rng.standard_normal((3, 5)),
        np.array([True, False, True]),
        np.arange(7, dtype=np.int64).reshape(7, 1),
        np.zeros((0, 4)),
    ]:
        b = J.dec_array(J.enc_array(a))
        assert b.dtype == a.dtype and b.shape == a.shape
        assert a.tobytes() == b.tobytes()


def test_graph_config_constraints_codecs():
    g = as_graph(residual_block_ir())
    assert J.dec_graph(J.enc_graph(g)) == g
    for c in SPACE[:3]:
        assert J.dec_config(J.enc_config(c)) == c
    con = Constraints(1.5e6, float("inf"), 2.25e9, float("inf"))
    assert J.dec_constraints(J.enc_constraints(con)) == con


def test_error_codec_keeps_type_and_payload():
    e = InfeasibleBudgetError("too small", min_feasible_budget_words=4096.0)
    d = J.dec_error(J.enc_error(e))
    assert type(d) is InfeasibleBudgetError
    assert d.min_feasible_budget_words == 4096.0
    t = J.dec_error(J.enc_error(
        TransientFailure("gone", cause=RuntimeError("x"), attempts=4)))
    assert type(t) is TransientFailure and t.attempts == 4


# ---------------------------------------------------------------------------
# WAL mechanics
# ---------------------------------------------------------------------------


def test_wal_append_and_load(tmp_path):
    j = J.Journal(tmp_path, fsync=False)
    j.append("admit", {"rid": 0})
    j.append("tick", {"tick": 1, "rids": [0]})
    j.append("response", {"rid": 0})
    j.close()
    state, recs = J.load(tmp_path)
    assert state is None
    assert [r["type"] for r in recs] == ["admit", "tick", "response"]
    assert [r["seq"] for r in recs] == [1, 2, 3]


def test_wal_rejects_unknown_record_type(tmp_path):
    j = J.Journal(tmp_path, fsync=False)
    with pytest.raises(ValueError):
        j.append("frobnicate", {})


def test_torn_tail_is_dropped_but_interior_corruption_raises(tmp_path):
    j = J.Journal(tmp_path, fsync=False)
    for i in range(4):
        j.append("admit", {"rid": i})
    j.close()
    wal = pathlib.Path(tmp_path) / J.WAL_NAME
    lines = wal.read_text().splitlines()

    # torn tail: final record cut mid-write -> silently dropped
    wal.write_text("\n".join(lines[:-1] + [lines[-1][: len(lines[-1]) // 2]]))
    _, recs = J.load(tmp_path)
    assert [r["payload"]["rid"] for r in recs] == [0, 1, 2]

    # interior corruption: same damage NOT at the tail -> typed refusal
    wal.write_text("\n".join(
        [lines[0], lines[1][: len(lines[1]) // 2], lines[2], lines[3]]))
    with pytest.raises(JournalCorrupt):
        J.load(tmp_path)


def test_sequence_gap_raises(tmp_path):
    j = J.Journal(tmp_path, fsync=False)
    for i in range(3):
        j.append("admit", {"rid": i})
    j.close()
    wal = pathlib.Path(tmp_path) / J.WAL_NAME
    lines = wal.read_text().splitlines()
    wal.write_text("\n".join([lines[0], lines[2]]))  # drop the middle record
    with pytest.raises(JournalCorrupt):
        J.load(tmp_path)


def test_snapshot_compacts_and_verifies(tmp_path):
    j = J.Journal(tmp_path, fsync=False, snapshot_every=2)
    j.append("admit", {"rid": 0})
    assert not j.maybe_snapshot(lambda: {"n": 1})   # 1 < snapshot_every
    j.append("admit", {"rid": 1})
    assert j.maybe_snapshot(lambda: {"n": 2})
    j.append("admit", {"rid": 2})
    j.close()
    state, recs = J.load(tmp_path)
    assert state == {"n": 2}
    assert [r["payload"]["rid"] for r in recs] == [2]  # WAL compacted
    assert len(list(pathlib.Path(tmp_path).glob("snapshot_*.json"))) == 1

    snap = next(pathlib.Path(tmp_path).glob("snapshot_*.json"))
    body = json.loads(snap.read_text())
    body["state"]["n"] = 999  # bit-rot the snapshot
    snap.write_text(json.dumps(body))
    with pytest.raises(JournalCorrupt):
        J.load(tmp_path)


# ---------------------------------------------------------------------------
# crash recovery: every-record-boundary kill points
# ---------------------------------------------------------------------------


def _run_uninterrupted(tmp_path, n=50, **kw):
    """A journaled n-request run; returns {rid: response} (not popped —
    read straight from the service's response map)."""
    svc = _service(tmp_path, **kw)
    graphs = _graphs()
    rids = []
    for i in range(n):
        rids.append(svc.submit(PlanRequest(
            graph=graphs[i % len(graphs)],
            sram_budget_words=[float("inf"), 2e6][(i // 2) % 2],
        )))
        if i % 7 == 6:  # interleave ticks so tick records pepper the WAL
            svc.tick()
    svc.drain()
    resps = {rid: svc._responses[rid] for rid in rids}
    svc.close()
    return resps


def test_recover_at_every_record_boundary_is_exactly_once(tmp_path):
    """The PR's headline property, exhaustively: kill the service after
    EVERY WAL record of a 50-request run; recovery + drain must answer
    exactly the durably-owed set, bit-identically, no duplicates, no
    losses.  (tests/test_journal_property.py adds the hypothesis-driven
    arbitrary-byte-offset and interior-corruption variants.)"""
    base_dir = tmp_path / "base"
    expected = _run_uninterrupted(base_dir, n=50)
    wal_lines = (base_dir / J.WAL_NAME).read_text().splitlines()
    records = [json.loads(line) for line in wal_lines]
    # every request got exactly one durable response record
    assert sum(r["type"] == "response" for r in records) == 50

    for cut in range(len(wal_lines) + 1):
        crash_dir = tmp_path / f"cut{cut}"
        crash_dir.mkdir()
        (crash_dir / J.WAL_NAME).write_text(
            "".join(line + "\n" for line in wal_lines[:cut]))

        prefix = records[:cut]
        admitted = {r["payload"]["rid"] for r in prefix if r["type"] == "admit"}
        pre_answered = {
            r["payload"]["rid"] for r in prefix if r["type"] == "response"
        }
        # plan-cache hits answer at submit without queueing, so the durable
        # obligation is: every admit AND every already-journaled response.
        owed = admitted | pre_answered

        svc = PlanningService.recover(
            crash_dir, journal_fsync=False, snapshot_every=0,
            config_space=SPACE, backoff_seconds=0.0)
        assert svc.queue_depth == len(admitted - pre_answered)
        svc.drain()

        got = dict(svc._responses)
        assert set(got) == owed, f"cut={cut}"  # no loss, no invention
        for rid in owed:
            assert_responses_equivalent(expected[rid], got[rid])
        # replayed (pre-crash) answers are byte-level identical incl timing
        for rid in pre_answered:
            assert got[rid].latency_seconds == expected[rid].latency_seconds
        svc.close()


def test_recover_with_snapshots_matches(tmp_path):
    """Same exactly-once property when snapshots compact the WAL: recover
    from (snapshot + tail) instead of the full record stream."""
    base_dir = tmp_path / "snap"
    expected = _run_uninterrupted(base_dir, n=20, snapshot_every=9)
    assert list(base_dir.glob("snapshot_*.json"))  # snapshots really exist
    svc = PlanningService.recover(
        base_dir, journal_fsync=False, config_space=SPACE,
        backoff_seconds=0.0)
    svc.drain()
    assert set(svc._responses) == set(expected)
    for rid, resp in expected.items():
        assert_responses_equivalent(resp, svc._responses[rid])
    svc.close()


def test_recovery_composes_with_itself(tmp_path):
    """Crash the recovered service too: recover(recover(crash)) still
    answers exactly once."""
    d = tmp_path / "j"
    svc = _service(d)
    g = _graphs()[0]
    rids = [svc.submit(PlanRequest(graph=g)) for _ in range(3)]
    svc.tick()  # answers the batch
    r4 = svc.submit(PlanRequest(graph=_graphs()[1]))
    svc.close()  # crash with r4 admitted but unanswered

    mid = PlanningService.recover(
        d, journal_fsync=False, config_space=SPACE, backoff_seconds=0.0)
    assert mid.queue_depth == 1
    mid.close()  # crash again before draining

    fin = PlanningService.recover(
        d, journal_fsync=False, config_space=SPACE, backoff_seconds=0.0)
    assert fin.queue_depth == 1
    fin.drain()
    assert set(fin._responses) == set(rids) | {r4}
    assert fin._responses[r4].ok
    fin.close()


def test_recover_honours_precrash_cancel(tmp_path):
    d = tmp_path / "j"
    svc = _service(d)
    rid = svc.submit(PlanRequest(graph=_graphs()[0]))
    assert svc.cancel(rid)
    svc.close()  # crash before any tick

    rec = PlanningService.recover(
        d, journal_fsync=False, config_space=SPACE, backoff_seconds=0.0)
    assert rec.queue_depth == 0  # answered at recovery, not re-enqueued
    resp = rec.collect(rid)
    assert resp is not None and resp.error_type == "RequestCancelled"
    rec.close()


def test_recovered_deadline_restarts_with_admission_budget(tmp_path):
    """Deadlines are journaled as remaining budget: a recovered request
    gets its full budget back (monotonic clocks do not survive a crash),
    and an infinite deadline stays infinite."""
    d = tmp_path / "j"
    svc = _service(d)
    svc.submit(PlanRequest(graph=_graphs()[0], deadline_seconds=123.0))
    svc.submit(PlanRequest(graph=_graphs()[0]))
    svc.close()
    rec = PlanningService.recover(
        d, journal_fsync=False, config_space=SPACE, backoff_seconds=0.0)
    adms = list(rec._queue)
    now = rec.clock()
    assert 120.0 < adms[0].deadline - now < 124.0
    assert adms[1].deadline == float("inf")
    rec.close()
