"""Op-model coverage beyond 3x3 convs: oracle vs batched kernel lock-step.

The tracer emits depthwise/grouped convs (``LayerSpec.groups``), 1x1 /
pointwise and K x K != 3 kernels, and ``dot_general`` as the degenerate 1x1
convolution.  Eq. (1)-(4) must cost all of them identically in the scalar
``*_ref`` oracles and the vmapped batch kernel — the same lock-step
discipline the chain refactor established.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frontend as F
from repro.core import fusion, metrics as M
from repro.core.arch import DLAConfig
from repro.core.ir import EdgeSpec, GraphIR, LayerSpec, graph_ir

HWS = [DLAConfig("hsiao", 4, 4, 4, 4), DLAConfig("vwa", 8, 8, 3, 8)]


def _mixed_op_graph() -> GraphIR:
    """Stem -> {depthwise 3x3, pointwise 1x1, 5x5, 7x7} -> join -> matmul/fc:
    one graph exercising every newly covered operator."""
    nodes = (
        LayerSpec("stem", "conv", 8, 32, 16, 16, 3, 3, 1),
        LayerSpec("dw", "conv", 32, 32, 16, 16, 3, 3, 1, groups=32),
        LayerSpec("pw", "conv", 32, 32, 16, 16, 1, 1, 1),
        LayerSpec("k5", "conv", 32, 32, 16, 16, 5, 5, 1),
        LayerSpec("join", "elementwise", 32, 32, 16, 16),
        LayerSpec("k7", "conv", 32, 16, 16, 16, 7, 7, 2, groups=4),
        LayerSpec("mm", "matmul", 16 * 8 * 8, 64, 1, 1),
        LayerSpec("fc", "fc", 64, 10, 1, 1),
    )
    edges = (
        (0, 1), (1, 2), (1, 3), (2, 4), (3, 4), (4, 5),
        (5, 6, 16 * 8 * 8), (6, 7),
    )
    return graph_ir("mixed_ops", nodes, edges)


def test_grouped_layerspec_quantities():
    dw = LayerSpec("dw", "conv", 32, 32, 16, 16, 3, 3, 1, groups=32)
    assert dw.contracted_channels == 1
    assert dw.weight_words == 3 * 3 * 32  # one kernel per channel
    assert dw.macs == 1 * 3 * 3 * 32 * 16 * 16
    g4 = LayerSpec("g4", "conv", 32, 16, 16, 16, 7, 7, 2, groups=4)
    assert g4.contracted_channels == 8
    assert g4.weight_words == 8 * 7 * 7 * 16
    assert g4.macs == 8 * 7 * 7 * 16 * 8 * 8
    # activation frames are untouched by grouping
    dense = LayerSpec("d", "conv", 32, 32, 16, 16, 3, 3, 1)
    assert dw.in_words == dense.in_words and dw.out_words == dense.out_words


def test_groups_must_divide_channels():
    with pytest.raises(ValueError, match="groups"):
        LayerSpec("bad", "conv", 30, 32, 16, 16, 3, 3, 1, groups=4)
    with pytest.raises(ValueError, match="groups"):
        LayerSpec("bad", "conv", 32, 30, 16, 16, 3, 3, 1, groups=4)


def test_depthwise_latency_oracle_formula():
    """latency_ref must tile t_PB over the *contracted* channels."""
    g = graph_ir(
        "dw1",
        (LayerSpec("dw", "conv", 32, 32, 16, 16, 3, 3, 1, groups=32),),
        (),
    )
    hw = HWS[0]
    cuts = np.zeros(0, dtype=bool)
    expected_tpb = (
        math.ceil(32 / hw.f1) * math.ceil(1 / hw.f4)
        * math.ceil(256 / (hw.f2 * hw.f3)) * math.ceil(9 / 9)
    )
    n = g.nodes[0]
    io = (n.weight_words + n.in_words + n.out_words) / hw.dram_words_per_cycle
    assert M.latency_ref(g, cuts, hw) == expected_tpb + hw.pipeline_latency + io


@pytest.mark.parametrize("hw", HWS, ids=lambda h: h.style)
def test_mixed_ops_oracle_vs_batch_lockstep(hw):
    g = _mixed_op_graph()
    cuts_batch = fusion.enumerate_valid_edge_cuts(g)
    # bandwidth: numpy batch kernel, exact equality
    bw = M.bandwidth_batch_graph(g, cuts_batch)
    for i in range(cuts_batch.shape[0]):
        assert bw[i] == M.bandwidth_ref(g, cuts_batch[i])
    # all four metrics: jitted vmapped kernel vs scalar oracle
    esrc, edst, ewords = g.edge_arrays()
    out = np.asarray(
        M.evaluate_batch_graph(
            jnp.asarray(g.node_features()), jnp.asarray(esrc), jnp.asarray(edst),
            jnp.asarray(ewords), jnp.asarray(g.source_mask),
            jnp.asarray(g.sink_mask), jnp.asarray(cuts_batch),
            jnp.asarray(np.stack([hw.as_row()])),
            jnp.asarray(M.area_consts_of(hw)),
        )
    )
    for ci in range(0, cuts_batch.shape[0], 7):
        ref = M.evaluate_ref(g, cuts_batch[ci], hw)
        np.testing.assert_allclose(out[0, ci, 0], ref.bandwidth_words, rtol=1e-6)
        np.testing.assert_allclose(out[0, ci, 1], ref.latency_cycles, rtol=1e-6)
        np.testing.assert_allclose(out[0, ci, 2], ref.energy_nj, rtol=1e-6)
        np.testing.assert_allclose(out[0, ci, 3], ref.area_um2, rtol=1e-6)


def test_mixed_ops_search_batched_equals_scalar():
    g = _mixed_op_graph()
    best = fusion.brute_force_min_bw(g)
    best_scalar = fusion._brute_force_min_bw_scalar(g)
    np.testing.assert_array_equal(best.cuts, best_scalar.cuts)
    greedy = fusion.greedy_merge_cuts(g)
    greedy_scalar = fusion._greedy_merge_cuts_scalar(g)
    np.testing.assert_array_equal(greedy.cuts, greedy_scalar.cuts)


# ---------------------------------------------------------------------------
# Traced primitives land on the right LayerSpec
# ---------------------------------------------------------------------------


def _sds(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def test_traced_depthwise_conv_sets_groups():
    def fn(w, x):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=16,
        )

    g = F.trace(fn, _sds(3, 3, 1, 16), _sds(1, 8, 8, 16))
    (n,) = g.nodes
    assert n == LayerSpec(n.name, "conv", 16, 16, 8, 8, 3, 3, 1, groups=16)


@pytest.mark.parametrize("k", [1, 5, 7])
def test_traced_kxk_conv(k):
    def fn(w, x):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )

    g = F.trace(fn, _sds(k, k, 8, 4), _sds(1, 16, 16, 8))
    (n,) = g.nodes
    assert (n.kh, n.kw, n.n_in, n.n_out) == (k, k, 8, 4)
    assert n.macs == 8 * k * k * 4 * 16 * 16


def test_traced_dot_general_is_degenerate_conv():
    """A matmul over ``seq`` pixels is the 1x1-conv degenerate case — the
    traced LayerSpec must match the transformer builders' encoding."""
    g = F.trace(lambda w, x: x @ w, _sds(256, 512), _sds(128, 256))
    (n,) = g.nodes
    assert n == LayerSpec(n.name, "matmul", 256, 512, 128, 1)
    assert n.macs == 256 * 512 * 128
    assert n.weight_words == 256 * 512


def test_traced_single_pixel_dot_general_is_fc():
    g = F.trace(lambda w, x: x @ w, _sds(256, 10), _sds(1, 256))
    (n,) = g.nodes
    assert n == LayerSpec(n.name, "fc", 256, 10, 1, 1)


def test_traced_activation_activation_dot_general_is_actmul():
    """Both operands activations (attention QK^T): the kernel-side tensor
    counts as input traffic, mirroring the hand-built ``actmul`` layers."""

    def fn(_w, xs):
        q, k = xs
        return q @ k.T

    g = F.trace(fn, _sds(1,), (_sds(64, 32), _sds(64, 32)))
    (n,) = g.nodes
    assert n.kind == "actmul"
    assert n.n_in == 32 and n.n_out == 64 and n.h_in == 64
    # in_words covers both activation operands
    assert n.in_words == 32 * 64 + 32 * 64
    assert n.weight_words == 0


def test_actmul_with_raw_input_operand_counts_ext_words():
    """actmul of a projected query against the raw input: the input-side
    operand has no producer edge, so its frame is ext_in_words (read from
    DRAM in every grouping) — previously dropped entirely."""

    def fn(wq, x):
        q = x @ wq
        return q @ x.T

    g = F.trace(fn, _sds(32, 32), _sds(64, 32))
    q_node, am = g.nodes
    assert am.kind == "actmul" and am.ext_in_words == 64 * 32
    assert [(e.src, e.dst) for e in g.edges] == [(0, 1)]
    # fully fused physical truth: wq weights + x read by q + x re-read by
    # the actmul + the (64, 64) output write
    fused = M.bandwidth_ref(g, np.zeros(1, bool))
    assert fused == q_node.weight_words + 64 * 32 + 64 * 32 + 64 * 64


def test_join_of_two_raw_inputs_counts_both_frames():
    """a + b with a, b two graph inputs: the join is a source reading one
    frame via in_words; the second frame lands in ext_in_words —
    previously the op folded away and a frame read vanished."""

    def fn(w, ab):
        a, b = ab
        return jax.lax.conv_general_dilated(
            a + b, w, (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )

    frame = 8 * 8 * 8
    g = F.trace(fn, _sds(3, 3, 8, 8), (_sds(1, 8, 8, 8), _sds(1, 8, 8, 8)))
    join, conv = g.nodes
    assert join.kind == "elementwise" and join.ext_in_words == frame
    assert g.source_mask[0]  # the join is the graph's source
    w_words = conv.weight_words
    # fused: both input frames in, one output frame out
    assert M.bandwidth_ref(g, np.zeros(1, bool)) == w_words + 3 * frame
    # cut: + join's frame write and the conv's read-back
    assert M.bandwidth_ref(g, np.ones(1, bool)) == w_words + 5 * frame


def test_rectangular_spatial_reduce_raises():
    """A reduction the IR cannot represent must raise, not silently fold
    (folding would emit producer frames that disagree with edge words)."""

    def fn(w, x):
        h = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return jnp.mean(h, axis=(1, 2))

    with pytest.raises(ValueError, match="not representable"):
        F.trace(fn, _sds(3, 3, 8, 8), _sds(1, 8, 4, 8))


def test_square_global_mean_maps_to_pool():
    def fn(w, x):
        h = jax.lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        return jnp.mean(h, axis=(1, 2))

    g = F.trace(fn, _sds(3, 3, 8, 8), _sds(1, 8, 8, 8))
    assert [n.kind for n in g.nodes] == ["conv", "pool"]
    pool = g.nodes[1]
    assert (pool.kh, pool.kw, pool.stride) == (8, 8, 8)
    assert pool.out_words == 8  # (1, 1, C)


def test_conv_with_activation_kernel_raises():
    """conv(weights, activation-as-kernel) must raise, not silently drop
    the layer (activation products belong to dot_general/actmul)."""

    def fn(w, x):
        return jax.lax.conv_general_dilated(
            w, x, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )

    with pytest.raises(ValueError, match="activation kernel"):
        F.trace(fn, _sds(1, 8, 8, 4), _sds(3, 3, 4, 4))


def test_traced_graph_runs_batched_evaluator():
    """End-to-end: trace -> enumerate -> batched evaluator == oracle."""

    def fn(params, x):
        h = jax.lax.conv_general_dilated(
            x, params["wd"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=8,
        )
        h = jax.nn.relu(h + params["bd"])
        y = jax.lax.conv_general_dilated(
            h, params["wp"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        return x + y  # residual join

    params = {"wd": _sds(3, 3, 1, 8), "bd": _sds(8), "wp": _sds(1, 1, 8, 8)}
    g = F.trace(fn, params, _sds(1, 8, 8, 8))
    assert [n.kind for n in g.nodes] == ["conv", "conv", "elementwise"]
    assert g.nodes[0].groups == 8
    # the join re-reads the raw input x in every grouping (no producer edge)
    frame = 8 * 8 * 8
    assert g.nodes[2].ext_in_words == frame
    cuts = fusion.enumerate_valid_edge_cuts(g)
    bw = M.bandwidth_batch_graph(g, cuts)
    for i in range(cuts.shape[0]):
        assert bw[i] == M.bandwidth_ref(g, cuts[i])
    # physical truth, layer-by-layer: dw reads x + writes h; pw reads h
    # (cut edge) + writes y; join reads y (cut edge) + re-reads x + writes
    weights = g.nodes[0].weight_words + g.nodes[1].weight_words
    lbl = M.bandwidth_ref(g, fusion.layer_by_layer_cuts(g))
    assert lbl == weights + 7 * frame
    # fully fused: x read once by dw, re-read by the join, one output write
    assert M.bandwidth_ref(g, np.zeros(g.n_edges, bool)) == weights + 3 * frame
