"""The paper's Sec. III experiment: optimisation flow on VGG-16.

Locks in the calibrated reproduction (see DESIGN.md §calibration and
EXPERIMENTS.md): optimal config (4,4,4,4) hsiao; fusion reductions
BW 60.2% / latency 37.7% / energy 40.6% (paper: 55.6 / 36.7 / 49.2);
layer-by-layer violates the paper's 65 mJ + 12 M-cycle constraints while
fusion meets all four.
"""
import numpy as np
import pytest

from repro.core.arch import (
    Constraints, DLAConfig, PAPER_CONSTRAINTS, PAPER_OPTIMAL_CONFIG,
    paper_config_space,
)
from repro.core.flow import compare_fusion, run_flow
from repro.core.ir import vgg16_ir


@pytest.fixture(scope="module")
def vgg():
    return vgg16_ir(pool_mode="separate")


def test_optimal_config_is_4444_hsiao(vgg):
    res = run_flow(
        vgg, config_space=paper_config_space(),
        constraints=PAPER_CONSTRAINTS, groupings="pool",
    )
    assert res.best_hw == PAPER_OPTIMAL_CONFIG
    assert res.best_metrics.meets(PAPER_CONSTRAINTS)


def test_fusion_reductions_match_calibration(vgg):
    cmp = compare_fusion(vgg, PAPER_OPTIMAL_CONFIG)
    assert cmp.bw_reduction == pytest.approx(0.602, abs=0.005)
    assert cmp.latency_reduction == pytest.approx(0.377, abs=0.005)
    assert cmp.energy_reduction == pytest.approx(0.406, abs=0.005)
    # within 10 pp of the paper's published numbers under-determined by it
    assert abs(cmp.bw_reduction - 0.556) < 0.10
    assert abs(cmp.latency_reduction - 0.367) < 0.10
    assert abs(cmp.energy_reduction - 0.492) < 0.10


def test_lbl_violates_constraints_fusion_meets(vgg):
    cmp = compare_fusion(vgg, PAPER_OPTIMAL_CONFIG)
    assert not cmp.lbl.meets(PAPER_CONSTRAINTS)
    assert cmp.fused.meets(PAPER_CONSTRAINTS)
    assert cmp.lbl.latency_cycles > 12e6
    assert cmp.lbl.energy_nj > 65e6
    assert cmp.fused.bandwidth_words < 20e6


def test_infeasible_points_of_predefined_set(vgg):
    # (2,2,2,2) latency-bound; (16,16,16,16) area-bound; VWA energy-bound.
    for cfgs, should_fail in [
        ([DLAConfig("hsiao", 2, 2, 2, 2)], True),
        ([DLAConfig("hsiao", 16, 16, 16, 16)], True),
        ([DLAConfig("vwa", 8, 8, 3, 8)], True),
        ([DLAConfig("hsiao", 8, 8, 8, 8)], False),
    ]:
        if should_fail:
            with pytest.raises(ValueError):
                run_flow(vgg, config_space=cfgs,
                         constraints=PAPER_CONSTRAINTS, groupings="pool")
        else:
            run_flow(vgg, config_space=cfgs,
                     constraints=PAPER_CONSTRAINTS, groupings="pool")


def test_exhaustive_grouping_beats_pool_heuristic(vgg):
    """Beyond-paper: the evaluator finds groupings better than the paper's
    pool-boundary policy under the same constraints."""
    pool = run_flow(vgg, config_space=[PAPER_OPTIMAL_CONFIG],
                    constraints=PAPER_CONSTRAINTS, groupings="pool")
    exh = run_flow(vgg, config_space=[PAPER_OPTIMAL_CONFIG],
                   constraints=PAPER_CONSTRAINTS, groupings="exhaustive")
    assert exh.best_metrics.energy_nj <= pool.best_metrics.energy_nj
    assert exh.best_metrics.bandwidth_words < pool.best_metrics.bandwidth_words


def test_flow_sweep_is_vectorised(vgg):
    res = run_flow(vgg, constraints=PAPER_CONSTRAINTS, groupings="pool")
    # default space: 256 hsiao + 64 vwa configs x 2 groupings (pool, lbl)
    assert res.n_candidates == 320 * 2
    assert res.candidates_per_second > 100
