"""Frontend <-> hand-builder lock-step.

Since the tracing frontend landed, ``repro.core.ir.vgg16_ir`` and
``resnet18_ir`` are thin wrappers over tracing the real JAX models.  The
oracles here are *verbatim transcriptions of the pre-frontend hand-built
constructions* (repo convention: a regression in the tracer cannot hide
behind both paths changing together) — the traced graphs must reproduce
them node-and-edge-identically, and the fusion search must return identical
best cuts on both.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import frontend as F
from repro.core import fusion, metrics as M
from repro.core.arch import PAPER_OPTIMAL_CONFIG
from repro.core.flow import run_flow
from repro.core.ir import (
    RESNET18_STAGE_PLAN,
    VGG16_CONV_PLAN,
    EdgeSpec,
    GraphIR,
    LayerSpec,
    NetworkIR,
    as_graph,
    resnet18_ir,
    vgg16_ir,
)


# ---------------------------------------------------------------------------
# Verbatim transcriptions of the pre-frontend hand builders (the oracles)
# ---------------------------------------------------------------------------


def _vgg16_ir_handbuilt(*, pool_mode="separate", include_fc=False) -> NetworkIR:
    if pool_mode not in ("separate", "absorbed"):
        raise ValueError(pool_mode)
    layers = []
    for name, n_in, n_out, hw, pooled in VGG16_CONV_PLAN:
        if pooled and pool_mode == "absorbed":
            layers.append(
                LayerSpec(name, "conv", n_in, n_out, hw, hw, 3, 3, 1, pool_after=2)
            )
        else:
            layers.append(LayerSpec(name, "conv", n_in, n_out, hw, hw, 3, 3, 1))
            if pooled:
                layers.append(
                    LayerSpec(f"pool{name[4]}", "pool", n_out, n_out, hw, hw, 2, 2, 2)
                )
    if include_fc:
        layers.append(LayerSpec("fc6", "fc", 512 * 7 * 7, 4096, 1, 1))
        layers.append(LayerSpec("fc7", "fc", 4096, 4096, 1, 1))
        layers.append(LayerSpec("fc8", "fc", 4096, 1000, 1, 1))
    return NetworkIR("vgg16", tuple(layers))


def _resnet18_ir_handbuilt(*, input_hw=224) -> GraphIR:
    nodes, edges = [], []

    def add_node(spec):
        nodes.append(spec)
        return len(nodes) - 1

    def connect(src, dst, words=None):
        edges.append(
            EdgeSpec(src, dst, nodes[src].out_words if words is None else words)
        )

    conv1 = add_node(LayerSpec("conv1", "conv", 3, 64, input_hw, input_hw, 7, 7, 2))
    pool1 = add_node(
        LayerSpec("pool1", "pool", 64, 64, input_hw // 2, input_hw // 2, 3, 3, 2)
    )
    connect(conv1, pool1)
    cur = pool1
    c_in = 64
    hw_cur = input_hw // 4
    for stage, n_blocks, c_out, stride0 in RESNET18_STAGE_PLAN:
        for b in range(n_blocks):
            stride = stride0 if b == 0 else 1
            cin_blk = c_in if b == 0 else c_out
            tag = f"s{stage}b{b}"
            ca = add_node(
                LayerSpec(f"{tag}.conv_a", "conv", cin_blk, c_out, hw_cur, hw_cur, 3, 3, stride)
            )
            connect(cur, ca)
            hw_out = hw_cur // stride
            cb = add_node(
                LayerSpec(f"{tag}.conv_b", "conv", c_out, c_out, hw_out, hw_out, 3, 3, 1)
            )
            connect(ca, cb)
            if stride != 1 or cin_blk != c_out:
                ds = add_node(
                    LayerSpec(f"{tag}.downsample", "conv", cin_blk, c_out, hw_cur, hw_cur, 1, 1, stride)
                )
                connect(cur, ds)
                skip = ds
            else:
                skip = cur
            add = add_node(
                LayerSpec(f"{tag}.add", "elementwise", c_out, c_out, hw_out, hw_out)
            )
            connect(cb, add)
            connect(skip, add)
            cur = add
            hw_cur = hw_out
        c_in = c_out
    gap = add_node(
        LayerSpec("avgpool", "pool", 512, 512, hw_cur, hw_cur, hw_cur, hw_cur, hw_cur)
    )
    connect(cur, gap)
    fc = add_node(LayerSpec("fc", "fc", 512, 1000, 1, 1))
    connect(gap, fc)
    return GraphIR("resnet18", tuple(nodes), tuple(edges))


def _anon(g: GraphIR) -> GraphIR:
    """Strip node names (the only field the raw tracer cannot know)."""
    return GraphIR(
        g.name,
        tuple(dataclasses.replace(n, name=f"n{i}") for i, n in enumerate(g.nodes)),
        g.edges,
    )


# ---------------------------------------------------------------------------
# Traced == hand-built (nodes, edges, buffer sizes)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "kw",
    [
        {"pool_mode": "separate"},
        {"pool_mode": "absorbed"},
        {"pool_mode": "separate", "include_fc": True},
        {"pool_mode": "absorbed", "include_fc": True},
    ],
    ids=lambda kw: "-".join(f"{k}={v}" for k, v in kw.items()),
)
def test_traced_vgg16_equals_handbuilt(kw):
    assert vgg16_ir(**kw) == _vgg16_ir_handbuilt(**kw)


def test_traced_resnet18_equals_handbuilt():
    g, h = resnet18_ir(), _resnet18_ir_handbuilt()
    assert g.nodes == h.nodes
    assert g.edges == h.edges
    assert g == h


def test_raw_trace_of_vgg_forward_matches_as_graph():
    """``frontend.trace(model)`` with *no* renaming reproduces
    ``as_graph(vgg16_ir(...))`` — structure, edges and every buffer-relevant
    field — so the frontend needs zero per-model knowledge."""
    import jax
    import jax.numpy as jnp

    from repro.models import vgg

    g = F.trace(
        vgg.forward,
        vgg.param_specs(),
        jax.ShapeDtypeStruct((1, 224, 224, 3), jnp.float32),
        name="vgg16",
    )
    hand = as_graph(_vgg16_ir_handbuilt(pool_mode="separate", include_fc=True))
    assert _anon(g) == _anon(hand)


def test_traced_buffer_sizes_identical():
    g, h = resnet18_ir(), _resnet18_ir_handbuilt()
    rng = np.random.default_rng(0)
    for _ in range(10):
        cuts = rng.random(g.n_edges) < 0.5
        assert M.buffer_words_ref(g, cuts) == M.buffer_words_ref(h, cuts)
        assert M.bandwidth_ref(g, cuts) == M.bandwidth_ref(h, cuts)


# ---------------------------------------------------------------------------
# Fusion search parity on traced vs hand-built IRs
# ---------------------------------------------------------------------------


def test_optimal_cuts_identical_on_traced_vgg():
    a = fusion.optimal_cuts(vgg16_ir(pool_mode="separate"))
    b = fusion.optimal_cuts(_vgg16_ir_handbuilt(pool_mode="separate"))
    np.testing.assert_array_equal(a.cuts, b.cuts)
    assert a.group_cost_words == b.group_cost_words
    assert a.n_groups == b.n_groups


def test_optimal_cuts_identical_on_traced_resnet18():
    a = fusion.optimal_cuts(resnet18_ir())
    b = fusion.optimal_cuts(_resnet18_ir_handbuilt())
    np.testing.assert_array_equal(a.cuts, b.cuts)
    assert a.group_cost_words == b.group_cost_words


# ---------------------------------------------------------------------------
# Previously unrepresentable workloads through the full flow
# ---------------------------------------------------------------------------

SMALL_PLAN = ((32, 16, 1, 1), (16, 16, 1, 4))  # stem + 2 blocks, one skip


def test_mobilenet_graph_structure():
    g = F.mobilenet_graph(input_hw=56, plan=SMALL_PLAN)
    names = [n.name for n in g.nodes]
    assert names == [
        "stem", "b0.dw", "b0.project",
        "b1.expand", "b1.dw", "b1.project", "b1.add",
    ]
    dw = {n.name: n for n in g.nodes}
    # depthwise: groups == channels, one kernel per channel
    assert dw["b0.dw"].groups == 32 and dw["b0.dw"].weight_words == 9 * 32
    assert dw["b1.dw"].groups == 64 and dw["b1.dw"].contracted_channels == 1
    # the stride-1 bottleneck contributes a residual join
    add = names.index("b1.add")
    assert len(g.predecessors(add)) == 2
    assert not g.is_chain


def test_mobilenet_flow_batched_equals_scalar():
    g = F.mobilenet_graph(input_hw=56, plan=SMALL_PLAN)
    best = fusion.brute_force_min_bw(g)
    best_scalar = fusion._brute_force_min_bw_scalar(g)
    np.testing.assert_array_equal(best.cuts, best_scalar.cuts)
    beam = fusion.beam_merge_cuts(g)
    beam_scalar = fusion._beam_merge_cuts_scalar(g)
    np.testing.assert_array_equal(beam.cuts, beam_scalar.cuts)
    res = run_flow(g, groupings="search")
    assert res.best_metrics.bandwidth_words > 0
    assert res.n_feasible >= 1


def test_mlp_block_graph_structure_and_flow():
    g = F.mlp_block_graph(d_model=128, d_ff=512, seq_len=64, act="swiglu")
    names = [n.name for n in g.nodes]
    assert names == ["mlp.w1", "mlp.w3", "mlp.gate", "mlp.w2"]
    assert [n.kind for n in g.nodes] == ["matmul", "matmul", "elementwise", "matmul"]
    assert [(e.src, e.dst) for e in g.edges] == [(0, 2), (1, 2), (2, 3)]
    # both gate operands are (seq, d_ff) activations
    assert all(e.words == 64 * 512 for e in g.edges[:2])
    best = fusion.brute_force_min_bw(g)
    best_scalar = fusion._brute_force_min_bw_scalar(g)
    np.testing.assert_array_equal(best.cuts, best_scalar.cuts)
    res = run_flow(g, groupings="search")
    assert res.n_feasible >= 1
    # fusing the whole gated block keeps both d_ff-wide operands on chip
    lbl = M.bandwidth_ref(g, fusion.layer_by_layer_cuts(g))
    assert M.bandwidth_ref(g, best.cuts) < lbl


def test_mlp_block_graph_ungated_is_chain():
    g = F.mlp_block_graph(d_model=128, d_ff=512, seq_len=64, act="gelu")
    assert [n.name for n in g.nodes] == ["mlp.w1", "mlp.w2"]
    assert g.is_chain


# ---------------------------------------------------------------------------
# Tracer guard rails
# ---------------------------------------------------------------------------


def test_trace_rejects_batch_gt_one():
    import jax
    import jax.numpy as jnp

    from repro.models import vgg

    with pytest.raises(ValueError, match="batch size 1"):
        F.trace(
            vgg.forward,
            vgg.param_specs(),
            jax.ShapeDtypeStruct((2, 224, 224, 3), jnp.float32),
        )


def test_trace_rejects_valid_padding_geometry():
    import jax
    import jax.numpy as jnp

    def fn(w, x):
        return jax.lax.conv_general_dilated(
            x, w, (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )

    with pytest.raises(ValueError, match="SAME-padding"):
        F.trace(
            fn,
            jax.ShapeDtypeStruct((3, 3, 8, 8), jnp.float32),
            jax.ShapeDtypeStruct((1, 16, 16, 8), jnp.float32),
        )


def test_fold_pool_requires_window_eq_stride_and_conv_producer():
    """ResNet traces identically with fold_pool: its 3x3/2 max pool has
    window != stride and its global avg pool follows an elementwise add,
    so neither can be absorbed into a conv's inline pool unit."""
    import jax
    import jax.numpy as jnp

    from repro.models import resnet

    g = F.trace(
        resnet.forward,
        resnet.param_specs(),
        jax.ShapeDtypeStruct((1, 224, 224, 3), jnp.float32),
        name="resnet18",
        fold_pool=True,
    )
    assert _anon(g) == _anon(_resnet18_ir_handbuilt())
    assert [n.kind for n in g.nodes].count("pool") == 2


def test_rename_nodes_length_checked():
    g = F.mlp_block_graph()
    with pytest.raises(ValueError, match="names"):
        F.rename_nodes(g, ["a", "b"])


def test_traced_mobilenet_runs_paper_flow_end_to_end():
    """The full paper flow (Sec. II-C) on a traced depthwise workload."""
    g = F.mobilenet_graph()  # default 5-block plan, 18 edges
    res = run_flow(g, groupings="search", sram_budget_words=2**20)
    assert res.n_pruned >= 0 and res.n_feasible >= 1
    cmp_lbl = M.bandwidth_ref(g, fusion.layer_by_layer_cuts(g))
    assert res.best_metrics.bandwidth_words < cmp_lbl
