"""Fault-injection harness: corrupt graphs, chaos sweep, cache storms.

The headline test drives >= 500 mixed requests — valid workloads
interleaved with corrupt graphs, NaN budgets, expired deadlines,
impossible constraints — through a PlanningService under active fault
injection (transient sweep failures, search stalls, executable-cache
eviction storms) and asserts the service contract: every request gets
exactly one TYPED response (zero raw exceptions), and every non-degraded
successful plan is bit-identical to the offline ``run_fleet`` answer.
"""
import collections

import numpy as np
import pytest

from repro.core import flow, service
from repro.core.arch import paper_config_space
from repro.core.errors import EvaluatorError, GraphValidationError
from repro.core.service import PlanRequest, PlanningService
from repro.testing import faults as F

SPACE = paper_config_space()


# ---------------------------------------------------------------------------
# corrupt-graph builders: admission must catch every one
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("builder", F.CORRUPTIONS,
                         ids=lambda b: b.__name__)
def test_corruption_caught_by_revalidation(builder):
    g = F._valid_graphs()[1]
    bad = builder(g)
    with pytest.raises(GraphValidationError):
        bad.validate()
    # and through the service boundary: a typed response, not a raise
    resp = PlanningService(config_space=SPACE).plan(PlanRequest(graph=bad))
    assert not resp.ok and isinstance(resp.error, GraphValidationError)


def test_corruption_messages_name_the_offender():
    g = F._valid_graphs()[0]
    with pytest.raises(GraphValidationError, match="cyclic|topological"):
        F.corrupt_graph_cyclic(g).validate()
    with pytest.raises(GraphValidationError, match="words"):
        F.corrupt_graph_negative_words(g).validate()
    with pytest.raises(GraphValidationError, match="out of range"):
        F.corrupt_graph_dangling(g).validate()
    with pytest.raises(GraphValidationError, match="duplicate"):
        F.corrupt_graph_duplicate_edge(g).validate()


# ---------------------------------------------------------------------------
# injector mechanics
# ---------------------------------------------------------------------------


def test_eviction_storm_clears_executable_cache():
    flow.clear_sweep_cache()
    svc = PlanningService(
        config_space=SPACE, faults=F.FaultInjector(evict_every=1),
        backoff_seconds=0.0,
    )
    r = svc.plan(PlanRequest(graph=F._valid_graphs()[0]))
    assert r.ok
    # the storm fired before the sweep, so this tick recompiled from zero
    assert svc.faults.counts["evict_storms"] >= 1


def test_stall_trips_deadline():
    inj = F.FaultInjector(stall_every=1, stall_seconds=0.05)
    svc = PlanningService(config_space=SPACE, faults=inj,
                          backoff_seconds=0.0)
    r = svc.plan(PlanRequest(graph=F._valid_graphs()[0],
                             deadline_seconds=0.02))
    assert not r.ok
    from repro.core.errors import DeadlineExceeded

    assert isinstance(r.error, DeadlineExceeded)
    assert inj.counts["stalls"] == 1


# ---------------------------------------------------------------------------
# the chaos sweep
# ---------------------------------------------------------------------------


def test_chaos_sweep_500_requests_all_typed():
    n = 500
    inj = F.FaultInjector(
        transient_every=11,  # recurring transient sweep failures
        stall_every=97, stall_seconds=0.001,  # occasional search stalls
        evict_every=7,  # periodic executable-cache eviction storms
    )
    svc = PlanningService(
        config_space=SPACE, faults=inj, backoff_seconds=0.0,
        max_batch=16, max_queue_depth=n,
    )

    labels = {}
    for label, req in F.chaos_requests(n, seed=7):
        labels[svc.submit(req)] = label
    svc.drain()

    outcomes = collections.Counter()
    ok_exact = []  # (graph, budget, response) for bit-identity audit
    for rid, label in labels.items():
        resp = svc.collect(rid)
        assert resp is not None, f"request {rid} ({label}) got no response"
        if resp.ok:
            outcomes[f"{label}:ok"] += 1
            if not resp.degraded and not resp.from_cache:
                ok_exact.append((rid, resp))
        else:
            # the whole point: EVERY failure is a typed evaluator error
            assert isinstance(resp.error, EvaluatorError), (
                f"request {rid} ({label}) leaked "
                f"{type(resp.error).__name__}"
            )
            outcomes[f"{label}:{resp.error_type}"] += 1

    # hostile inputs were actually exercised, and valid ones succeeded
    assert sum(v for k, v in outcomes.items() if k.startswith("valid:")) > 0
    assert any(":GraphValidationError" in k for k in outcomes)
    assert any(":DeadlineExceeded" in k for k in outcomes)
    assert inj.counts["injected_transients"] > 0
    assert inj.counts["evict_storms"] > 0

    # bit-identity audit: sample non-degraded plans against offline
    by_key = {}
    for rid, resp in ok_exact:
        req = _REQUESTS_BY_RID[rid]
        by_key.setdefault(
            (req.graph, req.sram_budget_words), resp
        )
    for (g, budget), resp in list(by_key.items())[:12]:
        ref = flow.run_fleet(
            [g], config_space=SPACE, groupings="search",
            sram_budget_words=budget,
        ).results[0]
        assert np.array_equal(resp.plan.best_cuts, ref.best_cuts)
        assert resp.plan.best_metrics == ref.best_metrics
        assert resp.plan.best_hw == ref.best_hw


def test_chaos_sweep_with_active_shard_faults_all_typed():
    """The chunked-sweep variant of the chaos sweep: recurring shard
    failures inside the chunk loop (salvaged by the shared RetryPolicy)
    on top of the transient/stall/eviction faults.  The contract is the
    same — 100% typed responses, zero raw exceptions."""
    n = 200
    inj = F.FaultInjector(
        shard_fail_every=13,  # recurring chunk-compute shard failures
        transient_every=17,
        evict_every=11,
    )
    svc = PlanningService(
        config_space=SPACE, faults=inj, backoff_seconds=0.0,
        hw_chunk=5, max_batch=8, max_queue_depth=n,
    )
    labels = {}
    for label, req in F.chaos_requests(n, seed=13):
        labels[svc.submit(req)] = label
    svc.drain()
    ok = 0
    for rid, label in labels.items():
        resp = svc.collect(rid)
        assert resp is not None, f"request {rid} ({label}) got no response"
        if resp.ok:
            ok += 1
        else:
            assert isinstance(resp.error, EvaluatorError), (
                f"request {rid} ({label}) leaked "
                f"{type(resp.error).__name__}"
            )
    assert ok > 0
    # the shard-fault path was genuinely exercised and salvaged
    assert inj.counts["injected_shard_failures"] > 0
    assert inj.counts["chunk_computes"] > 0


# chaos_requests yields the request objects; the audit above needs them
# back by rid, so the test records them here as it submits.
_REQUESTS_BY_RID = {}


@pytest.fixture(autouse=True)
def _capture_requests(monkeypatch):
    _REQUESTS_BY_RID.clear()
    orig = PlanningService.submit

    def recording_submit(self, request):
        rid = orig(self, request)
        _REQUESTS_BY_RID[rid] = request
        return rid

    monkeypatch.setattr(PlanningService, "submit", recording_submit)
    yield
    _REQUESTS_BY_RID.clear()
