"""End-to-end integration: losses go down; VGG runs; serve generates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.data import TokenStream
from repro.models import vgg as VGG
from repro.optim import AdamWConfig, init_opt_state
from repro.runtime.steps import make_init, make_train_step


def run_training(cfg, steps=40, batch=8, seq=32, lr=3e-3):
    rc = RunConfig(xent_chunk=16, attn_chunk_kv=16, mamba_chunk=8,
                   learning_rate=lr, warmup_steps=4)
    init = make_init(cfg, rc)
    params, opt = init(jax.random.key(0))
    step = jax.jit(make_train_step(cfg, rc))
    stream = TokenStream(cfg, batch, seq, seed=0)
    losses = []
    for _ in range(steps):
        _, b = next(stream)
        b = jax.tree.map(jnp.asarray, b)
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    stream.close()
    return losses


def test_dense_lm_learns():
    cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      dtype="float32")
    losses = run_training(cfg)
    assert losses[-1] < losses[0] - 0.3, losses[::8]


def test_moe_lm_learns():
    cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      n_experts=4, top_k=2, moe_every=2, moe_offset=1,
                      moe_group_size=16, dtype="float32")
    losses = run_training(cfg)
    assert losses[-1] < losses[0] - 0.3, losses[::8]


def test_mamba_lm_learns():
    cfg = ModelConfig(name="s", family="ssm", n_layers=2, d_model=64,
                      n_heads=1, n_kv_heads=1, d_ff=0,
                      layer_pattern=("mamba",), vocab_size=256, ssm_state=8,
                      ssm_dt_rank=4, dtype="float32")
    losses = run_training(cfg)
    assert losses[-1] < losses[0] - 0.3, losses[::8]


def test_vgg_forward_and_loss_step():
    params = VGG.init_params(jax.random.key(0), in_hw=32, n_classes=10)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32, 3))
    logits = VGG.forward(params, x)
    assert logits.shape == (2, 10)
    batch = {"images": x, "labels": jnp.array([1, 7])}
    loss, grads = jax.value_and_grad(VGG.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gnorm > 0


def test_vgg_fused_kernel_path_matches_xla():
    from repro.kernels.ops import fused_conv_fn

    params = VGG.init_params(jax.random.key(2), in_hw=32, n_classes=10)
    x = jax.random.normal(jax.random.key(3), (1, 32, 32, 3))
    ref = VGG.forward(params, x)
    fused = VGG.forward(params, x, fused_conv_fn=fused_conv_fn())
    np.testing.assert_allclose(np.asarray(ref), np.asarray(fused),
                               atol=5e-2, rtol=5e-2)


def test_serve_driver_end_to_end():
    from repro.launch.serve import main

    gen = main(["--arch", "qwen3", "--requests", "2", "--prompt-len", "8",
                "--gen", "4"])
    assert gen.shape == (2, 4)
