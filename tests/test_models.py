"""Model-stack correctness: attention paths, mamba paths, MoE routing,
cache consistency (prefill+decode == uncached forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.models import model as M
from repro.models import moe as MOE
from repro.models import ssm as SSM

RC = RunConfig(xent_chunk=16, attn_chunk_kv=16, mamba_chunk=8)


def test_attention_chunked_matches_reference():
    key = jax.random.key(0)
    B, S, H, KV, hd = 2, 64, 8, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.key(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.key(2), (B, S, KV, hd))
    pos = jnp.arange(S)
    for mixer, w, c in [("attn", 0, 0), ("attn_local", 16, 0),
                        ("attn_chunked", 0, 16)]:
        r = L.attention_reference(q, k, v, q_pos=pos, kv_pos=pos, mixer=mixer,
                                  window=w, chunk=c)
        ch = L.attention_chunked(q, k, v, q_pos=pos, kv_pos=pos, mixer=mixer,
                                 window=w, chunk=c, kv_block=16)
        np.testing.assert_allclose(np.asarray(r), np.asarray(ch), atol=1e-5)


def test_attention_decode_matches_reference():
    key = jax.random.key(3)
    B, S, H, KV, hd = 2, 32, 4, 2, 16
    q = jax.random.normal(key, (B, 1, H, hd))
    k = jax.random.normal(jax.random.key(4), (B, S, KV, hd))
    v = jax.random.normal(jax.random.key(5), (B, S, KV, hd))
    pos = jnp.arange(S)
    r = L.attention_reference(q, k, v, q_pos=jnp.array([20]), kv_pos=pos,
                              kv_len=21)
    d = L.attention_chunked(q, k, v, q_pos=jnp.array([20]), kv_pos=pos,
                            kv_len=21)
    np.testing.assert_allclose(np.asarray(r), np.asarray(d), atol=1e-5)


def test_gqa_equals_repeated_mha():
    """GQA with KV repeated must equal MHA on the repeated heads."""
    key = jax.random.key(6)
    B, S, H, KV, hd = 1, 32, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.key(7), (B, S, KV, hd))
    v = jax.random.normal(jax.random.key(8), (B, S, KV, hd))
    pos = jnp.arange(S)
    gqa = L.attention_reference(q, k, v, q_pos=pos, kv_pos=pos)
    k_rep = L.repeat_kv(k, H)
    v_rep = L.repeat_kv(v, H)
    mha = L.attention_reference(q, k_rep, v_rep, q_pos=pos, kv_pos=pos)
    np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha), atol=1e-6)


def test_rope_relative_property():
    """RoPE: <q_i, k_j> depends only on (i - j)."""
    key = jax.random.key(9)
    q = jax.random.normal(key, (1, 1, 1, 32))
    k = jax.random.normal(jax.random.key(10), (1, 1, 1, 32))
    def dot_at(pi, pj):
        qr = L.apply_rope(q, jnp.array([pi]), 1e4)
        kr = L.apply_rope(k, jnp.array([pj]), 1e4)
        return float(jnp.sum(qr * kr))
    assert dot_at(5, 3) == pytest.approx(dot_at(10, 8), abs=1e-4)
    assert dot_at(7, 0) == pytest.approx(dot_at(17, 10), abs=1e-4)


def test_mamba_chunked_matches_reference():
    cfg = ModelConfig(name="s", family="ssm", n_layers=1, d_model=32,
                      n_heads=1, n_kv_heads=1, d_ff=0,
                      layer_pattern=("mamba",), vocab_size=64, ssm_state=8,
                      ssm_dt_rank=4, dtype="float32")
    p = SSM.init_mamba(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 32, 32))
    y_ref, _ = SSM.mamba_block(p, x, cfg, impl="reference")
    y_chk, _ = SSM.mamba_block(p, x, cfg, impl="chunked", chunk=8)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_chk),
                               atol=1e-4, rtol=1e-4)


def test_mamba_decode_matches_full():
    """Step-by-step decode with state cache == full-sequence scan."""
    cfg = ModelConfig(name="s", family="ssm", n_layers=1, d_model=16,
                      n_heads=1, n_kv_heads=1, d_ff=0,
                      layer_pattern=("mamba",), vocab_size=64, ssm_state=4,
                      ssm_dt_rank=4, dtype="float32")
    p = SSM.init_mamba(jax.random.key(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (1, 8, 16))
    y_full, _ = SSM.mamba_block(p, x, cfg, impl="reference")
    cache = SSM.init_mamba_cache(cfg, 1, jnp.float32)
    ys = []
    for t in range(8):
        y_t, cache = SSM.mamba_block(p, x[:, t : t + 1], cfg, cache)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=1e-4, rtol=1e-4)


def test_moe_routing_capacity_and_gates():
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      n_experts=4, top_k=2, moe_group_size=16,
                      capacity_factor=1.0, dtype="float32")
    p = MOE.init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32))
    y, aux = MOE.moe_block(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound is 1


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor >= E/k every token must be routed (no drops):
    output should differ from zero for all tokens."""
    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=64,
                      n_experts=2, top_k=1, moe_group_size=8,
                      capacity_factor=2.0, dtype="float32")
    p = MOE.init_moe(jax.random.key(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(3), (1, 8, 16))
    y, _ = MOE.moe_block(p, x, cfg)
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(norms.min()) > 0.0


@pytest.mark.parametrize("family_kw", [
    dict(name="d", family="dense"),
    dict(name="loc", family="dense",
         layer_pattern=("attn_local", "attn"), window_size=8, qk_norm=True),
    dict(name="moe", family="moe", n_experts=4, top_k=2, moe_every=2,
         moe_offset=1, moe_group_size=16, dense_residual_ff=32),
    dict(name="hyb", family="hybrid", layer_pattern=("mamba", "attn"),
         ssm_state=8, ssm_dt_rank=4, n_layers=4),
    dict(name="ssm", family="ssm", layer_pattern=("mamba",), d_ff=0,
         ssm_state=8, ssm_dt_rank=4),
    dict(name="vlm", family="vlm", frontend="vision", frontend_len=8),
    dict(name="aud", family="audio", is_encoder_decoder=True,
         n_enc_layers=2, frontend="audio", frontend_len=8, ffn_act="gelu"),
])
def test_prefill_decode_consistency(family_kw):
    """prefill(cache) last-position logits == uncached forward logits."""
    base = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                vocab_size=128, dtype="float32")
    base.update(family_kw)
    cfg = ModelConfig(**base)
    key = jax.random.key(11)
    params = M.init_params(key, cfg)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(key, (B, cfg.frontend_len,
                                                    cfg.d_model))
    h, _, _ = M.forward(params, cfg, RC, batch)
    head = params["embed"].T
    ref_logits = (h[:, -1:, :] @ head).astype(jnp.float32)
    cache = M.init_cache(cfg, B, 32)
    logits, cache = M.prefill(params, cfg, RC, batch, cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    logits2, cache = M.decode(params, cfg, RC, tok, cache)
    assert np.isfinite(np.asarray(logits2)).all()
    assert int(cache["len"]) == (S if cfg.is_encoder_decoder
                                 else S + (cfg.frontend_len if cfg.frontend else 0)) + 1


def test_decode_matches_teacher_forcing():
    """Decoding token-by-token == forward over the same full sequence."""
    cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32")
    key = jax.random.key(12)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 12), 0, 64)
    h_full, _, _ = M.forward(params, cfg, RC, {"tokens": toks})
    full_logits = (h_full @ params["embed"].T).astype(jnp.float32)
    cache = M.init_cache(cfg, 1, 16)
    logits_p, cache = M.prefill(params, cfg, RC, {"tokens": toks[:, :8]}, cache)
    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(full_logits[:, 7]), atol=1e-4)
    for t in range(8, 12):
        logits_d, cache = M.decode(params, cfg, RC, toks[:, t : t + 1], cache)
        np.testing.assert_allclose(np.asarray(logits_d[:, -1]),
                                   np.asarray(full_logits[:, t]), atol=1e-4)


def test_chunked_xent_matches_dense():
    key = jax.random.key(13)
    B, S, d, V = 2, 32, 16, 64
    h = jax.random.normal(key, (B, S, d))
    w = jax.random.normal(jax.random.key(14), (d, V)) * 0.3
    labels = jax.random.randint(jax.random.key(15), (B, S), 0, V)
    mask = labels > 4
    got = L.chunked_cross_entropy(h, w, labels, chunk=8, mask=mask)
    logits = (h @ w).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
    expect = (nll * mask).sum() / mask.sum()
    assert float(got) == pytest.approx(float(expect), rel=1e-5)
