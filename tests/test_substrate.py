"""Data pipeline, checkpointing, optimizer, fault tolerance."""
import dataclasses
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as CKPT
from repro.configs.base import ModelConfig, RunConfig
from repro.data import TokenStream, make_batch
from repro.models import model as M
from repro.optim import AdamWConfig, adamw_update, init_opt_state
from repro.runtime.fault_tolerance import ResilientTrainer, flaky
from repro.runtime.steps import make_init, make_train_step

TINY = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                   dtype="float32")
RC = RunConfig(xent_chunk=16, attn_chunk_kv=16, learning_rate=2e-3,
               warmup_steps=2)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_step_keyed():
    b1 = make_batch(TINY, 4, 32, seed=7, step=3)
    b2 = make_batch(TINY, 4, 32, seed=7, step=3)
    b3 = make_batch(TINY, 4, 32, seed=7, step=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_labels_are_next_tokens():
    b = make_batch(TINY, 2, 16, seed=0, step=0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_host_sharding_partitions():
    full = make_batch(TINY, 8, 16, seed=1, step=5, host=0, n_hosts=1)
    h0 = make_batch(TINY, 8, 16, seed=1, step=5, host=0, n_hosts=2)
    h1 = make_batch(TINY, 8, 16, seed=1, step=5, host=1, n_hosts=2)
    assert h0["tokens"].shape[0] == 4 and h1["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_stream_prefetch_and_replay():
    s = TokenStream(TINY, 4, 16, seed=3)
    step0, b0 = next(s)
    step1, b1 = next(s)
    assert (step0, step1) == (0, 1)
    replay = s.batch_at(0)
    np.testing.assert_array_equal(b0["tokens"], replay["tokens"])
    s.close()


def test_vlm_batch_masks_prefix():
    cfg = dataclasses.replace(TINY, frontend="vision", frontend_len=4)
    b = make_batch(cfg, 2, 16, seed=0, step=0)
    assert b["frontend"].shape == (2, 4, 32)
    assert (b["labels"][:, :4] == -1).all()
    assert b["tokens"].shape == (2, 12)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([3.0, -2.0])}
    cfg = AdamWConfig(weight_decay=0.0, grad_clip=1e9)
    state = init_opt_state(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, lr=0.05, cfg=cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_grad_clip():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    state = init_opt_state(params, cfg)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    _, state2, gnorm = adamw_update(g, state, params, lr=0.1, cfg=cfg)
    assert float(gnorm) == pytest.approx(1e6)
    # post-clip first moment bounded by (1-b1) * clip
    assert float(jnp.abs(state2["m"]["w"]).max()) <= 0.11


def test_adamw_bf16_state_roundtrip():
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    cfg = AdamWConfig(state_dtype="bfloat16")
    state = init_opt_state(params, cfg)
    assert state["m"]["w"].dtype == jnp.bfloat16
    g = {"w": jnp.full(4, 0.5, jnp.bfloat16)}
    p2, s2, _ = adamw_update(g, state, params, lr=0.01, cfg=cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["v"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6).reshape(2, 3), "b": [np.ones(2), np.zeros(1)]}
    CKPT.save(tmp_path, 5, tree, extra={"loss": 1.5})
    assert CKPT.latest_step(tmp_path) == 5
    back, extra = CKPT.restore(tmp_path, 5, like=tree)
    np.testing.assert_array_equal(back["a"], tree["a"])
    np.testing.assert_array_equal(back["b"][0], tree["b"][0])
    assert extra["loss"] == 1.5


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": np.arange(10.0)}
    path = CKPT.save(tmp_path, 1, tree)
    # flip bytes in the array file
    npz = path / "arrays.npz"
    data = bytearray(npz.read_bytes())
    data[-20] ^= 0xFF
    npz.write_bytes(bytes(data))
    with pytest.raises(Exception):
        CKPT.restore(tmp_path, 1, like=tree)


def test_checkpoint_latest_and_atomicity(tmp_path):
    tree = {"a": np.ones(3)}
    CKPT.save(tmp_path, 1, tree)
    CKPT.save(tmp_path, 2, tree)
    (tmp_path / "step_00000003.tmp").mkdir()  # simulated crashed save
    assert CKPT.latest_step(tmp_path) == 2


def test_async_checkpointer(tmp_path):
    ck = CKPT.AsyncCheckpointer(tmp_path)
    ck.submit(7, {"x": jnp.arange(4.0)})
    ck.wait()
    assert ck.last_saved == 7
    back, _ = CKPT.restore(tmp_path, 7, like={"x": np.zeros(4)})
    np.testing.assert_array_equal(back["x"], np.arange(4.0))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def _trainer(tmp_path, hook=None, ckpt_every=5):
    init = make_init(TINY, RC)
    params, opt = init(jax.random.key(0))
    stream = TokenStream(TINY, 4, 32, seed=0)
    step = jax.jit(make_train_step(TINY, RC))
    tr = ResilientTrainer(train_step=step, stream=stream,
                          ckpt_dir=tmp_path, ckpt_every=ckpt_every,
                          failure_hook=hook)
    return tr, params, opt, stream


def test_trainer_runs_and_learns(tmp_path):
    tr, params, opt, stream = _trainer(tmp_path)
    params, opt = tr.run(params, opt, 25)
    stream.close()
    assert tr.report.steps_run == 25
    assert tr.report.last_loss < tr.report.losses[0]
    assert CKPT.latest_step(tmp_path) is not None


def test_trainer_recovers_from_failures(tmp_path):
    hook = flaky({7, 13})
    tr, params, opt, stream = _trainer(tmp_path, hook=hook, ckpt_every=4)
    params, opt = tr.run(params, opt, 20)
    stream.close()
    assert tr.report.failures == 2
    assert tr.report.restores == 2
    assert tr.report.last_loss < tr.report.losses[0]
    hb = pathlib.Path(tmp_path) / "heartbeat.json"
    assert hb.exists()


def test_failure_replay_is_deterministic(tmp_path):
    """A run that fails and restores from checkpoint converges to the same
    loss as a clean run: restore + counter-based data replay is bit-exact."""
    tr1, p1, o1, s1 = _trainer(tmp_path / "clean", ckpt_every=5)
    tr1.run(p1, o1, 12)
    s1.close()
    hook = flaky({9})  # fails after the step-4 checkpoint exists
    tr2, p2, o2, s2 = _trainer(tmp_path / "flaky", hook=hook, ckpt_every=5)
    tr2.run(p2, o2, 12)
    s2.close()
    assert tr2.report.restores == 1
    assert tr2.report.last_loss == pytest.approx(tr1.report.last_loss, rel=1e-5)
