"""Graph IR: chain bit-identity, DAG builders, vectorised/oracle lockstep.

The chain-equivalence tests compare the graph path against *independent
re-implementations of the pre-refactor chain formulas* (copied verbatim from
the seed's metrics.py), so a regression in the edge-cut semantics cannot
hide behind both paths changing together.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fusion, metrics as M
from repro.core.arch import DLAConfig, PAPER_OPTIMAL_CONFIG
from repro.core.ir import (
    EdgeSpec, GraphIR, LayerSpec, NetworkIR, as_graph, encoder_decoder_ir,
    residual_block_ir, resnet18_ir, transformer_block_ir, vgg16_ir,
)

HW = DLAConfig("hsiao", 4, 4, 4, 4)


# ---------------------------------------------------------------------------
# Pre-refactor chain oracles (verbatim transcriptions of the seed formulas)
# ---------------------------------------------------------------------------


def legacy_bandwidth(ir: NetworkIR, cuts) -> float:
    start, end = M.group_masks(cuts)
    bw = 0.0
    for i, l in enumerate(ir.layers):
        bw += l.weight_words
        if start[i]:
            bw += l.in_words
        if end[i]:
            bw += l.out_words
    return bw


def legacy_latency(ir: NetworkIR, cuts, hw) -> float:
    start, end = M.group_masks(cuts)
    lat = 0.0
    for i, l in enumerate(ir.layers):
        lat += l.weight_words / hw.dram_words_per_cycle
        lat += hw.pe_busy_cycles(
            macs=l.macs, n_in=l.n_in, n_out=l.n_out, kh=l.kh, kw=l.kw,
            pixels_out=(l.h_in // l.stride) * (l.w_in // l.stride),
        )
        lat += hw.pipeline_latency
        if start[i]:
            lat += l.in_words / hw.dram_words_per_cycle
        if end[i]:
            lat += l.out_words / hw.dram_words_per_cycle
    return lat


def random_chain(rng, n=6):
    layers = []
    hw = int(rng.choice([8, 16, 32]))
    c = int(rng.choice([3, 8, 16]))
    for i in range(n):
        cout = int(rng.choice([8, 16, 32]))
        layers.append(LayerSpec(f"l{i}", "conv", c, cout, hw, hw, 3, 3, 1))
        c = cout
    return NetworkIR("rand", tuple(layers))


CHAIN_NETWORKS = [
    vgg16_ir(pool_mode="separate"),
    vgg16_ir(pool_mode="absorbed"),
    transformer_block_ir(name="blk", d_model=256, n_heads=4, n_kv_heads=2,
                         d_ff=512, seq_len=128),
]


@pytest.mark.parametrize("ir", CHAIN_NETWORKS, ids=lambda ir: ir.name)
def test_chain_bandwidth_latency_bit_identical_via_graph(ir):
    rng = np.random.default_rng(0)
    L = len(ir)
    for _ in range(25):
        cuts = rng.random(L - 1) < 0.5
        assert M.bandwidth_ref(ir, cuts) == legacy_bandwidth(ir, cuts)
        assert M.bandwidth_ref(as_graph(ir), cuts) == legacy_bandwidth(ir, cuts)
        assert M.latency_ref(ir, cuts, HW) == legacy_latency(ir, cuts, HW)
        assert M.energy_ref(ir, cuts, HW) == (
            HW.e_dram_nj * legacy_bandwidth(ir, cuts)
            + HW.e_sram_nj * M.sram_accesses_ref(ir)
            + HW.e_pb_nj * M.pe_energy_count_ref(ir, HW)
        )


def test_vgg_calibrated_numbers_via_graph_path():
    """The paper table (calibration: 60.2/37.7/40.6 vs paper 55.6/36.7/49.2)
    must survive the graph refactor unchanged, evaluated on the GraphIR."""
    from repro.core.flow import compare_fusion

    g = as_graph(vgg16_ir(pool_mode="separate"))
    cmp = compare_fusion(g, PAPER_OPTIMAL_CONFIG)
    assert cmp.bw_reduction == pytest.approx(0.602, abs=0.005)
    assert cmp.latency_reduction == pytest.approx(0.377, abs=0.005)
    assert cmp.energy_reduction == pytest.approx(0.406, abs=0.005)


@pytest.mark.parametrize("seed", range(3))
def test_chain_wrapper_equals_graph_batch(seed):
    rng = np.random.default_rng(seed)
    ir = random_chain(rng)
    g = as_graph(ir)
    feat = ir.feature_matrix()
    np.testing.assert_array_equal(feat, g.node_features())
    cuts_batch = fusion.enumerate_cuts(len(ir))
    hw_rows = np.stack([HW.as_row()])
    consts = jnp.asarray(M.area_consts_of(HW))
    via_chain = np.asarray(
        M.evaluate_batch(jnp.asarray(feat), jnp.asarray(cuts_batch),
                         jnp.asarray(hw_rows), consts)
    )
    esrc, edst, ewords = g.edge_arrays()
    via_graph = np.asarray(
        M.evaluate_batch_graph(
            jnp.asarray(feat), jnp.asarray(esrc), jnp.asarray(edst),
            jnp.asarray(ewords), jnp.asarray(g.source_mask),
            jnp.asarray(g.sink_mask), jnp.asarray(cuts_batch),
            jnp.asarray(hw_rows), consts,
        )
    )
    np.testing.assert_array_equal(via_chain, via_graph)


def random_dag(rng, n):
    """Random connected DAG with conv nodes and producer-sized edges."""
    nodes = []
    for i in range(n):
        c = int(rng.choice([4, 8, 16]))
        co = int(rng.choice([4, 8, 16]))
        nodes.append(LayerSpec(f"n{i}", "conv", c, co, 16, 16, 3, 3, 1))
    edges = []
    for i in range(1, n):
        src = int(rng.integers(0, i))  # keep it connected
        edges.append(EdgeSpec(src, i, nodes[src].out_words))
    extra = int(rng.integers(0, n))
    for _ in range(extra):
        a, b = sorted(rng.choice(n, size=2, replace=False))
        if all((e.src, e.dst) != (a, b) for e in edges):
            edges.append(EdgeSpec(int(a), int(b), nodes[a].out_words))
    return GraphIR("dag", tuple(nodes), tuple(edges))


@pytest.mark.parametrize("seed", range(4))
def test_vectorised_matches_reference_on_dags(seed):
    rng = np.random.default_rng(seed)
    g = random_dag(rng, int(rng.integers(4, 9)))
    cuts_batch = fusion.enumerate_valid_edge_cuts(g)
    hw_space = [
        DLAConfig("hsiao", 4, 4, 4, 4),
        DLAConfig("vwa", 8, 8, 3, 8),
    ]
    hw_rows = np.stack([c.as_row() for c in hw_space])
    esrc, edst, ewords = g.edge_arrays()
    out = np.asarray(
        M.evaluate_batch_graph(
            jnp.asarray(g.node_features()), jnp.asarray(esrc),
            jnp.asarray(edst), jnp.asarray(ewords),
            jnp.asarray(g.source_mask), jnp.asarray(g.sink_mask),
            jnp.asarray(cuts_batch), jnp.asarray(hw_rows),
            jnp.asarray(M.area_consts_of(hw_space[0])),
        )
    )
    for hi, hw in enumerate(hw_space):
        for ci in range(0, cuts_batch.shape[0], 3):  # sample
            ref = M.evaluate_ref(g, cuts_batch[ci], hw)
            got = out[hi, ci]
            np.testing.assert_allclose(got[0], ref.bandwidth_words, rtol=1e-6)
            np.testing.assert_allclose(got[1], ref.latency_cycles, rtol=1e-6)
            np.testing.assert_allclose(got[2], ref.energy_nj, rtol=1e-6)
            np.testing.assert_allclose(got[3], ref.area_um2, rtol=1e-6)


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


def test_resnet18_structure():
    g = resnet18_ir()
    assert not g.is_chain
    assert g.source_mask.sum() == 1 and g.sink_mask.sum() == 1
    assert all(e.src < e.dst for e in g.edges)
    # 8 basic blocks -> 8 skip edges on top of the sequential spine.
    n_adds = sum(1 for n in g.nodes if n.kind == "elementwise")
    assert n_adds == 8
    assert g.n_edges == len(g.nodes) - 1 + n_adds
    # Published ResNet-18 conv+fc MAC count at 224x224 is ~1.81 G.
    assert abs(g.total_macs - 1.814e9) / 1.814e9 < 0.01


def test_resnet18_fusion_saves_skip_roundtrip():
    """Fusing a whole residual block keeps the skip tensor on-chip — a
    grouping the chain IR cannot express (its best still cuts the skip)."""
    rb = residual_block_ir()
    lbl = M.bandwidth_ref(rb, fusion.layer_by_layer_cuts(rb))
    dag = fusion.brute_force_min_bw(rb)
    dag_bw = M.bandwidth_ref(rb, dag.cuts)
    skip_idx = next(k for k, e in enumerate(rb.edges) if (e.src, e.dst) == (0, 3))
    chain_bw = min(
        M.bandwidth_ref(rb, c)
        for c in fusion.enumerate_valid_edge_cuts(rb)
        if c[skip_idx]
    )
    assert dag_bw < chain_bw < lbl
    # Cutting the skip forces node 0's frame to DRAM: one write plus one
    # read per consumer (conv_a and add) = 3 frames vs the fused optimum.
    skip_words = rb.nodes[0].out_words
    assert chain_bw - dag_bw == pytest.approx(3 * skip_words)


def test_encoder_decoder_structure_and_metrics():
    g = encoder_decoder_ir(d_model=128, n_heads=4, d_ff=256, seq_enc=64,
                           seq_dec=32)
    assert not g.is_chain
    assert all(e.src < e.dst for e in g.edges)
    # The cross-attention K/V projection consumes the encoder memory.
    names = [n.name for n in g.nodes]
    xkv = names.index("encdec.dec.xkv")
    mem = names.index("encdec.enc.w2")
    assert mem in g.predecessors(xkv)
    # Full fusion beats layer-by-layer; metrics are finite and positive.
    full = np.zeros(g.n_edges, dtype=bool)
    lbl = fusion.layer_by_layer_cuts(g)
    assert M.bandwidth_ref(g, full) < M.bandwidth_ref(g, lbl)
    m = M.evaluate_ref(g, g.pool_boundary_cuts(), HW)
    assert m.bandwidth_words > 0 and np.isfinite(m.latency_cycles)


def test_pool_boundary_cuts_chain_vs_graph():
    ir = vgg16_ir(pool_mode="separate")
    np.testing.assert_array_equal(
        ir.pool_boundary_cuts(), as_graph(ir).pool_boundary_cuts()
    )


def test_graph_validation():
    l = LayerSpec("l", "conv", 4, 4, 8, 8, 3, 3, 1)
    with pytest.raises(ValueError):
        EdgeSpec(2, 1, 10)  # non-topological
    with pytest.raises(ValueError):
        EdgeSpec(0, 1, 0)  # empty tensor
    with pytest.raises(ValueError):
        GraphIR("g", (l, l), (EdgeSpec(0, 1, 8), EdgeSpec(0, 1, 8)))  # dup
    with pytest.raises(ValueError):
        GraphIR("g", (l,), (EdgeSpec(0, 1, 8),))  # dst out of range


# ---------------------------------------------------------------------------
# Pre-pool buffer sizing (satellite regression)
# ---------------------------------------------------------------------------


def test_absorbed_pool_intermediate_uses_prepool_frame():
    """With pool_after > 1 the fused intermediate is the *pre-pool* frame;
    sizing it post-pool undersized SRAM by pool_after^2 (= 4x for 2x2).

    Isolate the pooled conv1_2: group {conv1_2, conv2_1} (all other edges
    cut) makes conv1_2 the only internal producer."""
    ir = vgg16_ir(pool_mode="absorbed")
    pooled = ir.layers[1]
    assert pooled.pool_after == 2 and pooled.name == "conv1_2"
    cuts = np.ones(len(ir) - 1, dtype=bool)
    cuts[1] = False  # fuse conv1_2 -> conv2_1
    _, _, of_need = M.buffer_words_ref(ir, cuts)
    assert of_need == pooled.out_words_prepool
    assert pooled.out_words_prepool == 4 * pooled.out_words  # 2x2 pool
    feat = ir.feature_matrix()
    assert fusion.group_max_intermediate(feat, cuts) == pooled.out_words_prepool
    g = as_graph(ir)
    assert fusion.graph_max_intermediate(g, cuts) == pooled.out_words_prepool


def test_prepool_affects_area_not_bandwidth():
    ir = vgg16_ir(pool_mode="absorbed")
    cuts = np.ones(len(ir) - 1, dtype=bool)
    cuts[1] = False  # fuse conv1_2 (pooled) -> conv2_1
    # Bandwidth/latency/energy only see post-pool DRAM frames.
    assert M.bandwidth_ref(ir, cuts) == legacy_bandwidth(ir, cuts)
    assert M.latency_ref(ir, cuts, HW) == legacy_latency(ir, cuts, HW)
    # Area must reflect the larger pre-pool intermediate.
    if_w, w_w, _ = M.buffer_words_ref(ir, cuts)
    post = ir.layers[1].out_words  # what the old sizing would have used
    undersized = HW.area_um2(if_sram_words=if_w, w_sram_words=w_w,
                             of_sram_words=post)
    assert M.area_ref(ir, cuts, HW) > undersized
