"""Async transport, cooperative cancellation, circuit breaker, shadow
audit, and affinity batching (PR 9 robustness layer).

What must hold:

* the async transport serves the same bit-identical plans as the
  synchronous service it wraps, and drains on shutdown so every accepted
  future resolves — including when unwinding from Ctrl-C;
* a cancellation landing mid-sweep stops the chunked fleet program within
  ONE ``hw_chunk`` boundary (asserted via an injected per-chunk stall,
  counting chunks swept after the cancel);
* the circuit breaker walks CLOSED -> OPEN (degraded floor plans while
  open) -> HALF_OPEN probe -> CLOSED, and a failed probe re-opens it;
* the shadow audit passes clean runs silently (zero mismatches across a
  chaos stream) and converts an injected oracle divergence into a typed
  ``AuditMismatch`` answer;
* the lock-guarded plan cache reports stats in the same shape as
  ``flow.sweep_cache_stats()``.
"""
import struct
import threading
import time

import numpy as np
import pytest

from repro.core import flow
from repro.core.arch import paper_config_space
from repro.core.service import (
    AsyncPlanningService,
    BreakerState,
    PlanRequest,
    PlanningService,
)
from repro.core.ir import as_graph, residual_block_ir
from repro.core import frontend
from repro.testing.faults import FaultInjector, chaos_requests

SPACE = tuple(paper_config_space())

MLP = as_graph(frontend.mlp_block_graph())
RES = as_graph(residual_block_ir())


def _bits(x: float) -> bytes:
    return struct.pack("<d", float(x))


class FakeClock:
    """Injectable monotonic clock for deterministic breaker timing."""

    def __init__(self, t: float = 1_000.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _wait_until(pred, timeout=30.0, interval=0.005):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False


# ---------------------------------------------------------------------------
# async transport
# ---------------------------------------------------------------------------


def test_async_serves_bit_identical_to_sync():
    req = PlanRequest(graph=RES, sram_budget_words=2e6)
    sync = PlanningService(config_space=SPACE, backoff_seconds=0.0)
    want = sync.plan(req)
    assert want.ok
    with AsyncPlanningService(
        config_space=SPACE, backoff_seconds=0.0
    ) as svc:
        got = svc.plan(req, timeout=120)
    assert got.ok and not got.degraded
    assert got.plan.best_hw == want.plan.best_hw
    assert np.array_equal(got.plan.best_cuts, want.plan.best_cuts)
    for f in ("bandwidth_words", "latency_cycles", "energy_nj", "area_um2"):
        assert _bits(getattr(got.plan.best_metrics, f)) == _bits(
            getattr(want.plan.best_metrics, f))


def test_async_drain_on_shutdown_resolves_every_future():
    svc = AsyncPlanningService(config_space=SPACE, backoff_seconds=0.0)
    futs = [
        svc.submit(PlanRequest(graph=[MLP, RES][i % 2]))
        for i in range(6)
    ]
    svc.shutdown(drain=True, timeout=120)
    assert all(f.done() for f in futs)
    assert all(f.result().ok for f in futs)
    with pytest.raises(RuntimeError):
        svc.submit(PlanRequest(graph=MLP))


def test_async_context_manager_drains_like_ctrl_c():
    """__exit__ drains even when unwinding from an exception — the
    KeyboardInterrupt path examples/serve_lm.py relies on."""
    futs = []
    with pytest.raises(KeyboardInterrupt):
        with AsyncPlanningService(
            config_space=SPACE, backoff_seconds=0.0
        ) as svc:
            futs = [svc.submit(PlanRequest(graph=MLP)) for _ in range(3)]
            raise KeyboardInterrupt
    assert all(f.done() for f in futs)
    assert all(f.result().ok for f in futs)


def test_async_shutdown_without_drain_cancels_pending():
    inj = FaultInjector(chunk_stall_seconds=0.05)
    svc = AsyncPlanningService(
        config_space=SPACE, backoff_seconds=0.0, hw_chunk=2, faults=inj)
    # distinct budgets = distinct affinity keys: one request per tick, so
    # the tail is still queued when the worker reaches the stop branch
    futs = [
        svc.submit(PlanRequest(graph=RES, sram_budget_words=b))
        for b in (float("inf"), 2e6, 1e6)
    ]
    assert _wait_until(lambda: inj.counts["chunks"] >= 1)
    svc.shutdown(drain=False, timeout=120)
    assert all(f.done() for f in futs)
    outcomes = {f.result().error_type for f in futs}
    assert "RequestCancelled" in outcomes  # the still-pending tail


def test_async_heartbeat_and_watchdog_observe_a_stalled_sweep(tmp_path):
    beat = tmp_path / "heartbeat"
    ages = []
    inj = FaultInjector(chunk_stall_seconds=0.25)
    svc = AsyncPlanningService(
        config_space=SPACE, backoff_seconds=0.0, hw_chunk=2, faults=inj,
        heartbeat_path=beat, watchdog_seconds=0.05, on_stall=ages.append)
    try:
        resp = svc.plan(PlanRequest(graph=MLP), timeout=120)
        assert resp.ok
        assert beat.exists() and int(beat.read_text().split()[0]) > 0
        # the 4-chunk sweep stalled ~1s with no worker heartbeat: the
        # watchdog must have noticed
        assert ages and max(ages) > 0.05
        assert svc.stats()["transport"]["stalls"] >= 1
    finally:
        svc.shutdown(drain=True, timeout=120)


# ---------------------------------------------------------------------------
# cooperative cancellation
# ---------------------------------------------------------------------------


def test_cancel_queued_request_sync():
    svc = PlanningService(config_space=SPACE, backoff_seconds=0.0)
    rid = svc.submit(PlanRequest(graph=MLP))
    assert svc.cancel(rid) is True
    assert svc.cancel(10_000) is False  # unknown id
    svc.drain()
    resp = svc.collect(rid)
    assert resp.error_type == "RequestCancelled"
    # already answered: the (popped) answer stands
    assert svc.cancel(rid) is False


def test_cancel_mid_sweep_stops_within_one_chunk_boundary():
    """THE acceptance assertion: with an injected per-chunk stall, a
    cancel landing mid-sweep is honoured at the next ``hw_chunk``
    boundary — at most one further chunk is swept."""
    inj = FaultInjector(chunk_stall_seconds=0.15)
    svc = AsyncPlanningService(
        config_space=SPACE, backoff_seconds=0.0, hw_chunk=2, faults=inj)
    try:
        fut = svc.submit(PlanRequest(graph=RES))
        # wait until the chunked sweep is provably in flight
        assert _wait_until(lambda: inj.counts["chunks"] >= 1)
        chunks_at_cancel = inj.counts["chunks"]
        t0 = time.monotonic()
        assert svc.cancel(fut) is True
        resp = fut.result(timeout=120)
        cancel_latency = time.monotonic() - t0
        assert resp.error_type == "RequestCancelled"
        # the in-progress chunk finishes, the NEXT boundary aborts; +2
        # absorbs a boundary crossed between reading the counter and
        # flagging the cancel
        assert inj.counts["chunks"] <= chunks_at_cancel + 2
        # 8 configs / hw_chunk=2 = 4 chunks at 0.15s each: honoring the
        # cancel at a boundary is far cheaper than finishing the sweep
        assert cancel_latency < 2.0
        assert svc.stats()["counters"]["cancelled_in_sweep"] == 1
    finally:
        svc.shutdown(drain=True, timeout=120)


def test_deadline_enforced_at_chunk_boundary():
    """A deadline expiring mid-sweep is honoured the same way a cancel
    is: the chunked program stops at the next boundary with a typed
    DeadlineExceeded, never a silently late answer."""
    clock = FakeClock()
    inj = FaultInjector(chunk_stall_seconds=0.0)

    real_before_chunk = inj.before_chunk

    def stall_then_expire():
        real_before_chunk()
        if inj.counts["chunks"] == 2:
            clock.advance(100.0)  # the deadline dies between chunks

    inj.before_chunk = stall_then_expire
    svc = PlanningService(
        config_space=SPACE, backoff_seconds=0.0, hw_chunk=2, faults=inj,
        clock=clock)
    rid = svc.submit(PlanRequest(graph=RES, deadline_seconds=50.0))
    svc.drain()
    resp = svc.collect(rid)
    assert resp.error_type == "DeadlineExceeded"
    assert inj.counts["chunks"] == 2  # stopped right at the boundary


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------


def _breaker_service(inj, clock, **kw):
    return PlanningService(
        config_space=SPACE, backoff_seconds=0.0, max_retries=0,
        breaker_threshold=2, breaker_cooldown_seconds=10.0,
        faults=inj, clock=clock, **kw)


def test_breaker_full_lifecycle():
    clock = FakeClock()
    inj = FaultInjector(transient_sweeps=2)
    svc = _breaker_service(inj, clock)
    assert svc.breaker_state is BreakerState.CLOSED

    # two consecutive TransientFailure verdicts trip the breaker
    for _ in range(2):
        resp = svc.plan(PlanRequest(graph=MLP))
        assert resp.error_type == "TransientFailure"
    assert svc.breaker_state is BreakerState.OPEN
    assert svc.stats()["breaker"] == "open"
    assert svc.stats()["counters"]["breaker_trips"] == 1

    # while OPEN the ladder is pinned to the lbl floor — and serving that
    # degraded plan does NOT close the breaker
    resp = svc.plan(PlanRequest(graph=MLP))
    assert resp.ok and resp.degraded and resp.rung == "lbl"
    assert svc.breaker_state is BreakerState.OPEN

    # cooldown elapses: HALF_OPEN probe runs at full quality and closes
    clock.advance(11.0)
    resp = svc.plan(PlanRequest(graph=RES))
    assert resp.ok and not resp.degraded and resp.rung == "exact"
    assert svc.breaker_state is BreakerState.CLOSED
    assert svc.stats()["counters"]["breaker_closes"] == 1


def test_breaker_failed_probe_reopens():
    clock = FakeClock()
    inj = FaultInjector(transient_sweeps=2)
    svc = _breaker_service(inj, clock)
    for _ in range(2):
        svc.plan(PlanRequest(graph=MLP))
    assert svc.breaker_state is BreakerState.OPEN

    clock.advance(11.0)
    inj.transient_sweeps = 1  # the probe itself fails
    resp = svc.plan(PlanRequest(graph=RES))
    assert resp.error_type == "TransientFailure"
    assert svc.breaker_state is BreakerState.OPEN
    assert svc.stats()["counters"]["breaker_trips"] == 2


# ---------------------------------------------------------------------------
# shadow audit
# ---------------------------------------------------------------------------


def test_shadow_audit_clean_run_is_silent():
    svc = PlanningService(
        config_space=SPACE, backoff_seconds=0.0, shadow_audit_rate=1.0)
    resp = svc.plan(PlanRequest(graph=RES, sram_budget_words=2e6))
    assert resp.ok
    counters = svc.stats()["counters"]
    assert counters["audits"] == 1
    assert counters.get("audit_mismatches", 0) == 0


def test_shadow_audit_catches_injected_divergence():
    inj = FaultInjector(corrupt_audit_every=1)
    svc = PlanningService(
        config_space=SPACE, backoff_seconds=0.0, shadow_audit_rate=1.0,
        faults=inj)
    resp = svc.plan(PlanRequest(graph=RES, sram_budget_words=2e6))
    assert not resp.ok and resp.plan is None
    assert resp.error_type == "AuditMismatch"
    counters = svc.stats()["counters"]
    assert counters["audit_mismatches"] == 1
    assert inj.counts["audits_corrupted"] == 1


def test_shadow_audit_zero_mismatches_across_chaos_stream():
    """Acceptance: an uninjected chaos sweep with audit sampling on
    produces ZERO AuditMismatch verdicts."""
    svc = PlanningService(
        config_space=SPACE, backoff_seconds=0.0, shadow_audit_rate=0.25)
    rids = [svc.submit(req) for _, req in chaos_requests(24, seed=3)]
    svc.drain()
    assert all(svc.collect(rid) is not None for rid in rids)
    counters = svc.stats()["counters"]
    assert counters["audits"] >= 1
    assert counters.get("audit_mismatches", 0) == 0


# ---------------------------------------------------------------------------
# affinity batching + plan-cache stats
# ---------------------------------------------------------------------------


def test_affinity_batching_groups_by_key_without_starvation():
    svc = PlanningService(
        config_space=SPACE, backoff_seconds=0.0, affinity_batching=True)
    # interleave two affinity keys (same shape bucket, different budget)
    rids_a, rids_b = [], []
    for _ in range(3):
        rids_a.append(svc.submit(PlanRequest(graph=MLP)))
        rids_b.append(svc.submit(PlanRequest(graph=MLP,
                                             sram_budget_words=2e6)))
    svc.tick()
    # one tick took the FIFO head's whole key group, left the other queued
    assert all(svc.collect(r) is not None for r in rids_a)
    assert all(svc._responses.get(r) is None for r in rids_b)
    assert svc.queue_depth == 3
    assert svc.stats()["counters"]["affinity_batched"] == 2
    svc.tick()  # the other key is the new head: no starvation
    assert all(svc.collect(r) is not None for r in rids_b)


def test_plan_cache_stats_matches_sweep_cache_shape():
    svc = PlanningService(config_space=SPACE, backoff_seconds=0.0)
    assert svc.plan(PlanRequest(graph=RES, sram_budget_words=2e6)).ok
    hit = svc.plan(PlanRequest(graph=RES, sram_budget_words=2e6))
    assert hit.ok and hit.from_cache

    stats = svc.plan_cache_stats()
    assert set(stats) == set(flow.sweep_cache_stats())  # shape parity
    assert stats["size"] == len(stats["entries"]) == 1
    assert stats["entries"][0]["graph"] == RES.name
    assert stats["entries"][0]["engine"]
    assert stats["hits"] == 1 and stats["evictions"] == 0


def test_plan_cache_stats_safe_under_concurrent_reads():
    """The stats reader takes the cache lock: hammer it from a thread
    while the service mutates the LRU — no exceptions, consistent
    snapshots throughout (this deadlocked/corrupted before the lock)."""
    svc = PlanningService(
        config_space=SPACE, backoff_seconds=0.0, plan_cache_capacity=4)
    stop = threading.Event()
    errors = []

    def reader():
        while not stop.is_set():
            try:
                s = svc.plan_cache_stats()
                assert s["size"] == len(s["entries"]) <= 4
            except Exception as e:  # pragma: no cover - the failure mode
                errors.append(e)
                return

    t = threading.Thread(target=reader)
    t.start()
    try:
        for i in range(8):
            svc.plan(PlanRequest(graph=[MLP, RES][i % 2],
                                 sram_budget_words=float(2**i) * 1e4))
    finally:
        stop.set()
        t.join()
    assert not errors
