"""DAG fusion search: validity, greedy/beam vs brute-force edge-cut oracle."""
import numpy as np
import pytest

from repro.core import fusion, metrics as M
from repro.core.arch import Constraints, PAPER_OPTIMAL_CONFIG
from repro.core.flow import compare_fusion, run_flow
from repro.core.ir import (
    EdgeSpec, GraphIR, LayerSpec, as_graph, encoder_decoder_ir,
    residual_block_ir, resnet18_ir, vgg16_ir,
)
from test_graph_ir import random_chain, random_dag

RELAXED = Constraints(max_bandwidth_words=1e12, max_latency_cycles=1e12,
                      max_energy_nj=1e12, max_area_um2=1e12)


# ---------------------------------------------------------------------------
# Cut validity
# ---------------------------------------------------------------------------


def diamond():
    """0 -> 1 -> 2 with a shortcut 0 -> 2 (the minimal convexity testbed)."""
    n = [LayerSpec(f"n{i}", "conv", 4, 4, 8, 8, 3, 3, 1) for i in range(3)]
    e = (EdgeSpec(0, 1, 256), EdgeSpec(1, 2, 256), EdgeSpec(0, 2, 256))
    return GraphIR("diamond", tuple(n), e)


def _cuts_of(g, cut_pairs):
    """Cut vector in the graph's canonical (sorted) edge order."""
    return np.asarray([(e.src, e.dst) in cut_pairs for e in g.edges], bool)


def test_consistency_rejected():
    g = diamond()
    # (0,1) and (1,2) uncut join all three nodes; cutting (0,2) inside that
    # group is inconsistent.
    cuts = fusion.cuts_from_labels(g, np.array([0, 0, 0]))
    assert fusion.is_valid_cuts(g, cuts)
    assert not fusion.is_valid_cuts(g, _cuts_of(g, {(0, 2)}))


def test_convexity_rejected():
    g = diamond()
    # Group {0, 2} via the shortcut, with 1 outside: dataflow leaves the
    # group (0->1) and re-enters (1->2) — the quotient has a 2-cycle.
    non_convex = _cuts_of(g, {(0, 1), (1, 2)})
    assert not fusion.is_valid_cuts(g, non_convex)
    # 5 partitions of a 2-path-diamond are valid: all-cut, all-fused,
    # {01}{2}, {0}{12}, and {0}{1}{2} == all-cut... enumerated exactly:
    valid = fusion.enumerate_valid_edge_cuts(g)
    assert all(fusion.is_valid_cuts(g, c) for c in valid)
    assert len(valid) == 4  # {0}{1}{2}, {0,1}{2}, {0}{1,2}, {0,1,2}


def test_chain_every_cut_vector_valid():
    rng = np.random.default_rng(0)
    ir = random_chain(rng, n=5)
    g = as_graph(ir)
    valid = fusion.enumerate_valid_edge_cuts(g)
    assert valid.shape == (2 ** 4, 4)
    for c in fusion.enumerate_cuts(5):
        assert fusion.is_valid_cuts(g, c)


def test_enumerate_guard():
    rng = np.random.default_rng(1)
    g = resnet18_ir()
    with pytest.raises(ValueError):
        fusion.enumerate_valid_edge_cuts(g)  # 38 edges


# ---------------------------------------------------------------------------
# Search vs brute force (the acceptance-criterion property)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(6))
def test_beam_matches_bruteforce_on_random_dags(seed):
    rng = np.random.default_rng(200 + seed)
    g = random_dag(rng, int(rng.integers(4, 11)))
    feat = g.node_features()
    budget = float(np.median(feat[:, M.F_OUT_PRE]))
    for sram in (float("inf"), budget):
        bf = fusion.brute_force_min_bw(g, sram_budget_words=sram)
        beam = fusion.beam_merge_cuts(g, beam_width=32, sram_budget_words=sram)
        assert beam.group_cost_words == pytest.approx(bf.group_cost_words)
        assert fusion.is_valid_cuts(g, beam.cuts)
        assert fusion.graph_max_intermediate(g, beam.cuts) <= sram
        greedy = fusion.greedy_merge_cuts(g, sram_budget_words=sram)
        assert fusion.is_valid_cuts(g, greedy.cuts)
        assert fusion.graph_max_intermediate(g, greedy.cuts) <= sram
        # Greedy is a heuristic: never better than the oracle, and the beam
        # (which explores a superset of its states) never worse than greedy.
        assert greedy.group_cost_words >= bf.group_cost_words - 1e-9
        assert beam.group_cost_words <= greedy.group_cost_words + 1e-9


@pytest.mark.parametrize("seed", range(4))
def test_optimal_cuts_chain_fast_path_matches_dp(seed):
    rng = np.random.default_rng(300 + seed)
    ir = random_chain(rng, n=int(rng.integers(3, 8)))
    budget = float(np.median([l.out_words_prepool for l in ir.layers]))
    via_graph = fusion.optimal_cuts(as_graph(ir), sram_budget_words=budget)
    via_dp = fusion.optimal_cuts_dp(ir, sram_budget_words=budget)
    assert via_graph.group_cost_words == pytest.approx(via_dp.group_cost_words)
    np.testing.assert_array_equal(via_graph.cuts, via_dp.cuts)
    bf = fusion.brute_force_min_bw(ir, sram_budget_words=budget)
    assert via_graph.group_cost_words == pytest.approx(bf.group_cost_words)


def test_merging_monotone_bandwidth_on_dags():
    """Eq. (1) on graphs: fusing two adjacent groups removes >= 1 store+load
    pair — bandwidth is monotone non-increasing under a valid merge."""
    rng = np.random.default_rng(7)
    for _ in range(5):
        g = random_dag(rng, 7)
        cuts = fusion.layer_by_layer_cuts(g)
        bw = M.bandwidth_ref(g, cuts)
        labels = np.arange(len(g.nodes))
        for e in g.edges:
            merged = np.where(labels == labels[e.dst], labels[e.src], labels)
            mcuts = fusion.cuts_from_labels(g, merged)
            if fusion.is_valid_cuts(g, mcuts):
                assert M.bandwidth_ref(g, mcuts) < bw


# ---------------------------------------------------------------------------
# End-to-end: the acceptance-criterion networks through the full flow
# ---------------------------------------------------------------------------


def test_resnet18_through_flow_and_compare():
    g = resnet18_ir()
    res = run_flow(g, config_space=[PAPER_OPTIMAL_CONFIG],
                   constraints=RELAXED, groupings="search")
    assert res.n_candidates >= 3
    assert res.best_metrics.bandwidth_words > 0
    search = fusion.optimal_cuts(g)
    cmp = compare_fusion(g, PAPER_OPTIMAL_CONFIG, fused_cuts=search.cuts)
    assert cmp.bw_reduction > 0.30  # residual fusion saves real bandwidth
    assert cmp.latency_reduction > 0
    assert cmp.energy_reduction > 0
    # The search grouping must beat the paper's pool-boundary policy, which
    # cannot keep skip tensors on-chip across stage boundaries.
    pool_cmp = compare_fusion(g, PAPER_OPTIMAL_CONFIG)
    assert cmp.bw_reduction >= pool_cmp.bw_reduction


def test_resnet18_under_sram_budget():
    g = resnet18_ir()
    budget = 200_000.0  # words — forces multiple groups
    res = fusion.optimal_cuts(g, sram_budget_words=budget)
    assert res.n_groups > 1
    assert fusion.graph_max_intermediate(g, res.cuts) <= budget
    assert fusion.is_valid_cuts(g, res.cuts)


def test_encoder_decoder_through_flow_and_compare():
    g = encoder_decoder_ir(d_model=256, n_heads=4, d_ff=512, seq_enc=128,
                           seq_dec=64)
    res = run_flow(g, config_space=[PAPER_OPTIMAL_CONFIG],
                   constraints=RELAXED, groupings="search")
    assert res.best_metrics.energy_nj > 0
    cmp = compare_fusion(g, PAPER_OPTIMAL_CONFIG,
                         fused_cuts=fusion.optimal_cuts(g).cuts)
    assert cmp.bw_reduction > 0.30  # cross-attention memory stays on-chip


def test_flow_explicit_cut_batch_on_graph():
    rb = residual_block_ir()
    batch = fusion.enumerate_valid_edge_cuts(rb)
    res = run_flow(rb, config_space=[PAPER_OPTIMAL_CONFIG],
                   constraints=RELAXED, groupings=batch)
    # min-energy == min-bandwidth here (weights fixed): full fusion wins.
    assert res.best_metrics.bandwidth_words == M.bandwidth_ref(
        rb, fusion.brute_force_min_bw(rb).cuts
    )
    assert res.group_sizes == (4,)
