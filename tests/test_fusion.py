"""Fusion-grouping search: DP vs brute force, feasibility, cut encodings."""
import numpy as np
import pytest

from repro.core import fusion, metrics as M
from repro.core.ir import LayerSpec, NetworkIR, vgg16_ir


def random_chain(rng, n):
    layers = []
    c = int(rng.choice([4, 8]))
    hw = 16
    for i in range(n):
        cout = int(rng.choice([4, 8, 16]))
        layers.append(LayerSpec(f"l{i}", "conv", c, cout, hw, hw, 3, 3, 1))
        c = cout
    return NetworkIR("rand", tuple(layers))


@pytest.mark.parametrize("seed", range(6))
def test_dp_matches_bruteforce_unconstrained(seed):
    rng = np.random.default_rng(seed)
    ir = random_chain(rng, int(rng.integers(3, 9)))
    dp = fusion.optimal_cuts_dp(ir)
    bf = fusion.brute_force_min_bw(ir)
    assert dp.group_cost_words == pytest.approx(bf.group_cost_words)


@pytest.mark.parametrize("seed", range(6))
def test_dp_matches_bruteforce_with_sram_budget(seed):
    rng = np.random.default_rng(100 + seed)
    ir = random_chain(rng, int(rng.integers(3, 9)))
    budget = float(np.median([l.out_words for l in ir.layers]))
    try:
        dp = fusion.optimal_cuts_dp(ir, sram_budget_words=budget)
    except ValueError:
        with pytest.raises(ValueError):
            fusion.brute_force_min_bw(ir, sram_budget_words=budget)
        return
    bf = fusion.brute_force_min_bw(ir, sram_budget_words=budget)
    assert dp.group_cost_words == pytest.approx(bf.group_cost_words)
    feat = ir.feature_matrix()
    assert fusion.buffer_feasible(feat, dp.cuts, budget)


def test_cuts_groups_roundtrip():
    rng = np.random.default_rng(0)
    for _ in range(20):
        n = int(rng.integers(2, 12))
        cuts = rng.random(n - 1) < 0.5
        groups = M.groups_from_cuts(cuts)
        back = fusion.cuts_from_groups(groups, n)
        np.testing.assert_array_equal(cuts, back)
        assert sum(len(g) for g in groups) == n


def test_pool_boundary_cuts_vgg():
    ir = vgg16_ir(pool_mode="separate")
    groups = M.groups_from_cuts(ir.pool_boundary_cuts())
    # 5 stages, each ending with its pool layer
    assert len(groups) == 5
    for g in groups:
        assert ir.layers[g[-1]].kind == "pool"


def test_enumerate_cuts_count():
    assert fusion.enumerate_cuts(5).shape == (16, 4)
    assert fusion.enumerate_cuts(1).shape == (1, 0)
    with pytest.raises(ValueError):
        fusion.enumerate_cuts(40)
