"""Hypothesis property: the tracing frontend never leaks raw exceptions.

Random degenerate jax functions — hostile shapes, unsupported primitives,
batch sizes != 1, rank mismatches — must make ``frontend.trace`` either
return a valid :class:`GraphIR` or raise a TYPED error
(:class:`UnsupportedOpError` / :class:`GraphValidationError`), never a raw
``KeyError`` / ``IndexError`` / ``AttributeError`` from inside the tracer.
Deterministic per-op locks live in tests/test_frontend_ops.py (this module
is skipped entirely when hypothesis is absent, per suite convention).
"""
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import frontend as F
from repro.core.errors import GraphValidationError, UnsupportedOpError
from repro.core.ir import GraphIR


def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# A grab-bag of lowerable and non-lowerable computations; the property is
# about the *failure mode*, not which bucket each lands in.
_OPS = {
    "relu": lambda x: jax.nn.relu(x),
    "tanh": lambda x: jnp.tanh(x),  # unsupported elementwise primitive
    "sum": lambda x: jnp.sum(x),  # reduction: not a layer
    "transpose": lambda x: x.T if x.ndim >= 2 else x,
    "sort": lambda x: jnp.sort(x),  # unsupported primitive
    "square": lambda x: x * x,  # self-multiply: odd elementwise arity
    "add_self": lambda x: x + x,
    "reshape": lambda x: x.reshape(-1),
    "slice": lambda x: x[..., :1],
    "cumsum": lambda x: jnp.cumsum(x),
}


@given(
    op_names=st.lists(
        st.sampled_from(sorted(_OPS)), min_size=1, max_size=3
    ),
    shape=st.lists(st.integers(1, 8), min_size=1, max_size=4),
)
@settings(max_examples=60, deadline=None)
def test_trace_failures_are_typed(op_names, shape):
    def fn(x):
        for name in op_names:
            x = _OPS[name](x)
        return x

    try:
        g = F.trace(fn, _sds(*shape), name="fuzz")
    except (UnsupportedOpError, GraphValidationError):
        return  # a typed rejection is a correct outcome
    except (KeyError, IndexError, AttributeError, TypeError,
            AssertionError) as e:  # pragma: no cover - the bug we hunt
        pytest.fail(
            f"trace leaked raw {type(e).__name__} for "
            f"{op_names} @ {shape}: {e}"
        )
    assert isinstance(g, GraphIR)
    g.validate()  # anything traced must satisfy every IR invariant


@given(
    matmul_k=st.integers(1, 16),
    batch=st.integers(1, 4),
    features=st.integers(1, 16),
)
@settings(max_examples=30, deadline=None)
def test_trace_matmul_shapes_are_typed(matmul_k, batch, features):
    """Weight/activation shape mismatches and batch > 1 must come back as
    typed errors (or trace fine), never raw jax/tracer internals."""
    w = _sds(matmul_k, features)
    x = _sds(batch, matmul_k)
    try:
        g = F.trace(lambda w, x: x @ w, w, x, name="fuzz-mm")
    except (UnsupportedOpError, GraphValidationError):
        return
    assert isinstance(g, GraphIR)
    g.validate()
