"""Fault-tolerant fleet sweeps: poison quarantine, shard salvage, and
resumable co-search checkpoints.

The contract under test (docs/RESILIENCE.md):

* a sharded sweep killed at ANY hw-chunk boundary resumes bit-identically
  with exactly-once chunk recomputation (``checkpoint_dir=``);
* an injected NaN/Inf/negative/overflow cell is quarantined with
  (graph, hw, cut) provenance and can never win the argmin or enter a
  Pareto front; only a fully-poisoned graph raises
  :class:`PoisonedResultError`;
* chunk/shard failures are salvaged by the shared :class:`RetryPolicy`;
  a sick mesh degrades to the single-device program bit-identically.
"""
import dataclasses

import numpy as np
import pytest

from repro.checkpoint import SweepCheckpoint, sweep_fingerprint
from repro.core import flow, metrics as M
from repro.core.arch import Constraints, config_space_grid
from repro.core.errors import (
    EvaluatorError,
    GraphValidationError,
    JournalCorrupt,
    PoisonedResultError,
    RetryPolicy,
    TransientFailure,
)
from repro.core.ir import as_graph, residual_block_ir
from repro.core.service import PlanRequest, PlanningService
from repro.runtime.fault_tolerance import StragglerDetector
from repro.testing.faults import FaultInjector, InjectedShardFailure

RELAXED = Constraints(*[float("inf")] * 4)
SMALL_GRID = config_space_grid(
    f1s=(2, 4), f2s=(2, 4), f3s=(2, 4), f4s=(2, 4),
    bus_widths=(2, 4), sram_splits=("unified",),
)  # 32 configs -> 4 chunks of 8
HW_CHUNK = 8
N_CHUNKS = -(-len(SMALL_GRID) // HW_CHUNK)


def _graph():
    return as_graph(residual_block_ir())


def _cut_batch(g):
    """Explicit (C, E) grouping batch with a known candidate order."""
    rng = np.random.default_rng(11)
    rows = [np.ones(g.n_edges, bool), np.zeros(g.n_edges, bool)]
    rows += [rng.random(g.n_edges) < 0.5 for _ in range(4)]
    return np.unique(np.stack(rows), axis=0)


def _run(g, batch, **kw):
    kw.setdefault("config_space", SMALL_GRID)
    kw.setdefault("constraints", RELAXED)
    return flow.run_fleet([g], groupings=[batch], **kw)


def _assert_same_fleet(a, b):
    """Bit-identity of two FleetResults' answers (not their timings)."""
    assert a.n_graphs == b.n_graphs and a.n_candidates == b.n_candidates
    for ra, rb in zip(a.results, b.results):
        assert ra.best_hw == rb.best_hw
        assert np.array_equal(ra.best_cuts, rb.best_cuts)
        assert ra.best_metrics == rb.best_metrics  # exact float equality
        assert ra.group_sizes == rb.group_sizes
        assert ra.n_feasible == rb.n_feasible


def _winner_cell(res, batch, space):
    """(h, c) indices of a FlowResult's argmin in the swept grid."""
    h = next(
        i for i, cfg in enumerate(space)
        if np.array_equal(cfg.as_row(), res.best_hw.as_row())
    )
    c = next(
        i for i in range(batch.shape[0])
        if np.array_equal(batch[i], res.best_cuts)
    )
    return h, c


class _KillSwitch(Exception):
    """The simulated process kill (NOT an EvaluatorError: nothing below
    the test may absorb it)."""


def _killer(n_allowed: int):
    """abort_check that lets ``n_allowed`` boundary checks pass, then
    kills the sweep."""
    calls = {"n": 0}

    def check():
        calls["n"] += 1
        if calls["n"] > n_allowed:
            raise _KillSwitch(f"killed at boundary check {calls['n']}")

    return check


# ---------------------------------------------------------------------------
# RetryPolicy (the one shared retry/backoff implementation)
# ---------------------------------------------------------------------------


def test_retry_policy_delay_schedule_is_capped():
    p = RetryPolicy(max_retries=5, backoff_seconds=0.1, multiplier=2.0,
                    max_backoff_seconds=0.3)
    assert [p.delay(i) for i in range(4)] == [0.1, 0.2, 0.3, 0.3]


def test_retry_policy_validates_knobs():
    for kw in ({"max_retries": -1}, {"backoff_seconds": -0.1},
               {"multiplier": 0.5}, {"max_backoff_seconds": -1.0}):
        with pytest.raises(ValueError):
            RetryPolicy(**kw)


def test_retry_policy_retries_transients_then_succeeds():
    p = RetryPolicy(max_retries=3, backoff_seconds=0.1, multiplier=2.0)
    slept, retried, state = [], [], {"fails": 2}

    def fn():
        if state["fails"]:
            state["fails"] -= 1
            raise RuntimeError("flake")
        return "ok"

    out = p.call(fn, sleep=slept.append,
                 on_retry=lambda a, e: retried.append((a, type(e).__name__)))
    assert out == "ok"
    assert slept == [p.delay(0), p.delay(1)]
    assert retried == [(0, "RuntimeError"), (1, "RuntimeError")]


def test_retry_policy_never_retries_typed_evaluator_errors():
    p = RetryPolicy(max_retries=5, backoff_seconds=1.0)
    slept, calls = [], {"n": 0}

    def fn():
        calls["n"] += 1
        raise GraphValidationError("deterministic verdict")

    with pytest.raises(GraphValidationError):
        p.call(fn, sleep=slept.append)
    assert calls["n"] == 1 and slept == []


def test_retry_policy_exhaustion_is_typed():
    p = RetryPolicy(max_retries=2, backoff_seconds=0.0)

    def fn():
        raise KeyError("persistent")

    with pytest.raises(TransientFailure) as ei:
        p.call(fn, describe="hw chunk 3")
    assert ei.value.attempts == 3
    assert isinstance(ei.value.cause, KeyError)
    assert "hw chunk 3 failed after 3 attempts" in str(ei.value)
    assert isinstance(ei.value, EvaluatorError)


# ---------------------------------------------------------------------------
# StragglerDetector
# ---------------------------------------------------------------------------


def test_straggler_detector_warms_up_then_flags():
    d = StragglerDetector(factor=3.0, min_deadline_s=0.0, min_samples=5)
    for _ in range(4):
        assert d.deadline() == float("inf")  # warm-up: never flags
        d.observe(0.1)
    assert not d.is_straggler(100.0)  # 4 samples: still warming up
    d.observe(0.1)
    assert d.deadline() == pytest.approx(0.3)
    assert d.is_straggler(0.31) and not d.is_straggler(0.29)


def test_straggler_detector_window_is_bounded():
    d = StragglerDetector(window=10)
    for i in range(100):
        d.observe(float(i))
    assert len(d._durations) == 10 and d._durations[0] == 90.0


# ---------------------------------------------------------------------------
# poison_mask / assert_exact_f64 (the finite guard itself)
# ---------------------------------------------------------------------------


def test_poison_mask_flags_each_poison_kind():
    raw = np.ones((2, 3, 5))
    raw[0, 0, 1] = np.nan
    raw[0, 2, 0] = np.inf
    raw[1, 1, 4] = -1.0
    raw[1, 2, 2] = 2.0 ** 60  # beyond f64 integer exactness
    mask = M.poison_mask(raw)
    assert mask.tolist() == [[True, False, True], [False, True, True]]


def test_assert_exact_f64_accepts_exact_and_names_offender():
    M.assert_exact_f64(np.array([0.0, 1.0, 2.0 ** 53]))  # boundary is exact
    for bad in (np.nan, np.inf, -1.0, 1.5, float(2 ** 53) * 2):
        with pytest.raises(GraphValidationError, match="feature table"):
            M.assert_exact_f64(np.array([1.0, bad]))


# ---------------------------------------------------------------------------
# poison quarantine in the sweep
# ---------------------------------------------------------------------------


def test_poisoned_nonwinner_never_perturbs_the_argmin():
    g = _graph()
    batch = _cut_batch(g)
    clean = _run(g, batch)
    h_win, c_win = _winner_cell(clean.results[0], batch, SMALL_GRID)
    h_bad = (h_win + 1) % len(SMALL_GRID)  # poison a NON-winning cell
    faults = FaultInjector(poison_cell=(0, h_bad, c_win))
    r = _run(g, batch, hooks=faults)
    assert faults.counts["poisoned_cells"] == 1
    # selection among the clean cells is unchanged...
    assert r.results[0].best_hw == clean.results[0].best_hw
    assert np.array_equal(r.results[0].best_cuts, clean.results[0].best_cuts)
    assert r.results[0].best_metrics == clean.results[0].best_metrics
    # ...and exactly the poisoned cell left the feasible set
    assert r.results[0].n_feasible == clean.results[0].n_feasible - 1
    assert r.quarantine is not None and r.quarantine.n_cells == 1
    cell = r.quarantine.cells[0]
    assert (cell.graph, cell.hw, cell.cut) == (0, h_bad, c_win)
    assert cell.reason == "nan" and cell.column in flow.RAW_COLUMNS
    assert r.results[0].quarantine.cells == r.quarantine.cells


def test_poisoned_winner_is_quarantined_not_selected():
    g = _graph()
    batch = _cut_batch(g)
    clean = _run(g, batch)
    h_win, c_win = _winner_cell(clean.results[0], batch, SMALL_GRID)
    faults = FaultInjector(poison_cell=(0, h_win, c_win))
    r = _run(g, batch, hooks=faults, pareto=True)
    new_win = _winner_cell(r.results[0], batch, SMALL_GRID)
    assert new_win != (h_win, c_win)  # the poisoned cell cannot win
    assert r.results[0].n_feasible == clean.results[0].n_feasible - 1
    assert "(g=0, h=" in r.quarantine.describe()
    front = r.results[0].pareto
    assert front is not None and np.isfinite(front.metrics).all()


@pytest.mark.parametrize(
    "value,reason",
    [(float("inf"), "inf"), (-1.0, "negative"), (2.0 ** 60, "overflow")],
)
def test_quarantine_names_each_poison_reason(value, reason):
    g = _graph()
    batch = _cut_batch(g)
    faults = FaultInjector(poison_cell=(0, 3, 0), poison_value=value)
    r = _run(g, batch, hooks=faults)
    assert r.quarantine.cells[0].reason == reason
    assert r.quarantine.cells[0].value == value


def test_fully_poisoned_graph_raises_typed_error():
    g = _graph()
    batch = _cut_batch(g)

    class _PoisonEverything:
        def poison_plane(self, plane, h0):
            plane = np.array(plane, copy=True)
            plane[...] = np.nan
            return plane

    with pytest.raises(PoisonedResultError) as ei:
        _run(g, batch, hooks=_PoisonEverything())
    assert ei.value.quarantined  # full per-cell provenance survives
    assert isinstance(ei.value, ArithmeticError)  # dual inheritance
    assert isinstance(ei.value, EvaluatorError)


def test_quarantine_provenance_uses_global_hw_index_across_chunks():
    g = _graph()
    batch = _cut_batch(g)
    h_bad = 2 * HW_CHUNK + 3  # lives in chunk 2 of the chunked sweep
    faults = FaultInjector(poison_cell=(0, h_bad, 1))
    r = _run(g, batch, hw_chunk=HW_CHUNK, hooks=faults)
    assert faults.counts["poisoned_cells"] == 1
    assert r.quarantine.cells[0].hw == h_bad  # global, not chunk-local


# ---------------------------------------------------------------------------
# chunk salvage + mesh degradation
# ---------------------------------------------------------------------------


def test_chunk_failures_are_salvaged_by_retry_policy():
    g = _graph()
    batch = _cut_batch(g)
    clean = _run(g, batch)
    faults = FaultInjector(shard_fail_chunks=2)
    r = _run(
        g, batch, hw_chunk=HW_CHUNK, hooks=faults,
        retry_policy=RetryPolicy(max_retries=3, backoff_seconds=0.0),
    )
    _assert_same_fleet(clean, r)
    assert faults.counts["injected_shard_failures"] == 2
    assert faults.counts["chunk_computes"] == N_CHUNKS + 2  # 2 retries
    assert r.chunks_computed == N_CHUNKS


def test_chunk_retry_exhaustion_is_typed():
    g = _graph()
    batch = _cut_batch(g)
    with pytest.raises(TransientFailure) as ei:
        _run(
            g, batch, hw_chunk=HW_CHUNK,
            hooks=FaultInjector(shard_fail_chunks=100),
            retry_policy=RetryPolicy(max_retries=1, backoff_seconds=0.0),
        )
    assert ei.value.attempts == 2
    assert isinstance(ei.value.cause, InjectedShardFailure)


def test_without_retry_policy_shard_failures_propagate_raw():
    g = _graph()
    batch = _cut_batch(g)
    with pytest.raises(InjectedShardFailure):
        _run(g, batch, hw_chunk=HW_CHUNK,
             hooks=FaultInjector(shard_fail_chunks=1))


def test_sick_mesh_degrades_to_single_device_bit_identically():
    g = _graph()
    batch = _cut_batch(g)
    clean = _run(g, batch)  # the plain single-device program
    # Fail the mesh program through its whole retry budget (2 attempts),
    # then heal: the degraded single-device rung must answer.
    faults = FaultInjector(shard_fail_chunks=2)
    r = _run(
        g, batch, devices=1, hooks=faults,
        retry_policy=RetryPolicy(max_retries=1, backoff_seconds=0.0),
    )
    assert r.mesh_degraded and r.device_count == 1
    assert faults.counts["injected_shard_failures"] == 2  # retry budget
    assert "degraded to single-device" in r.describe()
    _assert_same_fleet(clean, r)


# ---------------------------------------------------------------------------
# SweepCheckpoint (the durable chunk store)
# ---------------------------------------------------------------------------


def test_sweep_checkpoint_roundtrip_is_bit_exact(tmp_path):
    rng = np.random.default_rng(3)
    planes = {0: rng.random((2, 4, 3, 5)), 4: rng.random((2, 4, 3, 5))}
    ck = SweepCheckpoint(tmp_path)
    assert ck.load("fp") == {}
    for h0, p in planes.items():
        ck.append_chunk(h0, p)
    got = SweepCheckpoint(tmp_path).load("fp")
    assert set(got) == {0, 4}
    for h0 in planes:
        assert got[h0].dtype == planes[h0].dtype
        assert got[h0].tobytes() == planes[h0].tobytes()


def test_sweep_checkpoint_requires_load_before_append(tmp_path):
    with pytest.raises(ValueError, match="load"):
        SweepCheckpoint(tmp_path).append_chunk(0, np.ones((1, 1, 1, 5)))


def test_sweep_checkpoint_discards_foreign_fingerprint(tmp_path):
    ck = SweepCheckpoint(tmp_path)
    ck.load("sweep-a")
    ck.append_chunk(0, np.ones((1, 2, 3, 5)))
    other = SweepCheckpoint(tmp_path)
    assert other.load("sweep-b") == {}  # never splice a different sweep
    assert not ck.path.exists()


def test_sweep_checkpoint_tolerates_torn_tail_only(tmp_path):
    ck = SweepCheckpoint(tmp_path)
    ck.load("fp")
    ck.append_chunk(0, np.ones((1, 1, 1, 5)))
    ck.append_chunk(1, np.full((1, 1, 1, 5), 2.0))
    raw = ck.path.read_bytes()
    ck.path.write_bytes(raw[: len(raw) - 40])  # tear the final record
    got = SweepCheckpoint(tmp_path).load("fp")
    assert list(got) == [0]  # the torn chunk simply recomputes
    lines = raw.split(b"\n")
    lines[1] = lines[1].replace(b'"h0": 0', b'"h0": 7')  # interior tamper
    ck.path.write_bytes(b"\n".join(lines))
    with pytest.raises(JournalCorrupt):
        SweepCheckpoint(tmp_path).load("fp")


def test_sweep_fingerprint_binds_every_input():
    a = (np.ones((2, 3)), np.arange(4.0))
    fp = sweep_fingerprint(a, 8)
    assert fp == sweep_fingerprint(tuple(np.copy(x) for x in a), 8)
    assert fp != sweep_fingerprint(a, 4)  # chunking is part of the key
    b = (np.ones((2, 3)), np.arange(4.0) + 1)
    assert fp != sweep_fingerprint(b, 8)


# ---------------------------------------------------------------------------
# resumable checkpoints: kill at EVERY chunk boundary
# ---------------------------------------------------------------------------


def test_checkpoint_dir_requires_hw_chunk():
    with pytest.raises(ValueError, match="hw_chunk"):
        _run(_graph(), _cut_batch(_graph()), checkpoint_dir="/tmp/x")


@pytest.mark.parametrize("kill_at", range(1, N_CHUNKS))
def test_kill_at_every_chunk_boundary_resumes_bit_identically(
    tmp_path, kill_at
):
    g = _graph()
    batch = _cut_batch(g)
    baseline = _run(g, batch, hw_chunk=HW_CHUNK)
    first = FaultInjector()
    with pytest.raises(_KillSwitch):
        _run(g, batch, hw_chunk=HW_CHUNK, checkpoint_dir=tmp_path,
             abort_check=_killer(kill_at), hooks=first)
    # chunks 0..kill_at-1 completed (and are durable) before the kill
    assert first.counts["chunk_computes"] == kill_at
    second = FaultInjector()
    r = _run(g, batch, hw_chunk=HW_CHUNK, checkpoint_dir=tmp_path,
             hooks=second)
    # exactly-once: the resumed run recomputes ONLY the missing chunks
    assert r.chunks_restored == kill_at
    assert r.chunks_computed == N_CHUNKS - kill_at
    assert second.counts["chunk_computes"] == N_CHUNKS - kill_at
    assert f"{kill_at} chunks restored" in r.describe()
    _assert_same_fleet(baseline, r)


def test_completed_checkpoint_resumes_with_zero_recompute(tmp_path):
    g = _graph()
    batch = _cut_batch(g)
    baseline = _run(g, batch, hw_chunk=HW_CHUNK, checkpoint_dir=tmp_path)
    assert baseline.chunks_computed == N_CHUNKS
    again = FaultInjector()
    r = _run(g, batch, hw_chunk=HW_CHUNK, checkpoint_dir=tmp_path,
             hooks=again)
    assert r.chunks_restored == N_CHUNKS and r.chunks_computed == 0
    assert again.counts["chunk_computes"] == 0
    _assert_same_fleet(baseline, r)


def test_checkpoint_from_different_sweep_is_never_spliced(tmp_path):
    g = _graph()
    batch = _cut_batch(g)
    _run(g, batch, hw_chunk=HW_CHUNK, checkpoint_dir=tmp_path)
    tighter = dataclasses.replace(
        RELAXED, max_area_um2=1e12
    )  # different constraints -> same fingerprint (sweep inputs identical)
    r = _run(g, batch, hw_chunk=HW_CHUNK, checkpoint_dir=tmp_path,
             constraints=tighter)
    assert r.chunks_restored == N_CHUNKS  # constraints are post-sweep
    smaller = _cut_batch(g)[:2]  # different sweep inputs -> new fingerprint
    r2 = _run(g, smaller, hw_chunk=HW_CHUNK, checkpoint_dir=tmp_path)
    assert r2.chunks_restored == 0 and r2.chunks_computed == N_CHUNKS
    _assert_same_fleet(_run(g, smaller, hw_chunk=HW_CHUNK), r2)


# ---------------------------------------------------------------------------
# service integration: one RetryPolicy, salvage across request retries
# ---------------------------------------------------------------------------


def test_service_checkpoint_dir_requires_hw_chunk(tmp_path):
    with pytest.raises(ValueError, match="hw_chunk"):
        PlanningService(checkpoint_dir=tmp_path)


def test_service_retry_policy_overrides_legacy_knobs():
    p = RetryPolicy(max_retries=7, backoff_seconds=0.0)
    svc = PlanningService(retry_policy=p)
    assert svc.retry_policy is p
    legacy = PlanningService(max_retries=2, backoff_seconds=0.125)
    assert legacy.retry_policy == RetryPolicy(
        max_retries=2, backoff_seconds=0.125
    )


def test_service_salvages_completed_chunks_across_request_retries(tmp_path):
    g = _graph()

    class _MidSweepCrash:
        """Raises once from the 3rd between-chunk boundary check — AFTER
        two chunks are durable — so the request-level retry must resume
        instead of recomputing."""

        def __init__(self):
            self.chunks = 0
            self.fired = False

        def before_chunk(self):
            self.chunks += 1
            if self.chunks == 3 and not self.fired:
                self.fired = True
                raise InjectedShardFailure("mid-sweep crash")

    faults = _MidSweepCrash()
    svc = PlanningService(
        config_space=SMALL_GRID, hw_chunk=HW_CHUNK,
        checkpoint_dir=tmp_path, faults=faults, backoff_seconds=0.0,
    )
    resp = svc.plan(PlanRequest(graph=g))
    assert resp.ok and faults.fired
    assert svc.stats()["counters"]["transient_retries"] == 1
    ref = flow.run_fleet(
        [g], config_space=SMALL_GRID, groupings="search",
    ).results[0]
    assert resp.plan.best_metrics == ref.best_metrics
    assert np.array_equal(resp.plan.best_cuts, ref.best_cuts)
    assert resp.plan.best_hw == ref.best_hw
