"""Eq. (1)-(4): reference transcriptions vs the vectorised jnp kernels."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fusion, metrics as M
from repro.core.arch import DLAConfig, default_config_space
from repro.core.ir import LayerSpec, NetworkIR, vgg16_ir


@pytest.fixture(scope="module")
def vgg():
    return vgg16_ir(pool_mode="separate")


def random_chain(rng, n=6):
    layers = []
    hw = int(rng.choice([8, 16, 32]))
    c = int(rng.choice([3, 8, 16]))
    for i in range(n):
        cout = int(rng.choice([8, 16, 32]))
        layers.append(LayerSpec(f"l{i}", "conv", c, cout, hw, hw, 3, 3, 1))
        c = cout
    return NetworkIR("rand", tuple(layers))


def test_bandwidth_layer_by_layer_equals_sum_of_layers(vgg):
    cuts = fusion.layer_by_layer_cuts(len(vgg))
    bw = M.bandwidth_ref(vgg, cuts)
    expect = sum(l.weight_words + l.in_words + l.out_words for l in vgg.layers)
    assert bw == expect


def test_bandwidth_full_fusion_only_edges(vgg):
    cuts = np.zeros(len(vgg) - 1, dtype=bool)
    bw = M.bandwidth_ref(vgg, cuts)
    expect = (
        sum(l.weight_words for l in vgg.layers)
        + vgg.layers[0].in_words
        + vgg.layers[-1].out_words
    )
    assert bw == expect


def test_vgg16_macs_against_published_count(vgg):
    # VGG-16 conv MACs at 224x224 are ~15.35 G (conv layers only).
    macs = sum(l.macs for l in vgg.layers)
    assert abs(macs - 15.35e9) / 15.35e9 < 0.01


@pytest.mark.parametrize("seed", range(4))
def test_vectorised_matches_reference(seed):
    rng = np.random.default_rng(seed)
    ir = random_chain(rng)
    feat = ir.feature_matrix()
    cuts_batch = fusion.enumerate_cuts(len(ir))
    hw_space = [
        DLAConfig("hsiao", 4, 4, 4, 4),
        DLAConfig("vwa", 8, 8, 3, 8),
        DLAConfig("hsiao", 2, 16, 16, 2),
    ]
    hw_rows = np.stack([c.as_row() for c in hw_space])
    out = np.asarray(
        M.evaluate_batch(
            jnp.asarray(feat), jnp.asarray(cuts_batch), jnp.asarray(hw_rows),
            jnp.asarray(M.area_consts_of(hw_space[0])),
        )
    )
    for hi, hw in enumerate(hw_space):
        for ci in range(0, cuts_batch.shape[0], 7):  # sample
            ref = M.evaluate_ref(ir, cuts_batch[ci], hw)
            got = out[hi, ci]
            # evaluate_batch runs in f32 (jax default) => ~1e-7 relative
            np.testing.assert_allclose(got[0], ref.bandwidth_words, rtol=1e-6)
            np.testing.assert_allclose(got[1], ref.latency_cycles, rtol=1e-6)
            np.testing.assert_allclose(got[2], ref.energy_nj, rtol=1e-6)
            np.testing.assert_allclose(got[3], ref.area_um2, rtol=1e-6)


def test_pe_busy_cycles_hsiao_vs_vwa():
    hw_h = DLAConfig("hsiao", 4, 4, 4, 4)
    hw_v = DLAConfig("vwa", 4, 4, 3, 4)
    kw = dict(macs=1e6, n_in=16, n_out=32, kh=3, kw=3, pixels_out=1024)
    # hsiao: one PE retires a 3x3 window/cycle
    assert hw_h.pe_busy_cycles(**kw) == np.ceil(32 / 4) * np.ceil(16 / 4) * np.ceil(1024 / 16) * 1
    # vwa: 3 columns stream kernel columns; kh * ceil(kw/3) cycles
    assert hw_v.pe_busy_cycles(**kw) == np.ceil(32 / 4) * np.ceil(16 / 4) * np.ceil(1024 / 4) * 3


def test_energy_monotone_in_dram_traffic(vgg):
    hw = DLAConfig("hsiao", 4, 4, 4, 4)
    lbl = M.evaluate_ref(vgg, fusion.layer_by_layer_cuts(len(vgg)), hw)
    fus = M.evaluate_ref(vgg, vgg.pool_boundary_cuts(), hw)
    assert fus.bandwidth_words < lbl.bandwidth_words
    assert fus.energy_nj < lbl.energy_nj
    assert fus.latency_cycles < lbl.latency_cycles
    # area grows with fusion (bigger frame SRAMs)
    assert fus.area_um2 >= lbl.area_um2


def test_area_components(vgg):
    hw = DLAConfig("hsiao", 4, 4, 4, 4)
    cuts = vgg.pool_boundary_cuts()
    if_w, w_w, of_w = M.buffer_words_ref(vgg, cuts)
    a = M.area_ref(vgg, cuts, hw)
    assert a == pytest.approx(
        hw.area_pe_um2()
        + (if_w + w_w + of_w) * hw.area_per_sram_byte_um2
        + hw.area_controller_um2
    )
