"""Property-based kill-point testing of the write-ahead journal.

The PR's headline robustness property, exercised three ways over ONE
journaled 50-request run (built once per module — the expensive part):

* **every record boundary** — exhaustively truncate the WAL after each of
  its records, recover, drain: the answered set is exactly the
  durably-owed set (every durable admit + every durable response), every
  response bit-identical to the uninterrupted run's, no duplicates, no
  losses;
* **any byte offset** (hypothesis) — a crash does not respect record
  boundaries, so truncate at arbitrary byte offsets: the torn partial
  line is dropped and the boundary property holds for the surviving
  record prefix;
* **interior corruption** (hypothesis) — flip any byte of any non-final
  record: recovery must REFUSE with ``JournalCorrupt`` rather than
  replay a log it cannot trust.

Plus hypothesis round-trip properties for the bit-exact float/array
codecs the whole scheme rests on.
"""
import json
import math
import struct

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

# tmp_path is shared across a test's examples (each example writes its own
# uniquely-named crash dir inside it), so the function-scoped-fixture
# health check does not apply.
_SETTINGS = dict(
    deadline=None,
    suppress_health_check=[HealthCheck.function_scoped_fixture],
)

from repro.core import frontend, journal as J
from repro.core.arch import paper_config_space
from repro.core.errors import JournalCorrupt
from repro.core.ir import as_graph, residual_block_ir
from repro.core.service import PlanRequest, PlanningService

SPACE = tuple(paper_config_space())


def _bits(x: float) -> bytes:
    return struct.pack("<d", float(x))


def assert_responses_equivalent(a, b):
    """Bit-identical *answers*: everything except per-run timing."""
    assert a.request_id == b.request_id
    assert a.ok == b.ok
    assert a.error_type == b.error_type
    assert (a.engine, a.rung, a.exact, a.degraded) == (
        b.engine, b.rung, b.exact, b.degraded)
    assert _bits(a.quality_bound) == _bits(b.quality_bound)
    if a.plan is None:
        assert b.plan is None
        return
    pa, pb = a.plan, b.plan
    assert pa.best_hw == pb.best_hw
    assert np.array_equal(pa.best_cuts, pb.best_cuts)
    for f in ("bandwidth_words", "latency_cycles", "energy_nj", "area_um2"):
        assert _bits(getattr(pa.best_metrics, f)) == _bits(
            getattr(pb.best_metrics, f))
    assert pa.group_sizes == pb.group_sizes


@pytest.fixture(scope="module")
def base_run(tmp_path_factory):
    """One journaled 50-request run: (wal_bytes, {rid: expected response})."""
    d = tmp_path_factory.mktemp("journal_base")
    svc = PlanningService(
        journal_dir=d, journal_fsync=False, snapshot_every=0,
        config_space=SPACE, backoff_seconds=0.0)
    graphs = [as_graph(frontend.mlp_block_graph()),
              as_graph(residual_block_ir())]
    rids = []
    for i in range(50):
        rids.append(svc.submit(PlanRequest(
            graph=graphs[i % len(graphs)],
            sram_budget_words=[float("inf"), 2e6][(i // 2) % 2],
        )))
        if i % 7 == 6:  # interleave ticks so tick records pepper the WAL
            svc.tick()
    svc.drain()
    expected = {rid: svc._responses[rid] for rid in rids}
    svc.close()
    wal_bytes = (d / J.WAL_NAME).read_bytes()
    assert len(expected) == 50
    return wal_bytes, expected


def _recover_and_check(tmp_path, wal_prefix_bytes: bytes, expected, tag):
    """Write a truncated WAL, recover, drain, and assert the exactly-once
    contract for whatever records survived intact."""
    crash_dir = tmp_path / f"crash_{tag}"
    crash_dir.mkdir(exist_ok=True)  # shrinking replays the same example
    (crash_dir / J.WAL_NAME).write_bytes(wal_prefix_bytes)

    # The durable prefix: complete, parseable lines (the torn final
    # partial line — if any — must be dropped by recovery).
    prefix = []
    for line in wal_prefix_bytes.decode("utf-8", errors="replace").split("\n"):
        try:
            prefix.append(json.loads(line))
        except json.JSONDecodeError:
            break
    admitted = {r["payload"]["rid"] for r in prefix if r["type"] == "admit"}
    pre_answered = {
        r["payload"]["rid"] for r in prefix if r["type"] == "response"
    }
    owed = admitted | pre_answered  # cache hits answer without an admit

    svc = PlanningService.recover(
        crash_dir, journal_fsync=False, snapshot_every=0,
        config_space=SPACE, backoff_seconds=0.0)
    assert svc.queue_depth == len(admitted - pre_answered)
    svc.drain()

    got = dict(svc._responses)
    assert set(got) == owed  # no loss, no duplicate, no invention
    for rid in owed:
        assert_responses_equivalent(expected[rid], got[rid])
    for rid in pre_answered:  # replayed answers: byte-identical timing too
        assert got[rid].latency_seconds == expected[rid].latency_seconds
    svc.close()


def test_kill_point_at_every_record_boundary(base_run, tmp_path):
    """Exhaustive: the service dies after each record it ever wrote."""
    wal_bytes, expected = base_run
    lines = wal_bytes.decode().splitlines(keepends=True)
    assert len(lines) > 50  # 50 responses + admits + ticks
    for cut in range(len(lines) + 1):
        _recover_and_check(
            tmp_path, b"".join(line.encode() for line in lines[:cut]),
            expected, f"line{cut}")


@settings(max_examples=25, **_SETTINGS)
@given(data=st.data())
def test_kill_point_at_any_byte_offset(base_run, tmp_path, data):
    """A crash tears mid-record: truncate at an arbitrary byte offset."""
    wal_bytes, expected = base_run
    offset = data.draw(st.integers(0, len(wal_bytes)), label="byte_offset")
    _recover_and_check(tmp_path, wal_bytes[:offset], expected, f"b{offset}")


@settings(max_examples=25, **_SETTINGS)
@given(data=st.data())
def test_interior_corruption_is_refused(base_run, tmp_path, data):
    """Flip one byte of any non-final record: replay must refuse loudly
    (a silently-wrong replayed state is the one unacceptable outcome)."""
    wal_bytes, _ = base_run
    lines = wal_bytes.decode().splitlines(keepends=True)
    li = data.draw(st.integers(0, len(lines) - 2), label="line")
    line = bytearray(lines[li].encode())
    bi = data.draw(st.integers(0, len(line) - 2), label="byte")  # keep \n
    old = line[bi]
    new = data.draw(
        st.integers(33, 125).filter(lambda b: b != old), label="newbyte")
    line[bi] = new
    corrupted = b"".join(
        bytes(line) if i == li else l.encode() for i, l in enumerate(lines))
    crash_dir = tmp_path / f"corrupt_{li}_{bi}_{new}"
    crash_dir.mkdir(exist_ok=True)
    (crash_dir / J.WAL_NAME).write_bytes(corrupted)
    with pytest.raises(JournalCorrupt):
        J.load(crash_dir)


@settings(max_examples=200, deadline=None)
@given(st.floats(allow_nan=True, allow_infinity=True))
def test_float_codec_round_trips_bit_exactly(x):
    y = J.dec_float(J.enc_float(x))
    if math.isnan(x):
        assert math.isnan(y)
    else:
        assert _bits(x) == _bits(y)


@settings(max_examples=50, deadline=None)
@given(
    st.lists(st.floats(allow_nan=True, allow_infinity=True),
             min_size=0, max_size=32),
    st.sampled_from([np.float64, np.float32, np.int64, np.bool_]),
)
def test_array_codec_round_trips_bit_exactly(values, dtype):
    a = np.asarray(values, dtype=np.float64).astype(dtype)
    b = J.dec_array(J.enc_array(a))
    assert b.dtype == a.dtype and b.shape == a.shape
    assert a.tobytes() == b.tobytes()
