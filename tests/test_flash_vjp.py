"""Flash custom-vjp attention: forward and gradients vs plain-AD reference,
ring-buffer local KV cache correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.models import model as M
from repro.models.flash import flash_attention_vjp


def _grads(fn, q, k, v):
    def loss(q, k, v):
        return jnp.sum(jnp.sin(fn(q, k, v)))

    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("mixer,window,chunk", [
    ("attn", 0, 0), ("attn_local", 16, 0), ("attn_chunked", 0, 32),
])
def test_flash_vjp_matches_reference(mixer, window, chunk):
    key = jax.random.key(0)
    B, S, H, KV, hd = 2, 64, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.key(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.key(2), (B, S, KV, hd))
    pos = jnp.arange(S)

    def ref_fn(q, k, v):
        return L.attention_reference(q, k, v, q_pos=pos, kv_pos=pos,
                                     mixer=mixer, window=window, chunk=chunk)

    def flash_fn(q, k, v):
        return flash_attention_vjp(q, k, v, q_pos=pos, kv_pos=pos,
                                   mixer=mixer, window=window, chunk=chunk,
                                   kv_block=16)

    np.testing.assert_allclose(np.asarray(flash_fn(q, k, v)),
                               np.asarray(ref_fn(q, k, v)), atol=1e-5)
    g_ref = _grads(ref_fn, q, k, v)
    g_fl = _grads(flash_fn, q, k, v)
    for a, b in zip(g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_flash_vjp_bf16_tiles_close():
    key = jax.random.key(3)
    B, S, H, KV, hd = 1, 64, 2, 1, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.key(4), (B, S, KV, hd))
    v = jax.random.normal(jax.random.key(5), (B, S, KV, hd))
    pos = jnp.arange(S)

    def exact(q, k, v):
        return flash_attention_vjp(q, k, v, q_pos=pos, kv_pos=pos, kv_block=16)

    def tiled(q, k, v):
        return flash_attention_vjp(q, k, v, q_pos=pos, kv_pos=pos, kv_block=16,
                                   bf16_tiles=True)

    o1, o2 = exact(q, k, v), tiled(q, k, v)
    rel = float(jnp.abs(o1 - o2).max() / jnp.abs(o1).max())
    assert rel < 1e-2
    g1 = _grads(exact, q, k, v)
    g2 = _grads(tiled, q, k, v)
    for a, b in zip(g1, g2):
        rel = float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
        assert rel < 2e-2


def test_train_step_with_flash_matches_plain():
    cfg = ModelConfig(name="d", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      layer_pattern=("attn_local", "attn"), window_size=16,
                      dtype="float32")
    rc0 = RunConfig(xent_chunk=16, attn_chunk_kv=16)
    rc1 = dataclasses.replace(rc0, flash_vjp=True)
    key = jax.random.key(6)
    params = M.init_params(key, cfg)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, 128),
             "labels": jax.random.randint(jax.random.key(7), (2, 32), 0, 128)}

    def loss(rc):
        def f(p):
            return M.loss_fn(p, cfg, rc, batch)[0]
        return f

    l0, g0 = jax.value_and_grad(loss(rc0))(params)
    l1, g1 = jax.value_and_grad(loss(rc1))(params)
    assert float(l0) == pytest.approx(float(l1), rel=1e-5)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-3)


def test_ring_cache_decode_matches_full_cache():
    """Local-attention decode with a W-entry ring == full-context cache."""
    W = 8
    cfg = ModelConfig(name="g", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      layer_pattern=("attn_local", "attn"), window_size=W,
                      dtype="float32")
    rc_full = RunConfig(xent_chunk=16, attn_chunk_kv=16)
    rc_ring = dataclasses.replace(rc_full, local_ring_cache=True)
    key = jax.random.key(8)
    params = M.init_params(key, cfg)
    toks = jax.random.randint(key, (1, 24), 0, 64)

    def decode_run(rc, ring):
        cache = M.init_cache(cfg, 1, 32, ring=ring)
        logits, cache = M.prefill(params, cfg, rc, {"tokens": toks[:, :16]},
                                  cache)
        outs = [np.asarray(logits)]
        for t in range(16, 24):
            logits, cache = M.decode(params, cfg, rc, toks[:, t : t + 1], cache)
            outs.append(np.asarray(logits))
        return np.concatenate(outs, axis=1), cache

    full, _ = decode_run(rc_full, ring=False)
    ringd, cache = decode_run(rc_ring, ring=True)
    np.testing.assert_allclose(ringd, full, atol=1e-4, rtol=1e-4)
    # the ring buffer really is window-sized
    k_local = cache["segments"][0]["sub0"]["k"]
    assert k_local.shape[2] == W
    k_global = cache["segments"][0]["sub1"]["k"]
    assert k_global.shape[2] == 32
