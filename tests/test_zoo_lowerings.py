"""Deterministic locks on the PR 8 lowerings behind the config zoo.

These are the no-hypothesis counterparts of tests/test_zoo_property.py:
exact structural claims about what :func:`frontend.transformer_graph`,
:func:`frontend.mamba_graph`, and :func:`frontend.moe_block_graph` emit —
the attention actmul pair, the recurrent ``scan`` node and its
``state_words``, the chunk-boundary carry/conv-tail edges, and the MoE
router + expert fan-out — plus an all-registry trace smoke at scaled-down
shapes.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import frontend as F, metrics as M
from repro.core.arch import PAPER_OPTIMAL_CONFIG as HW
from repro.configs import REGISTRY, scaled_down
from repro.models.moe import _capacity


def _lockstep(g):
    """Batched evaluator == scalar oracle on a handful of fixed cuts."""
    rng = np.random.default_rng(0)
    cuts = rng.random((3, g.n_edges)) < 0.5
    hw_rows = np.stack([HW.as_row()])
    ac = M.area_consts_of(HW)
    feat = g.node_features()
    esrc, edst, ewords = g.edge_arrays()
    with M.enable_x64():
        batch = M.compose_metrics(M._evaluate_batch_graph(
            feat, esrc, edst, ewords, g.source_mask, g.sink_mask, cuts,
            hw_rows, ac,
        ), hw_rows)
    for c in range(cuts.shape[0]):
        m = M.evaluate_ref(g, cuts[c], HW)
        assert batch[0, c, 0] == m.bandwidth_words
        assert batch[0, c, 1] == m.latency_cycles
        assert batch[0, c, 2] == m.energy_nj
        assert batch[0, c, 3] == m.area_um2


def test_attention_lowering_actmul_pair():
    """One attention sublayer = QK^T and PV actmuls with the O(S^2)
    score matrix as an explicit n_heads*S*S edge between them."""
    cfg = scaled_down(REGISTRY["qwen3-0.6b"])
    S = 64
    g = F.transformer_graph(cfg, seq_len=S, n_sublayers=1)
    actmuls = [i for i, n in enumerate(g.nodes) if n.kind == "actmul"]
    assert len(actmuls) == 2
    qk, pv = actmuls
    score = [e for e in g.edges if e.src == qk and e.dst == pv]
    # Softmax folds into the QK^T producer, so the pair is directly
    # connected and the score matrix words are the full S^2 spill.
    assert any(e.words == cfg.n_heads * S * S for e in score)
    assert all(n.state_words == 0 for n in g.nodes)  # attn carries none
    _lockstep(g)


def test_mamba_scan_state_words():
    """The selective scan lowers to a weightless ``scan`` node whose
    state_words is exactly the (d_inner, d_state) carry."""
    cfg = scaled_down(REGISTRY["falcon-mamba-7b"])
    g = F.mamba_graph(cfg, seq_len=64, chunks=1)
    scans = [n for n in g.nodes if n.kind == "scan"]
    assert len(scans) == 1
    (scan,) = scans
    assert scan.state_words == cfg.d_inner * cfg.ssm_state
    assert scan.macs == 0
    assert M.F_STATE == 12  # the 13th feature column, doc'd in OP_COVERAGE
    feat = g.node_features()
    assert feat[:, M.F_STATE].sum() == scan.state_words
    _lockstep(g)


def test_mamba_chunked_carry_and_conv_tail_edges():
    """chunks=2 threads the SSM cache between the calls: the
    (d_inner, d_state) carry and the (conv-1)-token convolution tail
    both surface as real cut-point edges."""
    cfg = scaled_down(REGISTRY["falcon-mamba-7b"])
    g = F.mamba_graph(cfg, seq_len=64, chunks=2)
    scans = [i for i, n in enumerate(g.nodes) if n.kind == "scan"]
    assert len(scans) == 2
    a, b = scans
    carry = [e for e in g.edges if e.src == a and e.dst == b]
    assert [e.words for e in carry] == [cfg.d_inner * cfg.ssm_state]
    tail_words = (cfg.ssm_conv - 1) * cfg.d_inner
    assert any(e.words == tail_words for e in g.edges)
    _lockstep(g)


def test_moe_lowering_router_and_fanout():
    """MoE FFN = router matmul + 3 stacks of E expert branches (swiglu
    w1/w3 + w2), dispatch edges carrying the routed capacity words."""
    cfg = dataclasses.replace(
        scaled_down(REGISTRY["mixtral-8x7b"]), n_experts=4, top_k=2
    )
    S = 32
    g = F.moe_block_graph(cfg, seq_len=S)
    matmuls = [n for n in g.nodes if n.kind in ("matmul", "fc")]
    assert len(matmuls) == 1 + 3 * cfg.n_experts
    groups = S // min(cfg.moe_group_size, S)
    cap = _capacity(cfg, min(cfg.moe_group_size, S))
    branch_words = groups * cap * cfg.d_model
    fanout = [e for e in g.edges if e.words == branch_words]
    # Dispatch feeds each expert's w1 AND w3 (swiglu): >= 2E such edges.
    assert len(fanout) >= 2 * cfg.n_experts
    _lockstep(g)


def test_moe_capacity_scales_with_top_k():
    """Doubling top_k doubles the routed capacity and hence the words
    on every dispatch edge (capacity_factor held fixed)."""
    base = scaled_down(REGISTRY["mixtral-8x7b"])
    words = {}
    for tk in (1, 2):
        cfg = dataclasses.replace(base, n_experts=4, top_k=tk)
        sg = min(cfg.moe_group_size, 32)
        g = F.moe_block_graph(cfg, seq_len=32)
        w = (32 // sg) * _capacity(cfg, sg) * cfg.d_model
        assert any(e.words == w for e in g.edges)
        words[tk] = w
    assert words[2] == 2 * words[1]


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_every_registry_config_traces_scaled_down(name):
    """The whole zoo lowers at scaled-down shapes: one pattern period per
    config traces to a validated GraphIR with > 0 compute."""
    cfg = scaled_down(REGISTRY[name])
    g = F.transformer_graph(cfg, seq_len=64)
    assert g.n_nodes > 0 and g.n_edges > 0
    assert g.total_macs > 0
    kinds = {n.kind for n in g.nodes}
    if "mamba" in cfg.layer_pattern:
        assert "scan" in kinds
    if cfg.n_experts > 1:
        assert "actmul" in kinds  # dispatch/combine appear
