"""Frontier-state DP: exact beyond the 2^E enumeration wall.

Deterministic coverage (this module must run WITHOUT hypothesis — the
random-DAG cross-checks here use numpy seeds; the hypothesis variants live
in ``test_frontier_dp_property.py`` behind an importorskip):

* bit-identical minimum group cost vs ``brute_force_min_bw`` on random
  valid DAGs (with and without SRAM budgets) and on the in-repo builders;
* deterministic cost locks on ``residual_block_ir`` / ``encoder_decoder_ir``
  and the ResNet-18 exact-optimum-at-most-beam guarantee (38 edges — a
  space flat enumeration can never certify);
* the ``optimal_cuts`` dispatch chain (chain DP -> frontier DP ->
  exhaustive for small-but-wide DAGs -> beam) with ``engine`` provenance,
  including the flow integration;
* the elimination-order / frontier-width utilities in ``repro.core.ir``;
* the small-graph enumeration threshold (scalar filter under
  ``SMALL_ENUM_PATTERNS``, identical output, memo intact).
"""
import numpy as np
import pytest

from repro.core import fusion, metrics as M
from repro.core.arch import Constraints, PAPER_OPTIMAL_CONFIG
from repro.core.flow import run_flow
from repro.core.ir import (
    EdgeSpec,
    GraphIR,
    LayerSpec,
    as_graph,
    encoder_decoder_ir,
    min_width_topo_order,
    residual_block_ir,
    resnet18_ir,
    topo_frontier_sets,
    topo_frontier_width,
    vgg16_ir,
)
from test_graph_ir import random_dag

RELAXED = Constraints(max_bandwidth_words=1e12, max_latency_cycles=1e12,
                      max_energy_nj=1e12, max_area_um2=1e12)


def _assert_exact_match(g, dp, bf, sram):
    """The DP contract vs brute force: bit-identical minimum cost; the DP's
    cuts must themselves be valid, feasible, and achieve that cost (ties
    may resolve to a different optimal vector than brute force's
    first-pattern rule)."""
    assert dp.group_cost_words == bf.group_cost_words
    assert dp.engine == "frontier_dp" and dp.exact
    assert fusion.is_valid_cuts(g, dp.cuts)
    assert fusion.graph_max_intermediate(g, dp.cuts) <= sram
    assert fusion._graph_cost(g, dp.cuts) == dp.group_cost_words
    labels = fusion.cut_group_labels(g, dp.cuts)
    assert dp.n_groups == int(labels.max()) + 1


# ---------------------------------------------------------------------------
# Bit-identical minimum cost vs brute force
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(12))
def test_dp_bit_identical_cost_random_dags(seed):
    rng = np.random.default_rng(4200 + seed)
    g = random_dag(rng, int(rng.integers(3, 11)))
    feat = g.node_features()
    budget = float(np.median(feat[:, M.F_OUT_PRE]))
    for sram in (float("inf"), budget):
        bf = fusion.brute_force_min_bw(g, sram_budget_words=sram)
        dp = fusion.frontier_dp_min_bw(
            g, sram_budget_words=sram, max_width=None, max_states=1 << 22
        )
        _assert_exact_match(g, dp, bf, sram)


@pytest.mark.parametrize("sram", [float("inf"), 150_000.0])
def test_dp_bit_identical_residual_block(sram):
    rb = residual_block_ir()
    bf = fusion.brute_force_min_bw(rb, sram_budget_words=sram)
    dp = fusion.frontier_dp_min_bw(rb, sram_budget_words=sram)
    _assert_exact_match(rb, dp, bf, sram)


def test_dp_bit_identical_encoder_decoder_vs_enumeration():
    """The acceptance case: 21 edges = 2^21 flat patterns; the DP must agree
    with the full enumeration bit-for-bit on the minimum."""
    ed = encoder_decoder_ir()
    bf = fusion.brute_force_min_bw(ed)
    dp = fusion.frontier_dp_min_bw(ed)
    _assert_exact_match(ed, dp, bf, float("inf"))


# ---------------------------------------------------------------------------
# Deterministic locks + ResNet-18 exactness
# ---------------------------------------------------------------------------


def test_dp_locked_optima():
    """Geometry-derived optima of the in-repo builders — any change to the
    DP, the cost model, or the builders must consciously update these."""
    rb = residual_block_ir()
    ed = encoder_decoder_ir()
    assert fusion.frontier_dp_min_bw(rb).group_cost_words == 200704.0
    assert fusion.frontier_dp_min_bw(
        rb, sram_budget_words=150_000.0
    ).group_cost_words == 501760.0
    assert fusion.frontier_dp_min_bw(ed).group_cost_words == 720896.0
    assert fusion.frontier_dp_min_bw(
        ed, sram_budget_words=300_000.0
    ).group_cost_words == 11206656.0


@pytest.mark.parametrize("sram", [float("inf"), 200_000.0])
def test_resnet18_exact_at_most_beam(sram):
    """ResNet-18 (38 edges) was heuristic-only before the frontier DP; the
    certified exact optimum can only match or beat the beam answer."""
    g = resnet18_ir()
    dp = fusion.frontier_dp_min_bw(g, sram_budget_words=sram)
    beam = fusion.beam_merge_cuts(g, sram_budget_words=sram)
    assert dp.group_cost_words <= beam.group_cost_words
    assert fusion.is_valid_cuts(g, dp.cuts)
    assert fusion.graph_max_intermediate(g, dp.cuts) <= sram
    assert fusion._graph_cost(g, dp.cuts) == dp.group_cost_words


# ---------------------------------------------------------------------------
# Dispatch, provenance, caps
# ---------------------------------------------------------------------------


def _wide_dag(n_mid: int) -> GraphIR:
    """source -> n_mid parallel convs -> sink join: every topological order
    holds all middles on the frontier at once, so width == n_mid."""
    nodes = [LayerSpec("src", "conv", 4, 4, 8, 8, 3, 3, 1)]
    for i in range(n_mid):
        nodes.append(LayerSpec(f"m{i}", "conv", 4, 4, 8, 8, 3, 3, 1))
    nodes.append(LayerSpec("join", "elementwise", 4, 4, 8, 8))
    edges = [EdgeSpec(0, i + 1, nodes[0].out_words) for i in range(n_mid)]
    edges += [
        EdgeSpec(i + 1, n_mid + 1, nodes[i + 1].out_words)
        for i in range(n_mid)
    ]
    return GraphIR("wide", tuple(nodes), tuple(edges))


def _wide_fanin_dag(n_src: int) -> GraphIR:
    """n_src parallel sources feeding one join: width n_src, n_src edges —
    wide for the DP but small enough to enumerate."""
    nodes = [
        LayerSpec(f"s{i}", "conv", 4, 4, 8, 8, 3, 3, 1) for i in range(n_src)
    ] + [LayerSpec("join", "elementwise", 4, 4, 8, 8)]
    edges = [EdgeSpec(i, n_src, nodes[i].out_words) for i in range(n_src)]
    return GraphIR("fanin", tuple(nodes), tuple(edges))


def test_dispatch_engines():
    assert fusion.optimal_cuts(vgg16_ir()).engine == "chain_dp"
    assert fusion.optimal_cuts(residual_block_ir()).engine == "frontier_dp"
    assert fusion.optimal_cuts(resnet18_ir()).engine == "frontier_dp"
    # a DAG wider than the cap but within the 2^E wall keeps a CERTIFIED
    # optimum via exhaustive enumeration (the pre-DP dispatch guarantee)
    fanin = _wide_fanin_dag(fusion.FRONTIER_DP_MAX_WIDTH + 1)
    assert fanin.n_edges <= fusion.MAX_EXHAUSTIVE_EDGES
    res = fusion.optimal_cuts(fanin)
    assert res.engine == "exhaustive" and res.exact
    # wide AND beyond the enumeration wall: beam, with provenance saying so
    wide = _wide_dag(fusion.FRONTIER_DP_MAX_WIDTH + 1)
    assert wide.n_edges > fusion.MAX_EXHAUSTIVE_EDGES
    res = fusion.optimal_cuts(wide)
    assert res.engine == "beam" and not res.exact
    with pytest.raises(fusion.FrontierTooWide):
        fusion.frontier_dp_min_bw(wide)


def test_state_cap_raises_frontier_too_wide():
    # On the in-repo builders dominance + branch-and-bound collapse the DP
    # to a single live state per step (the greedy incumbent is already
    # optimal there), so the cap needs a graph whose incumbent is loose: a
    # budgeted random DAG where greedy overpays keeps competing states.
    rng = np.random.default_rng(4200)
    g = random_dag(rng, int(rng.integers(3, 11)))
    budget = float(np.median(g.node_features()[:, M.F_OUT_PRE]))
    with pytest.raises(fusion.FrontierTooWide):
        fusion.frontier_dp_min_bw(
            g, sram_budget_words=budget, max_width=None, max_states=1
        )


def test_optimal_cuts_returns_fresh_cuts():
    """The dispatch memo must hand every caller an independent cut vector —
    mutating one result cannot poison later searches."""
    g = residual_block_ir()
    a = fusion.optimal_cuts(g)
    a.cuts[:] = True
    b = fusion.optimal_cuts(g)
    assert not b.cuts.all()
    assert b.group_cost_words == 200704.0


def test_run_flow_search_provenance_and_exact_optimum():
    g = resnet18_ir()
    res = run_flow(g, config_space=[PAPER_OPTIMAL_CONFIG],
                   constraints=RELAXED, groupings="search")
    assert res.search_engine == "frontier_dp"
    dp = fusion.frontier_dp_min_bw(g)
    assert res.best_metrics.bandwidth_words == M.bandwidth_ref(g, dp.cuts)
    # chain fast path + exhaustive provenance strings
    res_chain = run_flow(vgg16_ir(), config_space=[PAPER_OPTIMAL_CONFIG],
                         constraints=RELAXED, groupings="search")
    assert res_chain.search_engine == "chain_dp"
    res_ex = run_flow(residual_block_ir(),
                      config_space=[PAPER_OPTIMAL_CONFIG],
                      constraints=RELAXED, groupings="exhaustive")
    assert res_ex.search_engine == "exhaustive"


# ---------------------------------------------------------------------------
# Elimination-order / frontier-width utilities
# ---------------------------------------------------------------------------


def test_frontier_width_known_graphs():
    assert topo_frontier_width(residual_block_ir()) == 2
    assert topo_frontier_width(as_graph(encoder_decoder_ir())) == 3
    assert topo_frontier_width(resnet18_ir()) == 2
    assert topo_frontier_width(_wide_dag(5)) == 5


def test_frontier_sets_cover_pending_edges():
    rng = np.random.default_rng(7)
    g = random_dag(rng, 9)
    sets = topo_frontier_sets(g)
    assert sets[-1] == []
    for t, frontier in enumerate(sets):
        want = sorted(
            {e.src for e in g.edges if e.src <= t < e.dst}
        )
        assert frontier == want


def test_min_width_order_is_topological_and_no_wider():
    for seed in range(6):
        rng = np.random.default_rng(90 + seed)
        g = random_dag(rng, int(rng.integers(4, 12)))
        order = min_width_topo_order(g)
        assert sorted(order) == list(range(len(g.nodes)))
        pos = {v: t for t, v in enumerate(order)}
        assert all(pos[e.src] < pos[e.dst] for e in g.edges)
        # any-order DP invariance: the optimum is order-independent
        dp_nat = fusion.frontier_dp_min_bw(g, max_width=None)
        dp_alt = fusion.frontier_dp_min_bw(g, max_width=None, order=order)
        assert dp_nat.group_cost_words == dp_alt.group_cost_words


def test_frontier_sets_reject_non_topological_order():
    g = residual_block_ir()
    with pytest.raises(ValueError):
        topo_frontier_sets(g, [3, 2, 1, 0])
    with pytest.raises(ValueError):
        topo_frontier_sets(g, [0, 0, 1, 2])


# ---------------------------------------------------------------------------
# Small-graph enumeration threshold (cold-path satellite)
# ---------------------------------------------------------------------------


def test_small_graph_enumeration_uses_scalar_filter_identically():
    """Below SMALL_ENUM_PATTERNS the memoised enumeration runs the scalar
    per-pattern filter — output, ordering, caching, and read-only-ness all
    unchanged."""
    rb = residual_block_ir()
    assert (1 << rb.n_edges) <= fusion.SMALL_ENUM_PATTERNS
    fusion.enumerate_valid_edge_cuts.cache_clear()
    out = fusion.enumerate_valid_edge_cuts(rb)
    np.testing.assert_array_equal(
        out, fusion._enumerate_valid_edge_cuts_scalar(rb)
    )
    assert fusion.enumerate_valid_edge_cuts(rb) is out  # still memoised
    assert not out.flags.writeable
    rng = np.random.default_rng(11)
    g = random_dag(rng, 5)
    if (1 << g.n_edges) <= fusion.SMALL_ENUM_PATTERNS:
        np.testing.assert_array_equal(
            fusion.enumerate_valid_edge_cuts(g),
            fusion._enumerate_valid_edge_cuts_scalar(g),
        )
