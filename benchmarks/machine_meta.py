"""Machine metadata stamped into every ``BENCH_*.json`` record.

Throughput and speedup numbers are meaningless without knowing what they
ran on — in particular the sharded-sweep scaling in ``BENCH_shard.json``
is bounded by *physical* cores, not by ``jax.device_count()`` (the
``--xla_force_host_platform_device_count`` flag happily splits one core
into eight "devices").  Each writer calls :func:`machine_metadata` once
and embeds the result under a ``"machine"`` key.
"""
from __future__ import annotations

import os
import platform
import sys


def machine_metadata() -> dict:
    """Environment fingerprint for benchmark records (JSON-serialisable)."""
    import jax

    devices = jax.devices()
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else None,
        "device_count": len(devices),
        "cpu_count": os.cpu_count(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "platform": platform.platform(),
        "python": sys.version.split()[0],
    }
