"""Config-zoo benchmark — every registry architecture through ONE fleet sweep.

PR 8's frontend extensions (batched ``dot_general`` -> attention actmuls,
``scan`` -> SSM state nodes, expert-branch expansion -> MoE fan-out) mean
every model in ``repro.configs.REGISTRY`` now traces to a GraphIR.  This
benchmark exercises that end to end: one superblock graph per architecture
(:func:`repro.core.frontend.transformer_graph`, the real model forward at
``seq_len=512``), each paired with an explicit cut batch —

* ``lbl``    — layer-by-layer (every edge cut; the paper's baseline);
* ``fused``  — fully fused (no cuts; infinite-SRAM upper bound);
* ``search`` — :func:`repro.core.fusion.optimal_cuts` optimum, for graphs
  with at most ``SEARCH_EDGE_CAP`` edges (the frontier DP certifies the
  small/medium zoo; the three widest graphs — jamba / arctic / llama4,
  569-1600 edges — skip the search row and this is recorded per config
  rather than silently dropped).

All graphs + batches go through a **single** :func:`repro.core.flow.run_fleet`
call (PR 4 shape buckets, PR 6 Pareto fronts), so the whole zoo pays one XLA
compile.  Per config the record carries the best hardware point, the winning
cuts, the Pareto front size, and the fused-vs-layer-by-layer bandwidth /
latency / energy reductions from :func:`repro.core.flow.compare_fusion`
evaluated at that best hardware point.

Writes ``BENCH_zoo.json`` at the repo root.

Usage: ``python benchmarks/bench_zoo.py [--smoke]`` (``--smoke`` = the
two small configs qwen3-0.6b + phi3-mini-3.8b, for the CI core lane).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_zoo.json"

try:  # running from a checkout without `pip install -e .`
    import repro  # noqa: F401
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(ROOT / "src"))

from machine_meta import machine_metadata

SEQ_LEN = 512
#: Exact search is run only for graphs at or below this edge count.  The
#: frontier DP certifies everything in the zoo up to gemma3 (98 edges) in
#: well under a second; the expert-fan-out giants (jamba 569, arctic 787,
#: llama4 1600 edges) fall to beam merge which takes minutes, so their
#: batches carry lbl + fused only and ``search_skipped`` marks them.
SEARCH_EDGE_CAP = 128
SMOKE_ARCHS = ("qwen3_0_6b", "phi3_mini_3_8b")


def _zoo_graphs(smoke: bool):
    """name -> GraphIR for the (sub)zoo, traced from the real modules."""
    from repro.configs import REGISTRY, resolve
    from repro.core.frontend import transformer_graph

    names = [resolve(a).name for a in SMOKE_ARCHS] if smoke else sorted(REGISTRY)
    return {n: transformer_graph(REGISTRY[n], seq_len=SEQ_LEN) for n in names}


def _cut_batches(graphs):
    """Per-graph explicit (C_i, E_i) cut batches + per-config search notes."""
    import numpy as np

    from repro.core import fusion

    batches, notes = [], {}
    for name, g in graphs.items():
        lbl = np.asarray(fusion.layer_by_layer_cuts(g), bool).reshape(-1)
        rows = [lbl, np.zeros_like(lbl)]
        if g.n_edges <= SEARCH_EDGE_CAP:
            res = fusion.optimal_cuts(g)
            rows.append(np.asarray(res.cuts, bool).reshape(-1))
            notes[name] = {"search_skipped": False, "engine": res.engine,
                           "exact": bool(res.exact)}
        else:
            notes[name] = {"search_skipped": True, "engine": None,
                           "exact": False}
        batches.append(np.stack(rows))
    return batches, notes


def run_child(smoke: bool) -> None:
    """The cold measurement in this (fresh) process; JSON on the last line."""
    from repro.core import flow
    from repro.core.arch import Constraints

    loose = Constraints(*[float("inf")] * 4)

    t0 = time.perf_counter()
    graphs = _zoo_graphs(smoke)
    trace_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    batches, notes = _cut_batches(graphs)
    search_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    fl = flow.run_fleet(
        list(graphs.values()), groupings=batches, constraints=loose,
        pareto=True,
    )
    fleet_wall = time.perf_counter() - t0

    configs = {}
    for (name, g), r in zip(graphs.items(), fl.results):
        comp = flow.compare_fusion(g, r.best_hw, r.best_cuts)
        # The batch always contains the lbl row, so the winner can only
        # improve on (or tie) layer-by-layer.
        assert comp.bw_reduction >= -1e-9, (name, comp.bw_reduction)
        configs[name] = {
            **notes[name],
            "n_nodes": len(g.nodes),
            "n_edges": int(g.n_edges),
            "n_feasible": int(r.n_feasible),
            "best_hw": dataclasses.asdict(r.best_hw),
            "best_cuts": [int(c) for c in r.best_cuts],
            "n_groups": len(r.group_sizes),
            "best_metrics": {
                "bandwidth_words": float(r.best_metrics.bandwidth_words),
                "latency_cycles": float(r.best_metrics.latency_cycles),
                "energy_nj": float(r.best_metrics.energy_nj),
                "area_um2": float(r.best_metrics.area_um2),
            },
            "pareto_points": int(r.pareto.metrics.shape[0]),
            "bw_reduction_vs_lbl": round(float(comp.bw_reduction), 6),
            "latency_reduction_vs_lbl": round(
                float(comp.latency_reduction), 6),
            "energy_reduction_vs_lbl": round(float(comp.energy_reduction), 6),
        }

    print(json.dumps({
        "n_configs": len(graphs),
        "trace_s": round(trace_s, 6),
        "search_s": round(search_s, 6),
        "fleet_wall_s": round(fleet_wall, 6),
        "compile_s": round(fl.compile_seconds, 6),
        "sweep_s": round(fl.sweep_seconds, 6),
        "n_candidates": int(fl.n_candidates),
        "candidates_per_second": round(fl.candidates_per_second, 1),
        "configs": configs,
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="two-config subset (CI core lane)")
    ap.add_argument("--child", action="store_true",
                    help="(internal) run the cold measurement in-process")
    args = ap.parse_args()
    if args.child:
        run_child(args.smoke)
        return

    cmd = [sys.executable, str(pathlib.Path(__file__).resolve()), "--child"]
    if args.smoke:
        cmd.append("--smoke")
    # Inherit the full environment (PR 6): a minimal env drops JAX_PLATFORMS
    # and libtpu then probes GCP instance metadata for minutes.
    env = {**os.environ,
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    proc = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT,
                          env=env)
    if proc.returncode != 0:  # surface the child's traceback in CI logs
        sys.stderr.write(proc.stdout)
        sys.stderr.write(proc.stderr)
        raise SystemExit("bench_zoo child failed")
    row = json.loads(proc.stdout.strip().splitlines()[-1])

    record = {
        "bench": "zoo",
        "smoke": args.smoke,
        "machine": machine_metadata(),
        "metric_note": (
            "One run_fleet program over the whole config zoo: each "
            "architecture's real forward pass traced to GraphIR at "
            f"seq_len={SEQ_LEN}, swept over the default hardware space with "
            "an explicit per-graph cut batch (layer-by-layer / fully-fused "
            "/ optimal_cuts optimum for graphs <= "
            f"{SEARCH_EDGE_CAP} edges — wider graphs record "
            "search_skipped=true).  bw_reduction_vs_lbl is compare_fusion's "
            "fused-vs-layer-by-layer DRAM-traffic reduction at the "
            "per-config best hardware point; candidates_per_second counts "
            "(hw x cut) evaluations in the single compiled sweep."
        ),
        **row,
    }
    OUT.write_text(json.dumps(record, indent=2) + "\n")
    skipped = [n for n, c in row["configs"].items() if c["search_skipped"]]
    print(f"\n[bench_zoo] {row['n_configs']} configs, "
          f"{row['n_candidates']} candidates "
          f"({row['candidates_per_second']:.0f}/s) -> {OUT}")
    for name, c in row["configs"].items():
        print(f"  {name:28s} L={c['n_nodes']:4d} E={c['n_edges']:4d} "
              f"bw_red {100 * c['bw_reduction_vs_lbl']:5.1f}%  "
              f"pareto {c['pareto_points']:3d}")
    if skipped:
        print(f"[bench_zoo] exact search skipped (edges > {SEARCH_EDGE_CAP}):"
              f" {', '.join(skipped)}")


if __name__ == "__main__":
    main()
