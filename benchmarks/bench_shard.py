"""Sharded co-search benchmark — candidates/s vs host device count.

PR 4 collapsed the fleet sweep to ONE XLA program; this benchmark measures
what sharding that program's *hardware axis* over a 1-D ``hardware`` mesh
buys as devices are added.  For each device count d in (1, 2, 4, 8) a
**fresh subprocess** (cold caches) is launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=d``; d=1 runs the plain
single-device program (``devices=None``), d>1 runs
``run_fleet(devices=d)``.  Every child sweeps the same >= 1000-point
:func:`repro.core.arch.config_space_grid` co-search space with Pareto
extraction on, and reports best metrics + the full Pareto front so the
parent can assert the sharded sweep is **bit-identical** to the
single-device one at every d — the same guarantee the test suite pins at
2/8 devices — before any throughput number is written.

Speedup caveat: ``--xla_force_host_platform_device_count`` splits the host
CPU into d XLA devices regardless of how many physical cores exist, so
scaling saturates at the *core* count (a 1-core container shows ~1x at
every d — honest, and why the record embeds ``machine`` metadata).  Pass
``--require-speedup`` (multi-core CI runners) to assert >= 3x candidates/s
at 8 devices vs 1.

``--faults`` adds the fault-tolerance lanes (in-process, single device):
the wall-clock overhead of salvaging a chunked sweep through injected
shard failures (per-chunk RetryPolicy), and of a kill-at-mid-sweep +
checkpointed resume vs recomputing from scratch — with bit-identity and
exactly-once recomputation asserted before any number is reported.

Writes ``BENCH_shard.json`` at the repo root.

Usage: ``python benchmarks/bench_shard.py [--smoke] [--require-speedup]
[--faults]`` (``--smoke`` = pruned config grid and two workloads, for the
CI smoke job).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import subprocess
import sys
import tempfile
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_shard.json"

try:  # running from a checkout without `pip install -e .`
    import repro  # noqa: F401
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(ROOT / "src"))

from machine_meta import machine_metadata

DEVICE_COUNTS = (1, 2, 4, 8)


def _config_space(smoke: bool):
    from repro.core.arch import config_space_grid

    if smoke:  # 256 points: exercises the sharded path, fits the CI budget
        return config_space_grid(
            f1s=(2, 4), f2s=(2, 4), f3s=(2, 4), f4s=(2, 4),
            bus_widths=(2, 4), sram_splits=("unified",),
        )
    return config_space_grid()  # 2560-point co-search space


def _workloads(smoke: bool):
    from repro.core.ir import (
        as_graph,
        encoder_decoder_ir,
        residual_block_ir,
        resnet18_ir,
        vgg16_ir,
    )

    works = {
        "resnet18": resnet18_ir(),
        "residual_block": residual_block_ir(),
    }
    if not smoke:
        works["vgg16"] = as_graph(vgg16_ir(pool_mode="separate"))
        works["encoder_decoder"] = encoder_decoder_ir()
    return works


def _front_digest(front) -> dict:
    """Pareto front as JSON for cross-device bit-identity asserts."""
    return {
        "size": front.size,
        "metrics": front.metrics.tolist(),
        "hw_indices": front.hw_indices.tolist(),
        "cut_indices": front.cut_indices.tolist(),
    }


def run_child(n_devices: int, smoke: bool) -> None:
    """One cold sweep at this device count; JSON on the last line."""
    import jax

    assert len(jax.devices()) == n_devices, (
        f"child expected {n_devices} host devices, jax sees "
        f"{len(jax.devices())} (XLA_FLAGS not applied?)"
    )
    from repro.core import flow
    from repro.core.arch import Constraints

    loose = Constraints(*[float("inf")] * 4)
    space = _config_space(smoke)
    works = _workloads(smoke)
    devices = None if n_devices == 1 else n_devices

    def sweep():
        return flow.run_fleet(
            list(works.values()), config_space=space, constraints=loose,
            groupings="pool", devices=devices, pareto=True,
        )

    t0 = time.perf_counter()
    fl = sweep()
    cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    fl2 = sweep()
    steady_wall = time.perf_counter() - t0

    rows = {
        name: [
            r.best_metrics.bandwidth_words, r.best_metrics.latency_cycles,
            r.best_metrics.energy_nj, r.best_metrics.area_um2,
        ]
        for name, r in zip(works, fl.results)
    }
    rows2 = {
        name: [
            r.best_metrics.bandwidth_words, r.best_metrics.latency_cycles,
            r.best_metrics.energy_nj, r.best_metrics.area_um2,
        ]
        for name, r in zip(works, fl2.results)
    }
    assert rows == rows2, "steady-state re-run changed the best points"
    stats = flow.sweep_cache_stats()
    assert stats["misses"] == 1, (
        f"expected ONE compiled executable for the sharded fleet, "
        f"cache reports {stats}"
    )
    print(json.dumps({
        "n_devices": n_devices,
        "device_count_used": fl.device_count,
        "n_workloads": len(works),
        "n_hw_configs": len(space),
        "n_candidates": fl.n_candidates,
        "cold_wall_s": round(cold_wall, 6),
        "steady_wall_s": round(steady_wall, 6),
        "compile_s": round(fl.compile_seconds, 6),
        "sweep_s": round(fl.sweep_seconds, 6),
        "steady_sweep_s": round(fl2.sweep_seconds, 6),
        "candidates_per_s": round(fl2.candidates_per_second),
        "candidates_per_s_cold": round(fl.candidates_per_second),
        "best_metrics": rows,
        "pareto": {
            name: _front_digest(r.pareto)
            for name, r in zip(works, fl.results)
        },
        "machine": machine_metadata(),
    }))


def run_faults(smoke: bool) -> dict:
    """Fault-tolerance lanes: salvage overhead under injected shard
    failures, and checkpointed kill/resume overhead vs full recompute.
    Bit-identity and exactly-once recomputation are asserted before any
    timing is reported."""
    from repro.core import flow
    from repro.core.arch import Constraints
    from repro.core.errors import RetryPolicy
    from repro.testing.faults import FaultInjector

    loose = Constraints(*[float("inf")] * 4)
    space = _config_space(smoke)
    works = _workloads(smoke)
    hw_chunk = max(1, len(space) // 8)  # 8 chunks
    n_chunks = -(-len(space) // hw_chunk)
    policy = RetryPolicy(max_retries=3, backoff_seconds=0.0)

    def sweep(**kw):
        return flow.run_fleet(
            list(works.values()), config_space=space, constraints=loose,
            groupings="pool", hw_chunk=hw_chunk, **kw,
        )

    def best_rows(fl):
        return {
            name: [
                r.best_metrics.bandwidth_words, r.best_metrics.latency_cycles,
                r.best_metrics.energy_nj, r.best_metrics.area_um2,
            ]
            for name, r in zip(works, fl.results)
        }

    sweep()  # warm the executable cache: the lanes time salvage, not XLA
    t0 = time.perf_counter()
    clean = sweep()
    clean_wall = time.perf_counter() - t0

    # Lane 1: salvage — every 3rd chunk compute fails once, the per-chunk
    # RetryPolicy absorbs it, and the answer must not move a bit.
    inj = FaultInjector(shard_fail_every=3)
    t0 = time.perf_counter()
    salvaged = sweep(hooks=inj, retry_policy=policy)
    salvage_wall = time.perf_counter() - t0
    assert best_rows(salvaged) == best_rows(clean), (
        "salvaged sweep diverged from the clean sweep"
    )
    assert inj.counts["injected_shard_failures"] > 0

    # Lane 2: kill at the sweep's midpoint, resume from the checkpoint.
    class _Kill(Exception):
        pass

    kill_at = n_chunks // 2
    state = {"n": 0}

    def killer():
        state["n"] += 1
        if state["n"] > kill_at:
            raise _Kill()

    with tempfile.TemporaryDirectory() as ckpt:
        t0 = time.perf_counter()
        try:
            sweep(checkpoint_dir=ckpt, abort_check=killer)
        except _Kill:
            pass
        killed_wall = time.perf_counter() - t0
        resumed_inj = FaultInjector()
        t0 = time.perf_counter()
        resumed = sweep(checkpoint_dir=ckpt, hooks=resumed_inj)
        resume_wall = time.perf_counter() - t0
    assert resumed.chunks_restored == kill_at, (
        f"expected {kill_at} restored chunks, got {resumed.chunks_restored}"
    )
    assert resumed_inj.counts["chunk_computes"] == n_chunks - kill_at, (
        "resume recomputed already-durable chunks"
    )
    assert best_rows(resumed) == best_rows(clean), (
        "resumed sweep diverged from the clean sweep"
    )

    return {
        "metric_note": (
            "salvage lane: chunked sweep with every 3rd chunk compute "
            "failing once, absorbed by the per-chunk RetryPolicy (zero "
            "backoff) — overhead_vs_clean is the honest retry cost.  "
            "resume lane: sweep killed at the midpoint boundary, resumed "
            "from the SweepCheckpoint — resume_vs_full_recompute compares "
            "against recomputing everything.  At bench scale chunk "
            "compute is milliseconds, so checkpoint decode can dominate "
            "and the ratio exceed 1; it shrinks below 1 as per-chunk "
            "compute grows (the multi-hour co-searches the checkpoint "
            "exists for).  Bit-identity and exactly-once recomputation "
            "are asserted before either number is written."
        ),
        "n_workloads": len(works),
        "n_hw_configs": len(space),
        "n_candidates": clean.n_candidates,
        "hw_chunk": hw_chunk,
        "n_chunks": n_chunks,
        "clean_chunked_wall_s": round(clean_wall, 6),
        "salvage": {
            "injected_shard_failures": inj.counts["injected_shard_failures"],
            "wall_s": round(salvage_wall, 6),
            "overhead_vs_clean": round(salvage_wall / clean_wall, 3),
        },
        "resume": {
            "killed_at_chunk": kill_at,
            "killed_wall_s": round(killed_wall, 6),
            "resume_wall_s": round(resume_wall, 6),
            "chunks_restored": resumed.chunks_restored,
            "chunks_recomputed": resumed.chunks_computed,
            "resume_vs_full_recompute": round(resume_wall / clean_wall, 3),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="pruned grid + two workloads (CI)")
    ap.add_argument("--require-speedup", action="store_true",
                    help="assert >= 3x candidates/s at 8 devices vs 1 "
                         "(needs >= 8 physical cores)")
    ap.add_argument("--faults", action="store_true",
                    help="add salvage/resume fault-tolerance lanes")
    ap.add_argument("--devices", type=int,
                    help="(internal) run one cold measurement in-process")
    args = ap.parse_args()
    if args.devices:
        run_child(args.devices, args.smoke)
        return
    if args.faults:
        lanes = run_faults(args.smoke)
        record = json.loads(OUT.read_text()) if OUT.exists() else {
            "bench": "shard", "smoke": args.smoke,
            "machine": machine_metadata(),
        }
        record["faults"] = lanes
        OUT.write_text(json.dumps(record, indent=2) + "\n")
        print(
            f"[bench_shard] faults: salvage "
            f"{lanes['salvage']['overhead_vs_clean']}x clean "
            f"({lanes['salvage']['injected_shard_failures']} failures), "
            f"resume {lanes['resume']['resume_vs_full_recompute']}x full "
            f"recompute ({lanes['resume']['chunks_restored']} chunks "
            f"restored) -> {OUT}"
        )
        return

    rows: dict[int, dict] = {}
    for d in DEVICE_COUNTS:
        cmd = [sys.executable, str(pathlib.Path(__file__).resolve()),
               "--devices", str(d)]
        if args.smoke:
            cmd.append("--smoke")
        # Inherit the full environment: a minimal env drops JAX_PLATFORMS
        # and libtpu then probes GCP instance metadata for minutes.
        env = {
            **os.environ,
            "XLA_FLAGS": f"--xla_force_host_platform_device_count={d}",
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
        }
        proc = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT,
                              env=env)
        if proc.returncode != 0:  # surface the child's traceback in CI logs
            sys.stderr.write(proc.stdout)
            sys.stderr.write(proc.stderr)
            raise SystemExit(f"bench_shard child --devices {d} failed")
        rows[d] = json.loads(proc.stdout.strip().splitlines()[-1])
        r = rows[d]
        print(
            f"devices {d}  sweep {r['sweep_s']*1e3:8.1f} ms cold / "
            f"{r['steady_sweep_s']*1e3:8.1f} ms steady  "
            f"({r['candidates_per_s']:>12,} cand/s, compile "
            f"{r['compile_s']*1e3:6.0f} ms)"
        )

    # The contract before any throughput claim: every device count finds
    # the SAME best points and the SAME Pareto fronts, bit for bit.
    base = rows[DEVICE_COUNTS[0]]
    for d in DEVICE_COUNTS[1:]:
        assert rows[d]["best_metrics"] == base["best_metrics"], (
            f"devices={d} best metrics diverge from single-device"
        )
        assert rows[d]["pareto"] == base["pareto"], (
            f"devices={d} Pareto front diverges from single-device"
        )
        assert rows[d]["n_candidates"] == base["n_candidates"]

    speedup = {
        d: round(rows[d]["candidates_per_s"] / base["candidates_per_s"], 2)
        for d in DEVICE_COUNTS
    }
    machine = machine_metadata()
    record = {
        "bench": "shard",
        "smoke": args.smoke,
        "machine": machine,
        "metric_note": (
            "candidates_per_s = steady-state fleet sweep throughput (warm "
            "executable, the co-search inner loop); cold variants include "
            "the one-off XLA compile.  d=1 is the plain single-device "
            "program, d>1 shards the hardware axis over a 1-D `hardware` "
            "mesh of d host devices (XLA_FLAGS=--xla_force_host_platform_"
            "device_count).  All device counts are asserted bit-identical "
            "on best metrics AND full Pareto fronts before speedups are "
            "reported.  Host-platform devices share physical cores: "
            "speedup saturates at machine.cpu_count, so interpret "
            "speedup_vs_1_device against that."
        ),
        "n_workloads": base["n_workloads"],
        "n_hw_configs": base["n_hw_configs"],
        "n_candidates": base["n_candidates"],
        "device_counts": list(DEVICE_COUNTS),
        "runs": {str(d): rows[d] for d in DEVICE_COUNTS},
        "speedup_vs_1_device": {str(d): speedup[d] for d in DEVICE_COUNTS},
        "pareto_front_sizes": {
            name: front["size"] for name, front in base["pareto"].items()
        },
    }
    OUT.write_text(json.dumps(record, indent=2) + "\n")
    print(f"\n[bench_shard] {base['n_candidates']:,} candidates x "
          f"{len(DEVICE_COUNTS)} device counts -> {OUT}")
    print(f"[bench_shard] speedup vs 1 device: {speedup} "
          f"(physical cores: {machine['cpu_count']})")
    if args.require_speedup:
        assert speedup[8] >= 3.0, (
            f"8-device sweep only {speedup[8]}x vs 1 device "
            f"(cores: {machine['cpu_count']})"
        )


if __name__ == "__main__":
    main()
