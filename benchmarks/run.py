"""Benchmark harness — one function per paper table/figure + system tables.

Prints ``name,us_per_call,derived`` CSV rows (one per table entry) and a
human-readable block per table.  Usage: ``python -m benchmarks.run``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import REGISTRY, SHAPES
from repro.core import fusion, metrics as M
from repro.core.arch import (
    Constraints, DLAConfig, PAPER_CONSTRAINTS, PAPER_OPTIMAL_CONFIG,
    default_config_space, paper_config_space,
)
from repro.core.flow import compare_fusion, run_flow
from repro.core.ir import residual_block_ir, resnet18_ir, vgg16_ir
from repro.core.planner import plan_model

ROWS: list[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row)


def timed(fn, *args, reps=3, **kw):
    fn(*args, **kw)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / reps * 1e6


# ---------------------------------------------------------------------------
def table1_vgg16_flow():
    """Paper Sec. III: optimal config under the four constraints + the
    fusion-vs-layer-by-layer reductions (paper: (4,4,4,4); 55.6/36.7/49.2%).
    """
    print("\n== table1: VGG-16 optimisation flow (paper Sec. III) ==")
    ir = vgg16_ir(pool_mode="separate")
    res, us = timed(
        run_flow, ir, config_space=paper_config_space(),
        constraints=PAPER_CONSTRAINTS, groupings="pool",
    )
    emit("table1.optimal_config", us,
         f"{res.best_hw.style}(F={res.best_hw.f1}x{res.best_hw.f2}x"
         f"{res.best_hw.f3}x{res.best_hw.f4});paper=(4x4x4x4)")
    cmp = compare_fusion(ir, PAPER_OPTIMAL_CONFIG)
    emit("table1.bw_reduction_pct", us, f"{cmp.bw_reduction*100:.1f};paper=55.6")
    emit("table1.latency_reduction_pct", us,
         f"{cmp.latency_reduction*100:.1f};paper=36.7")
    emit("table1.energy_reduction_pct", us,
         f"{cmp.energy_reduction*100:.1f};paper=49.2")
    emit("table1.lbl_meets_constraints", us, str(cmp.lbl.meets(PAPER_CONSTRAINTS)))
    emit("table1.fused_meets_constraints", us,
         str(cmp.fused.meets(PAPER_CONSTRAINTS)))
    print(cmp.describe())


def table2_energy_per_group():
    """Paper Fig. 2: per-fusion-group energy, fused vs layer-by-layer."""
    print("\n== table2: energy per fusion group (paper Fig. 2) ==")
    from repro.core.ir import NetworkIR

    ir = vgg16_ir(pool_mode="separate")
    hw = PAPER_OPTIMAL_CONFIG
    cuts = ir.pool_boundary_cuts()
    groups = M.groups_from_cuts(cuts)
    t0 = time.perf_counter()
    for gi, g in enumerate(groups):
        sub_ir = NetworkIR(f"g{gi}", tuple(ir.layers[g[0] : g[-1] + 1]))
        lbl = M.energy_ref(sub_ir, fusion.layer_by_layer_cuts(len(sub_ir)), hw)
        fus = M.energy_ref(sub_ir, np.zeros(len(sub_ir) - 1, bool), hw)
        emit(f"table2.group{gi+1}_energy_mJ", 0.0,
             f"lbl={lbl/1e6:.2f};fused={fus/1e6:.2f};"
             f"red={100*(1-fus/lbl):.1f}%")
    us = (time.perf_counter() - t0) * 1e6 / len(groups)
    emit("table2.us_per_group", us, f"{len(groups)}groups")


def table3_arch_compare():
    """Hsiao [2] vs VWA [3] across uniform configs (evaluator application)."""
    print("\n== table3: accelerator architecture comparison ==")
    ir = vgg16_ir(pool_mode="separate")
    cuts = ir.pool_boundary_cuts()
    for style, f3 in (("hsiao", None), ("vwa", 3)):
        for f in (4, 8):
            hw = DLAConfig(style, f, f, f3 or f, f)
            m, us = timed(M.evaluate_ref, ir, cuts, hw, reps=5)
            emit(f"table3.{style}_{f}", us,
                 f"lat={m.latency_cycles/1e6:.2f}Mcyc;E={m.energy_nj/1e6:.1f}mJ;"
                 f"A={m.area_um2/1e6:.1f}mm2;BW={m.bandwidth_words/1e6:.1f}MB")


def table4_sweep_throughput():
    """Vectorised flow throughput: the exhaustive sweep as one XLA program."""
    print("\n== table4: evaluator sweep throughput ==")
    ir = vgg16_ir(pool_mode="separate")
    res, us = timed(
        run_flow, ir, constraints=PAPER_CONSTRAINTS, groupings="exhaustive",
        reps=1,
    )
    emit("table4.exhaustive_sweep", us,
         f"{res.n_candidates}cand;{res.candidates_per_second:,.0f}cand_per_s")
    res2, us2 = timed(
        run_flow, ir, constraints=PAPER_CONSTRAINTS, groupings="pool", reps=3,
    )
    emit("table4.predefined_sweep", us2,
         f"{res2.n_candidates}cand;{res2.candidates_per_second:,.0f}cand_per_s")


def table5_kernel_fusion():
    """Per-kernel Eq. (1) HBM-traffic savings (fused vs layer-by-layer) and
    interpret-mode correctness residual vs the jnp oracle."""
    print("\n== table5: kernel fusion groups ==")
    from repro.kernels import ops, ref

    key = jax.random.key(0)
    # attention: (Sq x Skv) score frame stays in VMEM
    B, S, H, KV, hd = 1, 512, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.key(1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.key(2), (B, S, KV, hd))
    out, us = timed(ops.attention, q, k, v, reps=1)
    err = float(jnp.abs(out - ref.flash_attention_ref(q, k, v)).max())
    unfused = (B * H * S * S * 4) * 2 + B * S * (H + 2 * KV) * hd * 4
    fused = B * S * (H + 2 * KV) * hd * 4 * 2
    emit("table5.flash_attention", us,
         f"hbm_lbl={unfused/2**20:.0f}MiB;hbm_fused={fused/2**20:.1f}MiB;"
         f"saving={100*(1-fused/unfused):.1f}%;maxerr={err:.1e}")
    # mlp: (T x ff) hidden frame stays in VMEM
    T, d, ff = 256, 128, 512
    x = jax.random.normal(key, (T, d))
    w1 = jax.random.normal(jax.random.key(3), (d, ff)) * 0.1
    w3 = jax.random.normal(jax.random.key(4), (d, ff)) * 0.1
    w2 = jax.random.normal(jax.random.key(5), (ff, d)) * 0.1
    out, us = timed(ops.mlp, x, w1, w2, w3, reps=1)
    err = float(jnp.abs(out - ref.fused_mlp_ref(x, w1, w2, w3)).max())
    unfused = (2 * T * ff + T * (2 * d + ff)) * 4
    fusedb = (2 * T * d) * 4
    emit("table5.fused_mlp", us,
         f"hbm_lbl={unfused/2**20:.1f}MiB;hbm_fused={fusedb/2**20:.2f}MiB;"
         f"saving={100*(1-fusedb/unfused):.1f}%;maxerr={err:.1e}")
    # conv+pool: pre-pool frame stays in VMEM (the paper's own fusion)
    xi = jax.random.normal(key, (1, 32, 32, 16))
    wc = jax.random.normal(jax.random.key(6), (3, 3, 16, 32)) * 0.1
    bc = jnp.zeros((32,))
    out, us = timed(ops.conv3x3, xi, wc, bc, pool=True, reps=1)
    err = float(jnp.abs(out - ref.fused_conv3x3_ref(xi, wc, bc, pool=True)).max())
    unfused = (32 * 32 * 32 * 2 + 16 * 16 * 32) * 4
    fusedb = 16 * 16 * 32 * 4
    emit("table5.fused_conv_pool", us,
         f"hbm_lbl={unfused/2**10:.0f}KiB;hbm_fused={fusedb/2**10:.0f}KiB;"
         f"saving={100*(1-fusedb/unfused):.1f}%;maxerr={err:.1e}")
    # mamba scan: state sequence never materialised
    Bs, Ss, di, ds = 1, 256, 64, 16
    dA = jax.random.uniform(key, (Bs, Ss, di, ds), minval=0.5, maxval=0.98)
    dBx = jax.random.normal(jax.random.key(7), (Bs, Ss, di, ds)) * 0.1
    C = jax.random.normal(jax.random.key(8), (Bs, Ss, ds))
    out, us = timed(ops.ssm_scan, dA, dBx, C, chunk=64, block_d=32, reps=1)
    err = float(jnp.abs(out - ref.selective_scan_ref(dA, dBx, C)).max())
    unfused = Bs * Ss * di * ds * 4 * 3  # h sequence write+read + dA/dBx
    fusedb = Bs * Ss * di * ds * 4 * 2  # dA/dBx streamed once
    emit("table5.mamba_scan", us,
         f"hbm_lbl={unfused/2**20:.1f}MiB;hbm_fused={fusedb/2**20:.1f}MiB;"
         f"saving={100*(1-fusedb/unfused):.1f}%;maxerr={err:.1e}")


def table6_planner():
    """The evaluator driving kernel selection for every assigned arch."""
    print("\n== table6: fusion planner decisions (10 archs) ==")
    for name, cfg in sorted(REGISTRY.items()):
        plan, us = timed(plan_model, cfg, 4096, reps=2)
        emit(f"table6.{name}", us,
             f"attn={plan.attn_block_q}x{plan.attn_block_k};"
             f"mlp={plan.mlp_block_m}x{plan.mlp_block_f};"
             f"blockBWsave={plan.bw_saving*100:.1f}%")


def table7_resnet_fusion():
    """Graph-IR fusion on residual networks — groupings the chain IR could
    never express (the skip tensor stays on-chip across a fused block)."""
    print("\n== table7: resnet fusion (graph IR; beyond-paper) ==")
    hw = PAPER_OPTIMAL_CONFIG

    # One basic block: brute-force edge-cut optimum vs the best grouping a
    # chain IR could express (= the skip edge forced to round-trip DRAM).
    rb = residual_block_ir()
    lbl_bw = M.bandwidth_ref(rb, fusion.layer_by_layer_cuts(rb))
    dag, us = timed(fusion.brute_force_min_bw, rb)
    dag_bw = M.bandwidth_ref(rb, dag.cuts)
    skip_idx = next(
        k for k, e in enumerate(rb.edges) if (e.src, e.dst) == (0, 3)
    )
    # Chain-best = the optimum with the skip edge forced to round-trip DRAM,
    # scored by the batched evaluator in one call.
    valid = fusion.enumerate_valid_edge_cuts(rb)
    chain_bw = float(M.bandwidth_batch_graph(rb, valid[valid[:, skip_idx]]).min())
    emit("table7.resblock_bw_reduction_pct", us,
         f"{100*(1-dag_bw/lbl_bw):.1f};chain_best={100*(1-chain_bw/lbl_bw):.1f};"
         f"dag_only_delta={100*(chain_bw-dag_bw)/lbl_bw:.1f}")

    # Full ResNet-18: search-grouped vs layer-by-layer under the paper's
    # hw.  The 38-edge DAG is beyond the 2^22 enumeration wall; the
    # frontier DP certifies the optimum exactly (engine provenance below),
    # where earlier revisions could only report a beam heuristic.
    g = resnet18_ir()
    search, us = timed(fusion.optimal_cuts, g, reps=1)
    cmp = compare_fusion(g, hw, fused_cuts=search.cuts)
    emit("table7.resnet18_bw_reduction_pct", us, f"{cmp.bw_reduction*100:.1f}")
    emit("table7.resnet18_latency_reduction_pct", us,
         f"{cmp.latency_reduction*100:.1f}")
    emit("table7.resnet18_energy_reduction_pct", us,
         f"{cmp.energy_reduction*100:.1f}")
    emit("table7.resnet18_groups", us,
         f"{search.n_groups};engine={search.engine};exact={search.exact}")
    print(cmp.describe())


def table9_frontend_workloads():
    """Traced-model scenarios (frontend; beyond-paper): a depthwise
    MobileNet stack and a gated transformer MLP block — workloads no hand
    builder existed for — through the full grouping search + flow."""
    print("\n== table9: traced frontend workloads (beyond-paper) ==")
    from repro.core.frontend import mlp_block_graph, mobilenet_graph

    g, us = timed(mobilenet_graph, reps=2)
    emit("table9.mobilenet_trace", us,
         f"{g.n_nodes}nodes;{g.n_edges}edges;"
         f"dw={sum(1 for n in g.nodes if n.groups > 1)}")
    best, us = timed(fusion.optimal_cuts, g, reps=1)
    lbl = M.bandwidth_ref(g, fusion.layer_by_layer_cuts(g))
    bw = M.bandwidth_ref(g, best.cuts)
    emit("table9.mobilenet_bw_reduction_pct", us,
         f"{100*(1-bw/lbl):.1f};groups={best.n_groups};engine={best.engine}")
    res, us = timed(run_flow, g, groupings="search", reps=1)
    emit("table9.mobilenet_flow", us,
         f"{res.n_candidates}cand;E={res.best_metrics.energy_nj/1e6:.2f}mJ")

    m, us = timed(mlp_block_graph, d_model=1024, d_ff=4096, seq_len=512,
                  reps=2)
    emit("table9.mlp_trace", us, f"{m.n_nodes}nodes;{m.n_edges}edges")
    best, us = timed(fusion.optimal_cuts, m, reps=1)
    lbl = M.bandwidth_ref(m, fusion.layer_by_layer_cuts(m))
    bw = M.bandwidth_ref(m, best.cuts)
    emit("table9.mlp_bw_reduction_pct", us,
         f"{100*(1-bw/lbl):.1f};groups={best.n_groups};engine={best.engine}")
    # The 25 M-MAC gated block busts the paper's CNN-scale envelope; lift
    # the latency/energy ceilings and let the flow pick the best config.
    loose = Constraints(max_latency_cycles=1e9, max_energy_nj=1e9)
    res, us = timed(run_flow, m, groupings="search", constraints=loose, reps=1)
    emit("table9.mlp_flow", us,
         f"{res.n_candidates}cand;E={res.best_metrics.energy_nj/1e6:.2f}mJ")


def table7_roofline_summary():
    """Condensed §Roofline: per (arch x shape) single-pod bound + mfu cap."""
    print("\n== table7: dry-run roofline summary (single pod) ==")
    import json
    import pathlib

    droot = pathlib.Path(__file__).resolve().parents[1] / "experiments/dryrun"
    if not droot.exists():
        emit("table7.missing", 0.0, "run launch/dryrun --all first")
        return
    for f in sorted(droot.glob("*__single.json")):
        rec = json.loads(f.read_text())
        if rec.get("tag"):
            continue  # §Perf iteration records are reported separately
        rl = rec["roofline"]
        step_s = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        emit(
            f"table7.{rec['arch']}.{rec['shape']}", 0.0,
            f"bound={rl['bound']};mfu_cap={rl['mfu_bound']*100:.1f}%;"
            f"step_ms={step_s*1e3:.1f}",
        )


def table8_perf_iterations():
    """§Perf hillclimb: every tagged dry-run record vs its baseline."""
    print("\n== table8: perf iterations (tagged dry-run records) ==")
    import json
    import pathlib

    droot = pathlib.Path(__file__).resolve().parents[1] / "experiments/dryrun"
    if not droot.exists():
        emit("table8.missing", 0.0, "run launch/dryrun --all first")
        return
    recs = [json.loads(f.read_text()) for f in sorted(droot.glob("*.json"))]
    base = {
        (r["arch"], r["shape"], r["mesh"]): r for r in recs if not r.get("tag")
    }
    for r in recs:
        if not r.get("tag"):
            continue
        b = base.get((r["arch"], r["shape"], r["mesh"]))
        if b is None:
            continue
        step = lambda x: max(
            x["roofline"]["compute_s"], x["roofline"]["memory_s"],
            x["roofline"]["collective_s"],
        )
        emit(
            f"table8.{r['arch']}.{r['shape']}.{r['mesh']}.{r['tag']}", 0.0,
            f"step={step(r)*1e3:.1f}ms;baseline={step(b)*1e3:.1f}ms;"
            f"speedup={step(b)/max(step(r),1e-12):.2f}x;"
            f"bound={r['roofline']['bound']};"
            f"mfu={r['roofline']['mfu_bound']*100:.2f}%",
        )


TABLES = [
    table1_vgg16_flow,
    table2_energy_per_group,
    table3_arch_compare,
    table4_sweep_throughput,
    table5_kernel_fusion,
    table6_planner,
    table7_resnet_fusion,
    table7_roofline_summary,
    table8_perf_iterations,
    table9_frontend_workloads,
]


def main() -> None:
    print("name,us_per_call,derived")
    for t in TABLES:
        t()
    print(f"\n[benchmarks] {len(ROWS)} rows emitted")


if __name__ == "__main__":
    main()
