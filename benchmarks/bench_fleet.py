"""Fleet benchmark — the XLA recompile tax vs shape buckets + run_fleet.

``BENCH_search.json`` showed the steady-state sweep is nearly free while the
*cold* path is dominated by per-shape XLA compilation: every distinct graph
signature pays its own compile.  This benchmark times a multi-model sweep
over every in-repo workload three ways, each in a **fresh subprocess** (cold
caches, the honest serving-system number):

* ``sequential`` — per-graph :func:`repro.core.flow.run_flow` with
  ``bucket=False`` (the pre-bucketing behaviour: one XLA compile per
  distinct graph shape);
* ``bucketed``   — per-graph ``run_flow`` with shape buckets (default): all
  workloads share one ``(L, E, C)`` bucket, so the fleet pays ONE compile;
* ``fleet``      — :func:`repro.core.flow.run_fleet`: all graphs stacked
  and evaluated as a single vmapped XLA program (one compile, one dispatch).

Each child re-runs the loop a second time for the steady-state split, and
reports the per-graph best metrics so the parent can assert all three modes
agree bit-for-bit — plus the executable-cache accounting (``bucketed`` and
``fleet`` must compile exactly once).  Groupings use the paper's pool-
boundary policy so the timed section isolates the evaluator cold path
rather than the (mode-independent) grouping search.

Writes ``BENCH_fleet.json`` at the repo root.

Usage: ``python benchmarks/bench_fleet.py [--smoke]`` (``--smoke`` = the
six-workload subset, for the CI smoke job).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_fleet.json"

try:  # running from a checkout without `pip install -e .`
    import repro  # noqa: F401
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(ROOT / "src"))

from machine_meta import machine_metadata


def _workloads(smoke: bool):
    """name -> GraphIR for every in-repo workload (distinct shapes)."""
    from repro.core.frontend import mlp_block_graph, mobilenet_graph
    from repro.core.ir import (
        as_graph,
        encoder_decoder_ir,
        lm_ir,
        residual_block_ir,
        resnet18_ir,
        transformer_block_ir,
        vgg16_ir,
    )

    works = {
        "vgg16": as_graph(vgg16_ir(pool_mode="separate")),
        "resnet18": resnet18_ir(),
        "mobilenet": mobilenet_graph(),
        "mlp_block": as_graph(mlp_block_graph()),
        "encoder_decoder": encoder_decoder_ir(),
        "residual_block": residual_block_ir(),
    }
    if not smoke:
        works["vgg16_absorbed"] = as_graph(vgg16_ir(pool_mode="absorbed"))
        works["transformer_block"] = as_graph(transformer_block_ir(
            name="tb", d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
            seq_len=2048,
        ))
        works["lm_2block"] = as_graph(lm_ir(
            name="lm", n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
            d_ff=4096, seq_len=2048, repeat=2,
        ))
        works["lm_3block"] = as_graph(lm_ir(
            name="lm3", n_layers=24, d_model=2048, n_heads=16, n_kv_heads=4,
            d_ff=8192, seq_len=1024, repeat=3,
        ))
    return works


def _metrics_rows(results) -> dict:
    return {
        name: [
            r.best_metrics.bandwidth_words,
            r.best_metrics.latency_cycles,
            r.best_metrics.energy_nj,
            r.best_metrics.area_um2,
        ]
        for name, r in results.items()
    }


def run_child(mode: str, smoke: bool) -> None:
    """One cold measurement in this (fresh) process; JSON on the last line."""
    from repro.core import flow
    from repro.core.arch import Constraints

    loose = Constraints(*[float("inf")] * 4)
    works = _workloads(smoke)

    def sweep():
        if mode == "fleet":
            fl = flow.run_fleet(
                list(works.values()), groupings="pool", constraints=loose
            )
            results = dict(zip(works, fl.results))
            return results, fl.compile_seconds, fl.sweep_seconds
        bucket = mode == "bucketed"
        results = {
            name: flow.run_flow(
                g, groupings="pool", constraints=loose, bucket=bucket
            )
            for name, g in works.items()
        }
        compile_s = sum(r.compile_seconds for r in results.values())
        sweep_s = sum(r.sweep_seconds for r in results.values())
        return results, compile_s, sweep_s

    t0 = time.perf_counter()
    results, compile_s, sweep_s = sweep()
    cold_wall = time.perf_counter() - t0
    t0 = time.perf_counter()
    results2, _, steady_sweep = sweep()
    steady_wall = time.perf_counter() - t0

    stats = flow.sweep_cache_stats()
    expect = len(works) if mode == "sequential" else 1
    assert stats["misses"] == expect, (
        f"{mode}: expected {expect} compiled executable(s), "
        f"cache reports {stats}"
    )
    assert _metrics_rows(results) == _metrics_rows(results2)
    print(json.dumps({
        "mode": mode,
        "n_workloads": len(works),
        "cold_wall_s": round(cold_wall, 6),
        "steady_wall_s": round(steady_wall, 6),
        "compile_s": round(compile_s, 6),
        "sweep_s": round(sweep_s, 6),
        "steady_sweep_s": round(steady_sweep, 6),
        "executables_compiled": stats["misses"],
        "cache": stats,
        "best_metrics": _metrics_rows(results),
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="six-workload subset (CI)")
    ap.add_argument("--mode", choices=["sequential", "bucketed", "fleet"],
                    help="(internal) run one cold measurement in-process")
    args = ap.parse_args()
    if args.mode:
        run_child(args.mode, args.smoke)
        return

    rows: dict[str, dict] = {}
    for mode in ("sequential", "bucketed", "fleet"):
        cmd = [sys.executable, str(pathlib.Path(__file__).resolve()),
               "--mode", mode]
        if args.smoke:
            cmd.append("--smoke")
        proc = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT)
        if proc.returncode != 0:  # surface the child's traceback in CI logs
            sys.stderr.write(proc.stdout)
            sys.stderr.write(proc.stderr)
            raise SystemExit(f"bench_fleet child --mode {mode} failed")
        rows[mode] = json.loads(proc.stdout.strip().splitlines()[-1])
        r = rows[mode]
        print(
            f"{mode:10s} cold {r['cold_wall_s']*1e3:8.0f} ms "
            f"(compile {r['compile_s']*1e3:7.0f} ms, "
            f"{r['executables_compiled']} executables)  "
            f"steady {r['steady_wall_s']*1e3:7.1f} ms"
        )

    # All three modes must agree bit-for-bit on every workload's best point.
    assert rows["sequential"]["best_metrics"] == rows["bucketed"]["best_metrics"]
    assert rows["sequential"]["best_metrics"] == rows["fleet"]["best_metrics"]

    seq, fleet = rows["sequential"], rows["fleet"]
    speedup_fleet = seq["cold_wall_s"] / fleet["cold_wall_s"]
    speedup_bucketed = seq["cold_wall_s"] / rows["bucketed"]["cold_wall_s"]
    record = {
        "bench": "fleet",
        "smoke": args.smoke,
        "machine": machine_metadata(),
        "metric_note": (
            "cold_wall_s = first multi-model sweep in a fresh process "
            "(includes XLA compilation); steady_wall_s = the same sweep "
            "re-run with warm executable caches.  sequential compiles one "
            "executable per distinct graph shape; bucketed and fleet "
            "compile exactly one for the whole fleet (asserted via the "
            "sweep-cache accounting).  All modes are asserted bit-identical "
            "on every workload's best metrics."
        ),
        "n_workloads": seq["n_workloads"],
        "modes": rows,
        "cold_speedup_fleet_vs_sequential": round(speedup_fleet, 2),
        "cold_speedup_bucketed_vs_sequential": round(speedup_bucketed, 2),
    }
    OUT.write_text(json.dumps(record, indent=2) + "\n")
    print(f"\n[bench_fleet] {len(rows)} modes x {seq['n_workloads']} "
          f"workloads -> {OUT}")
    print(f"[bench_fleet] cold-path speedup: fleet {speedup_fleet:.1f}x, "
          f"bucketed run_flow {speedup_bucketed:.1f}x vs sequential")


if __name__ == "__main__":
    main()
