"""Serving benchmark — latency and degradation of the planning service.

Drives a paced request stream (deterministic inter-arrival at an offered
QPS) through :class:`repro.core.service.PlanningService` at several load
levels, with and without active fault injection
(:class:`repro.testing.faults.FaultInjector`: recurring transient sweep
failures + executable-cache eviction storms).  Each (load, fault-mode)
measurement runs in a **fresh subprocess** — cold process, one warmup plan
to populate the executable cache (serving steady state), then the timed
stream.

Reported per level: p50/p99 response latency, achieved QPS, degradation
rate (responses answered below the exact rung by the deadline ladder),
plan-cache hit rate, and the response taxonomy split.  The parent asserts
the service contract before writing anything: every request — faults or
not — got exactly one typed response.

Writes ``BENCH_serve.json`` at the repo root.

Usage: ``python benchmarks/bench_serve.py [--smoke]`` (``--smoke`` = one
load level, fewer requests, for the CI smoke job).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_serve.json"

try:  # running from a checkout without `pip install -e .`
    import repro  # noqa: F401
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(ROOT / "src"))

from machine_meta import machine_metadata

# Per-request deadline: generous at idle, binding once a backlog forms —
# the knob that makes the degradation ladder visible under load.  Sized
# against the heaviest workload in the stream (resnet18: ~25 ms exact
# frontier-DP search), so queue wait under load pushes requests down the
# ladder instead of straight to DeadlineExceeded.
DEADLINE_S = 0.06


def _percentile(xs: list[float], q: float) -> float:
    ys = sorted(xs)
    if not ys:
        return float("nan")
    i = min(len(ys) - 1, int(round(q * (len(ys) - 1))))
    return ys[i]


def run_child(qps: float, n: int, faults: bool) -> None:
    """One paced-stream measurement in this (fresh) process; JSON on the
    last line."""
    from repro.core.arch import Constraints, paper_config_space
    from repro.core.errors import EvaluatorError
    from repro.core.ir import resnet18_ir
    from repro.core.service import PlanRequest, PlanningService
    from repro.testing.faults import FaultInjector, _valid_graphs

    injector = (
        FaultInjector(transient_every=3, evict_every=5) if faults else None
    )
    svc = PlanningService(
        config_space=paper_config_space(),
        # Loose constraints: the benchmark measures serving behaviour, so
        # the only failure modes left are deadlines and injected faults.
        constraints=Constraints(*[float("inf")] * 4),
        faults=injector,
        backoff_seconds=0.0,
        max_batch=16,
        max_queue_depth=4 * n,
    )
    graphs = _valid_graphs() + [resnet18_ir()]
    # Cycle length coprime to the graph cycle, so the stream covers the
    # full (graph, budget) key space yet still repeats -> cache hits.
    budgets = [float("inf"), 4e6, 1e6]

    # Warmup: compile the fleet executable once (steady-state serving).
    svc.plan(PlanRequest(graph=graphs[0]))

    interval = 1.0 / qps
    rids = []
    t_start = time.perf_counter()
    for i in range(n):
        target = t_start + i * interval
        # Pace: tick while waiting for the next arrival, else sleep.
        while time.perf_counter() < target:
            if svc.queue_depth:
                svc.tick()
            else:
                time.sleep(min(1e-4, max(0.0,
                                         target - time.perf_counter())))
        rids.append(svc.submit(PlanRequest(
            graph=graphs[i % len(graphs)],
            sram_budget_words=budgets[i % len(budgets)],
            deadline_seconds=DEADLINE_S,
        )))
    svc.drain()
    wall = time.perf_counter() - t_start

    latencies, outcomes = [], {}
    n_ok = n_degraded = n_cached = 0
    for rid in rids:
        resp = svc.collect(rid)
        assert resp is not None, f"request {rid}: no response"
        if resp.ok:
            n_ok += 1
            n_degraded += resp.degraded
            n_cached += resp.from_cache
            key = f"ok:{resp.rung or 'cache'}"
        else:
            assert isinstance(resp.error, EvaluatorError), (
                f"request {rid}: untyped {type(resp.error).__name__}"
            )
            key = resp.error_type
        outcomes[key] = outcomes.get(key, 0) + 1
        latencies.append(resp.latency_seconds)

    stats = svc.stats()
    print(json.dumps({
        "qps_offered": qps,
        "faults": faults,
        "n_requests": n,
        "achieved_qps": round(n / wall, 2),
        "p50_ms": round(_percentile(latencies, 0.50) * 1e3, 3),
        "p99_ms": round(_percentile(latencies, 0.99) * 1e3, 3),
        "ok_rate": round(n_ok / n, 4),
        "degradation_rate": round(n_degraded / max(n_ok, 1), 4),
        "cache_hit_rate": round(n_cached / max(n_ok, 1), 4),
        "outcomes": outcomes,
        "transient_retries": stats["counters"].get("transient_retries", 0),
        "injected": dict(injector.counts) if injector else {},
        "plan_cache": stats["plan_cache"],
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one load level, fewer requests (CI)")
    ap.add_argument("--qps", type=float, help="(internal) child load level")
    ap.add_argument("--n", type=int, help="(internal) child request count")
    ap.add_argument("--faults", action="store_true",
                    help="(internal) child fault injection on")
    args = ap.parse_args()
    if args.qps:
        run_child(args.qps, args.n, args.faults)
        return

    levels = [100.0] if args.smoke else [25.0, 100.0, 400.0]
    n = 24 if args.smoke else 80
    rows: list[dict] = []
    for qps in levels:
        for faults in (False, True):
            cmd = [sys.executable, str(pathlib.Path(__file__).resolve()),
                   "--qps", str(qps), "--n", str(n)]
            if faults:
                cmd.append("--faults")
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  cwd=ROOT)
            if proc.returncode != 0:  # surface the child's traceback
                sys.stderr.write(proc.stdout)
                sys.stderr.write(proc.stderr)
                raise SystemExit(
                    f"bench_serve child qps={qps} faults={faults} failed"
                )
            row = json.loads(proc.stdout.strip().splitlines()[-1])
            rows.append(row)
            tag = "faults" if faults else "clean "
            print(
                f"qps {qps:6.0f} [{tag}] p50 {row['p50_ms']:8.2f} ms  "
                f"p99 {row['p99_ms']:8.2f} ms  "
                f"degraded {row['degradation_rate']*100:5.1f}%  "
                f"ok {row['ok_rate']*100:5.1f}%"
            )

    # Contract: every stream — with or without faults — is 100% answered
    # (the children already asserted per-request typed responses).
    for row in rows:
        assert sum(row["outcomes"].values()) == row["n_requests"], row
        assert row["ok_rate"] > 0, row
    # Fault injection really fired in every faulted stream.
    assert all(r["injected"].get("injected_transients", 0) > 0
               for r in rows if r["faults"])

    record = {
        "bench": "serve",
        "smoke": args.smoke,
        "machine": machine_metadata(),
        "metric_note": (
            "Paced request stream at each offered QPS in a fresh process "
            "(one warmup plan, then the timed stream).  Latency is "
            "submit-to-response per request; degradation_rate is the "
            "fraction of successful responses the deadline ladder answered "
            f"below the exact rung (deadline {DEADLINE_S}s).  'faults' "
            "rows run under active injection: a transient sweep failure "
            "every 3rd sweep and an executable-cache eviction storm every "
            "5th tick — the contract (one typed response per request) is "
            "asserted in both modes before this file is written."
        ),
        "deadline_seconds": DEADLINE_S,
        "levels": rows,
    }
    OUT.write_text(json.dumps(record, indent=2) + "\n")
    print(f"\n[bench_serve] {len(rows)} (load x fault) levels -> {OUT}")


if __name__ == "__main__":
    main()
