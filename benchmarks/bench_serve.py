"""Serving benchmark — latency and degradation of the planning service.

Drives a paced request stream (deterministic inter-arrival at an offered
QPS) through :class:`repro.core.service.PlanningService` at several load
levels, with and without active fault injection
(:class:`repro.testing.faults.FaultInjector`: recurring transient sweep
failures + executable-cache eviction storms).  Each (load, fault-mode)
measurement runs in a **fresh subprocess** — cold process, one warmup plan
to populate the executable cache (serving steady state), then the timed
stream.

Reported per level: p50/p99 response latency, achieved QPS, degradation
rate (responses answered below the exact rung by the deadline ladder),
plan-cache hit rate, and the response taxonomy split.  The parent asserts
the service contract before writing anything: every request — faults or
not — got exactly one typed response.

With ``--async`` three more measurement families run (PR 9):

* the same paced stream through :class:`AsyncPlanningService`
  (submit-to-future-resolution latency, i.e. transport included);
* **cancellation latency** — chunk-stalled sweeps cancelled mid-flight,
  measuring cancel-to-response time (the chunk-boundary guarantee);
* **recovery replay** — a journaled run killed mid-stream, then
  ``PlanningService.recover`` timed: WAL replay cost and the re-run cost
  for the requests the crash left in flight.

Writes ``BENCH_serve.json`` at the repo root.

Usage: ``python benchmarks/bench_serve.py [--smoke] [--async]``
(``--smoke`` = one load level, fewer requests, for the CI smoke job).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_serve.json"

try:  # running from a checkout without `pip install -e .`
    import repro  # noqa: F401
except ImportError:  # pragma: no cover
    sys.path.insert(0, str(ROOT / "src"))

from machine_meta import machine_metadata

# Per-request deadline: generous at idle, binding once a backlog forms —
# the knob that makes the degradation ladder visible under load.  Sized
# against the heaviest workload in the stream (resnet18: ~25 ms exact
# frontier-DP search), so queue wait under load pushes requests down the
# ladder instead of straight to DeadlineExceeded.
DEADLINE_S = 0.06


def _percentile(xs: list[float], q: float) -> float:
    ys = sorted(xs)
    if not ys:
        return float("nan")
    i = min(len(ys) - 1, int(round(q * (len(ys) - 1))))
    return ys[i]


def run_child(qps: float, n: int, faults: bool) -> None:
    """One paced-stream measurement in this (fresh) process; JSON on the
    last line."""
    from repro.core.arch import Constraints, paper_config_space
    from repro.core.errors import EvaluatorError
    from repro.core.ir import resnet18_ir
    from repro.core.service import PlanRequest, PlanningService
    from repro.testing.faults import FaultInjector, _valid_graphs

    injector = (
        FaultInjector(transient_every=3, evict_every=5) if faults else None
    )
    svc = PlanningService(
        config_space=paper_config_space(),
        # Loose constraints: the benchmark measures serving behaviour, so
        # the only failure modes left are deadlines and injected faults.
        constraints=Constraints(*[float("inf")] * 4),
        faults=injector,
        backoff_seconds=0.0,
        max_batch=16,
        max_queue_depth=4 * n,
    )
    graphs = _valid_graphs() + [resnet18_ir()]
    # Cycle length coprime to the graph cycle, so the stream covers the
    # full (graph, budget) key space yet still repeats -> cache hits.
    budgets = [float("inf"), 4e6, 1e6]

    # Warmup: compile the fleet executable once (steady-state serving).
    svc.plan(PlanRequest(graph=graphs[0]))

    interval = 1.0 / qps
    rids = []
    t_start = time.perf_counter()
    for i in range(n):
        target = t_start + i * interval
        # Pace: tick while waiting for the next arrival, else sleep.
        while time.perf_counter() < target:
            if svc.queue_depth:
                svc.tick()
            else:
                time.sleep(min(1e-4, max(0.0,
                                         target - time.perf_counter())))
        rids.append(svc.submit(PlanRequest(
            graph=graphs[i % len(graphs)],
            sram_budget_words=budgets[i % len(budgets)],
            deadline_seconds=DEADLINE_S,
        )))
    svc.drain()
    wall = time.perf_counter() - t_start

    latencies, outcomes = [], {}
    n_ok = n_degraded = n_cached = 0
    for rid in rids:
        resp = svc.collect(rid)
        assert resp is not None, f"request {rid}: no response"
        if resp.ok:
            n_ok += 1
            n_degraded += resp.degraded
            n_cached += resp.from_cache
            key = f"ok:{resp.rung or 'cache'}"
        else:
            assert isinstance(resp.error, EvaluatorError), (
                f"request {rid}: untyped {type(resp.error).__name__}"
            )
            key = resp.error_type
        outcomes[key] = outcomes.get(key, 0) + 1
        latencies.append(resp.latency_seconds)

    stats = svc.stats()
    print(json.dumps({
        "qps_offered": qps,
        "faults": faults,
        "n_requests": n,
        "achieved_qps": round(n / wall, 2),
        "p50_ms": round(_percentile(latencies, 0.50) * 1e3, 3),
        "p99_ms": round(_percentile(latencies, 0.99) * 1e3, 3),
        "ok_rate": round(n_ok / n, 4),
        "degradation_rate": round(n_degraded / max(n_ok, 1), 4),
        "cache_hit_rate": round(n_cached / max(n_ok, 1), 4),
        "outcomes": outcomes,
        "transient_retries": stats["counters"].get("transient_retries", 0),
        "injected": dict(injector.counts) if injector else {},
        "plan_cache": stats["plan_cache"],
    }))


def run_child_async(qps: float, n: int) -> None:
    """The paced stream through the async transport.  Latency here is
    submit-to-future-resolution wall clock — inbox wait, worker loop, and
    delivery included — the number a remote caller would see."""
    import concurrent.futures

    from repro.core.arch import Constraints, paper_config_space
    from repro.core.ir import resnet18_ir
    from repro.core.service import AsyncPlanningService, PlanRequest
    from repro.testing.faults import _valid_graphs

    svc = AsyncPlanningService(
        config_space=paper_config_space(),
        constraints=Constraints(*[float("inf")] * 4),
        backoff_seconds=0.0,
        max_batch=16,
        max_queue_depth=4 * n,
    )
    graphs = _valid_graphs() + [resnet18_ir()]
    budgets = [float("inf"), 4e6, 1e6]
    svc.plan(PlanRequest(graph=graphs[0]), timeout=300)  # warmup compile

    latencies: list[float] = []  # appended from done-callbacks (GIL-atomic)
    futs = []
    interval = 1.0 / qps
    t_start = time.perf_counter()
    for i in range(n):
        target = t_start + i * interval
        while time.perf_counter() < target:
            time.sleep(min(1e-4, max(0.0, target - time.perf_counter())))
        t_sub = time.perf_counter()
        fut = svc.submit(PlanRequest(
            graph=graphs[i % len(graphs)],
            sram_budget_words=budgets[i % len(budgets)],
            deadline_seconds=DEADLINE_S,
        ))
        fut.add_done_callback(
            lambda f, t=t_sub: latencies.append(time.perf_counter() - t))
        futs.append(fut)
    concurrent.futures.wait(futs, timeout=300)
    wall = time.perf_counter() - t_start
    svc.shutdown(drain=True, timeout=300)

    assert all(f.done() for f in futs)
    responses = [f.result() for f in futs]
    n_ok = sum(r.ok for r in responses)
    outcomes: dict[str, int] = {}
    for r in responses:
        key = f"ok:{r.rung or 'cache'}" if r.ok else r.error_type
        outcomes[key] = outcomes.get(key, 0) + 1
    print(json.dumps({
        "qps_offered": qps,
        "n_requests": n,
        "achieved_qps": round(n / wall, 2),
        "p50_ms": round(_percentile(latencies, 0.50) * 1e3, 3),
        "p99_ms": round(_percentile(latencies, 0.99) * 1e3, 3),
        "ok_rate": round(n_ok / n, 4),
        "degradation_rate": round(
            sum(r.ok and r.degraded for r in responses) / max(n_ok, 1), 4),
        "outcomes": outcomes,
    }))


def run_child_cancel(rounds: int) -> None:
    """Mid-flight cancellation latency: every sweep is chunk-stalled so
    the cancel provably lands while the fleet program is running; the
    measured time is cancel() -> future resolution."""
    from repro.core.arch import Constraints, paper_config_space
    from repro.core.ir import residual_block_ir
    from repro.core.service import AsyncPlanningService, PlanRequest
    from repro.testing.faults import FaultInjector

    inj = FaultInjector(chunk_stall_seconds=0.05)
    svc = AsyncPlanningService(
        config_space=paper_config_space(),
        constraints=Constraints(*[float("inf")] * 4),
        backoff_seconds=0.0,
        hw_chunk=2,
        faults=inj,
    )
    g = residual_block_ir()
    lats = []
    for r in range(rounds):
        base = inj.counts["chunks"]
        # distinct budgets: cancelled answers are never cached, but keep
        # every round a genuine sweep regardless
        fut = svc.submit(PlanRequest(
            graph=g, sram_budget_words=float(2 ** r) * 1e5))
        deadline = time.perf_counter() + 60.0
        while inj.counts["chunks"] <= base:  # sweep provably in flight
            if time.perf_counter() > deadline:
                raise SystemExit("cancel bench: sweep never started")
            time.sleep(1e-3)
        t0 = time.perf_counter()
        assert svc.cancel(fut)
        resp = fut.result(timeout=300)
        lats.append(time.perf_counter() - t0)
        assert resp.error_type == "RequestCancelled", resp.error_type
    svc.shutdown(drain=True, timeout=300)
    print(json.dumps({
        "rounds": rounds,
        "chunk_stall_seconds": inj.chunk_stall_seconds,
        "hw_chunk": 2,
        "cancel_p50_ms": round(_percentile(lats, 0.50) * 1e3, 3),
        "cancel_p99_ms": round(_percentile(lats, 0.99) * 1e3, 3),
    }))


def run_child_recover(n: int) -> None:
    """Crash-recovery replay time: a journaled (fsync'd) run is killed
    mid-stream; recovery replays the WAL (timed) and re-runs what the
    crash left in flight (timed separately)."""
    import tempfile

    from repro.core import journal as journal_mod
    from repro.core.arch import Constraints, paper_config_space
    from repro.core.ir import resnet18_ir
    from repro.core.service import PlanRequest, PlanningService
    from repro.testing.faults import _valid_graphs

    tmp = tempfile.mkdtemp(prefix="bench_recover_")
    space = paper_config_space()
    kw = dict(
        config_space=space,
        constraints=Constraints(*[float("inf")] * 4),
        backoff_seconds=0.0,
    )
    svc = PlanningService(journal_dir=tmp, journal_fsync=True,
                          snapshot_every=0, **kw)
    graphs = _valid_graphs() + [resnet18_ir()]
    budgets = [float("inf"), 4e6, 1e6]
    for i in range(n):
        svc.submit(PlanRequest(
            graph=graphs[i % len(graphs)],
            sram_budget_words=budgets[i % len(budgets)],
        ))
        if i % 5 == 4:  # serve some of the stream before the "crash"
            svc.tick()
    pending_at_crash = svc.queue_depth
    svc.close()  # the crash: everything in memory is gone

    t0 = time.perf_counter()
    rec = PlanningService.recover(tmp, journal_fsync=True, snapshot_every=0,
                                  **kw)
    replay_s = time.perf_counter() - t0
    restored = len(rec._responses)
    assert rec.queue_depth == pending_at_crash
    t1 = time.perf_counter()
    rec.drain()
    rerun_s = time.perf_counter() - t1
    assert len(rec._responses) == n
    rec.close()
    _, records = journal_mod.load(tmp)
    print(json.dumps({
        "n_requests": n,
        "wal_records": len(records),
        "responses_restored": restored,
        "reenqueued": pending_at_crash,
        "replay_ms": round(replay_s * 1e3, 3),
        "rerun_ms": round(rerun_s * 1e3, 3),
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one load level, fewer requests (CI)")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="also measure the async transport, cancellation "
                         "latency, and recovery-replay time")
    ap.add_argument("--qps", type=float, help="(internal) child load level")
    ap.add_argument("--n", type=int, help="(internal) child request count")
    ap.add_argument("--faults", action="store_true",
                    help="(internal) child fault injection on")
    ap.add_argument("--mode", default="paced",
                    choices=("paced", "async", "cancel", "recover"),
                    help="(internal) child measurement family")
    args = ap.parse_args()
    if args.n:  # child processes always carry --n
        if args.mode == "paced":
            run_child(args.qps, args.n, args.faults)
        elif args.mode == "async":
            run_child_async(args.qps, args.n)
        elif args.mode == "cancel":
            run_child_cancel(args.n)
        else:
            run_child_recover(args.n)
        return

    levels = [100.0] if args.smoke else [25.0, 100.0, 400.0]
    n = 24 if args.smoke else 80
    rows: list[dict] = []
    for qps in levels:
        for faults in (False, True):
            cmd = [sys.executable, str(pathlib.Path(__file__).resolve()),
                   "--qps", str(qps), "--n", str(n)]
            if faults:
                cmd.append("--faults")
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  cwd=ROOT)
            if proc.returncode != 0:  # surface the child's traceback
                sys.stderr.write(proc.stdout)
                sys.stderr.write(proc.stderr)
                raise SystemExit(
                    f"bench_serve child qps={qps} faults={faults} failed"
                )
            row = json.loads(proc.stdout.strip().splitlines()[-1])
            rows.append(row)
            tag = "faults" if faults else "clean "
            print(
                f"qps {qps:6.0f} [{tag}] p50 {row['p50_ms']:8.2f} ms  "
                f"p99 {row['p99_ms']:8.2f} ms  "
                f"degraded {row['degradation_rate']*100:5.1f}%  "
                f"ok {row['ok_rate']*100:5.1f}%"
            )

    # Contract: every stream — with or without faults — is 100% answered
    # (the children already asserted per-request typed responses).
    for row in rows:
        assert sum(row["outcomes"].values()) == row["n_requests"], row
        assert row["ok_rate"] > 0, row
    # Fault injection really fired in every faulted stream.
    assert all(r["injected"].get("injected_transients", 0) > 0
               for r in rows if r["faults"])

    def _run_aux(mode: str, extra: list[str]) -> dict:
        cmd = [sys.executable, str(pathlib.Path(__file__).resolve()),
               "--mode", mode] + extra
        proc = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT)
        if proc.returncode != 0:
            sys.stderr.write(proc.stdout)
            sys.stderr.write(proc.stderr)
            raise SystemExit(f"bench_serve child mode={mode} failed")
        return json.loads(proc.stdout.strip().splitlines()[-1])

    async_rows: list[dict] = []
    cancel_row = recover_row = None
    if args.async_:
        for qps in levels:
            row = _run_aux("async", ["--qps", str(qps), "--n", str(n)])
            async_rows.append(row)
            print(
                f"qps {qps:6.0f} [async ] p50 {row['p50_ms']:8.2f} ms  "
                f"p99 {row['p99_ms']:8.2f} ms  "
                f"ok {row['ok_rate']*100:5.1f}%"
            )
            assert sum(row["outcomes"].values()) == row["n_requests"], row
        cancel_row = _run_aux(
            "cancel", ["--n", "4" if args.smoke else "8"])
        print(
            f"cancel latency      p50 {cancel_row['cancel_p50_ms']:8.2f} ms  "
            f"p99 {cancel_row['cancel_p99_ms']:8.2f} ms"
        )
        recover_row = _run_aux(
            "recover", ["--n", "12" if args.smoke else "32"])
        print(
            f"recovery            replay {recover_row['replay_ms']:8.2f} ms  "
            f"re-run {recover_row['rerun_ms']:8.2f} ms  "
            f"({recover_row['reenqueued']} in flight at crash)"
        )

    record = {
        "bench": "serve",
        "smoke": args.smoke,
        "machine": machine_metadata(),
        "metric_note": (
            "Paced request stream at each offered QPS in a fresh process "
            "(one warmup plan, then the timed stream).  Latency is "
            "submit-to-response per request; degradation_rate is the "
            "fraction of successful responses the deadline ladder answered "
            f"below the exact rung (deadline {DEADLINE_S}s).  'faults' "
            "rows run under active injection: a transient sweep failure "
            "every 3rd sweep and an executable-cache eviction storm every "
            "5th tick — the contract (one typed response per request) is "
            "asserted in both modes before this file is written."
        ),
        "deadline_seconds": DEADLINE_S,
        "levels": rows,
    }
    if args.async_:
        record["async_levels"] = async_rows
        record["cancellation"] = cancel_row
        record["recovery"] = recover_row
        record["async_note"] = (
            "async_levels: the same paced stream through "
            "AsyncPlanningService; latency is submit-to-future-resolution "
            "(transport included).  cancellation: chunk-stalled sweeps "
            "cancelled mid-flight, cancel()-to-response time — bounded by "
            "one hw_chunk boundary.  recovery: fsync'd journaled run "
            "killed mid-stream; replay_ms restores served responses "
            "bit-identically, rerun_ms re-answers the in-flight tail."
        )
    OUT.write_text(json.dumps(record, indent=2) + "\n")
    n_rows = len(rows) + len(async_rows)
    print(f"\n[bench_serve] {n_rows} measurement levels -> {OUT}")


if __name__ == "__main__":
    main()
