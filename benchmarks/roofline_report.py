"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Roofline tables.

Usage: ``python -m benchmarks.roofline_report [--dir experiments/dryrun]``
Emits a markdown table per mesh with the three roofline terms, the
dominant bound, useful-FLOPs ratio and the MFU upper bound, plus the
per-cell "what would move the dominant term" note.
"""
from __future__ import annotations

import argparse
import json
import pathlib

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

NOTES = {
    ("memory", "train"): "cut AD-saved tiles (flash-attn custom_vjp) / raise microbatch",
    ("memory", "prefill"): "fuse attention score frames (flash path), widen kv blocks",
    ("memory", "decode"): "shrink KV reads: window-sized local caches, quantised KV",
    ("compute", "train"): "reduce remat recompute; larger per-device batch",
    ("compute", "prefill"): "already MXU-bound: raise block sizes toward MXU peak",
    ("compute", "decode"): "batch more requests per step",
    ("collective", "train"): "reduce-scatter grads + int8 cross-pod; overlap with compute",
    ("collective", "prefill"): "shard KV heads not sequence; avoid re-gathers",
    ("collective", "decode"): "replicate small weights; avoid per-token all-gathers",
}


def load(dir_: pathlib.Path):
    recs = [json.loads(f.read_text()) for f in sorted(dir_.glob("*.json"))]
    return [r for r in recs if not r.get("tag")]


def fmt_row(r):
    rl = r["roofline"]
    step = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
    kind = r["kind"]
    note = NOTES.get((rl["bound"], kind), "")
    return (
        f"| {r['arch']} | {r['shape']} | {rl['compute_s']*1e3:9.2f} "
        f"| {rl['memory_s']*1e3:9.2f} | {rl['collective_s']*1e3:9.2f} "
        f"| **{rl['bound']}** | {rl['useful_flops_ratio']*100:5.1f}% "
        f"| {rl['mfu_bound']*100:5.1f}% | {r['resident_total_gib']:.2f} "
        f"| {note} |"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args(argv)
    recs = load(pathlib.Path(args.dir))
    for mesh in ("single", "multi"):
        rows = [r for r in recs if r["mesh"] == mesh]
        rows.sort(key=lambda r: (r["arch"], ORDER.index(r["shape"])))
        chips = rows[0]["n_chips"] if rows else 0
        print(f"\n### Roofline — {mesh} pod ({chips} chips)\n")
        print("| arch | shape | compute ms | memory ms | collective ms "
              "| bound | useful | MFU cap | resident GiB | lever |")
        print("|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(fmt_row(r))


if __name__ == "__main__":
    main()
