"""Search-engine benchmark — PR 1 scalar path vs the batched engine.

Times enumeration, brute force, and greedy/beam merge search on the three
DAG builders (residual block, encoder-decoder, ResNet-18) and writes
``BENCH_search.json`` at the repo root with candidates/s and the speedup
vs the preserved scalar implementations (``fusion._*_scalar``).  Cases
where the scalar path is intractable (2^21 patterns through a per-pattern
Python filter) report batched-only throughput.

Whenever both paths run, the benchmark also asserts the cut vectors are
bit-identical — a free regression check in CI.

Usage: ``python benchmarks/bench_search.py [--smoke]`` (``--smoke`` = one
measured rep per case, for the CI smoke job).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from repro.core import fusion, metrics as M
from repro.core.ir import encoder_decoder_ir, residual_block_ir, resnet18_ir

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_search.json"


def _clear_engine_caches() -> None:
    fusion.enumerate_valid_edge_cuts.cache_clear()
    fusion._exhaustive_tables.cache_clear()


def _bench(fn, reps: int):
    """(result, best_seconds, cold_seconds) — cold includes one-time cache
    builds; best is the steady state the flow sees on repeated searches."""
    times = []
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return out, min(times), times[0]


class Bench:
    def __init__(self, reps: int):
        self.reps = reps
        self.cases: list[dict] = []

    def case(
        self,
        name: str,
        *,
        batched,
        scalar=None,
        n_candidates: int | None = None,
        compare_cuts: bool = True,
        scalar_reps: int = 1,
    ) -> None:
        _clear_engine_caches()
        b_res, b_best, b_cold = _bench(batched, max(self.reps, 2))
        s_best = s_res = None
        if scalar is not None:
            s_res, s_best, _ = _bench(scalar, scalar_reps)
            if compare_cuts:
                assert np.array_equal(
                    np.asarray(b_res.cuts), np.asarray(s_res.cuts)
                ), f"{name}: batched cuts differ from scalar"
        row = {
            "name": name,
            "n_candidates": n_candidates,
            # batched_s: steady state (warm per-graph caches — what the flow
            # sees on repeated searches); batched_cold_s: first call, full
            # pipeline.  candidates_per_s is computed from the cold time so
            # it reports pipeline throughput, not a cache hit.
            "batched_s": round(b_best, 6),
            "batched_cold_s": round(b_cold, 6),
            "scalar_s": round(s_best, 6) if s_best is not None else None,
            # speedup: steady state; speedup_cold: first call incl. building
            # the per-graph memos (for beam there is no memo, so they agree).
            "speedup": round(s_best / b_best, 2) if s_best is not None else None,
            "speedup_cold": (
                round(s_best / b_cold, 2) if s_best is not None else None
            ),
            "candidates_per_s": (
                round(n_candidates / b_cold) if n_candidates else None
            ),
        }
        self.cases.append(row)
        sp = f"{row['speedup']}x" if row["speedup"] is not None else "n/a"
        print(
            f"{name:42s} batched {b_best*1e3:9.3f} ms  "
            f"scalar {s_best*1e3 if s_best else float('nan'):9.3f} ms  "
            f"speedup {sp}"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one measured rep per case (CI)")
    args = ap.parse_args()
    reps = 2 if args.smoke else 5

    rb = residual_block_ir()
    ed = encoder_decoder_ir()
    rn = resnet18_ir()
    bench = Bench(reps)

    # -- enumeration -------------------------------------------------------
    bench.case(
        "enumerate.residual_block",
        batched=lambda: fusion.enumerate_valid_edge_cuts(rb),
        scalar=lambda: fusion._enumerate_valid_edge_cuts_scalar(rb),
        n_candidates=2**rb.n_edges,
        compare_cuts=False,
        scalar_reps=reps,
    )
    bench.case(
        "enumerate.encoder_decoder",  # 2^21 patterns: scalar path intractable
        batched=lambda: fusion.enumerate_valid_edge_cuts(ed),
        n_candidates=2**ed.n_edges,
    )

    # -- brute force (acceptance case: >= 10x on the residual block) ------
    bench.case(
        "brute_force.residual_block",
        batched=lambda: fusion.brute_force_min_bw(rb),
        scalar=lambda: fusion._brute_force_min_bw_scalar(rb),
        n_candidates=2**rb.n_edges,
        scalar_reps=reps,
    )
    budget_rb = 150_000.0
    bench.case(
        "brute_force.residual_block_sram_budget",
        batched=lambda: fusion.brute_force_min_bw(
            rb, sram_budget_words=budget_rb
        ),
        scalar=lambda: fusion._brute_force_min_bw_scalar(
            rb, sram_budget_words=budget_rb
        ),
        n_candidates=2**rb.n_edges,
        scalar_reps=reps,
    )
    bench.case(
        "brute_force.encoder_decoder",
        batched=lambda: fusion.brute_force_min_bw(ed),
        n_candidates=2**ed.n_edges,
    )

    # -- merge search (acceptance case: >= 10x on the ResNet-18 beam) -----
    bench.case(
        "greedy.resnet18",
        batched=lambda: fusion.greedy_merge_cuts(rn),
        scalar=lambda: fusion._greedy_merge_cuts_scalar(rn),
    )
    bench.case(
        "beam.resnet18",
        batched=lambda: fusion.beam_merge_cuts(rn),
        scalar=lambda: fusion._beam_merge_cuts_scalar(rn),
    )
    budget_rn = 200_000.0
    bench.case(
        "beam.resnet18_sram_budget",
        batched=lambda: fusion.beam_merge_cuts(
            rn, sram_budget_words=budget_rn
        ),
        scalar=lambda: fusion._beam_merge_cuts_scalar(
            rn, sram_budget_words=budget_rn
        ),
    )
    bench.case(
        "beam.encoder_decoder",
        batched=lambda: fusion.beam_merge_cuts(ed),
        scalar=lambda: fusion._beam_merge_cuts_scalar(ed),
    )

    record = {
        "bench": "search",
        "smoke": args.smoke,
        "metric_note": (
            "speedup = scalar_s / batched_s (steady state: warm per-graph "
            "memos, what repeated searches in a flow pay); speedup_cold = "
            "scalar_s / batched_cold_s (first call, full pipeline incl. "
            "memo build — the honest number for one-shot use; the merge "
            "searches have no memo, so for them the two agree)"
        ),
        "graphs": {
            "residual_block": {"nodes": len(rb.nodes), "edges": rb.n_edges},
            "encoder_decoder": {"nodes": len(ed.nodes), "edges": ed.n_edges},
            "resnet18": {"nodes": len(rn.nodes), "edges": rn.n_edges},
        },
        "cases": bench.cases,
    }
    OUT.write_text(json.dumps(record, indent=2) + "\n")
    print(f"\n[bench_search] {len(bench.cases)} cases -> {OUT}")

    acceptance = {
        c["name"]: f"{c['speedup']}x steady-state / {c['speedup_cold']}x cold"
        for c in bench.cases
        if c["name"] in ("brute_force.residual_block", "beam.resnet18")
    }
    print(f"[bench_search] acceptance speedups: {acceptance}")


if __name__ == "__main__":
    main()
