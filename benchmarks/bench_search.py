"""Search-engine benchmark — scalar path vs batched engine vs frontier DP.

Times enumeration, brute force, greedy/beam merge search, and the exact
frontier-state DP on the three DAG builders (residual block,
encoder-decoder, ResNet-18) and writes ``BENCH_search.json`` at the repo
root with candidates/s and the speedup vs each case's baseline (the
preserved ``fusion._*_scalar`` implementations, or — for the DP cases —
the 2^E flat enumeration / beam search it supersedes).  Cases where the
baseline is intractable (2^21 patterns through a per-pattern Python
filter) report batched-only throughput.

Whenever both paths run, the benchmark also asserts the agreed-on
invariant — bit-identical cut vectors, bit-identical minimum cost
(frontier DP vs brute force), or exact-at-most-heuristic (frontier DP vs
beam) — a free regression check in CI.

Usage: ``python benchmarks/bench_search.py [--smoke]`` (``--smoke`` = one
measured rep per case, for the CI smoke job).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

from machine_meta import machine_metadata
from repro.core import fusion, metrics as M
from repro.core.ir import encoder_decoder_ir, residual_block_ir, resnet18_ir

ROOT = pathlib.Path(__file__).resolve().parents[1]
OUT = ROOT / "BENCH_search.json"


def _clear_engine_caches() -> None:
    fusion.enumerate_valid_edge_cuts.cache_clear()
    fusion._exhaustive_tables.cache_clear()
    fusion._frontier_dp_cached.cache_clear()


def _bench(fn, reps: int):
    """(result, best_seconds, cold_seconds) — cold includes one-time cache
    builds; best is the steady state the flow sees on repeated searches."""
    times = []
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        times.append(time.perf_counter() - t0)
    return out, min(times), times[0]


class Bench:
    def __init__(self, reps: int):
        self.reps = reps
        self.cases: list[dict] = []

    def case(
        self,
        name: str,
        *,
        batched,
        scalar=None,
        n_candidates: int | None = None,
        compare: str | None = "cuts",
        scalar_reps: int = 1,
    ) -> None:
        """``compare``: the invariant asserted between the two paths —
        "cuts" (bit-identical vectors), "cost" (bit-identical minimum
        group cost: the frontier-DP-vs-enumeration contract, ties may pick
        different optimal cuts), "cost_le" (exact at most heuristic), or
        None."""
        _clear_engine_caches()
        b_res, b_best, b_cold = _bench(batched, max(self.reps, 2))
        s_best = s_res = None
        if scalar is not None:
            s_res, s_best, _ = _bench(scalar, scalar_reps)
            if compare == "cuts":
                assert np.array_equal(
                    np.asarray(b_res.cuts), np.asarray(s_res.cuts)
                ), f"{name}: batched cuts differ from scalar"
            elif compare == "cost":
                assert b_res.group_cost_words == s_res.group_cost_words, (
                    f"{name}: {b_res.group_cost_words} != "
                    f"{s_res.group_cost_words}"
                )
            elif compare == "cost_le":
                assert b_res.group_cost_words <= s_res.group_cost_words, (
                    f"{name}: exact {b_res.group_cost_words} worse than "
                    f"heuristic {s_res.group_cost_words}"
                )
        row = {
            "name": name,
            "n_candidates": n_candidates,
            # batched_s: steady state (warm per-graph caches — what the flow
            # sees on repeated searches); batched_cold_s: first call, full
            # pipeline.  candidates_per_s is computed from the cold time so
            # it reports pipeline throughput, not a cache hit.
            "batched_s": round(b_best, 6),
            "batched_cold_s": round(b_cold, 6),
            "scalar_s": round(s_best, 6) if s_best is not None else None,
            # speedup: steady state; speedup_cold: first call incl. building
            # the per-graph memos (for beam there is no memo, so they agree).
            "speedup": round(s_best / b_best, 2) if s_best is not None else None,
            "speedup_cold": (
                round(s_best / b_cold, 2) if s_best is not None else None
            ),
            "candidates_per_s": (
                round(n_candidates / b_cold) if n_candidates else None
            ),
        }
        self.cases.append(row)
        sp = f"{row['speedup']}x" if row["speedup"] is not None else "n/a"
        print(
            f"{name:42s} batched {b_best*1e3:9.3f} ms  "
            f"scalar {s_best*1e3 if s_best else float('nan'):9.3f} ms  "
            f"speedup {sp}"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one measured rep per case (CI)")
    args = ap.parse_args()
    reps = 2 if args.smoke else 5

    rb = residual_block_ir()
    ed = encoder_decoder_ir()
    rn = resnet18_ir()
    bench = Bench(reps)

    # -- enumeration -------------------------------------------------------
    bench.case(
        "enumerate.residual_block",
        batched=lambda: fusion.enumerate_valid_edge_cuts(rb),
        scalar=lambda: fusion._enumerate_valid_edge_cuts_scalar(rb),
        n_candidates=2**rb.n_edges,
        compare=None,
        scalar_reps=reps,
    )
    bench.case(
        "enumerate.encoder_decoder",  # 2^21 patterns: scalar path intractable
        batched=lambda: fusion.enumerate_valid_edge_cuts(ed),
        n_candidates=2**ed.n_edges,
    )

    # -- brute force (acceptance case: >= 10x on the residual block) ------
    bench.case(
        "brute_force.residual_block",
        batched=lambda: fusion.brute_force_min_bw(rb),
        scalar=lambda: fusion._brute_force_min_bw_scalar(rb),
        n_candidates=2**rb.n_edges,
        scalar_reps=reps,
    )
    budget_rb = 150_000.0
    bench.case(
        "brute_force.residual_block_sram_budget",
        batched=lambda: fusion.brute_force_min_bw(
            rb, sram_budget_words=budget_rb
        ),
        scalar=lambda: fusion._brute_force_min_bw_scalar(
            rb, sram_budget_words=budget_rb
        ),
        n_candidates=2**rb.n_edges,
        scalar_reps=reps,
    )
    bench.case(
        "brute_force.encoder_decoder",
        batched=lambda: fusion.brute_force_min_bw(ed),
        n_candidates=2**ed.n_edges,
    )

    # -- merge search (acceptance case: >= 10x on the ResNet-18 beam) -----
    bench.case(
        "greedy.resnet18",
        batched=lambda: fusion.greedy_merge_cuts(rn),
        scalar=lambda: fusion._greedy_merge_cuts_scalar(rn),
    )
    bench.case(
        "beam.resnet18",
        batched=lambda: fusion.beam_merge_cuts(rn),
        scalar=lambda: fusion._beam_merge_cuts_scalar(rn),
    )
    budget_rn = 200_000.0
    bench.case(
        "beam.resnet18_sram_budget",
        batched=lambda: fusion.beam_merge_cuts(
            rn, sram_budget_words=budget_rn
        ),
        scalar=lambda: fusion._beam_merge_cuts_scalar(
            rn, sram_budget_words=budget_rn
        ),
    )
    bench.case(
        "beam.encoder_decoder",
        batched=lambda: fusion.beam_merge_cuts(ed),
        scalar=lambda: fusion._beam_merge_cuts_scalar(ed),
    )

    # -- frontier DP (exact beyond the 2^E enumeration wall) --------------
    # Encoder-decoder: the DP's answer must be bit-identical in cost to the
    # 2^21 flat enumeration it supersedes (ties may differ in cuts), and the
    # acceptance bar is beating its cold wall clock outright.
    bench.case(
        "frontier_dp.encoder_decoder",
        batched=lambda: fusion.frontier_dp_min_bw(ed),
        scalar=lambda: fusion.brute_force_min_bw(ed),
        n_candidates=2**ed.n_edges,
        compare="cost",
    )
    # ResNet-18 (38 edges, 2^38 patterns): previously heuristic-only; the
    # exact DP optimum can only match or beat the beam answer.
    bench.case(
        "frontier_dp.resnet18",
        batched=lambda: fusion.frontier_dp_min_bw(rn),
        scalar=lambda: fusion.beam_merge_cuts(rn),
        compare="cost_le",
    )
    bench.case(
        "frontier_dp.resnet18_sram_budget",
        batched=lambda: fusion.frontier_dp_min_bw(
            rn, sram_budget_words=budget_rn
        ),
        scalar=lambda: fusion.beam_merge_cuts(
            rn, sram_budget_words=budget_rn
        ),
        compare="cost_le",
    )

    record = {
        "bench": "search",
        "smoke": args.smoke,
        "machine": machine_metadata(),
        "metric_note": (
            "speedup = scalar_s / batched_s (steady state: warm per-graph "
            "memos, what repeated searches in a flow pay); speedup_cold = "
            "scalar_s / batched_cold_s (first call, full pipeline incl. "
            "memo build — the honest number for one-shot use; the merge "
            "searches have no memo, so for them the two agree).  The "
            "frontier_dp.* cases baseline against what they supersede: "
            "the 2^E flat enumeration (encoder_decoder, cost asserted "
            "bit-identical) or beam search (resnet18, exact asserted <= "
            "heuristic)"
        ),
        "graphs": {
            "residual_block": {"nodes": len(rb.nodes), "edges": rb.n_edges},
            "encoder_decoder": {"nodes": len(ed.nodes), "edges": ed.n_edges},
            "resnet18": {"nodes": len(rn.nodes), "edges": rn.n_edges},
        },
        "cases": bench.cases,
    }
    OUT.write_text(json.dumps(record, indent=2) + "\n")
    print(f"\n[bench_search] {len(bench.cases)} cases -> {OUT}")

    acceptance = {
        c["name"]: f"{c['speedup']}x steady-state / {c['speedup_cold']}x cold"
        for c in bench.cases
        if c["name"] in (
            "brute_force.residual_block",
            "beam.resnet18",
            "frontier_dp.encoder_decoder",
        )
    }
    dp_ed = next(
        c for c in bench.cases if c["name"] == "frontier_dp.encoder_decoder"
    )
    assert dp_ed["speedup_cold"] > 1.0, (
        "frontier DP must beat cold 2^21 enumeration wall-clock"
    )
    print(f"[bench_search] acceptance speedups: {acceptance}")


if __name__ == "__main__":
    main()
