"""pydocstyle-lite: enforce the D1xx docstring subset over a package.

The container has no ruff/pydocstyle, so this is the checked-in
equivalent of ``ruff --select D1`` restricted to what the repo actually
promises: every public module, class, function, and method under the
target directories carries a non-empty docstring whose first line is not
blank.  "Public" means the name (and every enclosing class) does not
start with ``_``; dunder methods other than ``__init__`` are exempt, and
so are ``@overload`` stubs.  Trivial ``@property`` forwarders are NOT
exempt — a property is API surface like any other.

Usage::

    python tools/check_docstrings.py [dir ...]   # default: src/repro/core

Exit status 1 lists every violation as ``path:line CODE qualname``.
CI runs this in the required core lane (see .github/workflows/ci.yml).
"""
from __future__ import annotations

import ast
import pathlib
import sys

DEFAULT_TARGETS = ("src/repro/core",)

CODES = {
    "D100": "missing module docstring",
    "D101": "missing class docstring",
    "D102": "missing method docstring",
    "D103": "missing function docstring",
    "D419": "docstring is empty or starts with a blank line",
}


def _docstring_ok(node) -> str | None:
    """Return a violation code for ``node``'s docstring, or None."""
    doc = ast.get_docstring(node, clean=False)
    if doc is None:
        if isinstance(node, ast.Module):
            return "D100"
        if isinstance(node, ast.ClassDef):
            return "D101"
        return "D103"
    if not doc.strip() or not doc.splitlines()[0].strip():
        return "D419"
    return None


def _is_public(name: str) -> bool:
    if name.startswith("__") and name.endswith("__"):
        return name == "__init__"
    return not name.startswith("_")


def _is_overload(node) -> bool:
    for dec in node.decorator_list:
        target = dec.func if isinstance(dec, ast.Call) else dec
        name = target.attr if isinstance(target, ast.Attribute) else getattr(
            target, "id", None
        )
        if name == "overload":
            return True
    return False


def check_file(path: pathlib.Path) -> list[tuple[int, str, str]]:
    """All (line, code, qualname) violations in one source file."""
    tree = ast.parse(path.read_text(), filename=str(path))
    out: list[tuple[int, str, str]] = []
    code = _docstring_ok(tree)
    if code:
        out.append((1, code, "<module>"))

    def walk(node, prefix: str, in_class: bool) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if not _is_public(child.name) or _is_overload(child):
                    continue
                qual = f"{prefix}{child.name}"
                code = _docstring_ok(child)
                if code:
                    code = "D102" if in_class and code == "D103" else code
                    out.append((child.lineno, code, qual))
                # Nested defs are private implementation detail: skip.
            elif isinstance(child, ast.ClassDef):
                if not _is_public(child.name):
                    continue
                qual = f"{prefix}{child.name}"
                code = _docstring_ok(child)
                if code:
                    out.append((child.lineno, code, qual))
                walk(child, f"{qual}.", True)

    walk(tree, "", False)
    return out


def main(argv: list[str]) -> int:
    targets = argv or list(DEFAULT_TARGETS)
    root = pathlib.Path(__file__).resolve().parents[1]
    violations = 0
    n_files = 0
    for target in targets:
        base = root / target
        for path in sorted(base.rglob("*.py")):
            n_files += 1
            for line, code, qual in check_file(path):
                violations += 1
                rel = path.relative_to(root)
                print(f"{rel}:{line} {code} {qual} ({CODES[code]})")
    if violations:
        print(f"\n{violations} docstring violation(s) in {n_files} file(s)")
        return 1
    print(f"docstrings OK: {n_files} file(s) in {', '.join(targets)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
