"""Gradient compression for the slow cross-pod link (int8 + error feedback).

At 2 pods the inter-pod all-reduce carries the full gradient every step
over the slowest links in the system.  ``compressed_psum`` quantises each
tensor to int8 with a shared power-of-two-free scale, all-reduces the int8
payload (1 byte/element on the wire instead of 4/2), and de-quantises; the
quantisation residual is fed back into the next step's gradient (error
feedback), which keeps SGD/Adam convergence (Karimireddy et al., 2019).

Overflow note: an int8 all-reduce saturates if the summed magnitudes
exceed 127, so the scale is chosen for the *sum* across the axis
(pre-scaled by 1/n); with n=2 pods this costs 1 bit of precision — error
feedback absorbs it.  Used inside shard_map (explicit axis), see
runtime/spmd_train.make_compressed_grad_sync.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, n_summands: int = 1):
    """-> (q int8, scale f32).  Scale sized so an n-way sum cannot saturate."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax * n_summands, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jnp.ndarray, axis_name: str, *, mean: bool = True):
    """int8 all-reduce of ``x`` over ``axis_name`` (inside shard_map).

    Returns (reduced f32, local quantisation error for feedback).
    """
    n = jax.lax.psum(1, axis_name)
    # Shared scale: every participant must use the same scale or the int8
    # sum is meaningless -> take the max scale across the axis first
    # (a scalar collective, 4 bytes).
    q_local, scale_local = quantize_int8(x, n_summands=1)
    scale = jax.lax.pmax(scale_local * 1, axis_name) * n  # headroom for sum
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    err = x.astype(jnp.float32) - q.astype(jnp.float32) * scale
    summed = jax.lax.psum(q.astype(jnp.int8), axis_name)  # 1 B/elem on wire
    out = summed.astype(jnp.float32) * scale
    if mean:
        out = out / n
    return out, err


def ef_apply(grads, errors):
    """Add carried error feedback into this step's gradients."""
    if errors is None:
        return grads
    return jax.tree.map(lambda g, e: g + e.astype(g.dtype), grads, errors)
