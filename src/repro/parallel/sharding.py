"""GSPMD sharding rules for every parameter/activation/cache in the system.

Strategy (DP x TP with ZeRO-3-style FSDP, EP for MoE, SP for long decode):

* **TP** (``model`` axis): attention Q/K/V/O head dims, MLP d_ff
  (column/row parallel), MoE expert axis (expert parallelism), Mamba
  d_inner, vocab dim of embedding/LM head.
* **FSDP** (the data axes, ``("data",)`` or ``("pod","data")``): every
  weight *additionally* sharded over its largest remaining axis, so
  parameters + Adam state for the 400-500 B models fit 16 GB/chip HBM
  (ZeRO-3 storage; GSPMD inserts the all-gathers at use sites).
* **Sequence parallelism**: the `long_500k` decode cell has batch 1, so the
  KV-cache *sequence* axis shards over the data axes instead.

Rules are matched on parameter-path key names, which are stable across the
ten architectures because every model is built from the same modules.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# Logical axis tokens used by in-model sharding hints.
DP = ("pod", "data")  # data-parallel axes (whichever exist in the mesh)
TP = "model"

# Mesh axis name for the evaluator's hardware-config sharding
# (repro.core.flow.run_fleet(devices=...)): the (G, H, C) sweep's H axis is
# embarrassingly parallel, so it shards over a 1-D device mesh.
HW_AXIS = "hardware"


def shard_map_fn():
    """The ``shard_map`` entry point, across jax versions.

    jax >= 0.6 promotes it to ``jax.shard_map``; on 0.4.x it lives in
    ``jax.experimental.shard_map``.  Same keyword signature
    ``(f, mesh=..., in_specs=..., out_specs=...)`` either way.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm

    return sm


def shard_map_unchecked(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with replication/varying-manual-axes checking off,
    across jax versions: the flag is ``check_vma`` on jax >= 0.6 and
    ``check_rep`` on 0.4.x.  (The evaluator's uses are all statically
    replicated, so the check adds nothing but version skew.)"""
    sm = shard_map_fn()
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def use_mesh(mesh: Mesh):
    """Ambient-mesh context manager across jax versions.

    jax >= 0.6 spells it ``jax.set_mesh(mesh)``; on 0.4.x entering the
    ``Mesh`` itself sets the ambient mesh pjit/shard_map resolve against.
    Use this instead of either spelling directly (the PR 5
    ``hint``-resolution fix, promoted to the write side)."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def hardware_mesh(devices=None, *, axis: str = HW_AXIS) -> Mesh:
    """A 1-D mesh over ``devices`` for hardware-config sharding.

    ``devices`` may be ``None`` (every visible device), an int (the first N
    visible devices — errors if fewer exist), or an explicit device
    sequence.  The axis name defaults to :data:`HW_AXIS`, the name
    :func:`repro.core.metrics.sharded_fleet_kernel` shards over.
    """
    if devices is None:
        devices = jax.devices()
    elif isinstance(devices, int):
        avail = jax.devices()
        if devices < 1:
            raise ValueError(f"need >= 1 device, got {devices}")
        if devices > len(avail):
            raise ValueError(
                f"requested {devices} devices but only {len(avail)} visible "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "for host-platform sharding)"
            )
        devices = avail[:devices]
    devices = np.asarray(devices)
    if devices.size < 1:
        raise ValueError("empty device list")
    return Mesh(devices, (axis,))


def mesh_fingerprint(mesh: Mesh) -> tuple:
    """Hashable identity of a mesh: axis names, size, and device ids.

    This is the cache-key component that keeps executables compiled for one
    device layout from being served to another (an 8-device program is not
    a 1-device program even at identical argument shapes)."""
    return (
        ",".join(mesh.axis_names),
        int(mesh.devices.size),
        tuple(str(d) for d in mesh.devices.flat),
    )


def repair_spec(spec, shape, axis_size) -> "P":
    """Make ``spec`` valid for ``shape``: drop axes a dim cannot host
    (indivisible / too small) and greedily re-place them on the largest
    divisible dim.

    The re-placement is semantically meaningful, not just a fallback: e.g.
    a KV-head axis of 8 cannot host 16-way TP, so TP migrates to the
    sequence axis of the KV cache — which is flash-decode-style sequence
    sharding (partial softmax per shard + small cross-shard reduction).
    """
    out: list = [None] * len(shape)
    dropped: list = []
    used: set = set()
    for i, axis in enumerate(spec[: len(shape)]):
        if axis is None:
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        keep = []
        size_so_far = 1
        for a in axes:
            s = axis_size(a)
            if s <= 1 or a in used:
                continue
            if shape[i] % (size_so_far * s) == 0:
                keep.append(a)
                used.add(a)
                size_so_far *= s
            else:
                dropped.append(a)
        if keep:
            out[i] = tuple(keep) if len(keep) > 1 else keep[0]
    order = sorted(range(len(shape)), key=lambda i: -shape[i])
    for a in dropped:
        s = axis_size(a)
        if s <= 1 or a in used:
            continue
        used.add(a)
        for i in order:
            cur = out[i]
            cur_axes = () if cur is None else (cur if isinstance(cur, tuple) else (cur,))
            if a in cur_axes:
                continue
            total = s
            for c in cur_axes:
                total *= axis_size(c)
            if shape[i] % total == 0 and shape[i] >= total:
                out[i] = cur_axes + (a,) if cur_axes else a
                break
    return P(*out)


def _ambient_mesh_auto_axes():
    """(mesh, auto axis names) of the ambient mesh, across jax versions.

    jax >= 0.5 exposes ``jax.sharding.get_abstract_mesh()`` with per-axis
    ``AxisType`` (Manual axes inside shard_map must not be pinned); on
    jax 0.4.x the ambient mesh is the ``with mesh:`` context mesh from
    ``thread_resources`` and every axis is implicitly auto.  Outside any
    mesh both paths return (None, ()).
    """
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        am = get_am()
        if am is None or not am.axis_names:
            return None, ()
        from jax.sharding import AxisType

        return am, tuple(
            n for n, t in zip(am.axis_names, am.axis_types)
            if t == AxisType.Auto
        )
    from jax._src import core as core_lib
    from jax._src import mesh as mesh_lib

    pm = mesh_lib.thread_resources.env.physical_mesh
    if pm is None or pm.empty or not pm.axis_names:
        return None, ()
    # Inside shard_map the mesh axes are bound in the trace-time axis env —
    # those are Manual and must not be pinned (0.4.x has no AxisType, so
    # this is the only way to see them).
    manual: set = set()
    get_env = getattr(core_lib, "get_axis_env", None)
    if get_env is not None:
        try:
            manual = set(get_env().axis_sizes)
        except Exception:  # pragma: no cover - defensive across 0.4.x micros
            manual = set()
    return pm, tuple(n for n in pm.axis_names if n not in manual)


def hint(x, *spec):
    """``with_sharding_constraint`` against the ambient (abstract) mesh.

    Model code calls ``hint(q, DP, None, TP, None)``; axes absent from the
    current mesh are dropped, indivisible placements are repaired
    (see :func:`repair_spec`), and outside any mesh (single-device tests)
    this is a no-op.  This is how the models pin the shardings GSPMD cannot
    infer through reshapes (e.g. splitting the head axis into KV groups).
    """
    am, names = _ambient_mesh_auto_axes()
    if am is None:
        return x
    names = set(names)
    if not names:  # fully inside shard_map (Manual axes): nothing to pin
        return x

    def clean(a):
        if a is None:
            return None
        if isinstance(a, tuple):
            kept = tuple(ax for ax in a if ax in names)
            return kept if kept else None
        return a if a in names else None

    spec = tuple(clean(a) for a in spec)
    if len(spec) < x.ndim:
        spec = spec + (None,) * (x.ndim - len(spec))
    fixed = repair_spec(spec, x.shape, lambda a: am.shape[a])
    return jax.lax.with_sharding_constraint(x, fixed)


def _path_names(path) -> list[str]:
    out = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            out.append(str(e.key))
        elif isinstance(e, jax.tree_util.SequenceKey):
            out.append(str(e.idx))
        elif isinstance(e, jax.tree_util.GetAttrKey):
            out.append(str(e.name))
    return out


# (param name, rank-without-stacking) -> spec builder(dp, tp).
# Specs are written for the *unstacked* parameter; a leading None is added
# per stacking axis (scan segments / vmapped layer stacks).
def _param_rules(dp, tp) -> dict[str, Any]:
    return {
        "embed": P(tp, dp),  # (V, d)
        "lm_head": P(dp, tp),  # (d, V)
        "wq": P(dp, tp),
        "wk": P(dp, tp),
        "wv": P(dp, tp),
        "wo": P(tp, dp),
        "w1": P(dp, tp),  # dense mlp (d, ff) — overridden for MoE by path
        "w3": P(dp, tp),
        "w2": P(tp, dp),  # (ff, d)
        "router": P(dp, None),  # (d, E) tiny
        "moe.w1": P(tp, None, dp),  # (E, d, ff): experts on model (EP)
        "moe.w3": P(tp, None, dp),
        "moe.w2": P(tp, dp, None),  # (E, ff, d)
        "in_proj": P(dp, tp),  # mamba (d, 2*di)
        "conv_w": P(None, tp),  # (dc, di)
        "conv_b": P(tp),
        "x_proj": P(tp, None),  # (di, dr+2ds)
        "dt_proj": P(None, tp),  # (dr, di)
        "dt_bias": P(tp),
        "A_log": P(tp, None),  # (di, ds)
        "D": P(tp),
        "out_proj": P(tp, dp),  # (di, d)
        # norms and qk-norm scales: replicated
        "norm1": P(), "norm2": P(), "norm_x": P(), "final_norm": P(),
        "enc_final_norm": P(), "q_norm": P(), "k_norm": P(),
        # vgg
        "w": P(None, None, None, tp), "b": P(tp),
    }


def _spec_for_param(names: list[str], shape: tuple[int, ...], dp, tp) -> P:
    rules = _param_rules(dp, tp)
    leaf = names[-1]
    key = leaf
    if "moe" in names and leaf in ("w1", "w2", "w3"):
        key = f"moe.{leaf}"
    if "dense_residual" in names and leaf in ("w1", "w2", "w3"):
        key = leaf  # arctic's parallel dense MLP: plain MLP rules
    spec = rules.get(key)
    if spec is None:
        return P()
    # Add leading Nones for stacking axes (scan repeats / vmapped stacks).
    extra = len(shape) - len(spec)
    if extra > 0:
        spec = P(*([None] * extra), *spec)
    elif extra < 0:  # param smaller than rule (e.g. tiny test dims) — replicate
        return P()
    return spec


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def param_shardings(mesh: Mesh, abstract_params, *, fsdp: bool = True):
    """Pytree of NamedSharding matching ``abstract_params``."""
    dp = data_axes(mesh)
    dp = dp if (fsdp and dp) else None
    tp = "model" if "model" in mesh.axis_names else None

    def one(path, leaf):
        names = _path_names(path)
        spec = _spec_for_param(names, leaf.shape, dp, tp)
        spec = _validate(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, abstract_params)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _validate(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Repair the spec for exact divisibility (inputs/outputs to jit must
    divide evenly) — drops what can't fit and re-places it on the largest
    divisible dim; see :func:`repair_spec`."""
    return repair_spec(tuple(spec) + (None,) * (len(shape) - len(spec)),
                       shape, lambda a: mesh.shape[a] if a else 1)


# ---------------------------------------------------------------------------
# Batch / cache / activation shardings
# ---------------------------------------------------------------------------


def batch_shardings(mesh: Mesh, batch_abstract, *, seq_shard: bool = False):
    """tokens/labels: (B, S) on (dp, None); frontend: (B, L, d).

    ``seq_shard``: batch too small to fill dp (long_500k, B=1) — shard the
    sequence axis over dp instead (sequence parallelism).
    """
    dp = data_axes(mesh)

    def one(path, leaf):
        names = _path_names(path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if seq_shard and leaf.ndim >= 2:
            spec = P(None, dp, *([None] * (leaf.ndim - 2)))
        else:
            spec = P(dp, *([None] * (leaf.ndim - 1)))
        return NamedSharding(mesh, _validate(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, batch_abstract)


def cache_shardings(mesh: Mesh, cache_abstract, *, seq_shard: bool = False):
    """KV caches: (L, B, S, KV, hd) -> (None, dp, None, tp, None); with
    ``seq_shard`` the sequence axis takes dp (batch-1 long-context decode).
    Mamba states: (L, B, ..., di, ...) -> di on tp, batch on dp."""
    dp = data_axes(mesh)
    tp = "model" if "model" in mesh.axis_names else None

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if leaf.ndim == 0 or name in ("len", "primed"):
            return NamedSharding(mesh, P())
        if name in ("k", "v"):  # (L, B, S, KV, hd) or (B, S, KV, hd)
            lead = [None] * (leaf.ndim - 4)
            if seq_shard:
                spec = P(*lead, None, dp, tp, None)
            else:
                spec = P(*lead, dp, None, tp, None)
        elif name == "conv":  # (L, B, dc-1, di)
            spec = P(*([None] * (leaf.ndim - 3)), dp, None, tp)
        elif name == "h":  # (L, B, di, ds)
            spec = P(*([None] * (leaf.ndim - 3)), dp, tp, None)
        else:
            spec = P()
        return NamedSharding(mesh, _validate(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, cache_abstract)


def opt_state_shardings(mesh: Mesh, opt_abstract, param_shardings_tree):
    """Adam m/v mirror the parameter shardings; step is replicated."""

    def one(path, leaf):
        names = _path_names(path)
        if names and names[0] in ("m", "v"):
            sub = param_shardings_tree
            for n in names[1:]:
                if isinstance(sub, (list, tuple)):
                    sub = sub[int(n)]
                else:
                    sub = sub[n]
            return sub
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, opt_abstract)


def replicate(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
