"""GPipe-style pipeline parallelism via shard_map + collective_permute.

An optional third way to split the model: ``stages`` consecutive layer
groups live on disjoint device rows, microbatches stream through with
``jax.lax.ppermute`` hand-offs.  The schedule is the classic GPipe fill /
steady / drain loop expressed as one ``lax.scan`` over (microbatches +
stages - 1) ticks: at every tick each stage runs its layer group on the
activation it received last tick, then permutes it to the next stage.

Bubble fraction = (stages-1)/(ticks) — reported by ``bubble_fraction`` —
and the cross-stage traffic is ticks x (mb_tokens x d_model) bytes on the
``stage`` axis, which the dry-run counts as collective-permute bytes.

At the production mesh DPxTP already covers 512 chips for the assigned
models, so PP is exercised at small scale (tests/test_pipeline.py) and
available as a config knob rather than default-on.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding import shard_map_unchecked


def bubble_fraction(n_micro: int, stages: int) -> float:
    ticks = n_micro + stages - 1
    return (stages - 1) / ticks


def pipeline_apply(
    stage_fn,  # (stage_params, x) -> x    (one stage's layer group)
    stacked_params,  # pytree, leaves (stages, ...)  sharded on "stage"
    x_micro,  # (n_micro, mb, ...) microbatched input
    *,
    mesh,
    axis: str = "stage",
):
    """Run the GPipe schedule inside shard_map over the ``stage`` axis.

    Returns (n_micro, mb, ...) outputs (valid after the drain phase).
    """
    stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + stages - 1

    @partial(
        shard_map_unchecked, mesh=mesh,
        in_specs=(P(axis), P()),  # params split by stage; data replicated
        out_specs=P(),
    )
    def run(params, xm):
        stage = jax.lax.axis_index(axis)
        params = jax.tree.map(lambda p: p[0], params)  # my stage's params
        mb_shape = xm.shape[1:]

        def tick(carry, t):
            inflight, outputs = carry
            # Stage 0 ingests microbatch t (if any remain); others take the
            # activation handed over from the previous stage last tick.
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            fresh = xm[mb_idx]
            x_in = jnp.where(stage == 0, fresh, inflight)
            y = stage_fn(params, x_in)
            # Hand off to the next stage (ring; last stage's output wraps to
            # 0 where it is ignored as input but harvested below).
            perm = [(i, (i + 1) % stages) for i in range(stages)]
            handed = jax.lax.ppermute(y, axis, perm)
            # Last stage emits microbatch (t - stages + 1) at tick t.
            out_idx = t - (stages - 1)
            emit = jnp.logical_and(out_idx >= 0, stage == stages - 1)
            outputs = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, n_micro - 1), 0
                ),
                lambda o: o,
                outputs,
            )
            return (handed, outputs), None

        inflight0 = jnp.zeros(mb_shape, xm.dtype)
        outputs0 = jnp.zeros((n_micro,) + mb_shape, xm.dtype)
        (_, outputs), _ = jax.lax.scan(
            tick, (inflight0, outputs0), jnp.arange(ticks)
        )
        # Only the last stage holds real outputs; broadcast them.
        outputs = jax.lax.psum(
            jnp.where(stage == stages - 1, outputs, jnp.zeros_like(outputs)),
            axis,
        )
        return outputs

    return run(stacked_params, x_micro)
