"""Model / run configuration schema.

One frozen dataclass describes every assigned architecture (and VGG-16 for
the paper's own experiment).  Heterogeneous layer stacks (jamba's 1:7
attn:mamba interleave, gemma3's 5:1 local:global, llama4's alternating
dense/MoE) are expressed with a cyclic ``layer_pattern`` plus a cyclic MoE
placement (``moe_every``/``moe_offset``); the model builder turns this into
scan-able homogeneous segments (see ``repro.models.transformer``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# Sub-layer mixer kinds usable in ``layer_pattern``.
MIXERS = ("attn", "attn_local", "attn_chunked", "mamba")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm | cnn

    # ---- trunk dimensions ---------------------------------------------------
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // n_heads

    # ---- attention ----------------------------------------------------------
    layer_pattern: tuple[str, ...] = ("attn",)
    window_size: int = 1024  # sliding window for attn_local
    chunk_size: int = 8192  # chunk width for attn_chunked (llama4 iRoPE)
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0

    # ---- MLP / MoE ----------------------------------------------------------
    ffn_act: str = "swiglu"  # swiglu | gelu | relu
    n_experts: int = 0  # 0 => dense MLP everywhere
    top_k: int = 1
    moe_every: int = 1  # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    dense_residual_ff: int = 0  # arctic: parallel dense MLP width (0 = none)
    capacity_factor: float = 2.0
    moe_group_size: int = 512  # GShard-style group-limited routing

    # ---- SSM (mamba-1) ------------------------------------------------------
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 => ceil(d_model / 16)

    # ---- encoder-decoder ----------------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0

    # ---- modality frontend (STUB per task spec) -----------------------------
    frontend: str = ""  # "" | "vision" | "audio"
    frontend_len: int = 0  # prefix positions fed as precomputed embeddings

    # ---- misc ---------------------------------------------------------------
    rmsnorm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # Max positions a serve-time KV cache is allocated for (decode shapes
    # override this per run).
    max_seq_len: int = 32_768

    # ------------------------------------------------------------------------
    def __post_init__(self):
        for mixer in self.layer_pattern:
            if mixer not in MIXERS:
                raise ValueError(f"unknown mixer {mixer!r}")
        if self.n_heads and self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be a multiple of n_kv_heads")

    # ---- derived ------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or math.ceil(self.d_model / 16)

    def mixer_of(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def is_moe_layer(self, layer_idx: int) -> bool:
        if self.n_experts <= 1:
            return False
        return layer_idx % self.moe_every == self.moe_offset

    @property
    def pattern_period(self) -> int:
        """Smallest period after which (mixer, is_moe) repeats."""
        p = len(self.layer_pattern)
        if self.n_experts > 1:
            p = math.lcm(p, self.moe_every)
        return p

    def sublayer_kinds(self, start: int, count: int) -> tuple[tuple[str, bool], ...]:
        """(mixer, is_moe) for layers [start, start+count)."""
        return tuple(
            (self.mixer_of(i), self.is_moe_layer(i)) for i in range(start, start + count)
        )

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) --------------
    def param_counts(self) -> dict[str, float]:
        """Analytic parameter counts: total and *active* (MoE top-k) params."""
        d, hd = self.d_model, self.resolved_head_dim
        q_dim = self.n_heads * hd
        kv_dim = self.n_kv_heads * hd
        attn = d * q_dim + 2 * d * kv_dim + q_dim * d
        mult = 2 if self.ffn_act in ("swiglu", "geglu") else 1
        dense_mlp = (mult + 1) * d * self.d_ff
        expert_mlp = (mult + 1) * d * self.d_ff  # per expert
        router = d * self.n_experts
        mamba = (
            d * 2 * self.d_inner  # in_proj
            + self.d_inner * self.ssm_conv  # depthwise conv
            + self.d_inner * (self.dt_rank + 2 * self.ssm_state)  # x_proj
            + self.dt_rank * self.d_inner  # dt_proj
            + self.d_inner * self.ssm_state  # A_log
            + self.d_inner  # D
            + self.d_inner * d  # out_proj
        )
        total = active = 0.0
        n_dec = self.n_layers
        for i in range(n_dec):
            mixer = self.mixer_of(i)
            if mixer == "mamba":
                total += mamba
                active += mamba
            else:
                total += attn
                active += attn
            if self.is_moe_layer(i):
                total += router + self.n_experts * expert_mlp
                active += router + self.top_k * expert_mlp
                if self.dense_residual_ff:
                    dr = (mult + 1) * d * self.dense_residual_ff
                    total += dr
                    active += dr
            else:
                total += dense_mlp
                active += dense_mlp
            total += 2 * d  # norms
            active += 2 * d
        if self.is_encoder_decoder:
            enc = self.n_enc_layers * (attn + dense_mlp + 2 * d)
            xattn = n_dec * (d * q_dim + 2 * d * kv_dim + q_dim * d + d)
            total += enc + xattn
            active += enc + xattn
        emb = self.vocab_size * d
        total += emb + (0 if self.tie_embeddings else emb)
        active += emb + (0 if self.tie_embeddings else emb)
        return {"total": total, "active": active}


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Execution knobs orthogonal to the model definition (perf levers)."""

    microbatches: int = 1  # gradient-accumulation steps inside train_step
    remat: str = "dots"  # "none" | "dots" | "full"  activation checkpointing
    opt_state_dtype: str = "float32"  # bf16 for the >100B models
    use_pallas: bool = False  # planner may force fused kernels on
    attn_chunk_q: int = 1024  # online-softmax q block
    attn_chunk_kv: int = 1024  # online-softmax kv block
    xent_chunk: int = 512  # chunked cross-entropy sequence block
    mamba_chunk: int = 256  # chunked selective-scan block
    seq_shard: bool = False  # sequence parallelism for long-context decode
    # §Perf levers (hillclimb iterations; see EXPERIMENTS.md §Perf)
    flash_vjp: bool = False  # custom-vjp flash attention (no AD-saved tiles)
    attn_bf16_tiles: bool = False  # bf16 probability tiles for PV/dV matmuls
    local_ring_cache: bool = False  # window-sized KV cache for local layers
    shard_grads: bool = False  # pin micro-grads to param sharding (=> RS not AR)
    fsdp: bool = True  # ZeRO-3 weight sharding (off for serving: pure TP)
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    grad_compression: str = "none"  # "none" | "int8" (cross-pod error-feedback)


def scaled_down(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests.

    Keeps the *structure* (pattern, MoE placement, GQA ratio, enc-dec,
    frontend) while shrinking every dimension.
    """
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    small_heads = max(ratio, 2)
    small: dict = dict(
        n_layers=min(cfg.n_layers, 2 * cfg.pattern_period),
        d_model=64,
        n_heads=small_heads,
        n_kv_heads=max(small_heads // ratio, 1),
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        head_dim=32,
        window_size=min(cfg.window_size, 16),
        chunk_size=min(cfg.chunk_size, 16),
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        moe_group_size=16,
        dense_residual_ff=64 if cfg.dense_residual_ff else 0,
        ssm_state=min(cfg.ssm_state, 8),
        ssm_dt_rank=4,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        frontend_len=min(cfg.frontend_len, 4) if cfg.frontend else 0,
        max_seq_len=64,
        dtype="float32",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
