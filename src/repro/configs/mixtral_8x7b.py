"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8e top-2 on every layer, sliding-window attention.
[hf:mistralai/Mixtral-8x7B-v0.1; unverified]

Added as the search-tractable MoE reference for the config-zoo sweep:
8 experts keep the traced superblock small enough that exact fusion
search (``optimal_cuts``/frontier DP) completes where llama4's 128-expert
fan-out only admits the heuristic searchers.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    layer_pattern=("attn_local",),
    window_size=4096,
    n_experts=8,
    top_k=2,
    moe_every=1,
    moe_offset=0,
    ffn_act="swiglu",
    rope_theta=1_000_000.0,
)
