"""falcon-mamba-7b [ssm] — 64L d_model=4096 attn-free d_ff=0 vocab=65024,
ssm_state=16 (mamba-1).  [arXiv:2410.05355; unverified]

Pure Mamba-1: every block is mixer-only (no FFN sublayer — ``d_ff=0``);
d_inner = 2*4096 = 8192, dt_rank = 256.  O(1) state in context length =>
the flagship long_500k architecture.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65_024,
    layer_pattern=("mamba",),
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
)
