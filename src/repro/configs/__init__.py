"""Architecture registry: ``--arch <id>`` resolution, shape applicability,
and per-(arch x shape) execution defaults (microbatching / remat / optimizer
state dtype) sized so every cell fits 16 GB/chip on the production meshes.
"""
from __future__ import annotations

import dataclasses

from .base import SHAPES, ModelConfig, RunConfig, ShapeConfig, scaled_down  # noqa: F401

from . import (  # noqa: E402
    arctic_480b,
    falcon_mamba_7b,
    gemma3_27b,
    granite_34b,
    internvl2_1b,
    jamba_1_5_large,
    llama4_maverick_400b,
    mixtral_8x7b,
    phi3_mini_3_8b,
    qwen3_0_6b,
    seamless_m4t_v2,
)

REGISTRY: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        llama4_maverick_400b, arctic_480b, internvl2_1b, granite_34b,
        phi3_mini_3_8b, gemma3_27b, qwen3_0_6b, seamless_m4t_v2,
        jamba_1_5_large, falcon_mamba_7b, mixtral_8x7b,
    )
}

# CLI aliases: underscores, short names.
ALIASES = {
    "llama4": "llama4-maverick-400b-a17b",
    "llama4-maverick-400b": "llama4-maverick-400b-a17b",
    "arctic": "arctic-480b",
    "internvl2": "internvl2-1b",
    "granite": "granite-34b",
    "phi3": "phi3-mini-3.8b",
    "phi3-mini": "phi3-mini-3.8b",
    "phi3-mini-3-8b": "phi3-mini-3.8b",  # resolve() maps _ -> - but not .
    "gemma3": "gemma3-27b",
    "qwen3": "qwen3-0.6b",
    "qwen3-0-6b": "qwen3-0.6b",
    "seamless": "seamless-m4t-large-v2",
    "seamless-m4t-v2": "seamless-m4t-large-v2",
    "jamba": "jamba-1.5-large-398b",
    "jamba-1.5-large": "jamba-1.5-large-398b",
    "falcon-mamba": "falcon-mamba-7b",
    "mixtral": "mixtral-8x7b",
}


def resolve(arch: str) -> ModelConfig:
    key = arch.replace("_", "-").lower()
    key = ALIASES.get(key, key)
    if key not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[key]


# Archs with sub-quadratic attention structure run the long_500k cell
# (SSM / hybrid / mostly-sliding-window / mostly-chunked); pure
# full-attention archs skip it per the task spec (noted in DESIGN.md).
LONG_CONTEXT_ARCHS = {
    "llama4-maverick-400b-a17b",  # 3/4 layers chunked-local 8192
    "gemma3-27b",  # 5/6 layers sliding-window 1024
    "jamba-1.5-large-398b",  # 7/8 layers Mamba
    "falcon-mamba-7b",  # pure SSM
}


def supported_shapes(name: str) -> list[str]:
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if name in LONG_CONTEXT_ARCHS:
        shapes.append("long_500k")
    return shapes


def all_cells() -> list[tuple[str, str]]:
    """Every runnable (arch, shape) cell (40 assigned minus documented skips)."""
    return [(a, s) for a in REGISTRY for s in supported_shapes(a)]


# ---------------------------------------------------------------------------
# Execution defaults per (arch x shape): memory-driven, see DESIGN.md §5.
# ---------------------------------------------------------------------------

_BIG = {"llama4-maverick-400b-a17b", "arctic-480b", "jamba-1.5-large-398b"}
_MEDIUM = {"granite-34b", "gemma3-27b"}


def run_config(name: str, shape: str, **overrides) -> RunConfig:
    rc = RunConfig()
    kw: dict = {}
    if shape == "train_4k":
        if name in _BIG:
            kw.update(microbatches=8, remat="full", opt_state_dtype="bfloat16")
        elif name in _MEDIUM:
            kw.update(microbatches=4, remat="full")
        elif name in ("phi3-mini-3.8b", "falcon-mamba-7b"):
            kw.update(microbatches=2, remat="full")
        else:
            kw.update(microbatches=1, remat="full")
    else:
        kw.update(remat="none")
    if shape == "long_500k":
        kw.update(seq_shard=True)
    kw.update(overrides)
    return dataclasses.replace(rc, **kw)
