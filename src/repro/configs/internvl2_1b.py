"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655, InternViT + LM decoder.  [arXiv:2404.16821; hf]

The vision tower is a STUB per the task spec: ``input_specs()`` provides
256 precomputed patch embeddings (`frontend_len`) prefixed to the token
stream; labels over the patch prefix are -1 (ignored by the loss).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_655,
    ffn_act="swiglu",
    frontend="vision",
    frontend_len=256,
    rope_theta=1_000_000.0,
)
