"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Assumptions beyond the assigned line (documented in DESIGN.md):
* MoE on every *other* layer (alternating dense/MoE, as in the released
  Maverick) — this is also what makes the "400b total / a17b active"
  numbers come out: 24 MoE layers x 128 experts x 3*5120*8192 ~= 386 B.
* iRoPE-style attention: 3 of every 4 layers use chunked-local attention
  (8192-token chunks), the 4th is global — this is the sub-quadratic
  structure that makes the long_500k cell runnable for this arch.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    layer_pattern=("attn_chunked", "attn_chunked", "attn_chunked", "attn"),
    chunk_size=8192,
    n_experts=128,
    top_k=1,
    moe_every=2,
    moe_offset=1,
    ffn_act="swiglu",
    rope_theta=500_000.0,
)
