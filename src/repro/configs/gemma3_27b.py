"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144, 5:1 local:global, 128k context.  [hf:google/gemma-3-1b-pt]

Five sliding-window (1024) layers per global layer => only ~1/6 of the
layers hold an unbounded KV cache; this is what qualifies gemma3 for the
long_500k cell (the global layers' 500k KV shards over the data axis).
62 = 10 full (5 local + 1 global) periods + 2 remainder local layers —
exercised by the segment-remainder path of the trunk.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21_504,
    vocab_size=262_144,
    layer_pattern=(
        "attn_local", "attn_local", "attn_local", "attn_local", "attn_local", "attn",
    ),
    window_size=1024,
    qk_norm=True,
    ffn_act="geglu",
    rope_theta=1_000_000.0,
)
