"""granite-34b [dense] — 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152, code model.  [arXiv:2405.04324; hf]

GPTBigCode-style MQA with a wide 4x GELU FFN; the 88-layer depth makes it
the longest fusion chain the evaluator sees (and the scan-over-layers
compile-time stress test).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24_576,
    vocab_size=49_152,
    ffn_act="gelu",
)
