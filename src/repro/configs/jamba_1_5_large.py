"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2, Mamba:attn 7:1.  [arXiv:2403.19887]

Jamba period-8 block: one attention layer (index 3) per seven Mamba
layers; MoE replaces the dense MLP on every other layer.  72 = 9 periods.
Mamba layers make the model O(state) in context => long_500k runs; the
9 attention layers' 500k KV (batch 1) shards its sequence axis over the
data axis (sequence parallelism).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24_576,
    vocab_size=65_536,
    layer_pattern=(
        "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba",
    ),
    n_experts=16,
    top_k=2,
    moe_every=2,
    moe_offset=1,
    ffn_act="swiglu",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
)
