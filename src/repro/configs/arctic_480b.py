"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864
vocab=32000, MoE 128e top-2 + dense residual.
[hf:Snowflake/snowflake-arctic-base; hf]

Snowflake Arctic's dense-MoE hybrid: every layer runs a 128-expert top-2
MoE **in parallel with** a dense residual MLP (``dense_residual_ff``).
Total params: 35 x 128 x 3*7168*4864 ~= 469 B experts + trunk ~= 480 B.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    n_experts=128,
    top_k=2,
    moe_every=1,
    dense_residual_ff=4864,
    ffn_act="swiglu",
)
