"""seamless-m4t-large-v2 [audio] — enc-dec, 24L each side, d_model=1024
16H (MHA kv=16) d_ff=8192 vocab=256206.  [arXiv:2308.11596; hf]

The speech frontend is a STUB per the task spec: the encoder consumes
1024 precomputed frame embeddings (``frontend_len``; ~20 s of speech at
20 ms stride).  Decoder shapes (seq_len x batch) apply to the text
decoder; cross-attention K/V over the encoder output are computed at
prefill and cached.  Positions use RoPE (adaptation from the original
sinusoidal encodings; documented in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256_206,
    is_encoder_decoder=True,
    n_enc_layers=24,
    ffn_act="relu",
    frontend="audio",
    frontend_len=1024,
)
