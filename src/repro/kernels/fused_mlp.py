"""Fused SwiGLU/GeGLU MLP as a Pallas TPU kernel.

Fusion group: ``x @ w1 -> act -> * (x @ w3) -> @ w2`` in one pass.  The
(tokens, d_ff) hidden activation — 4x the residual stream for the assigned
archs, e.g. 1 GiB/layer/device for granite's d_ff=24576 at train_4k — is
the fusion group's internal frame: it exists only as (block_m, block_f)
VMEM tiles.  HBM traffic per layer drops from
``2*T*ff + T*(2d+ff)`` words to ``T*2d + (weights)``, the Eq. (1)
bandwidth win for this group.

Grid: ``(T/block_m, ff/block_f)`` with the d_ff axis innermost; the output
(block_m, d) f32 tile accumulates partial ``h_blk @ w2_blk`` products in
VMEM scratch across d_ff steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref, acc_sc, *, n_fblocks, act):
    jf = pl.program_id(1)

    @pl.when(jf == 0)
    def _init():
        acc_sc[...] = jnp.zeros_like(acc_sc)

    x = x_ref[...].astype(jnp.float32)  # (bm, d)
    w1 = w1_ref[...].astype(jnp.float32)  # (d, bf)
    h = jax.lax.dot_general(x, w1, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if act == "swiglu":
        w3 = w3_ref[...].astype(jnp.float32)
        g = jax.lax.dot_general(x, w3, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        h = jax.nn.silu(h) * g
    elif act == "geglu":
        w3 = w3_ref[...].astype(jnp.float32)
        g = jax.lax.dot_general(x, w3, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        h = jax.nn.gelu(h) * g
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    w2 = w2_ref[...].astype(jnp.float32)  # (bf, d)
    acc_sc[...] += jax.lax.dot_general(h, w2, (((1,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(jf == n_fblocks - 1)
    def _finalize():
        o_ref[...] = acc_sc[...].astype(o_ref.dtype)


def fused_mlp(
    x: jnp.ndarray,  # (T, d)
    w1: jnp.ndarray,  # (d, ff)
    w2: jnp.ndarray,  # (ff, d)
    w3: jnp.ndarray | None = None,  # (d, ff) for gated acts
    *,
    act: str = "swiglu",
    block_m: int = 128,
    block_f: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    T, d = x.shape
    ff = w1.shape[1]
    block_m = min(block_m, T)
    block_f = min(block_f, ff)
    assert T % block_m == 0 and ff % block_f == 0
    nm, nf = T // block_m, ff // block_f
    if w3 is None:
        w3 = w1  # placeholder operand (unused for non-gated acts)

    kernel = functools.partial(_kernel, n_fblocks=nf, act=act)
    return pl.pallas_call(
        kernel,
        grid=(nm, nf),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda im, jf: (im, 0)),
            pl.BlockSpec((d, block_f), lambda im, jf: (0, jf)),
            pl.BlockSpec((d, block_f), lambda im, jf: (0, jf)),
            pl.BlockSpec((block_f, d), lambda im, jf: (jf, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d), lambda im, jf: (im, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        interpret=interpret,
    )(x, w1, w3, w2)


def vmem_bytes(block_m: int, block_f: int, d: int, dtype_bytes: int = 2) -> int:
    return (
        block_m * d * dtype_bytes  # x tile
        + 2 * d * block_f * dtype_bytes  # w1, w3 tiles
        + block_f * d * dtype_bytes  # w2 tile
        + 2 * block_m * block_f * 4  # h, g f32
        + block_m * d * 4  # accumulator
        + block_m * d * dtype_bytes  # out tile
    )
