"""Flash attention forward as a Pallas TPU kernel.

Fusion group: QK^T -> mask -> online softmax -> PV.  The (block_q, block_k)
score tile lives in VMEM/VREGs only — the (Sq, Skv) "intermediate frame"
(the paper's Eq. (1) group-internal tensor) never touches HBM, cutting the
attention HBM traffic from O(Sq*Skv) to O(Sq*hd + Skv*hd).

Grid: ``(batch*heads, Sq/block_q, Skv/block_k)`` with the KV axis innermost
and sequential; the running (m, l, acc) state persists in VMEM scratch
across KV steps.  GQA is handled in the index maps (q head h reads kv head
h // group).  Masking supports causal / sliding-window / chunked-local via
absolute position arithmetic (full-block skipping is a real-TPU grid-
pruning optimisation; here blocks are masked, which is what interpret mode
validates).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            block_q, block_k, n_kblocks, causal, window, chunk, scale):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0].astype(jnp.float32)  # (block_q, hd)
    k = k_ref[0].astype(jnp.float32)  # (block_k, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (block_q, block_k)

    q_idx = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_idx = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones(s.shape, jnp.bool_)
    if causal:
        ok &= k_idx <= q_idx
    if window > 0:
        ok &= (q_idx - k_idx) < window
    if chunk > 0:
        ok &= (q_idx // chunk) == (k_idx // chunk)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_sc[...] * corr + p.sum(axis=1)
    acc_new = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_sc[...] = m_new
    l_sc[...] = l_new
    acc_sc[...] = acc_new

    @pl.when(ik == n_kblocks - 1)
    def _finalize():
        o_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,  # sliding window (attn_local); 0 = off
    chunk: int = 0,  # chunked-local (attn_chunked); 0 = off
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas flash attention.  Requires Sq % block_q == Skv % block_k == 0."""
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    nq, nk = Sq // block_q, Skv // block_k

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)

    def q_map(bh, iq, ik):
        return (bh, iq, 0)

    def kv_map(bh, iq, ik):
        b = bh // H
        h = bh % H
        return (b * KV + h // G, ik, 0)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, n_kblocks=nk,
        causal=causal, window=window, chunk=chunk, scale=1.0 / math.sqrt(hd),
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), q_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
            pl.BlockSpec((1, block_k, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)


def vmem_bytes(block_q: int, block_k: int, hd: int, dtype_bytes: int = 2) -> int:
    """VMEM working set claimed by the BlockSpecs (planner feasibility)."""
    tiles = (
        block_q * hd * dtype_bytes  # q block
        + 2 * block_k * hd * dtype_bytes  # k, v blocks
        + block_q * hd * dtype_bytes  # out block
        + block_q * block_k * 4  # score tile (f32 vregs)
        + block_q * (hd + 2) * 4  # acc + m + l scratch
    )
    return tiles
