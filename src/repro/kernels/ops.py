"""Planner-aware jit'd wrappers over the Pallas kernels.

``use_kernels(plan)`` routes model-level calls either to the fused Pallas
kernels (with the planner's block sizes) or to the pure-JAX fused paths —
the runtime realisation of the evaluator's fusion decision.  On this CPU
container kernels run in interpret mode; on real TPU ``interpret=False``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import fused_attention, fused_conv, fused_mlp, mamba_scan
from . import ref

INTERPRET = True  # CPU container; flip on real TPU


@partial(jax.jit, static_argnames=("causal", "window", "chunk", "block_q",
                                   "block_k"))
def attention(q, k, v, *, causal=True, window=0, chunk=0, block_q=128,
              block_k=128):
    return fused_attention.flash_attention(
        q, k, v, causal=causal, window=window, chunk=chunk,
        block_q=block_q, block_k=block_k, interpret=INTERPRET,
    )


@partial(jax.jit, static_argnames=("act", "block_m", "block_f"))
def mlp(x, w1, w2, w3=None, *, act="swiglu", block_m=128, block_f=512):
    return fused_mlp.fused_mlp(
        x, w1, w2, w3, act=act, block_m=block_m, block_f=block_f,
        interpret=INTERPRET,
    )


@partial(jax.jit, static_argnames=("pool", "block_c"))
def conv3x3(x, w, b, *, pool=False, block_c=64):
    return fused_conv.fused_conv3x3(
        x, w, b, pool=pool, block_c=block_c, interpret=INTERPRET
    )


@partial(jax.jit, static_argnames=("chunk", "block_d"))
def ssm_scan(dA, dBx, C, *, chunk=64, block_d=512):
    return mamba_scan.selective_scan(
        dA, dBx, C, chunk=chunk, block_d=block_d, interpret=INTERPRET
    )


def fused_conv_fn(plan=None):
    """Adapter for repro.models.vgg.forward(fused_conv_fn=...)."""
    block_c = plan.conv_block_c if plan is not None else 64

    def fn(x, w, b, *, pool):
        return conv3x3(x, w, b, pool=pool, block_c=block_c)

    return fn


REFS = {
    "attention": ref.flash_attention_ref,
    "mlp": ref.fused_mlp_ref,
    "conv3x3": ref.fused_conv3x3_ref,
    "ssm_scan": ref.selective_scan_ref,
}
