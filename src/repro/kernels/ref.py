"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, chunk=0):
    """Materialised-scores attention, GQA-aware.  Mirrors fused_attention."""
    from ..models.layers import attention_reference

    Sq, Skv = q.shape[1], k.shape[1]
    return attention_reference(
        q, k, v,
        q_pos=jnp.arange(Sq), kv_pos=jnp.arange(Skv),
        mixer=("attn_local" if window else ("attn_chunked" if chunk else "attn")),
        causal=causal, window=window, chunk=chunk,
    )


def fused_mlp_ref(x, w1, w2, w3=None, *, act="swiglu"):
    h = (x.astype(jnp.float32) @ w1.astype(jnp.float32))
    if act == "swiglu":
        h = jax.nn.silu(h) * (x.astype(jnp.float32) @ w3.astype(jnp.float32))
    elif act == "geglu":
        h = jax.nn.gelu(h) * (x.astype(jnp.float32) @ w3.astype(jnp.float32))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return (h @ w2.astype(jnp.float32)).astype(x.dtype)


def fused_conv3x3_ref(x, w, b, *, pool=False):
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = jnp.maximum(y + b.astype(jnp.float32), 0.0)
    if pool:
        y = jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    return y.astype(x.dtype)


def selective_scan_ref(dA, dBx, C):
    from ..models.ssm import selective_scan_reference

    y, _ = selective_scan_reference(dA, dBx, C)
    return y
