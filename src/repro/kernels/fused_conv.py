"""Fused conv3x3 + bias + ReLU (+ 2x2 max-pool) — the paper's own workload.

This is the kernel the paper's DLA executes (Fig. 1: PE array + the inline
ReLU/BN/pool functional unit).  One fusion group = conv + activation +
pool: the pre-pool output frame (Noh x Now x M, the ``out_words_prepool``
quantity in the evaluator's area model) stays in VMEM; only the pooled
frame is written to HBM — the exact traffic the evaluator's Eq. (1)
credits a fused group.

TPU adaptation of the 3x3 systolic dataflows in [2][3]: the 3x3 window is
decomposed into 9 shifted (H*W, Cin) x (Cin, Cout-block) MXU matmuls (the
MXU replaces the PE adder trees; F1..F4 become grid/block factors).  VGG
feature maps (<= 224x224x64 = 6.4 MiB bf16) fit whole in VMEM, so the grid
is (batch, Cout/block_c) with full-frame blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, b_ref, o_ref, *, H, W, pool):
    x = x_ref[0].astype(jnp.float32)  # (H, W, Cin)
    w = w_ref[...].astype(jnp.float32)  # (3, 3, Cin, bc)
    b = b_ref[...].astype(jnp.float32)  # (bc,)
    Cin = x.shape[-1]
    bc = w.shape[-1]
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    acc = jnp.zeros((H * W, bc), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            patch = xp[dy : dy + H, dx : dx + W, :].reshape(H * W, Cin)
            acc += jax.lax.dot_general(
                patch, w[dy, dx], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    y = jnp.maximum(acc + b[None, :], 0.0).reshape(H, W, bc)
    if pool:  # fused 2x2 max pool: pre-pool frame never leaves VMEM
        y = y.reshape(H // 2, 2, W // 2, 2, bc).max(axis=(1, 3))
    o_ref[0] = y.astype(o_ref.dtype)


def fused_conv3x3(
    x: jnp.ndarray,  # (B, H, W, Cin)
    w: jnp.ndarray,  # (3, 3, Cin, Cout)
    b: jnp.ndarray,  # (Cout,)
    *,
    pool: bool = False,
    block_c: int = 64,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, W, Cin = x.shape
    Cout = w.shape[-1]
    block_c = min(block_c, Cout)
    assert Cout % block_c == 0
    Ho, Wo = (H // 2, W // 2) if pool else (H, W)

    kernel = functools.partial(_kernel, H=H, W=W, pool=pool)
    return pl.pallas_call(
        kernel,
        grid=(B, Cout // block_c),
        in_specs=[
            pl.BlockSpec((1, H, W, Cin), lambda ib, jc: (ib, 0, 0, 0)),
            pl.BlockSpec((3, 3, Cin, block_c), lambda ib, jc: (0, 0, 0, jc)),
            pl.BlockSpec((block_c,), lambda ib, jc: (jc,)),
        ],
        out_specs=pl.BlockSpec((1, Ho, Wo, block_c), lambda ib, jc: (ib, 0, 0, jc)),
        out_shape=jax.ShapeDtypeStruct((B, Ho, Wo, Cout), x.dtype),
        interpret=interpret,
    )(x, w, b)


def vmem_bytes(H: int, W: int, Cin: int, block_c: int, dtype_bytes: int = 2) -> int:
    return (
        (H + 2) * (W + 2) * Cin * 4  # padded input frame (f32)
        + 9 * Cin * block_c * dtype_bytes  # weights
        + H * W * block_c * 4  # pre-pool accumulator (the fused frame)
        + H * W * block_c * dtype_bytes  # out tile
    )
