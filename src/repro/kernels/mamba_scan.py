"""Selective-scan (Mamba-1 recurrence) as a Pallas TPU kernel.

Fusion group: discretisation already done upstream; this kernel fuses the
recurrence ``h_t = dA_t * h + dBx_t`` with the readout ``y_t = <h_t, C_t>``
so the (S, d_inner, d_state) transition tensors stream through VMEM chunk
by chunk and the (d_inner, d_state) state never leaves VMEM between steps
— 128x HBM-traffic reduction vs. materialising the state sequence for
falcon-mamba's d_inner=8192, d_state=16.

Grid: ``(B, d_inner/block_d, S/chunk)`` with the sequence axis innermost
and sequential; the state carry lives in VMEM scratch, zero-initialised at
chunk 0.  In-chunk steps run as a fori_loop (the associative-scan variant
is the chunked pure-JAX path in repro.models.ssm; this kernel validates
the memory-hierarchy layout in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dA_ref, dBx_ref, c_ref, y_ref, h_sc, *, chunk):
    js = pl.program_id(2)

    @pl.when(js == 0)
    def _init():
        h_sc[...] = jnp.zeros_like(h_sc)

    dA = dA_ref[0].astype(jnp.float32)  # (chunk, bd, ds)
    dBx = dBx_ref[0].astype(jnp.float32)
    c = c_ref[0].astype(jnp.float32)  # (chunk, ds)

    def step(t, carry):
        h, ys = carry
        h = dA[t] * h + dBx[t]  # (bd, ds)
        y_t = jnp.sum(h * c[t][None, :], axis=1)  # (bd,)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    h0 = h_sc[...]
    ys0 = jnp.zeros((chunk, dA.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_sc[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def selective_scan(
    dA: jnp.ndarray,  # (B, S, di, ds) f32
    dBx: jnp.ndarray,  # (B, S, di, ds) f32
    C: jnp.ndarray,  # (B, S, ds) f32
    *,
    chunk: int = 64,
    block_d: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    """Returns y (B, S, di) f32."""
    B, S, di, ds = dA.shape
    chunk = min(chunk, S)
    block_d = min(block_d, di)
    assert S % chunk == 0 and di % block_d == 0
    ns, nd = S // chunk, di // block_d

    kernel = functools.partial(_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B, nd, ns),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d, ds), lambda b, jd, js: (b, js, jd, 0)),
            pl.BlockSpec((1, chunk, block_d, ds), lambda b, jd, js: (b, js, jd, 0)),
            pl.BlockSpec((1, chunk, ds), lambda b, jd, js: (b, js, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d), lambda b, jd, js: (b, js, jd)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, ds), jnp.float32)],
        interpret=interpret,
    )(dA, dBx, C)
    return out


def vmem_bytes(chunk: int, block_d: int, ds: int) -> int:
    return (
        2 * chunk * block_d * ds * 4  # dA, dBx tiles
        + chunk * ds * 4  # C tile
        + block_d * ds * 4  # state scratch
        + chunk * block_d * 4  # y tile
    )
