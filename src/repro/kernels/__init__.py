"""Pallas TPU kernels for the fusion groups the planner selects.

Each kernel keeps a fusion group's intermediate tensors in VMEM (the TPU
analogue of the paper's on-chip SRAM): the flash-attention score tile, the
SwiGLU hidden activations, the conv3x3 pre-pool frame, and the selective
scan's discretised transitions never round-trip through HBM.

``ops.py`` holds the jit'd dispatch wrappers (planner-aware), ``ref.py``
the pure-jnp oracles every kernel is validated against (interpret mode on
CPU; see tests/test_kernels.py shape/dtype sweeps).
"""
