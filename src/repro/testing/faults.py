"""Fault-injection harness for the planning service.

Two halves:

* **Corrupt-graph builders** — clones of a valid :class:`GraphIR` with one
  invariant broken (a cycle-inducing edge, negative words, NaN features,
  dangling endpoints, duplicate edges).  ``GraphIR.__post_init__``
  validates at construction, so corruption is applied *after* the fact via
  ``object.__new__``/``object.__setattr__`` — exactly what a
  deserialisation bug or a buggy graph transform would produce.  The
  service's admission re-validation (:meth:`GraphIR.validate`) must catch
  every one of them with a typed :class:`GraphValidationError`.

* **FaultInjector** — a duck-typed hook object for
  :class:`repro.core.service.PlanningService` (the callable-hook idiom of
  :mod:`repro.runtime.fault_tolerance`): transient sweep failures (to
  exercise retry-with-backoff), search stalls (to exercise
  :class:`DeadlineExceeded`), and executable-cache eviction storms (to
  prove correctness is cache-independent).

:func:`chaos_requests` composes both into a reproducible mixed request
stream for the chaos tests and ``benchmarks/bench_serve.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Iterator

import numpy as np

from ..core import flow, frontend
from ..core.arch import Constraints
from ..core.ir import EdgeSpec, GraphIR
from ..core.service import PlanRequest


# ---------------------------------------------------------------------------
# corrupt-graph builders
# ---------------------------------------------------------------------------


def _raw_clone(g: GraphIR, *, nodes=None, edges=None, name=None) -> GraphIR:
    """Clone ``g`` WITHOUT running ``__post_init__`` validation — the
    vehicle for building deliberately-invalid graphs."""
    bad = object.__new__(GraphIR)
    object.__setattr__(bad, "name", g.name if name is None else name)
    object.__setattr__(bad, "nodes", g.nodes if nodes is None else tuple(nodes))
    object.__setattr__(bad, "edges", g.edges if edges is None else tuple(edges))
    return bad


def _raw_edge(src: int, dst: int, words) -> EdgeSpec:
    e = object.__new__(EdgeSpec)
    object.__setattr__(e, "src", src)
    object.__setattr__(e, "dst", dst)
    object.__setattr__(e, "words", words)
    return e


def corrupt_graph_cyclic(g: GraphIR) -> GraphIR:
    """Add a back edge (dst <= src), breaking the topological/acyclicity
    invariant."""
    return _raw_clone(
        g, edges=g.edges + (_raw_edge(g.n_nodes - 1, 0, 64),),
        name=f"{g.name}/cyclic",
    )


def corrupt_graph_negative_words(g: GraphIR) -> GraphIR:
    """Flip one edge's word count negative."""
    e0 = g.edges[0]
    return _raw_clone(
        g, edges=(_raw_edge(e0.src, e0.dst, -abs(e0.words)),) + g.edges[1:],
        name=f"{g.name}/negwords",
    )


def corrupt_graph_nan_feature(g: GraphIR) -> GraphIR:
    """Poison one layer's channel count with NaN (a float, not an int —
    doubly invalid)."""
    n0 = g.nodes[0]
    poisoned = object.__new__(type(n0))
    for f in dataclasses.fields(n0):
        object.__setattr__(poisoned, f.name, getattr(n0, f.name))
    object.__setattr__(poisoned, "n_out", float("nan"))
    return _raw_clone(
        g, nodes=(poisoned,) + g.nodes[1:], name=f"{g.name}/nan",
    )


def corrupt_graph_dangling(g: GraphIR) -> GraphIR:
    """Add an edge whose dst points past the last node."""
    return _raw_clone(
        g, edges=g.edges + (_raw_edge(0, g.n_nodes + 3, 64),),
        name=f"{g.name}/dangling",
    )


def corrupt_graph_duplicate_edge(g: GraphIR) -> GraphIR:
    """Duplicate the first edge."""
    e0 = g.edges[0]
    return _raw_clone(
        g, edges=g.edges + (_raw_edge(e0.src, e0.dst, e0.words),),
        name=f"{g.name}/dup",
    )


CORRUPTIONS = (
    corrupt_graph_cyclic,
    corrupt_graph_negative_words,
    corrupt_graph_nan_feature,
    corrupt_graph_dangling,
    corrupt_graph_duplicate_edge,
)


# ---------------------------------------------------------------------------
# fault injector (duck-typed PlanningService hooks)
# ---------------------------------------------------------------------------


class InjectedTransient(RuntimeError):
    """The injected stand-in for a transient sweep failure (an XLA compile
    hiccup, a cache race).  Deliberately NOT an EvaluatorError: the
    service must classify it as retryable."""


class InjectedShardFailure(RuntimeError):
    """The injected stand-in for a shard/chunk compute failure (a sick
    device, a collective timeout).  Also NOT an EvaluatorError: the
    per-chunk :class:`repro.core.errors.RetryPolicy` must classify it as
    retryable and salvage the sweep."""


class FaultInjector:
    """Configurable fault hooks for :class:`PlanningService`.

    ``transient_sweeps``      — the first N ``before_sweep`` calls raise
                                :class:`InjectedTransient` (retry path);
    ``transient_every``       — additionally every k-th sweep raises once
                                (0 = off), so faults recur under load;
    ``stall_every``/``stall_seconds`` — every k-th ``before_search`` call
                                sleeps, simulating a stalled search so
                                tight deadlines trip DeadlineExceeded;
    ``evict_every``           — every k-th tick clears the executable
                                cache (an eviction storm): plans must be
                                bit-identical with or without the cache;
    ``chunk_stall_seconds``   — every ``before_chunk`` call (the chunked
                                sweep's between-chunk preemption point)
                                sleeps, stretching the sweep so the
                                cancellation tests can land a cancel
                                mid-flight and measure how fast the next
                                chunk boundary honours it;
    ``corrupt_audit_every``   — every k-th shadow audit perturbs the
                                oracle's energy by +1 nJ (0 = off), so
                                the AuditMismatch path is exercisable
                                without a real evaluator bug;
    ``shard_fail_chunks``     — the first N ``before_chunk_compute``
                                calls raise :class:`InjectedShardFailure`
                                (chunk-salvage retry path);
    ``shard_fail_every``      — additionally every k-th chunk compute
                                raises once (0 = off);
    ``mesh_fail_sweeps``      — the first N chunk computes *on a multi-
                                device mesh* raise, driving the sweep
                                down the single-device degradation rung;
    ``poison_cell``           — a ``(g, h, c)`` triple whose raw cost row
                                ``poison_plane`` overwrites with
                                ``poison_value`` (quarantine path);
    ``poison_value``          — what to write there (default NaN).
    """

    def __init__(
        self,
        *,
        transient_sweeps: int = 0,
        transient_every: int = 0,
        stall_every: int = 0,
        stall_seconds: float = 0.0,
        evict_every: int = 0,
        chunk_stall_seconds: float = 0.0,
        corrupt_audit_every: int = 0,
        shard_fail_chunks: int = 0,
        shard_fail_every: int = 0,
        mesh_fail_sweeps: int = 0,
        poison_cell: tuple | None = None,
        poison_value: float = float("nan"),
        sleep=time.sleep,
    ):
        self.transient_sweeps = int(transient_sweeps)
        self.transient_every = int(transient_every)
        self.stall_every = int(stall_every)
        self.stall_seconds = float(stall_seconds)
        self.evict_every = int(evict_every)
        self.chunk_stall_seconds = float(chunk_stall_seconds)
        self.corrupt_audit_every = int(corrupt_audit_every)
        self.shard_fail_chunks = int(shard_fail_chunks)
        self.shard_fail_every = int(shard_fail_every)
        self.mesh_fail_sweeps = int(mesh_fail_sweeps)
        self.poison_cell = (
            None if poison_cell is None else tuple(int(v) for v in poison_cell)
        )
        self.poison_value = float(poison_value)
        self.sleep = sleep
        self.counts = collections.Counter()

    # -- PlanningService hook points ------------------------------------

    def on_tick(self, n: int) -> None:
        self.counts["ticks"] += 1
        if self.evict_every and n % self.evict_every == 0:
            self.counts["evict_storms"] += 1
            flow.clear_sweep_cache()

    def before_search(self, adm) -> None:
        self.counts["searches"] += 1
        if self.stall_every and self.counts["searches"] % self.stall_every == 0:
            self.counts["stalls"] += 1
            self.sleep(self.stall_seconds)

    def before_sweep(self, group_size: int) -> None:
        self.counts["sweeps"] += 1
        if self.transient_sweeps > 0:
            self.transient_sweeps -= 1
            self.counts["injected_transients"] += 1
            raise InjectedTransient("injected transient sweep failure")
        if self.transient_every and (
            self.counts["sweeps"] % self.transient_every == 0
        ):
            self.counts["injected_transients"] += 1
            raise InjectedTransient("injected periodic sweep failure")

    def before_chunk(self) -> None:
        self.counts["chunks"] += 1
        if self.chunk_stall_seconds > 0:
            self.sleep(self.chunk_stall_seconds)

    def before_chunk_compute(self, chunk_index: int, *,
                             device_count: int = 1) -> None:
        """run_fleet's per-chunk compute hook: raise here to simulate a
        shard failure (retried by the chunk RetryPolicy) or a sick mesh
        (``device_count > 1`` — drives the degradation ladder)."""
        self.counts["chunk_computes"] += 1
        if self.mesh_fail_sweeps > 0 and device_count > 1:
            self.mesh_fail_sweeps -= 1
            self.counts["injected_mesh_failures"] += 1
            raise InjectedShardFailure(
                f"injected mesh failure (devices={device_count})"
            )
        if self.shard_fail_chunks > 0:
            self.shard_fail_chunks -= 1
            self.counts["injected_shard_failures"] += 1
            raise InjectedShardFailure(
                f"injected shard failure at chunk {chunk_index}"
            )
        if self.shard_fail_every and (
            self.counts["chunk_computes"] % self.shard_fail_every == 0
        ):
            self.counts["injected_shard_failures"] += 1
            raise InjectedShardFailure(
                f"injected periodic shard failure at chunk {chunk_index}"
            )

    def poison_plane(self, plane, h0: int):
        """run_fleet's raw-plane hook: overwrite ``poison_cell``'s cost
        row with ``poison_value`` when that cell lives in this chunk —
        the finite guard must quarantine it before any selection."""
        if self.poison_cell is None:
            return plane
        g, h, c = self.poison_cell
        if h0 <= h < h0 + plane.shape[1]:
            plane = np.array(plane, copy=True)
            plane[g, h - h0, c, :] = self.poison_value
            self.counts["poisoned_cells"] += 1
        return plane

    def corrupt_audit(self, metrics):
        self.counts["audits_seen"] += 1
        if self.corrupt_audit_every and (
            self.counts["audits_seen"] % self.corrupt_audit_every == 0
        ):
            self.counts["audits_corrupted"] += 1
            return dataclasses.replace(
                metrics, energy_nj=metrics.energy_nj + 1.0
            )
        return metrics


# ---------------------------------------------------------------------------
# chaos request stream
# ---------------------------------------------------------------------------


def _valid_graphs() -> list[GraphIR]:
    """Small, fast-to-search workloads spanning chain and DAG searches."""
    from ..core.ir import as_graph, encoder_decoder_ir, residual_block_ir

    return [
        as_graph(frontend.mlp_block_graph()),
        as_graph(residual_block_ir()),
        as_graph(encoder_decoder_ir()),
    ]


def chaos_requests(
    n: int, *, seed: int = 0, faulty_fraction: float = 0.4
) -> Iterator[tuple[str, PlanRequest]]:
    """Yield ``n`` labelled requests mixing valid and hostile inputs.

    Labels: ``valid``, ``valid-budget`` (tight-but-feasible budget),
    ``corrupt:<builder>``, ``nan-budget``, ``negative-budget``,
    ``zero-deadline``, ``tight-deadline``, ``impossible-constraints``.
    Deterministic per ``seed``; roughly ``faulty_fraction`` of the stream
    is hostile."""
    rng = np.random.default_rng(seed)
    graphs = _valid_graphs()
    hostile = (
        ["corrupt:" + c.__name__ for c in CORRUPTIONS]
        + ["nan-budget", "negative-budget", "zero-deadline",
           "tight-deadline", "impossible-constraints"]
    )
    for _ in range(n):
        g = graphs[int(rng.integers(len(graphs)))]
        if rng.random() >= faulty_fraction:
            if rng.random() < 0.5:
                yield "valid", PlanRequest(graph=g)
            else:
                yield "valid-budget", PlanRequest(
                    graph=g, sram_budget_words=float(rng.integers(1e5, 4e6))
                )
            continue
        kind = hostile[int(rng.integers(len(hostile)))]
        if kind.startswith("corrupt:"):
            builder = CORRUPTIONS[
                ["corrupt:" + c.__name__ for c in CORRUPTIONS].index(kind)
            ]
            yield kind, PlanRequest(graph=builder(g))
        elif kind == "nan-budget":
            yield kind, PlanRequest(graph=g, sram_budget_words=float("nan"))
        elif kind == "negative-budget":
            yield kind, PlanRequest(graph=g, sram_budget_words=-64.0)
        elif kind == "zero-deadline":
            yield kind, PlanRequest(graph=g, deadline_seconds=0.0)
        elif kind == "tight-deadline":
            yield kind, PlanRequest(graph=g, deadline_seconds=1e-4)
        else:  # impossible-constraints: nothing can cost < 1 word of BW
            yield kind, PlanRequest(
                graph=g,
                constraints=Constraints(
                    max_bandwidth_words=0.5,
                    max_latency_cycles=1.0,
                    max_energy_nj=1.0,
                    max_area_um2=1.0,
                ),
            )
