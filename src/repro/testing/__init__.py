"""Test-support utilities: fault injection for the planning service.

Importable from production code paths (the service accepts any duck-typed
``faults`` object), but shipped under ``repro.testing`` because its only
in-repo consumers are the chaos tests and ``benchmarks/bench_serve.py``.
"""
from .faults import (
    FaultInjector,
    chaos_requests,
    corrupt_graph_cyclic,
    corrupt_graph_dangling,
    corrupt_graph_duplicate_edge,
    corrupt_graph_nan_feature,
    corrupt_graph_negative_words,
)

__all__ = [
    "FaultInjector",
    "chaos_requests",
    "corrupt_graph_cyclic",
    "corrupt_graph_dangling",
    "corrupt_graph_duplicate_edge",
    "corrupt_graph_nan_feature",
    "corrupt_graph_negative_words",
]
