"""Checkpointing: atomic, hashed, async-capable, resharding-aware."""
from .checkpoint import (  # noqa: F401
    AsyncCheckpointer, device_put_like, latest_step, restore, save,
)
