"""Checkpointing: atomic, hashed, async-capable, resharding-aware."""
from .checkpoint import (  # noqa: F401
    SWEEP_RECORD_TYPES,
    AsyncCheckpointer,
    SweepCheckpoint,
    device_put_like,
    latest_step,
    restore,
    save,
    sweep_fingerprint,
)
