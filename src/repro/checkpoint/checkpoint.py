"""Sharded checkpoint save/restore with integrity hashes and atomic commit.

Layout: ``<dir>/step_<N>/arrays.npz`` (flattened pytree, '/'-joined keys)
plus ``manifest.json`` carrying step, per-array sha256, shapes and dtypes.
Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a crash
mid-save never corrupts the latest good step (the restart path in
runtime.fault_tolerance relies on this).

Restore returns host numpy arrays; ``device_put_like`` re-shards them onto
any mesh — including a *different* mesh than the one that saved them,
which is what elastic re-scaling (runtime.elastic) uses.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for e in path:
            if isinstance(e, jax.tree_util.DictKey):
                keys.append(str(e.key))
            elif isinstance(e, jax.tree_util.SequenceKey):
                keys.append(str(e.idx))
            else:
                keys.append(str(getattr(e, "name", e)))
        flat[SEP.join(keys)] = np.asarray(leaf)
    return flat


def _unflatten_into(like, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        keys = []
        for e in path:
            if isinstance(e, jax.tree_util.DictKey):
                keys.append(str(e.key))
            elif isinstance(e, jax.tree_util.SequenceKey):
                keys.append(str(e.idx))
            else:
                keys.append(str(getattr(e, "name", e)))
        key = SEP.join(keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        leaves.append(flat[key])
    return treedef.unflatten(leaves)


def save(ckpt_dir, step: int, tree, *, extra: dict | None = None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "extra": extra or {},
        "arrays": {
            k: {
                "sha256": hashlib.sha256(v.tobytes()).hexdigest(),
                "shape": list(v.shape),
                "dtype": str(v.dtype),
            }
            for k, v in flat.items()
        },
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    with open(tmp / "manifest.json", "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, like=None, *, verify: bool = True):
    """Returns (tree_of_numpy, extra).  ``like`` gives the pytree structure."""
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    if verify:
        for k, meta in manifest["arrays"].items():
            h = hashlib.sha256(flat[k].tobytes()).hexdigest()
            if h != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {k}")
    tree = flat if like is None else _unflatten_into(like, flat)
    return tree, manifest.get("extra", {})


def device_put_like(tree_np, shardings):
    """Re-shard host arrays onto (possibly different) mesh shardings."""
    return jax.tree.map(
        lambda a, s: jax.device_put(a, s), tree_np, shardings
    )


# ---------------------------------------------------------------------------
# Sweep-chunk checkpoints — resumable fleet co-search
# ---------------------------------------------------------------------------

# Record vocabulary of the sweep-chunk log (docs/RESILIENCE.md table is
# machine-checked against this tuple): one `sweep_meta` header binding the
# log to a sweep fingerprint, then one `chunk_plane` per completed
# hardware-axis chunk.
SWEEP_RECORD_TYPES = ("sweep_meta", "chunk_plane")
SWEEP_LOG_NAME = "sweep_chunks.jsonl"


def sweep_fingerprint(args, hw_chunk: int) -> str:
    """sha256 over a chunked sweep's *entire* input (every argument
    array's dtype/shape/raw bytes plus the chunk size).

    Two sweeps share checkpointed chunks iff their fingerprints match, so
    a resumed co-search can never splice planes from a different fleet,
    config space, or chunking into its result.
    """
    h = hashlib.sha256()
    h.update(f"hw_chunk={int(hw_chunk)}".encode())
    for a in args:
        a = np.ascontiguousarray(a)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


class SweepCheckpoint:
    """Durable per-chunk result store for resumable fleet sweeps.

    Each completed hw-chunk's raw (G, h, C, 5) plane is appended to a
    JSONL log through the journal's bit-exact codecs
    (:func:`repro.core.journal.enc_array` — dtype/shape/raw bytes, so the
    restored plane is byte-identical) with the journal's sha256 record
    digests.  A killed sweep resumes by :meth:`load`-ing the completed
    planes and recomputing only the missing chunks
    (:func:`repro.core.flow.run_fleet` with ``checkpoint_dir=``).

    Crash semantics follow the WAL: a torn tail (the final record cut
    mid-append) is normal damage and silently dropped — that chunk simply
    recomputes; an *interior* record with a bad digest is refused with
    :class:`repro.core.errors.JournalCorrupt`.  A log written by a
    different sweep (fingerprint mismatch) is discarded and restarted,
    never spliced.
    """

    def __init__(self, directory, *, fsync: bool = True):
        """Open (or create) the sweep-chunk log under ``directory``."""
        self.directory = pathlib.Path(directory)
        self.path = self.directory / SWEEP_LOG_NAME
        self.fsync = bool(fsync)
        self._seq = 0
        self._fingerprint: str | None = None

    def _records(self):
        """Verified records of the log; tolerates only a torn tail."""
        from ..core.errors import JournalCorrupt
        from ..core.journal import record_digest

        if not self.path.exists():
            return []
        lines = [
            ln for ln in self.path.read_bytes().split(b"\n") if ln.strip()
        ]
        records = []
        for i, ln in enumerate(lines):
            last = i == len(lines) - 1
            try:
                rec = json.loads(ln)
                ok = (
                    rec.get("type") in SWEEP_RECORD_TYPES
                    and rec.get("digest")
                    == record_digest(rec["seq"], rec["type"], rec["payload"])
                )
            except (ValueError, KeyError, TypeError):
                ok = False
                rec = None
            if not ok:
                if last:
                    break  # torn tail: that chunk just recomputes
                raise JournalCorrupt(
                    f"{self.path}: interior record {i} failed verification"
                )
            records.append(rec)
        return records

    def load(self, fingerprint: str) -> dict[int, np.ndarray]:
        """{h0 -> raw plane} of every durably completed chunk.

        Binds this store to ``fingerprint``; a log headed by a different
        fingerprint (or missing its ``sweep_meta`` header) belongs to a
        different sweep and is discarded so stale planes can never leak
        into the resumed result.
        """
        from ..core.journal import dec_array

        self._fingerprint = fingerprint
        records = self._records()
        if (
            not records
            or records[0]["type"] != "sweep_meta"
            or records[0]["payload"].get("fingerprint") != fingerprint
        ):
            if self.path.exists():
                self.path.unlink()
            self._seq = 0
            return {}
        self._seq = records[-1]["seq"] + 1
        return {
            int(rec["payload"]["h0"]): dec_array(rec["payload"]["plane"])
            for rec in records[1:]
        }

    def _append(self, rtype: str, payload: dict) -> None:
        from ..core.journal import record_digest

        self.directory.mkdir(parents=True, exist_ok=True)
        rec = {
            "seq": self._seq,
            "type": rtype,
            "payload": payload,
            "digest": record_digest(self._seq, rtype, payload),
        }
        self._seq += 1
        with open(self.path, "a", encoding="ascii") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
            f.flush()
            if self.fsync:
                os.fsync(f.fileno())

    def append_chunk(self, h0: int, plane: np.ndarray) -> None:
        """Durably record one completed chunk's raw plane.

        The record is on disk (fsynced by default) before the caller
        moves on, so a kill at ANY later point never recomputes this
        chunk — the exactly-once property the kill-point tests assert.
        """
        from ..core.journal import enc_array

        if self._fingerprint is None:
            raise ValueError("call load(fingerprint) before append_chunk")
        if self._seq == 0:
            self._append("sweep_meta", {"fingerprint": self._fingerprint})
        self._append(
            "chunk_plane", {"h0": int(h0), "plane": enc_array(plane)}
        )


class AsyncCheckpointer:
    """Background-thread writer; ``wait()`` before reading ``last_saved``."""

    def __init__(self, ckpt_dir):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None
        self._err: Exception | None = None

    def submit(self, step: int, tree, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra=extra)
                self.last_saved = step
            except Exception as e:  # pragma: no cover
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err:
            raise self._err
