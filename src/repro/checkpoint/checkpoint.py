"""Sharded checkpoint save/restore with integrity hashes and atomic commit.

Layout: ``<dir>/step_<N>/arrays.npz`` (flattened pytree, '/'-joined keys)
plus ``manifest.json`` carrying step, per-array sha256, shapes and dtypes.
Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a crash
mid-save never corrupts the latest good step (the restart path in
runtime.fault_tolerance relies on this).

Restore returns host numpy arrays; ``device_put_like`` re-shards them onto
any mesh — including a *different* mesh than the one that saved them,
which is what elastic re-scaling (runtime.elastic) uses.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading

import jax
import numpy as np

SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for e in path:
            if isinstance(e, jax.tree_util.DictKey):
                keys.append(str(e.key))
            elif isinstance(e, jax.tree_util.SequenceKey):
                keys.append(str(e.idx))
            else:
                keys.append(str(getattr(e, "name", e)))
        flat[SEP.join(keys)] = np.asarray(leaf)
    return flat


def _unflatten_into(like, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths:
        keys = []
        for e in path:
            if isinstance(e, jax.tree_util.DictKey):
                keys.append(str(e.key))
            elif isinstance(e, jax.tree_util.SequenceKey):
                keys.append(str(e.idx))
            else:
                keys.append(str(getattr(e, "name", e)))
        key = SEP.join(keys)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        leaves.append(flat[key])
    return treedef.unflatten(leaves)


def save(ckpt_dir, step: int, tree, *, extra: dict | None = None) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "extra": extra or {},
        "arrays": {
            k: {
                "sha256": hashlib.sha256(v.tobytes()).hexdigest(),
                "shape": list(v.shape),
                "dtype": str(v.dtype),
            }
            for k, v in flat.items()
        },
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    with open(tmp / "manifest.json", "rb") as f:
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic commit
    return final


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [
        int(p.name.split("_")[1])
        for p in ckpt_dir.iterdir()
        if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir, step: int, like=None, *, verify: bool = True):
    """Returns (tree_of_numpy, extra).  ``like`` gives the pytree structure."""
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        flat = {k: z[k] for k in z.files}
    if verify:
        for k, meta in manifest["arrays"].items():
            h = hashlib.sha256(flat[k].tobytes()).hexdigest()
            if h != meta["sha256"]:
                raise IOError(f"checkpoint corruption in {k}")
    tree = flat if like is None else _unflatten_into(like, flat)
    return tree, manifest.get("extra", {})


def device_put_like(tree_np, shardings):
    """Re-shard host arrays onto (possibly different) mesh shardings."""
    return jax.tree.map(
        lambda a, s: jax.device_put(a, s), tree_np, shardings
    )


class AsyncCheckpointer:
    """Background-thread writer; ``wait()`` before reading ``last_saved``."""

    def __init__(self, ckpt_dir):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self._thread: threading.Thread | None = None
        self.last_saved: int | None = None
        self._err: Exception | None = None

    def submit(self, step: int, tree, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra=extra)
                self.last_saved = step
            except Exception as e:  # pragma: no cover
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err:
            raise self._err
