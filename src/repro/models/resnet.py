"""ResNet-18 in JAX (He et al., 2016) — the residual workload the evaluator
frontend traces into a :class:`repro.core.ir.GraphIR`.

``repro.core.ir.resnet18_ir`` is a thin wrapper over
``repro.core.frontend.resnet18_graph``, which runs :func:`forward` through
``jax.make_jaxpr`` and recovers the skip edges from the jaxpr's use-def
chains; ``tests/test_frontend.py`` locks the trace node-and-edge-identical
to a verbatim transcription of the original hand-built DAG builder.

The block body is written in the canonical order (conv_a -> conv_b ->
downsample -> add) so the traced node order matches the hand-built one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.ir import RESNET18_STAGE_PLAN


def _conv(x: jnp.ndarray, w: jnp.ndarray, stride: int) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _block_channels() -> list[tuple[int, int, int]]:
    """(c_in, c_out, stride) per basic block, following the stage plan."""
    out = []
    c_in = 64
    for _stage, n_blocks, c_out, stride0 in RESNET18_STAGE_PLAN:
        for b in range(n_blocks):
            out.append((c_in if b == 0 else c_out, c_out, stride0 if b == 0 else 1))
        c_in = c_out
    return out


def param_specs(*, n_classes: int = 1000, dtype=jnp.float32) -> dict:
    """``jax.ShapeDtypeStruct`` pytree for tracing (nothing materialised).
    Weight shapes are resolution-independent — the input size only enters
    through the activation example passed to the tracer."""
    sds = lambda *s: jax.ShapeDtypeStruct(tuple(s), dtype)
    blocks = []
    for c_in, c_out, stride in _block_channels():
        p = {
            "wa": sds(3, 3, c_in, c_out), "ba": sds(c_out),
            "wb": sds(3, 3, c_out, c_out), "bb": sds(c_out),
        }
        if stride != 1 or c_in != c_out:
            p["wd"] = sds(1, 1, c_in, c_out)
        blocks.append(p)
    return {
        "conv1": {"w": sds(7, 7, 3, 64), "b": sds(64)},
        "blocks": blocks,
        "fc": {"w": sds(512, n_classes), "b": sds(n_classes)},
    }


def init_params(key, *, n_classes: int = 1000, dtype=jnp.float32) -> dict:
    """He-initialised parameters matching :func:`param_specs`."""
    specs = param_specs(n_classes=n_classes, dtype=dtype)
    leaves, treedef = jax.tree_util.tree_flatten(specs)
    keys = jax.random.split(key, len(leaves))
    inits = []
    for k, leaf in zip(keys, leaves):
        if len(leaf.shape) >= 2:
            fan_in = int(jnp.prod(jnp.asarray(leaf.shape[:-1])))
            w = jax.random.normal(k, leaf.shape, jnp.float32)
            inits.append((w * (2.0 / fan_in) ** 0.5).astype(dtype))
        else:
            inits.append(jnp.zeros(leaf.shape, dtype))
    return jax.tree_util.tree_unflatten(treedef, inits)


def forward(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, H, W, 3) -> logits (B, n_classes)."""
    x = jax.nn.relu(_conv(x, params["conv1"]["w"], 2) + params["conv1"]["b"])
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
    )
    for p, (c_in, c_out, stride) in zip(params["blocks"], _block_channels()):
        y = jax.nn.relu(_conv(x, p["wa"], stride) + p["ba"])
        y = _conv(y, p["wb"], 1) + p["bb"]
        s = _conv(x, p["wd"], stride) if "wd" in p else x
        x = jax.nn.relu(y + s)
    hw = x.shape[1]
    x = jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, hw, hw, 1), (1, hw, hw, 1), "VALID"
    ) / float(hw * hw)
    x = x.reshape(x.shape[0], -1)
    return x @ params["fc"]["w"] + params["fc"]["b"]
