"""MobileNet-style inverted-residual stack (Sandler et al., 2018) in JAX.

The depthwise 3x3 convolutions (``feature_group_count == channels``) and
the linear-bottleneck skip adds make this the canonical beyond-3x3-conv
workload for the evaluator: the frontend traces :func:`forward` into a
:class:`repro.core.ir.GraphIR` whose depthwise nodes carry
``LayerSpec.groups`` and whose stride-1 blocks contribute residual joins
(``repro.core.frontend.mobilenet_graph``).

``MOBILENET_PLAN`` rows are ``(c_in, c_out, stride, expand)``; ``expand ==
1`` blocks skip the expansion 1x1 (MobileNet-v2's first bottleneck), and a
block has an identity skip iff ``stride == 1 and c_in == c_out``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# (c_in, c_out, stride, expand) — a v2-style truncation: stem 3->32 /2,
# then bottlenecks through two stride-2 stages with stride-1 skips.
MOBILENET_PLAN = (
    (32, 16, 1, 1),
    (16, 24, 2, 4),
    (24, 24, 1, 4),
    (24, 32, 2, 4),
    (32, 32, 1, 4),
)


def relu6(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.minimum(jnp.maximum(x, 0.0), 6.0)


def _conv(x, w, stride: int, *, groups: int = 1) -> jnp.ndarray:
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )


def param_specs(*, plan=MOBILENET_PLAN, dtype=jnp.float32) -> dict:
    """``jax.ShapeDtypeStruct`` pytree for tracing (nothing materialised)."""
    sds = lambda *s: jax.ShapeDtypeStruct(tuple(s), dtype)
    stem_out = plan[0][0]
    blocks = []
    for c_in, c_out, _stride, expand in plan:
        hidden = c_in * expand
        p = {}
        if expand != 1:
            p["we"] = sds(1, 1, c_in, hidden)
            p["be"] = sds(hidden)
        p["wd"] = sds(3, 3, 1, hidden)  # depthwise: one kernel per channel
        p["bd"] = sds(hidden)
        p["wp"] = sds(1, 1, hidden, c_out)
        p["bp"] = sds(c_out)
        blocks.append(p)
    return {"stem": {"w": sds(3, 3, 3, stem_out), "b": sds(stem_out)},
            "blocks": blocks}


def forward(params: dict, x: jnp.ndarray, *, plan=MOBILENET_PLAN) -> jnp.ndarray:
    """x: (B, H, W, 3) -> features (B, H', W', c_out_last)."""
    x = relu6(_conv(x, params["stem"]["w"], 2) + params["stem"]["b"])
    for p, (c_in, c_out, stride, expand) in zip(params["blocks"], plan):
        h = x
        if expand != 1:
            h = relu6(_conv(h, p["we"], 1) + p["be"])
        hidden = c_in * expand
        h = relu6(_conv(h, p["wd"], stride, groups=hidden) + p["bd"])
        h = _conv(h, p["wp"], 1) + p["bp"]  # linear bottleneck
        x = x + h if (stride == 1 and c_in == c_out) else h
    return x
