"""Shared neural-net building blocks (pure functions over param pytrees).

Conventions
-----------
* Activations: ``(batch, seq, ...)``; attention heads laid out
  ``(batch, seq, heads, head_dim)``.
* Every ``init_*`` returns a (nested) dict of ``jnp`` arrays; the matching
  ``apply`` is a pure function of ``(params, inputs)``.
* Numerics: parameters/activations in the config dtype (bf16 at scale);
  softmax/normalisation statistics and attention accumulators in float32 —
  the layer-fusion analogue of keeping the "intermediate frame" on chip in
  high precision.
* The **chunked attention** here is the pure-JAX realisation of the paper's
  fused-layer idea for transformers: QK^T -> softmax -> PV execute as one
  fusion group with the (Sq, Skv) score matrix tiled so it never exists in
  HBM (online softmax over KV chunks).  ``repro.kernels.fused_attention``
  is the Pallas version; ``attention_reference`` materialises the scores
  and is the test oracle.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype)


# ---------------------------------------------------------------------------
# Normalisation / positional encoding
# ---------------------------------------------------------------------------


def rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * scale


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim // 2,) inverse frequencies."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate-half RoPE.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * inv  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# Attention masks (positions are absolute token indices)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attention_bias(
    q_pos: jnp.ndarray,  # (Sq,)
    kv_pos: jnp.ndarray,  # (Skv,)
    *,
    mixer: str,  # attn | attn_local | attn_chunked
    causal: bool,
    window: int,
    chunk: int,
    kv_len: jnp.ndarray | int | None = None,
) -> jnp.ndarray:
    """(Sq, Skv) additive float32 bias (0 or NEG_INF).

    Negative kv positions are invalid (unwritten ring-buffer slots)."""
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    ok = kp >= 0
    if causal:
        ok &= kp <= qp
    if mixer == "attn_local":
        ok &= (qp - kp) < window
        if not causal:
            ok &= (kp - qp) < window
    elif mixer == "attn_chunked":
        ok &= (qp // chunk) == (kp // chunk)
    if kv_len is not None:
        ok &= kp < kv_len
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def ring_insert(buf: jnp.ndarray, new: jnp.ndarray, start) -> jnp.ndarray:
    """Insert ``new`` (B, S, KV, hd) into a W-entry ring buffer keyed by
    absolute position (slot = position % W).

    S == 1: decode step at position ``start``.  S > 1: prefill — assumes
    ``start == 0`` (the serving flow always primes the ring from scratch).
    """
    W = buf.shape[1]
    S = new.shape[1]
    if S == 1:
        return jax.lax.dynamic_update_slice(
            buf, new, (0, start % W, 0, 0)
        )
    if S >= W:
        keep = jax.lax.slice_in_dim(new, S - W, S, axis=1)
        return jnp.roll(keep, (S - W) % W, axis=1)
    return jax.lax.dynamic_update_slice(buf, new, (0, 0, 0, 0))


def ring_positions(W: int, p_last) -> jnp.ndarray:
    """Absolute position held by each of the W ring slots after the token at
    ``p_last`` was written (unwritten slots come out negative => masked)."""
    return p_last - ((p_last - jnp.arange(W)) % W)


# ---------------------------------------------------------------------------
# Attention: reference (materialised scores) — the oracle
# ---------------------------------------------------------------------------


def repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """(B, S, KV, hd) -> (B, S, H, hd) via head-index gather.

    A gather (not a reshape-split) so GSPMD can shard the output H axis on
    the model axis without factoring it into (KV, G) — the repeat is local
    to each TP shard (k is small: 1/G of q).
    """
    KV = k.shape[2]
    idx = jnp.arange(n_heads) // (n_heads // KV)
    return jnp.take(k, idx, axis=2)


def attention_reference(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    *,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    mixer: str = "attn",
    causal: bool = True,
    window: int = 0,
    chunk: int = 0,
    kv_len=None,
    logit_cap: float = 0.0,
) -> jnp.ndarray:
    B, Sq, H, hd = q.shape
    kr = repeat_kv(k, H).astype(jnp.float32)
    vr = repeat_kv(v, H).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bchd->bhqc", q.astype(jnp.float32), kr) * scale
    scores = softcap(scores, logit_cap)
    bias = attention_bias(
        q_pos, kv_pos, mixer=mixer, causal=causal, window=window, chunk=chunk,
        kv_len=kv_len,
    )
    scores = scores + bias[None, None]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqc,bchd->bqhd", probs, vr)
    return out.astype(q.dtype)


def attention_decode(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)  (the cache)
    v: jnp.ndarray,
    *,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    mixer: str = "attn",
    causal: bool = True,
    window: int = 0,
    chunk: int = 0,
    kv_len=None,
    logit_cap: float = 0.0,
    seq_sharded: bool = False,
) -> jnp.ndarray:
    """Single-query attention in KV-head space (no head repeat).

    The cache stays in its resident sharding (KV heads on TP; sequence on
    DP for the batch-1 long-context cell) and each device reads only its
    local shard — per-device HBM traffic is cache_bytes / n_chips, which is
    what makes the decode cells memory- rather than collective-bound.
    """
    from ..parallel.sharding import DP, TP, hint

    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd)  # tiny; replication is fine
    scale = 1.0 / math.sqrt(hd)
    # Native-dtype dots with f32 accumulation: the KV cache streams from HBM
    # once at its resident 2 bytes/element — an f32 cast here would triple
    # the dominant decode traffic (§Perf gemma3 iteration 3).
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k, preferred_element_type=jnp.float32
    ) * scale
    s = hint(
        softcap(s, logit_cap),
        *( (None, TP, None, DP) if seq_sharded else (DP, TP, None, None) ),
    )
    bias = attention_bias(
        q_pos, kv_pos, mixer=mixer, causal=causal, window=window, chunk=chunk,
        kv_len=kv_len,
    )  # (1, Skv)
    s = s + bias[0][None, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(k.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention: chunked online-softmax (fused-layer execution, pure JAX)
# ---------------------------------------------------------------------------


def attention_chunked(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    *,
    q_pos: jnp.ndarray,
    kv_pos: jnp.ndarray,
    mixer: str = "attn",
    causal: bool = True,
    window: int = 0,
    chunk: int = 0,
    kv_len=None,
    logit_cap: float = 0.0,
    kv_block: int = 1024,
    seq_sharded: bool = False,
) -> jnp.ndarray:
    """Flash-style attention: lax.scan over KV blocks with running (m, l, acc).

    The (Sq, kv_block) score tile is the only materialised intermediate —
    the transformer instance of the paper's fusion groups (Sec. II-B): the
    full (Sq, Skv) "intermediate frame" never round-trips through HBM.
    """
    from ..parallel.sharding import DP, TP, hint

    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    if Sq == 1:
        return attention_decode(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, mixer=mixer, causal=causal,
            window=window, chunk=chunk, kv_len=kv_len, logit_cap=logit_cap,
            seq_sharded=seq_sharded,
        )
    if Skv % kv_block:
        kv_block = Skv  # degenerate single block (small/test shapes)
    n_blocks = Skv // kv_block
    scale = 1.0 / math.sqrt(hd)
    qh = hint(q.astype(jnp.float32), DP, None, TP, None)

    kb = k.reshape(B, n_blocks, kv_block, KV, hd)
    vb = v.reshape(B, n_blocks, kv_block, KV, hd)
    pb = kv_pos.reshape(n_blocks, kv_block)

    def step(carry, xs):
        m, l, acc = carry  # (B,H,Sq), (B,H,Sq), (B,H,Sq,hd)
        k_c, v_c, p_c = xs
        k_r = hint(repeat_kv(k_c, H).astype(jnp.float32), DP, None, TP, None)
        v_r = hint(repeat_kv(v_c, H).astype(jnp.float32), DP, None, TP, None)
        s = jnp.einsum("bqhd,bchd->bhqc", qh, k_r) * scale
        s = hint(softcap(s, logit_cap), DP, TP, None, None)
        bias = attention_bias(
            q_pos, p_c, mixer=mixer, causal=causal, window=window, chunk=chunk,
            kv_len=kv_len,
        )
        s = s + bias[None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqc,bchd->bhqd", p, v_r)
        return (m_new, l_new, acc_new), None

    m0 = hint(jnp.full((B, H, Sq), NEG_INF, jnp.float32), DP, TP, None)
    l0 = jnp.zeros_like(m0)
    acc0 = hint(jnp.zeros((B, H, Sq, hd), jnp.float32), DP, TP, None, None)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, acc0),
        (
            jnp.moveaxis(kb, 1, 0),
            jnp.moveaxis(vb, 1, 0),
            pb,
        ),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 1, 2)  # (B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention module (projections + rope + qk-norm + cache handling)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def attention_block(
    params: dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    *,
    mixer: str,
    positions: jnp.ndarray,  # (S,) absolute positions of x
    cache: dict | None = None,  # {"k","v": (B, max_seq, KV, hd), "len": ()}
    cross_kv: tuple | None = None,  # encoder (k, v) for cross-attention
    causal: bool = True,
    impl: str = "chunked",
    kv_block: int = 1024,
    rope: bool = True,
    seq_sharded: bool = False,
    ring: bool = False,  # cache buffer is a window-sized ring (attn_local)
    flash_vjp: bool = False,  # custom-vjp flash for the no-cache path
    bf16_tiles: bool = False,
) -> tuple[jnp.ndarray, dict | None]:
    """Self- (or cross-) attention sub-layer.  Returns (out, new_cache)."""
    from ..parallel.sharding import DP, TP, hint

    B, S, d = x.shape
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads

    # Sharding hints: GSPMD cannot infer head-axis sharding through the
    # (H*hd) -> (H, hd) split; pin q heads on TP (k/v stay KV-small and
    # TP-replicated — the flash path repeats them per chunk, locally).
    q = (x @ params["wq"]).reshape(B, S, H, hd)
    if S > 1:
        q = hint(q, DP, None, TP, None)
    if cross_kv is None:
        k = (x @ params["wk"]).reshape(B, S, KV, hd)
        v = (x @ params["wv"]).reshape(B, S, KV, hd)
        if S > 1:
            k = hint(k, DP, None, None, None)
            v = hint(v, DP, None, None, None)
    else:
        k, v = cross_kv

    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.rmsnorm_eps)
        if cross_kv is None:
            k = rmsnorm(params["k_norm"], k, cfg.rmsnorm_eps)

    if rope and cross_kv is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cross_kv is not None:
        kv_pos = jnp.arange(k.shape[1])
        kv_len = None
        causal = False
    elif cache is not None and ring:
        # Window-sized ring buffer (local-attention layers): slots keyed by
        # position % W.  Decode attends to the ring; prefill attends to the
        # full fresh sequence and persists only the last window.
        start = cache["len"]
        k_ring = ring_insert(cache["k"], k, start)
        v_ring = ring_insert(cache["v"], v, start)
        new_cache = {"k": k_ring, "v": v_ring}
        if S == 1:
            k, v = k_ring, v_ring
            kv_pos = ring_positions(k.shape[1], start)
            kv_len = None  # validity from kp >= 0 + causal + window masks
        else:
            kv_pos = positions
            kv_len = start + S
    elif cache is not None:
        # Decode / incremental: write k,v at [len, len+S) then attend to cache.
        start = cache["len"]
        k_all = jax.lax.dynamic_update_slice(cache["k"], k, (0, start, 0, 0))
        v_all = jax.lax.dynamic_update_slice(cache["v"], v, (0, start, 0, 0))
        new_cache = {"k": k_all, "v": v_all, "len": start + S}
        k, v = k_all, v_all
        kv_pos = jnp.arange(k.shape[1])
        kv_len = start + S
    else:
        kv_pos = positions
        kv_len = None

    if (flash_vjp and cache is None and cross_kv is None and S > 1
            and cfg.logit_softcap == 0.0):
        from .flash import flash_attention_vjp

        out = flash_attention_vjp(
            q, k, v, q_pos=positions, kv_pos=kv_pos, mixer=mixer,
            window=cfg.window_size, chunk=cfg.chunk_size, kv_block=kv_block,
            bf16_tiles=bf16_tiles,
        )
        return out.reshape(B, S, H * hd) @ params["wo"], None

    fn = attention_reference if impl == "reference" else attention_chunked
    kwargs = dict(
        q_pos=positions,
        kv_pos=kv_pos,
        mixer=mixer,
        causal=causal,
        window=cfg.window_size,
        chunk=cfg.chunk_size,
        kv_len=kv_len,
        logit_cap=cfg.logit_softcap,
    )
    if impl != "reference":
        kwargs["kv_block"] = kv_block
        kwargs["seq_sharded"] = seq_sharded
    out = fn(q, k, v, **kwargs)
    out = out.reshape(B, S, H * hd) @ params["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


GATED_ACTS = ("swiglu", "geglu")


def init_mlp(key, d: int, d_ff: int, act: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w1": dense_init(ks[0], d, d_ff, dtype), "w2": dense_init(ks[1], d_ff, d, dtype)}
    if act in GATED_ACTS:
        p["w3"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_block(params: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    h = x @ params["w1"]
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ params["w3"])
    elif act == "geglu":
        h = jax.nn.gelu(h) * (x @ params["w3"])
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "relu":
        h = jax.nn.relu(h)
    else:
        raise ValueError(act)
    return h @ params["w2"]


# ---------------------------------------------------------------------------
# Chunked cross-entropy (vocab logits never fully materialised)
# ---------------------------------------------------------------------------


def chunked_cross_entropy(
    h: jnp.ndarray,  # (B, S, d) final hidden states
    lm_head: jnp.ndarray,  # (d, V)
    labels: jnp.ndarray,  # (B, S) int32
    *,
    chunk: int = 512,
    mask: jnp.ndarray | None = None,  # (B, S) bool, True = count
) -> jnp.ndarray:
    """Mean next-token NLL computed over sequence chunks.

    The (B, S, V) logits tensor (423 GB for llama4's train_4k cell) is the
    "intermediate frame" here; chunking the projection+logsumexp into one
    fusion group keeps only (B, chunk, V) live.
    """
    B, S, d = h.shape
    if S % chunk:
        chunk = S
    n = S // chunk
    hs = h.reshape(B, n, chunk, d).swapaxes(0, 1)  # (n, B, chunk, d)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    ms = (
        jnp.ones((n, B, chunk), bool)
        if mask is None
        else mask.reshape(B, n, chunk).swapaxes(0, 1)
    )

    @jax.checkpoint  # recompute (B, chunk, V) logits in backward: never stored
    def step(carry, xs):
        tot, cnt = carry
        hc, lc, mc = xs
        logits = (hc @ lm_head).astype(jnp.float32)  # (B, chunk, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = jnp.where(mc, lse - gold, 0.0)
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.int32(0)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1)
