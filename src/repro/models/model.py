"""Model dispatch: one API over decoder-only, encoder-decoder and VGG.

``init_params / forward / loss_fn / init_cache / prefill / decode`` all
dispatch on the config; launch scripts and tests only import this module.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import encdec as ED
from . import layers as L
from . import transformer as T


def init_params(key, cfg) -> dict:
    if cfg.is_encoder_decoder:
        return ED.init_params(key, cfg)
    return T.init_params(key, cfg)


def abstract_params(cfg):
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda k: init_params(k, cfg), jax.random.key(0))


def forward(params, cfg, rc, batch: dict, cache=None):
    if cfg.is_encoder_decoder:
        return ED.forward(params, cfg, rc, batch, cache)
    return T.forward(params, cfg, rc, batch, cache)


def loss_fn(params, cfg, rc, batch: dict):
    if not cfg.is_encoder_decoder:
        return T.loss_fn(params, cfg, rc, batch)
    h, _, aux = ED.forward(params, cfg, rc, batch)
    labels = batch["labels"]
    mask = labels >= 0
    nll = L.chunked_cross_entropy(
        h, params["embed"].T, jnp.maximum(labels, 0), chunk=rc.xent_chunk, mask=mask
    )
    return nll, {"nll": nll, "aux": aux}


def init_cache(cfg, batch: int, max_seq: int, *, ring: bool = False):
    if cfg.is_encoder_decoder:
        return ED.init_cache(cfg, batch, max_seq, cfg.frontend_len)
    return T.init_cache(cfg, batch, max_seq, ring=ring)


def abstract_cache(cfg, batch: int, max_seq: int, *, ring: bool = False):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_seq, ring=ring))


def prefill(params, cfg, rc, batch: dict, cache):
    """Run the prompt through the model, filling the cache.

    Returns (last-position logits (B, 1, V), new_cache).
    """
    h, new_cache, _ = forward(params, cfg, rc, batch, cache)
    logits = h[:, -1:, :] @ _head(params, cfg)
    return logits.astype(jnp.float32), new_cache


def decode(params, cfg, rc, tokens: jnp.ndarray, cache, extras: dict | None = None):
    """One decode step.  tokens: (B, 1).  Returns (logits (B,1,V), cache)."""
    batch = {"tokens": tokens}
    if extras:
        batch.update(extras)
    h, new_cache, _ = forward(params, cfg, rc, batch, cache)
    logits = h[:, -1:, :] @ _head(params, cfg)
    return logits.astype(jnp.float32), new_cache


def _head(params, cfg):
    if cfg.is_encoder_decoder:
        return params["embed"].T
    return T.lm_head_matrix(params, cfg)
