"""Mamba-1 selective-state-space mixer (falcon-mamba-7b, jamba).

The selective scan is evaluated **chunk-recurrently**: an outer
``lax.scan`` over sequence chunks carries the (B, d_inner, d_state) SSM
state; inside a chunk the recurrence runs as a parallel
``associative_scan``.  This is the paper's fusion idea applied to a
recurrence: the (B, S, d_inner, d_state) discretised-transition tensor —
128x the activation size for falcon-mamba — only ever exists one chunk at
a time (HBM traffic drops by S/chunk), exactly like a fusion group's
intermediate frame staying in SRAM.  ``repro.kernels.mamba_scan`` is the
Pallas version; ``selective_scan_reference`` (plain sequential scan) is
the oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_mamba(key, cfg, dtype) -> dict:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    dr, dc = cfg.dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    # A initialised to -[1..ds] per channel (S4D-real), stored as log.
    a_init = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_bias = jnp.log(
        jnp.exp(
            jnp.clip(
                jax.random.uniform(ks[0], (di,), jnp.float32) * (0.1 - 1e-3) + 1e-3,
                1e-4,
            )
        )
        - 1.0
    )  # softplus^-1 of dt in [1e-3, 0.1]
    return {
        "in_proj": dense_init(ks[1], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[2], (dc, di), jnp.float32) / math.sqrt(dc)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[3], di, dr + 2 * ds, dtype),
        "dt_proj": dense_init(ks[4], dr, di, dtype),
        "dt_bias": dt_bias.astype(jnp.float32),
        "A_log": jnp.log(a_init),  # fp32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], di, d, dtype),
    }


def mamba_param_specs(cfg, *, dtype=jnp.float32):
    """``jax.ShapeDtypeStruct`` tree matching :func:`init_mamba` (via
    ``jax.eval_shape`` — nothing materialised; the evaluator's trace hook)."""
    return jax.eval_shape(
        lambda k: init_mamba(k, cfg, dtype), jax.random.PRNGKey(0)
    )


def causal_depthwise_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                          state: jnp.ndarray | None = None):
    """x: (B, S, di); w: (dc, di).  Returns (y, new_state (B, dc-1, di))."""
    dc = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+dc-1, di)
    S = x.shape[1]
    y = sum(xp[:, j : j + S, :] * w[j][None, None, :] for j in range(dc))
    new_state = xp[:, -(dc - 1) :, :] if dc > 1 else state
    return y + b[None, None, :], new_state


def _ssm_inputs(params, x_c: jnp.ndarray, cfg):
    """Discretised (dA, dBx, C) from the conv output.  All fp32."""
    dr, ds = cfg.dt_rank, cfg.ssm_state
    proj = (x_c @ params["x_proj"]).astype(jnp.float32)  # (B,S,dr+2ds)
    dt_low, Bs, Cs = jnp.split(proj, [dr, dr + ds], axis=-1)
    dt = jax.nn.softplus(
        dt_low @ params["dt_proj"].astype(jnp.float32) + params["dt_bias"]
    )  # (B,S,di)
    A = -jnp.exp(params["A_log"])  # (di, ds)
    dA = jnp.exp(dt[..., None] * A[None, None])  # (B,S,di,ds)
    dBx = dt[..., None] * Bs[:, :, None, :] * x_c.astype(jnp.float32)[..., None]
    return dA, dBx, Cs


def selective_scan_reference(dA, dBx, Cs, h0=None):
    """Sequential oracle.  dA,dBx: (B,S,di,ds); Cs: (B,S,ds) -> y (B,S,di)."""
    B, S, di, ds = dA.shape
    h = jnp.zeros((B, di, ds), jnp.float32) if h0 is None else h0

    def step(h, xs):
        a, bx, c = xs
        h = a * h + bx
        return h, jnp.einsum("bds,bs->bd", h, c)

    h, ys = jax.lax.scan(
        step, h, (jnp.moveaxis(dA, 1, 0), jnp.moveaxis(dBx, 1, 0), jnp.moveaxis(Cs, 1, 0))
    )
    return jnp.moveaxis(ys, 0, 1), h


def _assoc_combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def selective_scan_chunked(dA, dBx, Cs, h0=None, chunk: int = 256):
    """Chunk-recurrent parallel scan (the fused-layer execution)."""
    B, S, di, ds = dA.shape
    if S % chunk:
        chunk = S
    n = S // chunk
    h = jnp.zeros((B, di, ds), jnp.float32) if h0 is None else h0
    dAc = dA.reshape(B, n, chunk, di, ds).swapaxes(0, 1)
    dBc = dBx.reshape(B, n, chunk, di, ds).swapaxes(0, 1)
    Cc = Cs.reshape(B, n, chunk, ds).swapaxes(0, 1)

    def step(h, xs):
        a, bx, c = xs  # (B, chunk, di, ds), ..., (B, chunk, ds)
        # h_t = (prod a)(h_in) + scan(b); fold h_in in via the first b term.
        bx0 = bx.at[:, 0].add(a[:, 0] * h)
        a_cum, h_all = jax.lax.associative_scan(_assoc_combine, (a, bx0), axis=1)
        y = jnp.einsum("bcds,bcs->bcd", h_all, c)
        return h_all[:, -1], y

    h, ys = jax.lax.scan(step, h, (dAc, dBc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)
    return y, h


def mamba_block(
    params: dict,
    x: jnp.ndarray,  # (B, S, d)
    cfg,
    cache: dict | None = None,  # {"conv": (B, dc-1, di), "h": (B, di, ds)}
    *,
    chunk: int = 256,
    impl: str = "chunked",
) -> tuple[jnp.ndarray, dict | None]:
    B, S, d = x.shape
    di = cfg.d_inner
    xz = x @ params["in_proj"]
    x_in, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    x_c, new_conv = causal_depthwise_conv(x_in, params["conv_w"], params["conv_b"], conv_state)
    x_c = jax.nn.silu(x_c)

    dA, dBx, Cs = _ssm_inputs(params, x_c, cfg)
    h0 = cache["h"] if cache is not None else None
    if impl == "reference" or S == 1:
        y, h = selective_scan_reference(dA, dBx, Cs, h0)
    else:
        y, h = selective_scan_chunked(dA, dBx, Cs, h0, chunk=chunk)

    y = y + params["D"][None, None, :] * x_c.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ params["out_proj"]
    new_cache = {"conv": new_conv, "h": h} if cache is not None else None
    return out, new_cache


def init_mamba_cache(cfg, batch: int, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }
