"""Decoder-only transformer trunk with heterogeneous layer stacks.

A model is a sequence of **segments**; each segment is ``repeats`` copies of
a *superblock* (one period of the config's cyclic ``layer_pattern`` x MoE
placement), with parameters stacked on a leading ``repeats`` axis and the
stack executed by ``jax.lax.scan``.  One trace per distinct superblock keeps
compile time flat in depth (granite's 88 layers trace once), which is what
makes the 40-cell x 512-device dry-run tractable.

Supported sublayer mixers: full/sliding-window/chunked attention and
Mamba-1; FFN is dense or MoE (with arctic's parallel dense-residual).  The
same trunk serves training (no cache), prefill (cache write) and decode
(cache read-extend) — jamba/gemma3/llama4/falcon-mamba all route through
here; seamless adds an encoder via :mod:`repro.models.encdec`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as MOE
from . import ssm as SSM


@dataclasses.dataclass(frozen=True)
class SegmentSpec:
    start_layer: int
    repeats: int
    kinds: tuple[tuple[str, bool], ...]  # (mixer, is_moe) per sublayer


def segments_of(cfg, n_layers: int | None = None) -> list[SegmentSpec]:
    n = cfg.n_layers if n_layers is None else n_layers
    P = cfg.pattern_period
    segs: list[SegmentSpec] = []
    n_full, rem = divmod(n, P)
    if n_full:
        segs.append(SegmentSpec(0, n_full, cfg.sublayer_kinds(0, P)))
    if rem:
        segs.append(SegmentSpec(n_full * P, 1, cfg.sublayer_kinds(n_full * P, rem)))
    return segs


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_sublayer(key, cfg, mixer: str, is_moe: bool, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    sub: dict[str, Any] = {"norm1": L.rmsnorm_init(cfg.d_model, dtype),
                           "norm2": L.rmsnorm_init(cfg.d_model, dtype)}
    if mixer == "mamba":
        sub["mamba"] = SSM.init_mamba(k1, cfg, dtype)
    else:
        sub["attn"] = L.init_attention(k1, cfg, dtype)
    if is_moe:
        sub["moe"] = MOE.init_moe(k2, cfg, dtype)
    elif cfg.d_ff > 0:
        sub["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.ffn_act, dtype)
    else:
        del sub["norm2"]  # mamba-1 blocks: mixer only, no FFN sublayer
    return sub


def init_segment(key, cfg, spec: SegmentSpec, dtype) -> dict:
    def one(k):
        ks = jax.random.split(k, len(spec.kinds))
        return {
            f"sub{j}": _init_sublayer(ks[j], cfg, m, e, dtype)
            for j, (m, e) in enumerate(spec.kinds)
        }

    keys = jax.random.split(key, spec.repeats)
    return jax.vmap(one)(keys)


def init_params(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": L.embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "segments": [
            init_segment(k, cfg, spec, dtype)
            for k, spec in zip(
                jax.random.split(ks[1], max(len(segments_of(cfg)), 1)), segments_of(cfg)
            )
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[2], cfg.d_model, cfg.vocab_size, dtype)
    return params


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, max_seq: int, n_layers: int | None = None,
               *, ring: bool = False) -> dict:
    """Decode cache pytree matching the segment structure.

    ``ring=True``: local-attention sublayers get a window-sized ring buffer
    instead of a full-context one (the §Perf decode lever — gemma3's 51/62
    local layers hold 1024 entries instead of 32k/500k).
    """
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    segs = []
    for spec in segments_of(cfg, n_layers):
        seg: dict[str, Any] = {}
        for j, (mixer, _) in enumerate(spec.kinds):
            if mixer == "mamba":
                seg[f"sub{j}"] = {
                    "conv": jnp.zeros(
                        (spec.repeats, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype
                    ),
                    "h": jnp.zeros(
                        (spec.repeats, batch, cfg.d_inner, cfg.ssm_state), jnp.float32
                    ),
                }
            else:
                entries = max_seq
                if ring and mixer == "attn_local":
                    entries = min(max_seq, cfg.window_size)
                seg[f"sub{j}"] = {
                    "k": jnp.zeros((spec.repeats, batch, entries, cfg.n_kv_heads, hd), dtype),
                    "v": jnp.zeros((spec.repeats, batch, entries, cfg.n_kv_heads, hd), dtype),
                }
        segs.append(seg)
    return {"segments": segs, "len": jnp.zeros((), jnp.int32)}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _sublayer(sub, x, cfg, rc, mixer, is_moe, positions, cache, cache_len, aux,
              attn_impl: str = "chunked"):
    """One (mixer + FFN) sublayer.  Returns (x, new_cache, aux)."""
    h = L.rmsnorm(sub["norm1"], x, cfg.rmsnorm_eps)
    new_cache = None
    if mixer == "mamba":
        out, new_cache = SSM.mamba_block(
            sub["mamba"], h, cfg, cache, chunk=rc.mamba_chunk,
            impl="chunked",
        )
    else:
        attn_cache = None
        if cache is not None:
            attn_cache = {"k": cache["k"], "v": cache["v"], "len": cache_len}
        out, nc = L.attention_block(
            sub["attn"], h, cfg,
            mixer=mixer, positions=positions, cache=attn_cache,
            impl=attn_impl, kv_block=rc.attn_chunk_kv, seq_sharded=rc.seq_shard,
            ring=(rc.local_ring_cache and mixer == "attn_local"),
            flash_vjp=rc.flash_vjp, bf16_tiles=rc.attn_bf16_tiles,
        )
        if nc is not None:
            new_cache = {"k": nc["k"], "v": nc["v"]}
    x = x + out
    if "norm2" not in sub:  # FFN-free block (mamba-1)
        return x, new_cache, aux
    h = L.rmsnorm(sub["norm2"], x, cfg.rmsnorm_eps)
    if is_moe:
        out, a = MOE.moe_block(sub["moe"], h, cfg)
        aux = aux + a
    else:
        out = L.mlp_block(sub["mlp"], h, cfg.ffn_act)
    return x + out, new_cache, aux


def block_forward(params, x, cfg, kinds, *, rc=None, attn_impl="chunked"):
    """Python-loop sublayer stack — the evaluator's tracing hook.

    ``run_segment`` scans ``lax.scan`` over *stacked* layer parameters,
    which a jaxpr-level consumer would misread as a recurrence; this
    variant loops the same :func:`_sublayer` bodies in Python over a list
    of per-sublayer param trees (``kinds`` as from
    ``cfg.sublayer_kinds``), no cache, positions built in-closure.
    ``attn_impl="reference"`` keeps attention scan-free so only the SSM's
    selective scan traces as a recurrent node."""
    if rc is None:
        from ..configs.base import RunConfig

        rc = RunConfig()
    positions = jnp.arange(x.shape[1])
    aux = jnp.float32(0.0)
    for sub, (mixer, is_moe) in zip(params, kinds):
        x, _, aux = _sublayer(
            sub, x, cfg, rc, mixer, is_moe, positions, None, None, aux,
            attn_impl=attn_impl,
        )
    return x


def sublayer_param_specs(cfg, kinds=None, *, dtype=jnp.float32):
    """``jax.ShapeDtypeStruct`` trees for :func:`block_forward` — one per
    sublayer, shaped by ``jax.eval_shape`` over the real initialiser (no
    weights are materialised; granite-34B costs nothing to spec)."""
    if kinds is None:
        kinds = cfg.sublayer_kinds(0, cfg.pattern_period)

    def init(key):
        ks = jax.random.split(key, max(len(kinds), 1))
        return [
            _init_sublayer(k, cfg, m, e, dtype)
            for k, (m, e) in zip(ks, kinds)
        ]

    return jax.eval_shape(init, jax.random.PRNGKey(0))


def _remat_wrap(fn, rc):
    if rc.remat == "none":
        return fn
    policy = (
        jax.checkpoint_policies.dots_saveable
        if rc.remat == "dots"
        else jax.checkpoint_policies.nothing_saveable
    )
    return jax.checkpoint(fn, policy=policy)


def run_segment(seg_params, x, cfg, rc, spec: SegmentSpec, *, positions,
                seg_cache=None, cache_len=None, aux):
    """Scan ``spec.repeats`` superblocks.  Returns (x, new_seg_cache, aux)."""
    has_cache = seg_cache is not None

    def body(carry, xs):
        x, aux = carry
        p = xs[0] if has_cache else xs
        c = xs[1] if has_cache else None
        new_c = {}
        for j, (mixer, is_moe) in enumerate(spec.kinds):
            sub_cache = c[f"sub{j}"] if c is not None else None
            x, nc, aux = _sublayer(
                p[f"sub{j}"], x, cfg, rc, mixer, is_moe, positions,
                sub_cache, cache_len, aux,
            )
            if nc is not None:
                new_c[f"sub{j}"] = nc
        return (x, aux), (new_c if has_cache else None)

    body = _remat_wrap(body, rc)
    xs = (seg_params, seg_cache) if has_cache else seg_params
    (x, aux), new_cache = jax.lax.scan(body, (x, aux), xs)
    return x, new_cache, aux


def embed_inputs(params, cfg, batch: dict) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token (+ frontend stub) embedding.  Returns (x, positions)."""
    from ..parallel.sharding import DP, hint

    tok_emb = params["embed"][batch["tokens"]]  # (B, S_tok, d)
    if cfg.frontend and "frontend" in batch:
        x = jnp.concatenate([batch["frontend"].astype(tok_emb.dtype), tok_emb], axis=1)
    else:
        x = tok_emb
    positions = jnp.arange(x.shape[1])
    return hint(x, DP, None, None), positions


def forward(params, cfg, rc, batch: dict, cache: dict | None = None):
    """Trunk forward.  batch: {"tokens": (B,S), ["frontend": (B,Lf,d)]}.

    With ``cache``: incremental (prefill writes at [len, len+S), decode
    extends); positions are offset by ``cache["len"]``.
    Returns (hidden (B,S,d), new_cache|None, aux_loss).
    """
    x, positions = embed_inputs(params, cfg, batch)
    cache_len = cache["len"] if cache is not None else None
    if cache is not None:
        positions = positions + cache_len
    aux = jnp.float32(0.0)
    new_segs = []
    for i, spec in enumerate(segments_of(cfg)):
        seg_cache = cache["segments"][i] if cache is not None else None
        x, new_seg, aux = run_segment(
            params["segments"][i], x, cfg, rc, spec,
            positions=positions, seg_cache=seg_cache, cache_len=cache_len, aux=aux,
        )
        new_segs.append(new_seg)
    x = L.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    new_cache = None
    if cache is not None:
        new_cache = {"segments": new_segs, "len": cache_len + x.shape[1]}
    return x, new_cache, aux


def lm_head_matrix(params, cfg) -> jnp.ndarray:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def loss_fn(params, cfg, rc, batch: dict) -> tuple[jnp.ndarray, dict]:
    """Next-token NLL (+ MoE aux).  Labels < 0 are ignored."""
    h, _, aux = forward(params, cfg, rc, batch)
    labels = batch["labels"]
    mask = labels >= 0
    nll = L.chunked_cross_entropy(
        h, lm_head_matrix(params, cfg), jnp.maximum(labels, 0),
        chunk=rc.xent_chunk, mask=mask,
    )
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


def logits_last(params, cfg, rc, h: jnp.ndarray) -> jnp.ndarray:
    """Logits of the final position only (serving)."""
    return (h[:, -1:, :] @ lm_head_matrix(params, cfg)).astype(jnp.float32)
