"""Mixture-of-Experts FFN with GShard-style group-limited capacity routing.

Design notes (why this shape of MoE):

* Routing is **capacity-based with token groups** (GShard / Switch): tokens
  are reshaped to ``(groups, group_size)`` and each group independently
  dispatches to per-expert capacity slots.  The dispatch/combine one-hots
  are ``(G, Sg, E, C)`` with ``C = ceil(top_k * Sg / E * capacity_factor)``;
  with the default ``group_size=512`` the dispatch einsum FLOPs stay <10 %
  of the expert-FFN FLOPs at every assigned shape (llama4 train_4k: 8.5 %),
  which keeps the roofline "useful-FLOPs" ratio honest.
* Under GSPMD the group axis shards over ``data`` and the expert axis over
  ``model`` (expert parallelism); the dispatch einsum's ``e`` output axis
  moving onto ``model`` is what induces the all-to-all in the compiled HLO.
* Over-capacity tokens are dropped (combine weight 0) — standard; the
  aux load-balance loss pushes the router away from that regime.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_moe(key, cfg, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 6)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale).astype(
            jnp.float32
        ),  # router kept fp32 (tiny; routing is precision-sensitive)
        "w1": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * scale).astype(dtype),
        "w2": (
            jax.random.normal(ks[2], (E, ff, d), jnp.float32) / math.sqrt(ff)
        ).astype(dtype),
    }
    if cfg.ffn_act in ("swiglu", "geglu"):
        p["w3"] = (jax.random.normal(ks[3], (E, d, ff), jnp.float32) * scale).astype(
            dtype
        )
    if cfg.dense_residual_ff:
        from .layers import init_mlp

        p["dense_residual"] = init_mlp(ks[4], d, cfg.dense_residual_ff, cfg.ffn_act, dtype)
    return p


def moe_param_specs(cfg, *, dtype=jnp.float32):
    """``jax.ShapeDtypeStruct`` tree matching :func:`init_moe` (via
    ``jax.eval_shape`` — nothing materialised; the evaluator's trace hook)."""
    return jax.eval_shape(
        lambda k: init_moe(k, cfg, dtype), jax.random.PRNGKey(0)
    )


def _capacity(cfg, group_size: int) -> int:
    c = math.ceil(cfg.top_k * group_size / cfg.n_experts * cfg.capacity_factor)
    return max(c, 1)


def route_topk(router_logits: jnp.ndarray, top_k: int):
    """(..., E) logits -> (gates, indices) each (..., top_k); gates sum to 1."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, probs


def moe_block(params: dict, x: jnp.ndarray, cfg) -> tuple[jnp.ndarray, jnp.ndarray]:
    """MoE FFN.  x: (B, S, d) -> (y, aux_loss)."""
    from ..parallel.sharding import DP, TP, hint

    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    Sg = min(cfg.moe_group_size, T)
    G = T // Sg
    assert G * Sg == T, f"tokens {T} not divisible by group size {Sg}"
    xg = hint(x.reshape(G, Sg, d), DP, None, None)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), params["router"])
    gates, idx, probs = route_topk(logits, K)  # (G, Sg, K)

    # Load-balance aux loss (Switch): E * sum_e f_e * p_e.
    me = probs.mean(axis=(0, 1))  # (E,)
    onehot_top1 = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
    fe = onehot_top1.mean(axis=(0, 1))
    aux = E * jnp.sum(fe * me)

    C = _capacity(cfg, Sg)
    # Position of each (token, k) claim within its expert's capacity.
    # claims: (G, Sg, K, E) one-hot; flatten (Sg, K) in token-major order so
    # earlier tokens (and lower k) win capacity slots.  One-hots are built in
    # the compute dtype (bf16 represents the small integers exactly) — the
    # (G, Sg, K, E, C) transient halves, the dominant MoE-cell temp buffer.
    dt = x.dtype
    claims = jax.nn.one_hot(idx, E, dtype=dt)  # (G, Sg, K, E)
    flat = claims.reshape(G, Sg * K, E)
    pos = jnp.cumsum(flat.astype(jnp.float32), axis=1).astype(dt) - flat
    keep = jnp.where(pos < C, flat, jnp.zeros((), dt))  # (G, Sg*K, E)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=dt) * keep[..., None]
    disp_flat = pos_oh.reshape(G, Sg, K, E, C)

    dispatch = disp_flat.sum(axis=2)  # (G, Sg, E, C)  (a token claims <=1 slot/expert)
    dispatch = hint(dispatch, DP, None, TP, None)
    combine = hint(
        jnp.einsum("gskec,gsk->gsec", disp_flat, gates.astype(dt)), DP, None, TP, None
    )

    dt = x.dtype
    # Dispatch: the e axis landing on TP is the expert-parallel all-to-all.
    xe = hint(jnp.einsum("gsec,gsd->gecd", dispatch.astype(dt), xg), DP, TP, None, None)
    h = jnp.einsum("gecd,edf->gecf", xe, params["w1"])
    if cfg.ffn_act == "swiglu":
        h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, params["w3"])
    elif cfg.ffn_act == "geglu":
        h = jax.nn.gelu(h) * jnp.einsum("gecd,edf->gecf", xe, params["w3"])
    elif cfg.ffn_act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    ye = hint(jnp.einsum("gecf,efd->gecd", h, params["w2"]), DP, TP, None, None)
    y = hint(jnp.einsum("gsec,gecd->gsd", combine.astype(dt), ye), DP, None, None)

    if "dense_residual" in params:  # arctic: parallel dense MLP
        from .layers import mlp_block

        y = y + mlp_block(params["dense_residual"], xg, cfg.ffn_act)

    return y.reshape(B, S, d), aux
