"""VGG-16 in JAX — the paper's own Sec. III workload, runnable end to end.

Used by the quickstart example (train on synthetic 32x32 data), by the
fused-conv Pallas kernel tests, and to cross-check the evaluator's layer IR
(``repro.core.ir.vgg16_ir``) against real tensor shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.ir import VGG16_CONV_PLAN


def init_params(key, *, in_hw: int = 224, n_classes: int = 1000,
                dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, len(VGG16_CONV_PLAN) + 3)
    convs = []
    for k, (name, n_in, n_out, hw, pooled) in zip(ks, VGG16_CONV_PLAN):
        w = jax.random.normal(k, (3, 3, n_in, n_out), jnp.float32)
        w = w * (2.0 / (9 * n_in)) ** 0.5  # He init
        convs.append({"w": w.astype(dtype), "b": jnp.zeros((n_out,), dtype)})
    # Spatial size after 5 pools.
    s = in_hw // 32
    k1, k2, k3 = ks[-3:]
    fcs = [
        {"w": (jax.random.normal(k1, (512 * s * s, 4096)) * 0.01).astype(dtype),
         "b": jnp.zeros((4096,), dtype)},
        {"w": (jax.random.normal(k2, (4096, 4096)) * 0.01).astype(dtype),
         "b": jnp.zeros((4096,), dtype)},
        {"w": (jax.random.normal(k3, (4096, n_classes)) * 0.01).astype(dtype),
         "b": jnp.zeros((n_classes,), dtype)},
    ]
    return {"convs": convs, "fcs": fcs}


def param_specs(*, in_hw: int = 224, n_classes: int = 1000,
                dtype=jnp.float32) -> dict:
    """``jax.ShapeDtypeStruct`` pytree mirroring :func:`init_params` — lets
    the evaluator frontend trace :func:`forward` without materialising the
    ~135M VGG-16 parameters."""
    sds = lambda *s: jax.ShapeDtypeStruct(tuple(s), dtype)
    convs = [
        {"w": sds(3, 3, n_in, n_out), "b": sds(n_out)}
        for _name, n_in, n_out, _hw, _pooled in VGG16_CONV_PLAN
    ]
    s = in_hw // 32
    fcs = [
        {"w": sds(512 * s * s, 4096), "b": sds(4096)},
        {"w": sds(4096, 4096), "b": sds(4096)},
        {"w": sds(4096, n_classes), "b": sds(n_classes)},
    ]
    return {"convs": convs, "fcs": fcs}


def max_pool_2x2(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def conv_bn_relu(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jax.nn.relu(y + p["b"])


def forward(params: dict, x: jnp.ndarray, *, fused_conv_fn=None) -> jnp.ndarray:
    """x: (B, H, W, 3) -> logits (B, n_classes).

    ``fused_conv_fn(x, w, b, pool)`` — optional fused conv+relu(+pool)
    implementation (the Pallas kernel); defaults to the XLA ops.
    """
    ci = 0
    for name, n_in, n_out, hw, pooled in VGG16_CONV_PLAN:
        p = params["convs"][ci]
        if fused_conv_fn is not None:
            x = fused_conv_fn(x, p["w"], p["b"], pool=pooled)
        else:
            x = conv_bn_relu(x, p)
            if pooled:
                x = max_pool_2x2(x)
        ci += 1
    x = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params["fcs"]):
        x = x @ p["w"] + p["b"]
        if i < 2:
            x = jax.nn.relu(x)
    return x


def loss_fn(params: dict, batch: dict, *, fused_conv_fn=None) -> jnp.ndarray:
    logits = forward(params, batch["images"], fused_conv_fn=fused_conv_fn)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    gold = jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
    return -gold.mean()
