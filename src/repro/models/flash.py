"""Flash attention with a custom VJP (pure JAX; §Perf iteration lever).

Plain AD through the chunked-attention scan saves the (Sq, kv_block)
probability tile of *every* KV block for the backward pass — a
(n_blocks, B, H, Sq, kv_block) f32 stack per layer that dominates both
temp memory and HBM traffic of the baseline train cells (EXPERIMENTS.md
§Perf, iteration 1).  The flash backward instead saves only
``(q, k, v, out, lse)`` and recomputes each block's probabilities from the
logsumexp — the paper's fusion principle applied to the *backward* pass:
the probability "intermediate frame" never exists outside the fused group.

``bf16_tiles=True`` additionally casts the probability tile to bf16 for
the PV / dV matmuls (iteration 2): halves the tile traffic that remains,
at <1e-2 relative error (validated in tests/test_flash_vjp.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .layers import NEG_INF, attention_bias, repeat_kv


def _mask_bias(q_pos, p_c, mixer, window, chunk):
    return attention_bias(
        q_pos, p_c, mixer=mixer, causal=True, window=window, chunk=chunk,
        kv_len=None,
    )


def _fwd_scan(q, k, v, q_pos, kv_pos, *, mixer, window, chunk, kv_block,
              bf16_tiles):
    from ..parallel.sharding import DP, TP, hint

    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    if Skv % kv_block:
        kv_block = Skv
    n = Skv // kv_block
    scale = 1.0 / math.sqrt(hd)
    qh = hint(q.astype(jnp.float32), DP, None, TP, None)
    kb = k.reshape(B, n, kv_block, KV, hd)
    vb = v.reshape(B, n, kv_block, KV, hd)
    pb = kv_pos.reshape(n, kv_block)

    def step(carry, xs):
        m, l, acc = carry
        k_c, v_c, p_c = xs
        k_r = hint(repeat_kv(k_c, H).astype(jnp.float32), DP, None, TP, None)
        v_r = hint(repeat_kv(v_c, H).astype(jnp.float32), DP, None, TP, None)
        s = jnp.einsum("bqhd,bchd->bhqc", qh, k_r) * scale
        s = hint(s, DP, TP, None, None) + _mask_bias(q_pos, p_c, mixer, window,
                                                     chunk)[None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        if bf16_tiles:
            # bf16 dot operands: the tile crosses HBM at 2 bytes, the MXU
            # accumulates in f32 (preferred_element_type).
            pv = jax.lax.dot_general(
                p.astype(jnp.bfloat16),
                v_r.astype(jnp.bfloat16),
                (((3,), (1,)), ((0, 1), (0, 2))),  # (B,H,Sq,C) x (B,C,H,hd)
                preferred_element_type=jnp.float32,
            )
        else:
            pv = jnp.einsum("bhqc,bchd->bhqd", p, v_r)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros_like(m0)
    acc0 = jnp.zeros((B, H, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb),
    )
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, lse  # out in (B, H, Sq, hd)


@functools.lru_cache(maxsize=64)
def make_flash(mixer: str, window: int, chunk: int, kv_block: int,
               bf16_tiles: bool):
    """Build the custom-vjp flash attention for one static mask config."""

    kw = dict(mixer=mixer, window=window, chunk=chunk, kv_block=kv_block,
              bf16_tiles=bf16_tiles)

    @jax.custom_vjp
    def flash(q, k, v, q_pos, kv_pos):
        out, _ = _fwd_scan(q, k, v, q_pos, kv_pos, **kw)
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, Sq, H, hd)

    def fwd(q, k, v, q_pos, kv_pos):
        out, lse = _fwd_scan(q, k, v, q_pos, kv_pos, **kw)
        return (
            jnp.moveaxis(out, 1, 2).astype(q.dtype),
            (q, k, v, q_pos, kv_pos, out, lse),
        )

    def bwd(res, dout):
        from ..parallel.sharding import DP, TP, hint

        q, k, v, q_pos, kv_pos, out, lse = res
        B, Sq, H, hd = q.shape
        Skv, KV = k.shape[1], k.shape[2]
        G = H // KV
        block = kv_block if Skv % kv_block == 0 else Skv
        n = Skv // block
        scale = 1.0 / math.sqrt(hd)
        qh = hint(q.astype(jnp.float32), DP, None, TP, None)
        do = jnp.moveaxis(dout.astype(jnp.float32), 2, 1)  # (B,H,Sq,hd)
        D = jnp.sum(do * out, axis=-1)  # (B,H,Sq)
        kb = k.reshape(B, n, block, KV, hd)
        vb = v.reshape(B, n, block, KV, hd)
        pb = kv_pos.reshape(n, block)

        def step(dq, xs):
            k_c, v_c, p_c = xs
            k_r = repeat_kv(k_c, H).astype(jnp.float32)
            v_r = repeat_kv(v_c, H).astype(jnp.float32)
            s = jnp.einsum("bqhd,bchd->bhqc", qh, k_r) * scale
            s = s + _mask_bias(q_pos, p_c, mixer, window, chunk)[None, None]
            p = jnp.exp(s - lse[..., None])  # recomputed, never stored
            tile_dt = jnp.bfloat16 if bf16_tiles else jnp.float32

            def tdot(a, b, spec):
                return jnp.einsum(
                    spec, a.astype(tile_dt), b.astype(tile_dt),
                    preferred_element_type=jnp.float32,
                )

            dv_r = tdot(p, do, "bhqc,bhqd->bchd")
            dp = tdot(do, v_r, "bhqd,bchd->bhqc")
            ds = (p * (dp - D[..., None]) * scale)
            dq = dq + tdot(ds, k_r, "bhqc,bchd->bqhd")
            dk_r = tdot(ds, qh, "bhqc,bqhd->bchd")
            # fold repeated heads back onto the KV heads
            dk_c = dk_r.reshape(B, block, KV, G, hd).sum(axis=3)
            dv_c = dv_r.reshape(B, block, KV, G, hd).sum(axis=3)
            return dq, (dk_c, dv_c)

        dq0 = jnp.zeros((B, Sq, H, hd), jnp.float32)
        dq, (dk, dv) = jax.lax.scan(
            step, dq0, (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb)
        )
        dk = jnp.moveaxis(dk, 0, 1).reshape(B, Skv, KV, hd)
        dv = jnp.moveaxis(dv, 0, 1).reshape(B, Skv, KV, hd)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                None, None)

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention_vjp(q, k, v, *, q_pos, kv_pos, mixer="attn", window=0,
                        chunk=0, kv_block=1024, bf16_tiles=False,
                        logit_cap=0.0):
    assert logit_cap == 0.0, "softcap unsupported in the flash-vjp path"
    fn = make_flash(mixer, int(window), int(chunk), int(kv_block),
                    bool(bf16_tiles))
    return fn(q, k, v, q_pos, kv_pos)
