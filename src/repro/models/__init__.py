"""Model definitions (pure-functional JAX, pytree params).

``vgg``, ``resnet`` and ``mobilenet`` double as evaluator workloads: their
``forward`` functions are traced into :class:`repro.core.ir.GraphIR` by
:mod:`repro.core.frontend` (each provides ``param_specs()`` — a
``jax.ShapeDtypeStruct`` pytree — so tracing materialises nothing).
"""
