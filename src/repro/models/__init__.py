"""Model definitions (pure-functional JAX, pytree params)."""
