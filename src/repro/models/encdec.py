"""Encoder-decoder backbone (seamless-m4t-large-v2).

Audio frontend is a STUB per the task spec: the encoder consumes
precomputed frame embeddings ``(B, S_enc, d)``.  Decoder layers are
self-attention + cross-attention + FFN; cross-attention K/V are computed
from the encoder output once and cached for decoding.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L


def _init_enc_layer(key, cfg, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "norm2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.ffn_act, dtype),
    }


def _init_dec_layer(key, cfg, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": L.init_attention(k1, cfg, dtype),
        "norm_x": L.rmsnorm_init(cfg.d_model, dtype),
        "xattn": L.init_attention(k2, cfg, dtype),
        "norm2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.ffn_act, dtype),
    }


def init_params(key, cfg) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.n_enc_layers)
    )
    dec = jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(
        jax.random.split(ks[1], cfg.n_layers)
    )
    return {
        "embed": L.embed_init(ks[2], cfg.vocab_size, cfg.d_model, dtype),
        "enc_stack": enc,
        "enc_final_norm": L.rmsnorm_init(cfg.d_model, dtype),
        "dec_stack": dec,
        "final_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }


def encode(params, cfg, rc, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_enc, d) precomputed embeddings -> encoder states."""
    positions = jnp.arange(frames.shape[1])
    x = frames.astype(jnp.dtype(cfg.dtype))

    def body(x, p):
        h = L.rmsnorm(p["norm1"], x, cfg.rmsnorm_eps)
        out, _ = L.attention_block(
            p["attn"], h, cfg, mixer="attn", positions=positions,
            causal=False, impl="chunked", kv_block=rc.attn_chunk_kv,
        )
        x = x + out
        h = L.rmsnorm(p["norm2"], x, cfg.rmsnorm_eps)
        return x + L.mlp_block(p["mlp"], h, cfg.ffn_act), None

    from .transformer import _remat_wrap

    x, _ = jax.lax.scan(_remat_wrap(body, rc), x, params["enc_stack"])
    return L.rmsnorm(params["enc_final_norm"], x, cfg.rmsnorm_eps)


def cross_kv(params, cfg, enc_h: jnp.ndarray) -> dict:
    """Per-decoder-layer cross-attention K/V, computed once.  Stacked (L, ...)."""
    B, Se, d = enc_h.shape
    hd = cfg.resolved_head_dim

    def one(p):
        k = (enc_h @ p["xattn"]["wk"]).reshape(B, Se, cfg.n_kv_heads, hd)
        v = (enc_h @ p["xattn"]["wv"]).reshape(B, Se, cfg.n_kv_heads, hd)
        return {"k": k, "v": v}

    return jax.vmap(one)(params["dec_stack"])


def decode_stack(params, cfg, rc, tokens: jnp.ndarray, xkv: dict,
                 cache: dict | None = None):
    """Decoder trunk.  cache: {"self": {k,v (L,B,max,KV,hd)}, "len"}."""
    x = params["embed"][tokens]
    cache_len = cache["len"] if cache is not None else None
    positions = jnp.arange(x.shape[1])
    if cache is not None:
        positions = positions + cache_len
    has_cache = cache is not None

    def body(x, xs):
        p, layer_xkv, self_c = xs
        h = L.rmsnorm(p["norm1"], x, cfg.rmsnorm_eps)
        attn_cache = (
            {"k": self_c["k"], "v": self_c["v"], "len": cache_len} if has_cache else None
        )
        out, nc = L.attention_block(
            p["attn"], h, cfg, mixer="attn", positions=positions,
            cache=attn_cache, impl="chunked", kv_block=rc.attn_chunk_kv,
        )
        x = x + out
        h = L.rmsnorm(p["norm_x"], x, cfg.rmsnorm_eps)
        out, _ = L.attention_block(
            p["xattn"], h, cfg, mixer="attn", positions=positions,
            cross_kv=(layer_xkv["k"], layer_xkv["v"]),
            impl="chunked", kv_block=rc.attn_chunk_kv,
        )
        x = x + out
        h = L.rmsnorm(p["norm2"], x, cfg.rmsnorm_eps)
        x = x + L.mlp_block(p["mlp"], h, cfg.ffn_act)
        new_c = {"k": nc["k"], "v": nc["v"]} if nc is not None else None
        return x, new_c

    from .transformer import _remat_wrap

    if has_cache:
        xs = (params["dec_stack"], xkv, cache["self"])
    else:
        dummy = {"k": jnp.zeros((cfg.n_layers, 0)), "v": jnp.zeros((cfg.n_layers, 0))}
        xs = (params["dec_stack"], xkv, dummy)

        def body_nc(x, xs):  # no-cache variant (training)
            p, layer_xkv, _ = xs
            return body(x, (p, layer_xkv, None))

    run = body if has_cache else body_nc
    x, new_self = jax.lax.scan(_remat_wrap(run, rc), x, xs)
    x = L.rmsnorm(params["final_norm"], x, cfg.rmsnorm_eps)
    new_cache = None
    if has_cache:
        new_cache = {"self": new_self, "len": cache_len + tokens.shape[1]}
    return x, new_cache


def forward(params, cfg, rc, batch: dict, cache: dict | None = None):
    """batch: {"frontend": (B, S_enc, d), "tokens": (B, S_dec)}.

    Presence of ``batch["frontend"]`` selects encode (training / prefill);
    decode steps omit it and reuse ``cache["xkv"]``.  Returns
    (hidden, new_cache, aux=0).
    """
    if cache is not None and "frontend" not in batch:
        xkv = cache["xkv"]  # decode: cross-KV computed at prefill
        inner = {"self": cache["self"], "len": cache["len"]}
        h, new_inner = decode_stack(params, cfg, rc, batch["tokens"], xkv, inner)
        return h, {"xkv": xkv, **new_inner}, jnp.float32(0.0)
    enc_h = encode(params, cfg, rc, batch["frontend"])
    xkv = cross_kv(params, cfg, enc_h)
    if cache is None:
        h, _ = decode_stack(params, cfg, rc, batch["tokens"], xkv, None)
        return h, None, jnp.float32(0.0)
    inner = {"self": cache["self"], "len": cache["len"]}
    h, new_inner = decode_stack(params, cfg, rc, batch["tokens"], xkv, inner)
    return h, {"xkv": xkv, **new_inner}, jnp.float32(0.0)


def init_cache(cfg, batch: int, max_seq: int, enc_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    Ldec = cfg.n_layers
    return {
        "self": {
            "k": jnp.zeros((Ldec, batch, max_seq, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((Ldec, batch, max_seq, cfg.n_kv_heads, hd), dtype),
        },
        "xkv": {
            "k": jnp.zeros((Ldec, batch, enc_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((Ldec, batch, enc_len, cfg.n_kv_heads, hd), dtype),
        },
        "len": jnp.zeros((), jnp.int32),
    }
