"""Jittable train / prefill / decode step builders.

``make_train_step`` supports gradient accumulation (``rc.microbatches``) —
the lever that keeps activation memory inside 16 GB/chip for the 400 B+
train cells — with fp32 gradient accumulators and donated params/opt-state
buffers for in-place updates.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..models import model as M
from ..optim import AdamWConfig, adamw_update, init_opt_state, warmup_cosine


def make_train_step(cfg, rc, opt_cfg: AdamWConfig | None = None,
                    grad_shardings=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_shardings`` (a NamedSharding pytree mirroring the params): pins
    every (micro-)gradient to the parameter's FSDP sharding, so GSPMD emits
    per-microbatch reduce-scatters instead of full all-reduces — the §Perf
    lever that collapses the collective term of the 400 B+ train cells
    (rc.shard_grads wires it from the launcher).
    """
    opt_cfg = opt_cfg or AdamWConfig(
        weight_decay=rc.weight_decay,
        grad_clip=rc.grad_clip,
        state_dtype=rc.opt_state_dtype,
    )

    def loss(p, mb):
        return M.loss_fn(p, cfg, rc, mb)

    grad_fn = jax.value_and_grad(loss, has_aux=True)

    def pin(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), g, grad_shardings
        )

    def train_step(params, opt_state, batch):
        if rc.microbatches > 1:
            mb_batch = jax.tree.map(
                lambda x: x.reshape(rc.microbatches, x.shape[0] // rc.microbatches,
                                    *x.shape[1:]),
                batch,
            )

            def micro(carry, mb):
                gsum, lsum = carry
                (l, _aux), g = grad_fn(params, mb)
                g = pin(g)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g
                )
                return (pin(gsum), lsum + l), None

            gzero = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            ))
            (gsum, lsum), _ = jax.lax.scan(
                micro, (gzero, jnp.float32(0.0)), mb_batch
            )
            grads = jax.tree.map(lambda g: g / rc.microbatches, gsum)
            loss_val = lsum / rc.microbatches
        else:
            (loss_val, _aux), grads = grad_fn(params, batch)
            grads = pin(grads)

        lr = warmup_cosine(
            opt_state["step"], peak_lr=rc.learning_rate, warmup_steps=rc.warmup_steps
        )
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, lr=lr, cfg=opt_cfg
        )
        metrics = {"loss": loss_val, "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step


def make_init(cfg, rc, opt_cfg: AdamWConfig | None = None):
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=rc.opt_state_dtype)

    def init(key):
        params = M.init_params(key, cfg)
        return params, init_opt_state(params, opt_cfg)

    return init


def make_prefill_step(cfg, rc):
    def prefill_step(params, cache, batch):
        return M.prefill(params, cfg, rc, batch, cache)

    return prefill_step


def make_decode_step(cfg, rc):
    def decode_step(params, cache, tokens):
        return M.decode(params, cfg, rc, tokens, cache)

    return decode_step
