"""Elastic re-scaling: resume on a different mesh, degrade a sick one.

When the pod count changes (2 -> 1 after a pod loss, or 1 -> 2 on
scale-up), the parameters and optimizer state are re-sharded from the
host checkpoint onto the new mesh's sharding rules, and the data pipeline
is re-keyed to the new host topology.  Nothing about the checkpoint format
is mesh-specific (host numpy + pytree paths), so this is pure re-placement
— the property that makes the 2-pod -> 1-pod test in
tests/test_elastic.py work without any conversion step.

The fleet sweep uses the same philosophy in miniature:
:func:`sweep_degradation_ladder` is the layout fallback the evaluator's
sharded co-search walks when its ``hardware`` mesh turns sick — the
sharded program and the single-device program are bit-identical by
construction (tests/test_multidevice.py), so degrading mid-sweep changes
wall-clock, never answers.

The model-stack imports are function-local so the evaluator core can use
the ladder without pulling the training stack into its import graph.
"""
from __future__ import annotations


def sweep_degradation_ladder(devices) -> tuple:
    """Device layouts a sick sweep falls back through, best first.

    ``devices`` is :func:`repro.core.flow.run_fleet`'s layout spec (None
    = single-device; an int or device sequence = a 1-D ``hardware``
    mesh).  The ladder is the requested layout followed by the
    single-device program — the one layout that needs no collective
    runtime at all, so it survives any mesh sickness.  Results are
    bit-identical at every rung (the sharded kernel is row-parallel with
    no cross-row reduction), so walking down the ladder trades only
    throughput, never correctness.
    """
    if devices is None:
        return (None,)
    return (devices, None)


def shardings_for(cfg, mesh, opt_cfg):
    """(param, opt-state) shardings of config ``cfg`` on ``mesh``."""
    import jax

    from ..models import model as M
    from ..optim import init_opt_state
    from ..parallel import sharding as SH

    aparams = M.abstract_params(cfg)
    pshard = SH.param_shardings(mesh, aparams)
    aopt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), aparams)
    oshard = SH.opt_state_shardings(mesh, aopt, pshard)
    return pshard, oshard


def resume_on_mesh(ckpt_dir, step: int, cfg, new_mesh, *, opt_cfg=None):
    """Restore step ``step`` re-sharded onto ``new_mesh``.

    Returns (params, opt_state) as jax Arrays with the new placement.
    """
    import jax

    from .. import checkpoint as CKPT
    from ..models import model as M
    from ..optim import AdamWConfig, init_opt_state

    opt_cfg = opt_cfg or AdamWConfig()
    aparams = M.abstract_params(cfg)
    aopt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), aparams)
    tree_np, _ = CKPT.restore(
        ckpt_dir, step, like={"params": aparams, "opt": aopt}
    )
    pshard, oshard = shardings_for(cfg, new_mesh, opt_cfg)
    params = CKPT.device_put_like(tree_np["params"], pshard)
    opt = CKPT.device_put_like(tree_np["opt"], oshard)
    return params, opt
