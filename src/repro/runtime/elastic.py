"""Elastic re-scaling: resume a checkpoint on a different mesh.

When the pod count changes (2 -> 1 after a pod loss, or 1 -> 2 on
scale-up), the parameters and optimizer state are re-sharded from the
host checkpoint onto the new mesh's sharding rules, and the data pipeline
is re-keyed to the new host topology.  Nothing about the checkpoint format
is mesh-specific (host numpy + pytree paths), so this is pure re-placement
— the property that makes the 2-pod -> 1-pod test in
tests/test_elastic.py work without any conversion step.
"""
from __future__ import annotations

import jax

from .. import checkpoint as CKPT
from ..models import model as M
from ..optim import AdamWConfig, init_opt_state
from ..parallel import sharding as SH


def shardings_for(cfg, mesh, opt_cfg: AdamWConfig):
    aparams = M.abstract_params(cfg)
    pshard = SH.param_shardings(mesh, aparams)
    aopt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), aparams)
    oshard = SH.opt_state_shardings(mesh, aopt, pshard)
    return pshard, oshard


def resume_on_mesh(ckpt_dir, step: int, cfg, new_mesh, *,
                   opt_cfg: AdamWConfig | None = None):
    """Restore step ``step`` re-sharded onto ``new_mesh``.

    Returns (params, opt_state) as jax Arrays with the new placement.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    aparams = M.abstract_params(cfg)
    aopt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), aparams)
    tree_np, _ = CKPT.restore(
        ckpt_dir, step, like={"params": aparams, "opt": aopt}
    )
    pshard, oshard = shardings_for(cfg, new_mesh, opt_cfg)
    params = CKPT.device_put_like(tree_np["params"], pshard)
    opt = CKPT.device_put_like(tree_np["opt"], oshard)
    return params, opt
