"""Training/serving runtime: step builders, fault tolerance, elasticity."""
