"""Fault-tolerant training driver: restore-on-failure, straggler
mitigation, heartbeats.

At thousands of nodes the mean time between failures drops below the
checkpoint interval, so the driver — not the operator — must own recovery:

* **Checkpoint/restart**: periodic async checkpoints (atomic + hashed, see
  repro.checkpoint); on any step failure the driver restores the latest
  good step and replays forward.  The counter-based data pipeline makes
  the replay bit-identical.
* **Straggler mitigation**: per-step wall-time deadline at ``k x`` the
  running median; a step breaching it is recorded and *re-dispatched*
  deterministically (same batch, same RNG) — the single-process analogue
  of re-scheduling a slow worker's shard.
* **Heartbeat**: a monotonically-increasing (step, time) file others can
  watch; doubles as the liveness signal a cluster supervisor would use.

Failure injection for tests/examples is a callable hook — a real cluster
would raise from the collective layer instead.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import statistics
import time
from typing import Callable

import jax

from .. import checkpoint as CKPT


class StragglerDetector:
    """Running-median wall-time deadline shared by the training driver and
    the fleet sweep's chunk loop.

    ``observe(dt)`` feeds one duration; ``is_straggler(dt)`` is True when
    ``dt`` exceeds ``factor x`` the running median of the last ``window``
    observations (never below ``min_deadline_s``), once at least
    ``min_samples`` durations are in.  The detector only *flags* — what to
    do about a straggler (re-dispatch the step, record the chunk index)
    is the caller's policy.
    """

    def __init__(self, *, factor: float = 3.0, min_deadline_s: float = 0.05,
                 min_samples: int = 5, window: int = 50):
        self.factor = float(factor)
        self.min_deadline_s = float(min_deadline_s)
        self.min_samples = int(min_samples)
        self.window = int(window)
        self._durations: list[float] = []

    def deadline(self) -> float:
        """Current straggler deadline; +inf until min_samples are in."""
        if len(self._durations) < self.min_samples:
            return float("inf")
        return max(
            self.min_deadline_s,
            self.factor * statistics.median(self._durations),
        )

    def is_straggler(self, dt: float) -> bool:
        """True when ``dt`` breaches the current deadline."""
        return dt > self.deadline()

    def observe(self, dt: float) -> None:
        """Record one duration (bounded window)."""
        self._durations.append(float(dt))
        if len(self._durations) > self.window:
            self._durations.pop(0)


@dataclasses.dataclass
class TrainerReport:
    steps_run: int = 0
    failures: int = 0
    restores: int = 0
    stragglers: int = 0
    redispatches: int = 0
    last_loss: float = float("nan")
    losses: list = dataclasses.field(default_factory=list)


class ResilientTrainer:
    def __init__(
        self,
        *,
        train_step: Callable,  # (params, opt_state, batch) -> (p, o, metrics)
        stream,  # repro.data.TokenStream
        ckpt_dir,
        ckpt_every: int = 10,
        straggler_factor: float = 3.0,
        min_deadline_s: float = 0.05,
        failure_hook: Callable[[int], None] | None = None,
    ):
        self.train_step = train_step
        self.stream = stream
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.min_deadline_s = min_deadline_s
        self.failure_hook = failure_hook
        self.checkpointer = CKPT.AsyncCheckpointer(ckpt_dir)
        self.report = TrainerReport()
        self.straggler = StragglerDetector(
            factor=straggler_factor, min_deadline_s=min_deadline_s
        )

    # ------------------------------------------------------------------
    def _heartbeat(self, step: int):
        hb = self.ckpt_dir / "heartbeat.json"
        self.ckpt_dir.mkdir(parents=True, exist_ok=True)
        hb.write_text(json.dumps({"step": step, "time": time.time()}))

    def _restore(self, params, opt_state):
        self.checkpointer.wait()  # an in-flight save may be the latest good step
        step = CKPT.latest_step(self.ckpt_dir)
        self.report.restores += 1
        if step is None:
            return 0, params, opt_state  # cold restart
        tree, extra = CKPT.restore(
            self.ckpt_dir, step, like={"params": params, "opt": opt_state}
        )
        params = jax.tree.map(jax.numpy.asarray, tree["params"])
        opt_state = jax.tree.map(jax.numpy.asarray, tree["opt"])
        return step + 1, params, opt_state

    def _run_one(self, params, opt_state, step: int, batch):
        if self.failure_hook is not None:
            self.failure_hook(step)  # may raise (simulated node failure)
        t0 = time.perf_counter()
        params, opt_state, metrics = self.train_step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        return params, opt_state, metrics, dt

    # ------------------------------------------------------------------
    def run(self, params, opt_state, n_steps: int, *, start_step: int = 0):
        step = start_step
        while step < start_step + n_steps:
            batch = self.stream.batch_at(step)
            try:
                params, opt_state, metrics, dt = self._run_one(
                    params, opt_state, step, batch
                )
            except Exception:
                self.report.failures += 1
                step, params, opt_state = self._restore(params, opt_state)
                continue

            # Straggler detection + deterministic re-dispatch.
            if self.straggler.is_straggler(dt):
                self.report.stragglers += 1
                params, opt_state, metrics, dt = self._run_one(
                    params, opt_state, step, batch
                )
                self.report.redispatches += 1
            self.straggler.observe(dt)

            loss = float(metrics["loss"])
            self.report.steps_run += 1
            self.report.last_loss = loss
            self.report.losses.append(loss)
            self._heartbeat(step)
            if (step + 1) % self.ckpt_every == 0:
                self.checkpointer.submit(
                    step, {"params": params, "opt": opt_state},
                    extra={"loss": loss},
                )
            step += 1
        self.checkpointer.wait()
        return params, opt_state


def flaky(fail_at_steps: set[int], *, already: set | None = None):
    """Failure hook raising once per listed step (then healing)."""
    seen = already if already is not None else set()

    def hook(step: int):
        if step in fail_at_steps and step not in seen:
            seen.add(step)
            raise RuntimeError(f"injected node failure at step {step}")

    return hook
