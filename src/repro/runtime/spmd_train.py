"""shard_map train step with explicit cross-pod gradient compression.

The pjit path (runtime.steps) lets GSPMD place every collective; that is
the right default, but it cannot express *mixed-precision collectives* —
int8 on the slow cross-pod links, full precision inside a pod.  This
variant computes per-pod mean gradients under ``jax.shard_map`` over the
``pod`` axis (GSPMD still handles data/model sharding *inside* each pod
via nested pjit semantics) and then reduces across pods with
``compressed_psum`` + error feedback.

Wire math for jamba train_4k on 2 pods: grads are ~398 B half-words; fp32
cross-pod all-reduce moves 1.59 TB/step on the pod links, int8 moves
0.40 TB — a 4x cut of the slowest collective term (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models import model as M
from ..optim import AdamWConfig, adamw_update, warmup_cosine
from ..parallel.compression import compressed_psum, ef_apply
from ..parallel.sharding import shard_map_unchecked


def make_compressed_train_step(cfg, rc, mesh, opt_cfg: AdamWConfig | None = None):
    """Train step with int8 error-feedback gradient sync over the pod axis.

    opt/params replicated across pods, batch split across pods; the error
    feedback buffers ride in ``opt_state["ef"]``.
    """
    assert "pod" in mesh.axis_names, "compressed sync needs a pod axis"
    opt_cfg = opt_cfg or AdamWConfig(
        weight_decay=rc.weight_decay, grad_clip=rc.grad_clip,
        state_dtype=rc.opt_state_dtype,
    )

    def loss(p, mb):
        return M.loss_fn(p, cfg, rc, mb)[0]

    grad_fn = jax.value_and_grad(loss)

    inner_axes = tuple(a for a in mesh.axis_names if a != "pod")

    @partial(
        shard_map_unchecked, mesh=mesh,
        in_specs=(P(), P(), P(), P("pod")),
        out_specs=(P(), P(), P(), P()),
    )
    def step(params, opt_state, ef, batch):
        l, g = grad_fn(params, batch)  # per-pod mean gradient
        g = ef_apply(g, ef)
        synced, new_ef = [], []
        flat_g, treedef = jax.tree.flatten(g)
        for leaf in flat_g:
            red, err = compressed_psum(leaf, "pod", mean=True)
            synced.append(red.astype(leaf.dtype))
            new_ef.append(err)
        grads = treedef.unflatten(synced)
        ef_out = treedef.unflatten(new_ef)
        lr = warmup_cosine(
            opt_state["step"], peak_lr=rc.learning_rate,
            warmup_steps=rc.warmup_steps,
        )
        params, opt_state, gnorm = adamw_update(
            grads, opt_state, params, lr=lr, cfg=opt_cfg
        )
        metrics = {"loss": jax.lax.pmean(l, "pod"), "grad_norm": gnorm, "lr": lr}
        return params, opt_state, ef_out, metrics

    def init_ef(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    return step, init_ef
