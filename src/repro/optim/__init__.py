"""Optimizer substrate (AdamW + schedules), no external dependencies."""
from .adamw import AdamWConfig, adamw_update, init_opt_state  # noqa: F401
from .schedule import warmup_cosine  # noqa: F401
