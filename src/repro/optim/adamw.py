"""AdamW with global-norm clipping and configurable state dtype.

State dtype matters at scale: fp32 (m, v) for a 480 B-parameter model is
3.84 TB; bf16 state (with fp32 update math each step) halves that and is
what lets arctic-480b's optimizer fit 256 v5e chips — the memory_analysis
numbers in EXPERIMENTS.md §Dry-run use bf16 state for the >100 B models.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # "float32" | "bfloat16"


def init_opt_state(params, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _decay_mask(path_leaf) -> bool:
    """Weight decay only on >=2-D tensors (skip norms/biases/scalars)."""
    return path_leaf.ndim >= 2


def adamw_update(grads, opt_state, params, *, lr, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, grad_norm).  Math in fp32."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    state_dt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(state_dt), v32.astype(state_dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
