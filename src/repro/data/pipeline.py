"""Synthetic token pipeline: deterministic, host-sharded, prefetched.

Counter-based RNG (Philox keyed on (seed, step, host)) makes any batch
recomputable from its step index alone — the property fault-tolerant
training needs: after restore-from-step-N the pipeline replays batch N+1
bit-identically, and straggler re-dispatch re-materialises the exact batch
without coordination.

The "language" is a deterministic mixture (Zipf-ish unigram + a repeated
motif) rather than uniform noise, so the training loss has learnable
structure for the convergence tests and examples.
"""
from __future__ import annotations

import queue
import threading

import numpy as np


def _rng(seed: int, step: int, host: int) -> np.random.Generator:
    key = (int(seed) << 96) | (int(step) << 32) | (int(host) << 16) | 0x5EED
    return np.random.Generator(np.random.Philox(key=key))


def make_batch(cfg, shape_batch: int, seq_len: int, *, seed: int = 0,
               step: int = 0, host: int = 0, n_hosts: int = 1) -> dict:
    """One global (or host-local) batch for the given model config."""
    assert shape_batch % n_hosts == 0
    B = shape_batch // n_hosts
    rng = _rng(seed, step, host)
    V = cfg.vocab_size

    # Zipf-ish unigram + motif repetition => learnable structure.
    total = seq_len + 1
    base = rng.zipf(1.3, size=(B, total)).astype(np.int64) % V
    motif_len = min(16, max(seq_len // 4, 1))
    motif = rng.integers(0, V, size=(B, 1, motif_len))
    reps = total // motif_len + 1
    motif_stream = np.tile(motif, (1, reps, 1)).reshape(B, -1)[:, :total]
    use_motif = rng.random((B, total)) < 0.5
    toks = np.where(use_motif, motif_stream, base).astype(np.int32)

    if cfg.is_encoder_decoder:
        batch = {
            "frontend": rng.standard_normal(
                (B, cfg.frontend_len, cfg.d_model), dtype=np.float32
            ),
            "tokens": toks[:, :seq_len],
            "labels": toks[:, 1 : seq_len + 1],
        }
    elif cfg.frontend:
        text = seq_len - cfg.frontend_len
        labels = np.concatenate(
            [np.full((B, cfg.frontend_len), -1, np.int32), toks[:, 1 : text + 1]],
            axis=1,
        )
        batch = {
            "frontend": rng.standard_normal(
                (B, cfg.frontend_len, cfg.d_model), dtype=np.float32
            ),
            "tokens": toks[:, :text],
            "labels": labels,
        }
    else:
        batch = {"tokens": toks[:, :seq_len], "labels": toks[:, 1 : seq_len + 1]}
    return batch


class TokenStream:
    """Iterator over steps with a background prefetch thread."""

    def __init__(self, cfg, batch: int, seq_len: int, *, seed: int = 0,
                 host: int = 0, n_hosts: int = 1, start_step: int = 0,
                 prefetch: int = 2):
        self.cfg, self.batch, self.seq_len = cfg, batch, seq_len
        self.seed, self.host, self.n_hosts = seed, host, n_hosts
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            b = make_batch(
                self.cfg, self.batch, self.seq_len, seed=self.seed,
                step=step, host=self.host, n_hosts=self.n_hosts,
            )
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.2)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, b = self._q.get()
        self.step = step + 1
        return step, b

    def __iter__(self):
        return self

    def batch_at(self, step: int) -> dict:
        """Random-access replay (restore / straggler re-dispatch)."""
        return make_batch(
            self.cfg, self.batch, self.seq_len, seed=self.seed, step=step,
            host=self.host, n_hosts=self.n_hosts,
        )

    def close(self):
        self._stop.set()
