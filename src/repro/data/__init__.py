"""Deterministic synthetic data pipeline (counter-based, restart-safe)."""
from .pipeline import TokenStream, make_batch  # noqa: F401
