"""Training driver: ``python -m repro.launch.train --arch qwen3 ...``.

Small-scale runnable on this CPU container (reduced configs); the same
code path lowers for the production meshes (launch/dryrun.py proves it).
Wires together: config registry -> model -> sharding rules -> data
pipeline -> fault-tolerant trainer -> checkpoints.
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import time

import jax

from ..configs import SHAPES, resolve, run_config, scaled_down
from ..data import TokenStream
from ..models import model as M
from ..optim import AdamWConfig, init_opt_state
from ..parallel import sharding as SH
from ..runtime.fault_tolerance import ResilientTrainer, flaky
from ..runtime.steps import make_train_step
from .mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="scaled-down config (CPU-sized); full configs are "
                         "for the dry-run meshes")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated steps to fail at (fault-tolerance demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve(args.arch)
    if args.reduced:
        cfg = scaled_down(cfg)
    rc = run_config(cfg.name, "train_4k", microbatches=1, remat="none")
    rc = dataclasses.replace(
        rc, learning_rate=args.lr, warmup_steps=max(args.steps // 10, 1),
        xent_chunk=min(64, args.seq), attn_chunk_kv=min(64, args.seq),
        mamba_chunk=16,
    )

    mesh = make_mesh((1, jax.device_count()), ("data", "model")) \
        if jax.device_count() > 1 else make_mesh((1, 1), ("data", "model"))

    key = jax.random.key(args.seed)
    params = M.init_params(key, cfg)
    opt_cfg = AdamWConfig(state_dtype=rc.opt_state_dtype,
                          weight_decay=rc.weight_decay,
                          grad_clip=rc.grad_clip)
    opt_state = init_opt_state(params, opt_cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name} reduced={args.reduced} params={n_params:,}")

    seq = args.seq
    if cfg.frontend and not cfg.is_encoder_decoder:
        seq = args.seq + cfg.frontend_len
    stream = TokenStream(cfg, args.batch, seq, seed=args.seed)

    with jax.set_mesh(mesh):
        step_fn = jax.jit(make_train_step(cfg, rc, opt_cfg), donate_argnums=(0, 1))
        hook = None
        if args.inject_failures:
            hook = flaky({int(s) for s in args.inject_failures.split(",")})
        trainer = ResilientTrainer(
            train_step=step_fn, stream=stream, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, failure_hook=hook,
        )
        t0 = time.perf_counter()
        params, opt_state = trainer.run(params, opt_state, args.steps)
        dt = time.perf_counter() - t0

    r = trainer.report
    print(
        f"[train] {r.steps_run} steps in {dt:.1f}s "
        f"({dt / max(r.steps_run, 1) * 1e3:.0f} ms/step)  "
        f"loss {r.losses[0]:.4f} -> {r.last_loss:.4f}  "
        f"failures={r.failures} restores={r.restores} "
        f"stragglers={r.stragglers}"
    )
    stream.close()
    return r


if __name__ == "__main__":
    main()
