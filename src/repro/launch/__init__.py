"""Launch: production mesh, abstract input specs, dry-run, train/serve."""
