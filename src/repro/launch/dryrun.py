import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: device count locks on first backend init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds abstract params/optimizer/cache/batch
(ShapeDtypeStruct only — no allocation), shards them with the production
rules, lowers the jitted step, compiles it for the 16x16 (single-pod,
256 chips) or 2x16x16 (multi-pod, 512 chips) mesh, and records:

* ``compiled.memory_analysis()``  — proves the cell fits per-device HBM,
* ``compiled.cost_analysis()``    — per-device FLOPs / bytes,
* collective bytes parsed from the post-SPMD HLO,
* the three-term roofline (compute / memory / collective seconds).

Usage:
  python -m repro.launch.dryrun --arch qwen3 --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --jobs 2 --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import subprocess
import sys
import time

import jax
import numpy as np

from ..configs import SHAPES, all_cells, resolve, run_config, supported_shapes
from ..core import roofline as RL
from ..models import model as M
from ..optim import AdamWConfig, init_opt_state
from ..parallel import sharding as SH
from ..runtime.steps import make_decode_step, make_prefill_step, make_train_step
from . import input_specs as IS
from .mesh import make_production_mesh

OUT_DEFAULT = "experiments/dryrun"


def _tree_bytes_per_device(tree, shardings) -> float:
    total = 0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(shardings)):
        shard_shape = sh.shard_shape(leaf.shape)
        total += int(np.prod(shard_shape)) * leaf.dtype.itemsize
    return float(total)


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    out = {}
    for field in (
        "argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes",
        "alias_size_in_bytes", "generated_code_size_in_bytes",
    ):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    return out


def build_cell(arch: str, shape_name: str, mesh_kind: str, rc_overrides: dict):
    cfg = resolve(arch)
    shape = SHAPES[shape_name]
    rc = run_config(cfg.name, shape_name, **rc_overrides)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    specs = IS.input_specs(cfg, shape, ring=rc.local_ring_cache)
    aparams = M.abstract_params(cfg)
    pshard = SH.param_shardings(mesh, aparams, fsdp=rc.fsdp)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(state_dtype=rc.opt_state_dtype)
        aopt = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), aparams)
        oshard = SH.opt_state_shardings(mesh, aopt, pshard)
        bshard = SH.batch_shardings(mesh, specs["batch"])
        step = make_train_step(
            cfg, rc, opt_cfg,
            grad_shardings=pshard if rc.shard_grads else None,
        )
        jitted = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        args = (aparams, aopt, specs["batch"])
        resident = {
            "params": _tree_bytes_per_device(aparams, pshard),
            "opt": _tree_bytes_per_device(aopt, oshard),
            "batch": _tree_bytes_per_device(specs["batch"], bshard),
        }
    elif shape.kind == "prefill":
        cshard = SH.cache_shardings(mesh, specs["cache"], seq_shard=rc.seq_shard)
        bshard = SH.batch_shardings(mesh, specs["batch"])
        step = make_prefill_step(cfg, rc)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, cshard, bshard),
            out_shardings=(None, cshard),
            donate_argnums=(1,),
        )
        args = (aparams, specs["cache"], specs["batch"])
        resident = {
            "params": _tree_bytes_per_device(aparams, pshard),
            "cache": _tree_bytes_per_device(specs["cache"], cshard),
            "batch": _tree_bytes_per_device(specs["batch"], bshard),
        }
    else:  # decode
        cshard = SH.cache_shardings(mesh, specs["cache"], seq_shard=rc.seq_shard)
        tshard = SH.batch_shardings(mesh, specs["tokens"], seq_shard=False)
        step = make_decode_step(cfg, rc)
        jitted = jax.jit(
            step,
            in_shardings=(pshard, cshard, tshard),
            out_shardings=(None, cshard),
            donate_argnums=(1,),
        )
        args = (aparams, specs["cache"], specs["tokens"])
        resident = {
            "params": _tree_bytes_per_device(aparams, pshard),
            "cache": _tree_bytes_per_device(specs["cache"], cshard),
        }
    return cfg, shape, rc, mesh, jitted, args, resident


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: pathlib.Path,
             rc_overrides: dict, tag: str = "") -> dict:
    cfg, shape, rc, mesh, jitted, args, resident = build_cell(
        arch, shape_name, mesh_kind, rc_overrides
    )
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    with jax.set_mesh(mesh):  # resolves in-model sharding hints (P specs)
        lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = _memory_analysis_dict(compiled)
    hlo = compiled.as_text()
    rl = RL.roofline_from_compiled(
        compiled,
        model_flops_total=RL.model_flops(cfg, shape, kind=shape.kind),
        n_chips=n_chips,
        hlo_text=hlo,
    )
    record = {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": mesh_kind,
        "n_chips": n_chips,
        "tag": tag,
        "run_config": dataclasses.asdict(rc),
        "seconds": {"lower": t_lower, "compile": t_compile},
        "memory_analysis": mem,
        "resident_bytes_per_device": resident,
        "resident_total_gib": sum(resident.values()) / 2**30,
        "roofline": rl.row(),
        "params": cfg.param_counts(),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    fname = out_dir / f"{cfg.name}__{shape.name}__{mesh_kind}{suffix}.json"
    fname.write_text(json.dumps(record, indent=1))
    print(
        f"[dryrun] {cfg.name} {shape.name} {mesh_kind}{suffix}: "
        f"compile {t_compile:.1f}s  resident {record['resident_total_gib']:.2f} GiB/dev  "
        f"bound={rl.bound}  step>={rl.step_seconds*1e3:.1f} ms  "
        f"mfu<={rl.mfu_bound*100:.1f}%",
        flush=True,
    )
    print(f"  memory_analysis: {mem}", flush=True)
    print(f"  cost: flops/dev={rl.flops:.3e} bytes/dev={rl.hbm_bytes:.3e} "
          f"coll/dev={rl.coll_bytes:.3e} {rl.coll_breakdown}", flush=True)
    return record


def sweep(cells, mesh_kinds, out_dir: pathlib.Path, jobs: int, force: bool):
    """Run cells in subprocesses (one compile per process, ``jobs`` wide)."""
    work = []
    for arch, shape in cells:
        for mk in mesh_kinds:
            suffix = out_dir / f"{arch}__{shape}__{mk}.json"
            if not force and suffix.exists():
                continue
            work.append((arch, shape, mk))
    print(f"[sweep] {len(work)} cells to run, jobs={jobs}")
    procs: list[tuple[subprocess.Popen, tuple]] = []
    failures = []
    idx = 0
    while idx < len(work) or procs:
        while idx < len(work) and len(procs) < jobs:
            arch, shape, mk = work[idx]
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mk,
                "--out", str(out_dir),
            ]
            p = subprocess.Popen(cmd)
            procs.append((p, work[idx]))
            idx += 1
        time.sleep(2.0)
        still = []
        for p, cell in procs:
            if p.poll() is None:
                still.append((p, cell))
            elif p.returncode != 0:
                failures.append(cell)
                print(f"[sweep] FAILED {cell} rc={p.returncode}", flush=True)
        procs = still
    print(f"[sweep] done; {len(failures)} failures: {failures}")
    return failures


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi"), default="single")
    ap.add_argument("--all", action="store_true", help="sweep all cells x meshes")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DEFAULT)
    ap.add_argument("--tag", default="", help="suffix for perf-iteration records")
    # perf levers (hillclimb)
    ap.add_argument("--microbatches", type=int)
    ap.add_argument("--remat", choices=("none", "dots", "full"))
    ap.add_argument("--seq-shard", action="store_true", default=None)
    ap.add_argument("--opt-dtype", choices=("float32", "bfloat16"))
    ap.add_argument("--attn-chunk-kv", type=int)
    ap.add_argument("--xent-chunk", type=int)
    ap.add_argument("--mamba-chunk", type=int)
    ap.add_argument("--flash-vjp", action="store_true", default=None)
    ap.add_argument("--bf16-tiles", action="store_true", default=None)
    ap.add_argument("--ring-cache", action="store_true", default=None)
    ap.add_argument("--shard-grads", action="store_true", default=None)
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false", default=None)
    args = ap.parse_args(argv)

    out_dir = pathlib.Path(args.out)
    mapping = {
        "microbatches": args.microbatches,
        "remat": args.remat,
        "seq_shard": args.seq_shard,
        "opt_state_dtype": args.opt_dtype,
        "attn_chunk_kv": args.attn_chunk_kv,
        "xent_chunk": args.xent_chunk,
        "mamba_chunk": args.mamba_chunk,
        "flash_vjp": args.flash_vjp,
        "attn_bf16_tiles": args.bf16_tiles,
        "local_ring_cache": args.ring_cache,
        "shard_grads": args.shard_grads,
        "fsdp": args.fsdp,
    }
    rc_overrides = {k: v for k, v in mapping.items() if v is not None}

    if args.all:
        failures = sweep(all_cells(), ("single", "multi"), out_dir, args.jobs, args.force)
        sys.exit(1 if failures else 0)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    if args.shape not in supported_shapes(resolve(args.arch).name):
        print(f"[dryrun] {args.arch} skips {args.shape} (see DESIGN.md)")
        return
    run_cell(args.arch, args.shape, args.mesh, out_dir, rc_overrides, tag=args.tag)


if __name__ == "__main__":
    main()
