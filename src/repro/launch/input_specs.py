"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) cell.

No device allocation happens here — the dry-run lowers against these
stand-ins.  The same builders, called with ``concrete=True`` RNG data via
``repro.data.pipeline``, feed the real train/serve drivers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs import ModelConfig, ShapeConfig
from ..models import model as M


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return {
            "frontend": _sds((B, cfg.frontend_len, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
    if cfg.frontend:
        return {
            "frontend": _sds((B, cfg.frontend_len, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, S - cfg.frontend_len), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
        }
    return {"tokens": _sds((B, S), jnp.int32), "labels": _sds((B, S), jnp.int32)}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return {
            "frontend": _sds((B, cfg.frontend_len, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, S), jnp.int32),
        }
    if cfg.frontend:
        return {
            "frontend": _sds((B, cfg.frontend_len, cfg.d_model), cfg.dtype),
            "tokens": _sds((B, S - cfg.frontend_len), jnp.int32),
        }
    return {"tokens": _sds((B, S), jnp.int32)}


def decode_token_specs(shape: ShapeConfig):
    return _sds((shape.global_batch, 1), jnp.int32)


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, *, ring: bool = False):
    """KV/SSM cache sized for the cell's context (decode: prefilled)."""
    return M.abstract_cache(cfg, shape.global_batch, shape.seq_len, ring=ring)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, ring: bool = False) -> dict:
    """All abstract inputs for the cell's step function.

    train  -> {"batch": ...}
    prefill-> {"batch": ..., "cache": ...}
    decode -> {"tokens": ..., "cache": ...}
    """
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape),
                "cache": cache_specs(cfg, shape, ring=ring)}
    return {"tokens": decode_token_specs(shape),
            "cache": cache_specs(cfg, shape, ring=ring)}
