"""Serving driver: batched prefill + decode loop.

``python -m repro.launch.serve --arch qwen3 --requests 4 --gen 16``

Runs a reduced config end-to-end on CPU: builds a KV/SSM cache, prefills a
batch of synthetic prompts, then decodes tokens autoregressively (greedy).
The same prefill/decode step functions are what the dry-run lowers for the
production meshes at prefill_32k / decode_32k / long_500k.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import resolve, run_config, scaled_down
from ..models import model as M
from ..runtime.steps import make_decode_step, make_prefill_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve(args.arch)
    if args.reduced:
        cfg = scaled_down(cfg, max_seq_len=args.prompt_len + args.gen + 8)
    rc = run_config(cfg.name, "decode_32k")
    rc = dataclasses.replace(
        rc, attn_chunk_kv=min(64, args.prompt_len), mamba_chunk=16,
        xent_chunk=64,
    )

    key = jax.random.key(args.seed)
    params = M.init_params(key, cfg)
    B = args.requests
    max_seq = args.prompt_len + args.gen + 8

    batch = {"tokens": jax.random.randint(key, (B, args.prompt_len), 0,
                                          cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model), jnp.float32
        ).astype(jnp.dtype(cfg.dtype))

    prefill = jax.jit(make_prefill_step(cfg, rc), donate_argnums=(1,))
    decode = jax.jit(make_decode_step(cfg, rc), donate_argnums=(1,))

    cache = M.init_cache(cfg, B, max_seq)
    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.concatenate(generated, axis=1)
    assert np.isfinite(np.asarray(logits)).all(), "non-finite logits"
    print(f"[serve] {cfg.name}: {B} requests, prompt {args.prompt_len}, "
          f"generated {gen.shape[1]} tokens/req")
    print(f"[serve] prefill {t_prefill*1e3:.0f} ms; decode "
          f"{t_decode / max(args.gen - 1, 1) * 1e3:.1f} ms/token")
    print(f"[serve] sample token ids: {gen[0][:12].tolist()}")
    return gen


if __name__ == "__main__":
    main()
