"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module does not touch JAX device state — smoke tests see 1 CPU device,
while the dry-run sets ``xla_force_host_platform_device_count=512`` before
its first JAX import and gets the full meshes.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 names explicit/auto axis types; 0.4.x has neither
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:  # pragma: no cover - exercised on jax 0.4.x only
    _AXIS_KW = lambda n: {}  # noqa: E731


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (v5e pod), or 2x16x16 = 512 chips for 2 pods.

    Axes: ``data`` (DP + FSDP shard axis), ``model`` (TP/EP), and ``pod``
    (outer DP + FSDP axis) when multi-pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (tests / elastic re-meshing)."""
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def single_device_mesh():
    return make_mesh((1, 1), ("data", "model"))
