"""The evaluator core: IR, tracing frontend, Eq. (1)-(4) metrics,
fusion search, the (hw x grouping) sweep flow, and the planning service.
See docs/ARCHITECTURE.md for how the pieces compose."""
