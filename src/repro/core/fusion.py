"""Layer-fusion grouping search.

The grouping space over an L-layer chain is the 2^(L-1) set of cut vectors.
Three strategies, all returning cut vectors compatible with
:mod:`repro.core.metrics`:

* ``enumerate_cuts``      — full enumeration (the paper's predefined-set sweep;
  fine for VGG-16's 13-18 layers).
* ``pool boundary cuts``  — the paper's Sec. III policy (via
  ``NetworkIR.pool_boundary_cuts``).
* ``optimal_cuts_dp``     — O(L^2) chain-partition DP.  Valid because Eq. (1)
  decomposes over groups (weights are grouping-independent; each group
  contributes in_first + out_last), and latency & energy are affine in the
  same per-group quantity, so one DP minimises all three simultaneously;
  buffer feasibility is a per-group predicate.  Tests cross-check DP ==
  brute force on random chains.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .arch import DLAConfig
from .ir import NetworkIR
from . import metrics as M

MAX_EXHAUSTIVE_LAYERS = 21  # 2^20 cut vectors ~ 1M candidates


def enumerate_cuts(n_layers: int) -> np.ndarray:
    """All 2^(L-1) cut vectors, shape (C, L-1), dtype bool."""
    ncuts = n_layers - 1
    if n_layers > MAX_EXHAUSTIVE_LAYERS:
        raise ValueError(
            f"{n_layers} layers -> 2^{ncuts} groupings; use optimal_cuts_dp"
        )
    if ncuts == 0:
        return np.zeros((1, 0), dtype=bool)
    idx = np.arange(2**ncuts, dtype=np.int64)
    bits = (idx[:, None] >> np.arange(ncuts)[None, :]) & 1
    return bits.astype(bool)


def cuts_from_groups(groups: list[list[int]], n_layers: int) -> np.ndarray:
    """Inverse of :func:`repro.core.metrics.groups_from_cuts`."""
    cuts = np.zeros(n_layers - 1, dtype=bool)
    pos = 0
    for g in groups[:-1]:
        pos += len(g)
        cuts[pos - 1] = True
    return cuts


def layer_by_layer_cuts(n_layers: int) -> np.ndarray:
    return np.ones(n_layers - 1, dtype=bool)


def group_max_intermediate(feat: np.ndarray, cuts: np.ndarray) -> float:
    """Largest on-chip intermediate frame implied by the grouping (words)."""
    end = np.concatenate([cuts, [True]])
    inter = np.where(end, 0.0, feat[:, M.F_OUT])
    return float(inter.max(initial=0.0))


def buffer_feasible(feat: np.ndarray, cuts: np.ndarray, sram_budget_words: float) -> bool:
    return group_max_intermediate(feat, cuts) <= sram_budget_words


def feasible_mask_batch(
    feat: np.ndarray, cuts_batch: np.ndarray, sram_budget_words: float
) -> np.ndarray:
    """(C,) bool — vectorised buffer feasibility for a batch of groupings."""
    end = np.concatenate(
        [cuts_batch, np.ones((cuts_batch.shape[0], 1), dtype=bool)], axis=1
    )
    inter = np.where(end, 0.0, feat[None, :, M.F_OUT])
    return inter.max(axis=1) <= sram_budget_words


@dataclasses.dataclass(frozen=True)
class DPResult:
    cuts: np.ndarray
    group_cost_words: float  # sum over groups of (in_first + out_last)
    n_groups: int


def optimal_cuts_dp(
    ir: NetworkIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """Min-bandwidth grouping via chain-partition DP (also min latency/energy).

    dp[j] = min cost of partitioning layers [0..j]; a group [i..j] is feasible
    iff every internal intermediate out_words fits the SRAM budget and the
    group length is within ``max_group_len``.
    """
    feat = ir.feature_matrix()
    L = feat.shape[0]
    ins = feat[:, M.F_IN]
    outs = feat[:, M.F_OUT]
    INF = float("inf")
    dp = np.full(L + 1, INF)
    back = np.full(L + 1, -1, dtype=np.int64)
    dp[0] = 0.0
    for j in range(1, L + 1):  # dp index: first j layers
        max_inter = 0.0
        lo = 0 if max_group_len is None else max(0, j - max_group_len)
        # iterate group starts i (0-based layer index) from j-1 down to lo
        for i in range(j - 1, lo - 1, -1):
            # group = layers [i .. j-1]; internal intermediates are outputs of
            # layers i .. j-2
            if i < j - 1:
                max_inter = max(max_inter, outs[i])
            if max_inter > sram_budget_words:
                break  # growing the group further only increases max_inter
            cost = dp[i] + ins[i] + outs[j - 1]
            if cost < dp[j]:
                dp[j] = cost
                back[j] = i
    if not np.isfinite(dp[L]):
        raise ValueError("no feasible grouping under the SRAM budget")
    # Reconstruct groups.
    bounds = []
    j = L
    while j > 0:
        bounds.append((back[j], j))
        j = back[j]
    bounds.reverse()
    groups = [list(range(i, j)) for i, j in bounds]
    cuts = cuts_from_groups(groups, L)
    return DPResult(cuts=cuts, group_cost_words=float(dp[L]), n_groups=len(groups))


def brute_force_min_bw(
    ir: NetworkIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """Exhaustive min-bandwidth grouping (test oracle for the DP)."""
    feat = ir.feature_matrix()
    L = feat.shape[0]
    best_cost, best_cuts, best_groups = float("inf"), None, 0
    for cuts in enumerate_cuts(L):
        if not buffer_feasible(feat, cuts, sram_budget_words):
            continue
        groups = M.groups_from_cuts(cuts)
        if max_group_len is not None and any(len(g) > max_group_len for g in groups):
            continue
        start, end = M.group_masks(cuts)
        cost = float(feat[start, M.F_IN].sum() + feat[end, M.F_OUT].sum())
        if cost < best_cost:
            best_cost, best_cuts, best_groups = cost, cuts, len(groups)
    if best_cuts is None:
        raise ValueError("no feasible grouping under the SRAM budget")
    return DPResult(cuts=best_cuts, group_cost_words=best_cost, n_groups=best_groups)
