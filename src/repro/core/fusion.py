"""Layer-fusion grouping search over chains and DAGs.

The grouping space over an L-layer *chain* is the 2^(L-1) set of cut
vectors; over a general DAG it is the set of *valid* edge-cut vectors: the
uncut edges must induce groups that are weakly connected (automatic — a
group is a connected component of the uncut subgraph), **consistent**
(every cut edge actually crosses two different groups) and **convex** (no
dataflow may leave a group and re-enter it; equivalently the quotient graph
obtained by contracting every group is acyclic).

Every step of the search runs as a *batched array program* over (C, E) cut
batches — there is no per-candidate Python on any search path:

* component labelling  — min-label propagation + pointer jumping over the
  whole batch (:func:`repro.core.ir.uncut_component_labels_batch`);
* validity             — batched consistency + vectorised Kahn peeling of
  the quotient graphs (:func:`is_valid_cuts_batch`);
* buffer feasibility   — incidence-matrix segment sums/maxes over
  ``F_OUT_PRE`` and internal incoming edge words
  (:func:`graph_max_intermediate_batch`);
* cost                 — batched Eq. (1) bandwidth
  (:func:`repro.core.metrics.bandwidth_batch_graph`), plus an O(degree)
  incremental bandwidth delta for greedy merging.

The scalar functions (``is_valid_cuts``, ``graph_max_intermediate``,
``bandwidth_ref``, the ``_*_scalar`` search variants) are kept as the
oracles; tests assert the batched kernels match them bit-for-bit, and
``benchmarks/bench_search.py`` measures the speedup against them.

Strategies, all returning cut vectors compatible with
:mod:`repro.core.metrics`:

* ``enumerate_cuts`` / ``enumerate_valid_edge_cuts`` — full enumeration as
  a chunked masked pipeline (the paper's predefined-set sweep; chains up to
  2^20 vectors, DAGs up to ``MAX_EXHAUSTIVE_EDGES`` = 22 edges).
* ``pool boundary cuts``  — the paper's Sec. III policy (via
  ``GraphIR.pool_boundary_cuts``).
* ``optimal_cuts_dp``     — O(L^2) chain-partition DP.  Valid because Eq. (1)
  decomposes over groups (weights are grouping-independent; each group
  contributes in_first + out_last), and latency & energy are affine in the
  same per-group quantity, so one DP minimises all three simultaneously;
  buffer feasibility is a per-group predicate.  Tests cross-check DP ==
  brute force on random chains.
* ``frontier_dp_min_bw``   — exact frontier-state DP for general DAGs: a
  topological sweep whose states are keyed by the open-group membership,
  paid-write flags, and quotient-reachability closure of the *frontier*
  (processed nodes with pending out-edges), with dominance pruning and a
  branch-and-bound lower bound.  Scales with the DAG's frontier width
  instead of 2^E — bit-identical minima to brute force, at ResNet-18 scale
  (2^38 patterns) in milliseconds.  See the section comment above it.
* ``greedy_merge_cuts`` / ``beam_merge_cuts`` — bottom-up group merging for
  general DAGs (bandwidth is monotone non-increasing under a valid merge,
  so merging is the natural move; the SRAM budget and convexity are what
  make the problem non-trivial).  Each round expands the whole frontier
  into one (M, E) cut batch, dedups it against every previously seen
  canonical label state, and scores it with one batched validity /
  feasibility / bandwidth pass.  Cross-checked against brute force on
  random DAGs in tests.
* ``optimal_cuts`` — dispatch: chain DP fast path, frontier DP (exact, up
  to a frontier-width cap), exhaustive enumeration for small-but-wide
  DAGs, beam search only for large-and-wide ones; results carry ``engine``
  provenance so callers can tell certified optima from heuristics.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import numpy as np

from .ir import (
    GraphIR,
    NetworkIR,
    as_graph,
    canonicalize_labels_batch,
    min_width_topo_order,
    quotient_acyclic_batch,
    scc_labels,
    topo_frontier_sets,
    topo_frontier_width,
    uncut_component_labels,
    _min_label_reps_batch,
)
from . import metrics as M
from .errors import InfeasibleBudgetError, SearchDeclined

MAX_EXHAUSTIVE_LAYERS = 21  # 2^20 cut vectors ~ 1M candidates (vectorised)
# DAG enumeration is a chunked masked array pipeline (batch labelling + Kahn
# peeling), so its cap is within striking distance of the chain cap.
MAX_EXHAUSTIVE_EDGES = 22
# Rows per chunk of the enumeration pipeline — bounds peak memory at
# ~chunk x L for the label/peeling intermediates.
ENUM_CHUNK_ROWS = 1 << 17
# Below this many bit patterns the per-pattern scalar filter beats the
# batched pipeline's cold setup (graph-array build + batch labelling +
# vectorised peeling all cost ~1 ms flat; the scalar filter is ~20 us per
# pattern), so tiny graphs dispatch straight to the preserved scalar path
# — BENCH_search.json showed 0.22x/0.4x *cold* "speedups" on the
# 16-candidate residual block before this threshold existed.
SMALL_ENUM_PATTERNS = 64
# Frontier-DP caps: beyond this frontier width (or live-state count) the
# exact DP abandons the attempt and `optimal_cuts` falls back to beam
# search.  Real network DAGs are narrow (ResNet-18: 2, encoder-decoder: 3);
# the caps only trip on adversarially dense random graphs.
FRONTIER_DP_MAX_WIDTH = 12
FRONTIER_DP_MAX_STATES = 1 << 17


class FrontierTooWide(SearchDeclined):
    """Raised by :func:`frontier_dp_min_bw` when the frontier width or the
    live state count exceeds its caps; :func:`optimal_cuts` absorbs it and
    falls back to exhaustive enumeration (small graphs) or beam search.
    A :class:`repro.core.errors.SearchDeclined`, so service callers that
    pin the exact engine get the typed decline instead of a bare
    ``ValueError``."""


def enumerate_cuts(n_layers: int) -> np.ndarray:
    """All 2^(L-1) chain cut vectors, shape (C, L-1), dtype bool."""
    ncuts = n_layers - 1
    if n_layers > MAX_EXHAUSTIVE_LAYERS:
        raise ValueError(
            f"{n_layers} layers -> 2^{ncuts} groupings; use optimal_cuts_dp"
        )
    if ncuts == 0:
        return np.zeros((1, 0), dtype=bool)
    idx = np.arange(2**ncuts, dtype=np.int64)
    bits = (idx[:, None] >> np.arange(ncuts)[None, :]) & 1
    return bits.astype(bool)


def cuts_from_groups(groups: list[list[int]], n_layers: int) -> np.ndarray:
    """Inverse of :func:`repro.core.metrics.groups_from_cuts` (chains)."""
    cuts = np.zeros(n_layers - 1, dtype=bool)
    pos = 0
    for g in groups[:-1]:
        pos += len(g)
        cuts[pos - 1] = True
    return cuts


def layer_by_layer_cuts(n_cuts_or_graph) -> np.ndarray:
    """All-cut vector: every layer its own group.  Accepts a GraphIR (one
    entry per edge) or the legacy chain layer count (L-1 entries)."""
    if isinstance(n_cuts_or_graph, GraphIR):
        return np.ones(n_cuts_or_graph.n_edges, dtype=bool)
    return np.ones(n_cuts_or_graph - 1, dtype=bool)


# ---------------------------------------------------------------------------
# DAG cut validity — scalar oracles
# ---------------------------------------------------------------------------


def cut_group_labels(g: GraphIR, cuts: np.ndarray) -> np.ndarray:
    """(L,) group labels: connected components of the uncut subgraph,
    relabelled to consecutive ints in order of first node appearance."""
    return uncut_component_labels(len(g.nodes), g.edges, cuts)


def groups_from_labels(labels: np.ndarray) -> list[list[int]]:
    """Component labels (node -> group id) to explicit member lists."""
    groups: list[list[int]] = [[] for _ in range(int(labels.max()) + 1)]
    for i, lab in enumerate(labels):
        groups[int(lab)].append(i)
    return groups


def _quotient_is_dag(g: GraphIR, labels: np.ndarray) -> bool:
    """Convexity <=> the group-contracted graph is acyclic (every strongly
    connected component of the quotient is a singleton)."""
    n = int(labels.max()) + 1
    arcs = {
        (int(labels[e.src]), int(labels[e.dst]))
        for e in g.edges
        if labels[e.src] != labels[e.dst]
    }
    return len(set(scc_labels(n, arcs))) == n


def is_valid_cuts(g: GraphIR, cuts: np.ndarray) -> bool:
    """A cut vector is valid iff every cut edge crosses two different groups
    (consistency) and every group is convex (quotient graph acyclic).
    Weak connectivity is automatic: groups are components of uncut edges.
    On a chain every cut vector is valid.  Scalar oracle for
    :func:`is_valid_cuts_batch`."""
    cuts = np.asarray(cuts, dtype=bool)
    labels = cut_group_labels(g, cuts)
    for k, e in enumerate(g.edges):
        if cuts[k] and labels[e.src] == labels[e.dst]:
            return False  # cut edge internal to a group via another path
    return _quotient_is_dag(g, labels)


def cuts_from_labels(g: GraphIR, labels: np.ndarray) -> np.ndarray:
    """(E,) cut vector: an edge is cut iff its endpoints have different labels."""
    labels = np.asarray(labels)
    return np.asarray(
        [labels[e.src] != labels[e.dst] for e in g.edges], dtype=bool
    )


# ---------------------------------------------------------------------------
# DAG cut validity — batched kernels
# ---------------------------------------------------------------------------


def is_valid_cuts_batch(
    g: GraphIR, cuts_batch: np.ndarray, *, labels: np.ndarray | None = None
) -> np.ndarray:
    """(C,) bool — batched :func:`is_valid_cuts` with no per-candidate Python.

    Consistency is one masked comparison over the (C, E) batch; convexity is
    vectorised Kahn peeling of the quotient graphs (only the consistent rows
    are peeled).  ``labels`` may pass in precomputed component
    representatives to avoid relabelling.
    """
    ga = M.graph_arrays(g)
    cuts_batch = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    C = cuts_batch.shape[0]
    if g.is_chain or g.n_edges == 0:
        return np.ones(C, dtype=bool)
    if labels is None:
        labels = _min_label_reps_batch(len(g.nodes), ga.esrc, ga.edst, cuts_batch)
    lab_s = labels[:, ga.esrc]
    lab_d = labels[:, ga.edst]
    ok = ~np.any(cuts_batch & (lab_s == lab_d), axis=1)  # consistency
    idx = np.flatnonzero(ok)
    if idx.size:
        ok[idx] = quotient_acyclic_batch(
            len(g.nodes), ga.esrc, ga.edst, labels[idx]
        )
    return ok


def _bit_chunks(n_bits: int, chunk_rows: int) -> Iterator[np.ndarray]:
    """Yield the 2^n bit patterns (little-endian, ascending) in row chunks."""
    total = 1 << n_bits
    shifts = np.arange(n_bits)[None, :]
    for lo in range(0, total, chunk_rows):
        idx = np.arange(lo, min(lo + chunk_rows, total), dtype=np.int64)
        yield ((idx[:, None] >> shifts) & 1).astype(bool)


@functools.lru_cache(maxsize=8)
def enumerate_valid_edge_cuts(
    g: GraphIR, *, chunk_rows: int = ENUM_CHUNK_ROWS
) -> np.ndarray:
    """All valid edge-cut vectors, shape (C, E), dtype bool (read-only).

    Chains short-circuit to :func:`enumerate_cuts` (every vector is valid);
    tiny DAGs (at most ``SMALL_ENUM_PATTERNS`` bit patterns) run the
    preserved per-pattern scalar filter directly — identical output in
    identical order, without the batched pipeline's ~1 ms cold setup;
    general DAGs push the 2^E bit patterns through the batched validity
    pipeline in chunks of ``chunk_rows`` (ascending pattern order, so the
    output ordering is identical to the per-pattern scalar filter).  The
    result is memoised per graph — the optimisation flow enumerates the
    same graph many times (prefilter, sweep, brute force) — and returned
    read-only so a caller cannot poison the cache; index or copy it before
    mutating.
    """
    if g.is_chain:
        out = enumerate_cuts(len(g.nodes))
    else:
        E = g.n_edges
        if E > MAX_EXHAUSTIVE_EDGES:
            raise ValueError(
                f"{E} edges -> 2^{E} cut patterns; use beam_merge_cuts"
            )
        if E == 0:
            out = np.zeros((1, 0), dtype=bool)
        elif (1 << E) <= SMALL_ENUM_PATTERNS:
            out = _enumerate_valid_edge_cuts_scalar(g)
        else:
            out = np.concatenate(
                [
                    bits[is_valid_cuts_batch(g, bits)]
                    for bits in _bit_chunks(E, chunk_rows)
                ],
                axis=0,
            )
    out.setflags(write=False)
    return out


def _enumerate_valid_edge_cuts_scalar(g: GraphIR) -> np.ndarray:
    """The PR 1 per-pattern filter — kept as the enumeration oracle and the
    benchmark baseline (``benchmarks/bench_search.py``)."""
    if g.is_chain:
        return enumerate_cuts(len(g.nodes))
    E = g.n_edges
    if E > MAX_EXHAUSTIVE_EDGES:
        raise ValueError(f"{E} edges -> 2^{E} cut patterns; use beam_merge_cuts")
    if E == 0:
        return np.zeros((1, 0), dtype=bool)
    idx = np.arange(2**E, dtype=np.int64)
    bits = ((idx[:, None] >> np.arange(E)[None, :]) & 1).astype(bool)
    keep = [c for c in bits if is_valid_cuts(g, c)]
    return np.stack(keep)


# ---------------------------------------------------------------------------
# Buffer feasibility
# ---------------------------------------------------------------------------


def group_max_intermediate(feat: np.ndarray, cuts: np.ndarray) -> float:
    """Largest on-chip intermediate implied by a *chain* grouping (words):
    an internal producer holds its **pre-pool** frame (the inline pool only
    reduces the DRAM write-out path) and its fused consumer holds the full
    input operand.  A node's recurrent ``state_words`` carry occupies SRAM
    in every grouping, on top of any fused input it holds."""
    cuts = np.asarray(cuts, dtype=bool)
    in_term = np.where(cuts, 0.0, feat[1:, M.F_IN]) + feat[1:, M.F_STATE]
    out_term = np.where(cuts, 0.0, feat[:-1, M.F_OUT_PRE])
    held = np.maximum(in_term, out_term)
    return float(max(held.max(initial=0.0), float(feat[0, M.F_STATE])))


def graph_max_intermediate(g: GraphIR, cuts: np.ndarray) -> float:
    """Largest on-chip tensor implied by an edge-cut grouping: the max over
    (a) pre-pool frames of nodes with >= 1 fused consumer and (b) summed
    internal incoming tensors of any node (multi-input nodes hold all fused
    operands at once).  Scalar oracle for
    :func:`graph_max_intermediate_batch`."""
    cuts = np.asarray(cuts, dtype=bool)
    feat = g.node_features()
    internal_in = np.zeros(len(g.nodes))
    internal_out = np.zeros(len(g.nodes), dtype=bool)
    for k, e in enumerate(g.edges):
        if not cuts[k]:
            internal_in[e.dst] += e.words
            internal_out[e.src] = True
    need = np.where(internal_out, feat[:, M.F_OUT_PRE], 0.0)
    # A recurrent carry is held for the node's whole execution, whether or
    # not its inputs are fused — it adds to the node's on-chip term in
    # every grouping.
    in_term = internal_in + feat[:, M.F_STATE]
    return float(max(need.max(initial=0.0), in_term.max(initial=0.0)))


def graph_max_intermediate_batch(g: GraphIR, cuts_batch: np.ndarray) -> np.ndarray:
    """(C,) batched :func:`graph_max_intermediate` — segment sums/maxes via
    the cached edge incidence matrices (exact: integer-valued words)."""
    ga = M.graph_arrays(g)
    cuts = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    unc = (~cuts).astype(np.float64)
    internal_in = unc @ ga.win_dst  # (C, L) summed internal incoming words
    internal_in += ga.feat[None, :, M.F_STATE]  # carry held in every grouping
    has_internal_out = (unc @ ga.inc_src) > 0.0
    need = np.where(has_internal_out, ga.feat[None, :, M.F_OUT_PRE], 0.0)
    return np.maximum(
        need.max(axis=1, initial=0.0), internal_in.max(axis=1, initial=0.0)
    )


def graph_feasible_mask_batch(
    g: GraphIR, cuts_batch: np.ndarray, sram_budget_words: float
) -> np.ndarray:
    """(C,) bool — graph analog of :func:`feasible_mask_batch`, used by the
    search strategies and as the SRAM prefilter in
    :func:`repro.core.flow.run_flow`."""
    return graph_max_intermediate_batch(g, cuts_batch) <= sram_budget_words


def padded_max_intermediate_batch(pg, cuts_batch: np.ndarray) -> np.ndarray:
    """(C,) masked :func:`graph_max_intermediate_batch` over a
    :class:`repro.core.ir.PaddedGraph` — padded edges are neither internal
    nor cut, so the result is bit-identical to the unpadded kernel on the
    real rows (locked in tests).  The fleet prefilter scores cut batches
    already padded to the fleet's edge bucket without unpadding them."""
    cuts = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    E_b, L_b = pg.esrc.shape[0], pg.feat.shape[0]
    unc = ((~cuts) & pg.edge_mask[None, :]).astype(np.float64)
    inc_src = np.zeros((E_b, L_b))
    inc_src[np.arange(E_b)[pg.edge_mask], pg.esrc[pg.edge_mask]] = 1.0
    win_dst = np.zeros((E_b, L_b))
    win_dst[np.arange(E_b), pg.edst] = pg.ewords  # padded rows: 0 words at 0
    internal_in = unc @ win_dst  # (C, L_b) summed internal incoming words
    internal_in += pg.feat[None, :, M.F_STATE]  # padded rows: state 0, inert
    has_internal_out = (unc @ inc_src) > 0.0
    need = np.where(has_internal_out, pg.feat[None, :, M.F_OUT_PRE], 0.0)
    return np.maximum(
        need.max(axis=1, initial=0.0), internal_in.max(axis=1, initial=0.0)
    )


def padded_feasible_mask_batch(
    pg, cuts_batch: np.ndarray, sram_budget_words: float
) -> np.ndarray:
    """(C,) bool — padded-graph analog of :func:`graph_feasible_mask_batch`,
    the SRAM prefilter of :func:`repro.core.flow.run_fleet`."""
    return padded_max_intermediate_batch(pg, cuts_batch) <= sram_budget_words


def buffer_feasible(feat: np.ndarray, cuts: np.ndarray, sram_budget_words: float) -> bool:
    """Chain grouping fits the budget (scalar oracle)."""
    return group_max_intermediate(feat, cuts) <= sram_budget_words


def feasible_mask_batch(
    feat: np.ndarray, cuts_batch: np.ndarray, sram_budget_words: float
) -> np.ndarray:
    """(C,) bool — vectorised chain buffer feasibility for a batch of groupings."""
    cuts_batch = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    in_term = (
        np.where(cuts_batch, 0.0, feat[None, 1:, M.F_IN])
        + feat[None, 1:, M.F_STATE]
    )
    out_term = np.where(cuts_batch, 0.0, feat[None, :-1, M.F_OUT_PRE])
    inter = np.maximum(in_term, out_term).max(axis=1, initial=0.0)
    inter = np.maximum(inter, float(feat[0, M.F_STATE]))
    return inter <= sram_budget_words


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DPResult:
    """A grouping-search answer: cut vector, Eq. (1) group cost, and
    engine provenance (see ``exact``)."""

    cuts: np.ndarray
    group_cost_words: float  # Eq. (1) minus the grouping-independent weights
    n_groups: int
    # Which engine produced the answer ("chain_dp", "frontier_dp",
    # "exhaustive", "greedy", "beam", ...) and whether the result carries an
    # optimality guarantee — the provenance `optimal_cuts` callers use to
    # tell an exact optimum from a heuristic.
    engine: str = ""

    @property
    def exact(self) -> bool:
        """True when the engine certifies a global optimum."""
        return self.engine in ("chain_dp", "frontier_dp", "exhaustive")


def optimal_cuts_dp(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """Min-bandwidth grouping via chain-partition DP (also min latency/energy).

    dp[j] = min cost of partitioning layers [0..j]; a group [i..j] is feasible
    iff every internal intermediate pre-pool frame fits the SRAM budget and
    the group length is within ``max_group_len``.  Requires a chain.
    """
    g = as_graph(ir)
    if not g.is_chain:
        raise ValueError("optimal_cuts_dp requires a chain; use optimal_cuts")
    feat = g.node_features()
    L = feat.shape[0]
    # A group starting at layer i>0 reads its cut incoming edge's words (==
    # in_words for NetworkIR embeddings, but not for hand-built chain graphs).
    _, _, ewords = g.edge_arrays()
    ins = np.concatenate([feat[:1, M.F_IN], ewords])
    outs = feat[:, M.F_OUT]
    pre = feat[:, M.F_OUT_PRE]
    state = feat[:, M.F_STATE]
    # A recurrent carry occupies SRAM in *every* grouping — if any node's
    # state alone exceeds the budget, no partition is feasible.
    if state.max(initial=0.0) > sram_budget_words:
        raise InfeasibleBudgetError(
            "no feasible grouping under the SRAM budget: a recurrent "
            "state carry alone exceeds it",
            min_feasible_budget_words=float(state.max()),
        )
    INF = float("inf")
    dp = np.full(L + 1, INF)
    back = np.full(L + 1, -1, dtype=np.int64)
    dp[0] = 0.0
    for j in range(1, L + 1):  # dp index: first j layers
        max_inter = 0.0
        lo = 0 if max_group_len is None else max(0, j - max_group_len)
        # iterate group starts i (0-based layer index) from j-1 down to lo
        for i in range(j - 1, lo - 1, -1):
            # group = layers [i .. j-1]; fusing edge i holds both the
            # producer's pre-pool frame (OF SRAM) and the edge's words (the
            # consumer's IF operand) on chip — same bound as
            # graph_max_intermediate.
            if i < j - 1:
                # fused edge i: consumer i+1 holds the edge words plus its
                # recurrent carry (carries of cut-input nodes are covered
                # by the global precheck above)
                max_inter = max(max_inter, pre[i], ewords[i] + state[i + 1])
            if max_inter > sram_budget_words:
                break  # growing the group further only increases max_inter
            cost = dp[i] + ins[i] + outs[j - 1]
            if cost < dp[j]:
                dp[j] = cost
                back[j] = i
    if not np.isfinite(dp[L]):
        raise InfeasibleBudgetError(
            "no feasible grouping under the SRAM budget"
        )
    # Reconstruct groups.
    bounds = []
    j = L
    while j > 0:
        bounds.append((back[j], j))
        j = back[j]
    bounds.reverse()
    groups = [list(range(i, j)) for i, j in bounds]
    cuts = cuts_from_groups(groups, L)
    return DPResult(cuts=cuts, group_cost_words=float(dp[L]),
                    n_groups=len(groups), engine="chain_dp")


def _graph_cost(g: GraphIR, cuts: np.ndarray) -> float:
    """Grouping-dependent part of Eq. (1) (bandwidth minus weight streaming)."""
    return M.bandwidth_ref(g, cuts) - float(g.total_weight_words)


def _graph_cost_batch(g: GraphIR, cuts_batch: np.ndarray) -> np.ndarray:
    """(C,) batched :func:`_graph_cost` (exact: integer-valued words)."""
    return M.bandwidth_batch_graph(g, cuts_batch) - float(g.total_weight_words)


def _max_group_size_batch(labels: np.ndarray) -> np.ndarray:
    """(C,) largest group cardinality per row of a (C, L) label batch."""
    C, L = labels.shape
    rows = np.arange(C)
    cnt = np.zeros((C, L), dtype=np.int16)
    for i in range(L):
        cnt[rows, labels[:, i]] += 1
    return cnt.max(axis=1)


@functools.lru_cache(maxsize=8)
def _exhaustive_tables(g: GraphIR) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-graph (valid cuts, max intermediate, group cost) — every column
    the exhaustive search filters or ranks on, none of which depends on the
    SRAM budget, so repeated searches over the same graph reduce to a mask
    + argmin over these tables."""
    cuts_all = enumerate_valid_edge_cuts(g)
    return (
        cuts_all,
        graph_max_intermediate_batch(g, cuts_all),
        _graph_cost_batch(g, cuts_all),
    )


def brute_force_min_bw(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """Exhaustive min-bandwidth grouping over valid edge cuts.

    One masked array pipeline over the cached per-graph tables: (batched
    enumeration -> batched feasibility -> batched Eq. (1) cost) once per
    graph, then a feasibility mask + first-min argmin per call, in
    ascending pattern order — bit-identical to the scalar per-candidate
    loop it replaced (``_brute_force_min_bw_scalar``, kept as the test
    oracle and benchmark baseline).
    """
    g = as_graph(ir)
    cuts_all, max_int, costs_all = _exhaustive_tables(g)
    feas = max_int <= sram_budget_words
    if max_group_len is not None and feas.any():
        ga = M.graph_arrays(g)
        rows = np.flatnonzero(feas)
        labels = _min_label_reps_batch(
            len(g.nodes), ga.esrc, ga.edst, cuts_all[rows]
        )
        feas = feas.copy()
        feas[rows] = _max_group_size_batch(labels) <= max_group_len
    costs = np.where(feas, costs_all, np.inf)
    j = int(np.argmin(costs))  # first min == the scalar loop's strict-< scan
    if not np.isfinite(costs[j]):
        raise InfeasibleBudgetError(
            "no feasible grouping under the SRAM budget",
            min_feasible_budget_words=float(max_int.min()),
        )
    best_cuts = cuts_all[j].copy()
    n_groups = int(cut_group_labels(g, best_cuts).max()) + 1
    return DPResult(
        cuts=best_cuts, group_cost_words=float(costs[j]), n_groups=n_groups,
        engine="exhaustive",
    )


def _brute_force_min_bw_scalar(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """The PR 1 per-candidate brute force — test oracle / benchmark baseline."""
    g = as_graph(ir)
    best_cost, best_cuts, best_groups = float("inf"), None, 0
    for cuts in _enumerate_valid_edge_cuts_scalar(g):
        if graph_max_intermediate(g, cuts) > sram_budget_words:
            continue
        labels = cut_group_labels(g, cuts)
        if max_group_len is not None and any(
            len(grp) > max_group_len for grp in groups_from_labels(labels)
        ):
            continue
        cost = _graph_cost(g, cuts)
        if cost < best_cost:
            best_cost, best_cuts = cost, cuts
            best_groups = int(labels.max()) + 1
    if best_cuts is None:
        raise InfeasibleBudgetError(
            "no feasible grouping under the SRAM budget"
        )
    return DPResult(cuts=best_cuts, group_cost_words=best_cost,
                    n_groups=best_groups, engine="exhaustive_scalar")


# ---------------------------------------------------------------------------
# Frontier-state DP — exact search beyond the 2^E enumeration wall
# ---------------------------------------------------------------------------
#
# Flat enumeration scores all 2^E cut patterns, so it dies at
# MAX_EXHAUSTIVE_EDGES = 22 (ResNet-18 has 38).  But the *future* of a
# partial grouping only depends on the partition of the **frontier** — the
# already-processed nodes that still have an edge into the unprocessed
# suffix — not on how the closed part of the graph was grouped.  Sweeping
# nodes in topological order and folding every partial grouping into its
# frontier signature turns the 2^E search into a DP whose state count is
# governed by the frontier *width* (3 on ResNet-18, 4 on the
# encoder-decoder), the same structural move LoopTree makes for the
# fused-loop design space.
#
# A state signature is exactly the information the future can observe:
#
# * the open-group membership of each frontier node (canonical labels);
# * one "paid" bit per frontier node — whether its output frame write has
#   already been charged (a node's out_words is charged once, at its first
#   cut out-edge), so future cut edges know their marginal cost;
# * the transitive reachability closure among open groups (as per-group
#   bitmasks), which is what incremental convexity checking needs: a new
#   arc A -> g closes a quotient cycle iff g already reaches A, and merging
#   two open groups is legal iff neither reaches the other (a path of
#   length >= 1 would either internalise a cut edge or close a cycle).
#   Paths through *closed* groups are composed into the closure before the
#   closed group's row/column is dropped — a closed group's arc set is
#   final (all of its nodes' edges are decided), so the projection is
#   lossless.
#
# Buffer feasibility needs no state at all: graph_max_intermediate is a max
# of per-node terms, each of which is decided exactly once (a node's
# internal-input sum when its in-edges are decided; a producer's pre-pool
# frame at its first uncut out-edge), so every term is checked against the
# budget the moment it is determined.
#
# Two states with identical signatures therefore have *identical* feasible
# completions with identical future cost deltas — keeping only the cheapest
# accumulated cost per signature (dominance) is lossless, and the DP's
# minimum is bit-identical to brute force (all words are integer-valued
# float64).  On top of dominance, a branch-and-bound prune drops states
# whose accumulated cost plus an admissible remaining lower bound (the
# unconditional sink writes of the unprocessed suffix, plus the cheapest
# cut-word set any over-budget node is forced to pay; every other edge's
# best case is uncut = free) already exceeds a greedy incumbent.
#
# Transition scoring is batched through the prefix-decomposable tables of
# :func:`repro.core.metrics.graph_prefix_tables`: each step scores the
# whole (states x 2^in_degree) grid of cut/no-cut extensions with numpy
# (cut words, first-cut write charges, feasibility, bound) and only the
# surviving transitions pay the per-candidate structural update.


@dataclasses.dataclass
class _DPState:
    """One live frontier state (signature fields + accumulators)."""

    labels: tuple[int, ...]  # group id per frontier node (canonical)
    paid: int  # bitmask over frontier positions: out_words charged
    reach: tuple[int, ...]  # per group: bitmask of groups it reaches
    acc: float  # accumulated grouping-dependent words
    cuts: np.ndarray  # (E,) decisions so far (undecided = False)


def _forced_cut_words_min(words: np.ndarray, budget: float) -> float:
    """Cheapest cut-word total that brings a node's uncut incoming sum
    within the SRAM budget — the admissible per-node bound the DP's
    branch-and-bound charges for over-budget joins (in-degrees are tiny, so
    enumerating the 2^d subsets is cheaper than a knapsack)."""
    d = len(words)
    total = float(words.sum())
    if total <= budget:
        return 0.0
    if d == 0:
        return float("inf")  # a state-only over-budget node: infeasible
    bits = ((np.arange(1 << d)[:, None] >> np.arange(d)) & 1).astype(bool)
    cutw = bits @ words
    ok = (total - cutw) <= budget
    if not ok.any():  # even all-cut leaves the node over budget
        return float("inf")
    return float(cutw[ok].min())


def frontier_dp_min_bw(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_width: int | None = FRONTIER_DP_MAX_WIDTH,
    max_states: int = FRONTIER_DP_MAX_STATES,
    order: "list[int] | None" = None,
) -> DPResult:
    """Exact min-bandwidth grouping via frontier-state DP (see the section
    comment above for the state design and correctness argument).

    Returns the same minimum ``group_cost_words`` as
    :func:`brute_force_min_bw` (bit-identical: integer-valued words) on any
    graph both can handle, but scales with the DAG's frontier width instead
    of 2^E — ResNet-18's 38-edge space (2^38 patterns) solves exactly in
    milliseconds.  Ties may resolve to a different (equally optimal) cut
    vector than brute force's first-pattern rule.  Raises
    :class:`FrontierTooWide` beyond ``max_width``/``max_states`` so
    :func:`optimal_cuts` can fall back to beam search.
    """
    g = as_graph(ir)
    ga = M.graph_arrays(g)
    pt = M.graph_prefix_tables(g)
    L, E = len(g.nodes), g.n_edges
    budget = float(sram_budget_words)
    finite = np.isfinite(budget)

    if order is None:
        order = list(range(L))
        alt = min_width_topo_order(g)
        if topo_frontier_width(g, alt) < topo_frontier_width(g, order):
            order = alt
    frontiers = topo_frontier_sets(g, order)
    width = max((len(f) for f in frontiers), default=0)
    if max_width is not None and width > max_width:
        raise FrontierTooWide(
            f"frontier width {width} exceeds the DP cap {max_width}"
        )

    # Admissible remaining-cost lower bounds, as suffixes of the sweep:
    # unconditional sink writes + budget-forced cut-word minima.
    node_lb = pt.sink_charge.copy()
    if finite:
        for v in range(L):
            # the node's recurrent carry shrinks the budget its uncut
            # incoming sum must fit within
            node_lb[v] += _forced_cut_words_min(
                pt.in_words[v], budget - float(pt.state_words[v])
            )
    suffix_lb = np.zeros(L + 1)
    suffix_lb[:L] = np.cumsum(node_lb[order][::-1])[::-1]

    # Greedy incumbent for the branch-and-bound prune (always feasible:
    # greedy starts from the always-valid, zero-footprint all-cut state).
    incumbent = greedy_merge_cuts(g, sram_budget_words=budget).group_cost_words
    const0 = pt.const_words

    states: "dict[tuple, _DPState]" = {
        ((), 0, ()): _DPState((), 0, (), 0.0, np.zeros(E, dtype=bool))
    }
    for t, v in enumerate(order):
        frontier = frontiers[t - 1] if t else []
        pos_of = {u: i for i, u in enumerate(frontier)}
        ks = pt.in_edges[v]
        srcs = pt.in_srcs[v]
        w = pt.in_words[v]
        d = len(ks)
        src_pos = np.asarray([pos_of[int(u)] for u in srcs], dtype=np.int64)

        bits = ((np.arange(1 << d)[:, None] >> np.arange(d)) & 1).astype(bool)
        cutw = bits @ w if d else np.zeros(1)
        feas_p = np.ones(1 << d, dtype=bool)
        if finite:
            # v's uncut incoming sum plus its recurrent carry must fit
            # (applies even at d == 0: a state-only node can be infeasible)
            feas_p &= (
                float(w.sum()) - cutw + float(pt.state_words[v])
            ) <= budget
        if finite and d:
            # an uncut out-edge pins the producer's pre-pool frame on chip
            ok_uncut = pt.prepool_words[srcs] <= budget
            feas_p &= (bits | ok_uncut[None, :]).all(axis=1)

        state_list = list(states.values())
        accs = np.asarray([s.acc for s in state_list])
        if d:
            paid_mat = (
                np.asarray([s.paid for s in state_list])[:, None]
                >> src_pos[None, :]
            ) & 1
            first_cut = bits[None, :, :] & ~paid_mat[:, None, :].astype(bool)
            extra = first_cut @ pt.out_words[srcs]  # (S, P) write charges
        else:
            extra = np.zeros((len(state_list), 1))
        delta = cutw[None, :] + extra + float(pt.sink_charge[v])
        keep = feas_p[None, :] & (
            accs[:, None] + delta + const0 + suffix_lb[t + 1] <= incumbent
        )

        new_frontier = frontiers[t]
        new_states: "dict[tuple, _DPState]" = {}
        for si in range(len(state_list)):
            if not keep[si].any():
                continue
            st = state_list[si]
            lab, reach = st.labels, st.reach
            G = len(reach)
            for p in np.flatnonzero(keep[si]):
                cut_i = [i for i in range(d) if bits[p, i]]
                uncut_i = [i for i in range(d) if not bits[p, i]]
                Sg = {lab[src_pos[i]] for i in uncut_i}
                Sg_mask = 0
                for a in Sg:
                    Sg_mask |= 1 << a
                # merging two open groups with any path between them would
                # internalise a cut edge or close a quotient cycle
                if any(reach[a] & (Sg_mask & ~(1 << a)) for a in Sg):
                    continue
                out_new = 0
                for a in Sg:
                    out_new |= reach[a]
                A_set = {lab[src_pos[i]] for i in cut_i}
                # a cut edge from a group being merged into v's group would
                # be internal (consistency); an arc A -> g_new with
                # g_new ~> A closes a cycle (convexity)
                if any(a in Sg or (out_new >> a) & 1 for a in A_set):
                    continue

                # --- structural update: merge, add arcs, keep the closure
                gid = G  # temporary id of v's (possibly merged) group
                reach2 = list(reach) + [out_new]
                for X in range(G):
                    if X in Sg:
                        continue
                    r = reach2[X]
                    if r & Sg_mask:  # X reached a merged member
                        reach2[X] = (r & ~Sg_mask) | (1 << gid) | out_new
                add_mask = (1 << gid) | out_new
                for A in A_set:
                    for X in range(G):
                        if X in Sg:
                            continue
                        if X == A or (reach2[X] >> A) & 1:
                            reach2[X] |= add_mask

                # --- project onto the new frontier: close groups with no
                # frontier nodes, relabel canonically, remap the closure
                raw = []
                for u in new_frontier:
                    if u == v:
                        raw.append(gid)
                    else:
                        a = lab[pos_of[u]]
                        raw.append(gid if a in Sg else a)
                remap: dict[int, int] = {}
                labs_new = []
                for a in raw:
                    if a not in remap:
                        remap[a] = len(remap)
                    labs_new.append(remap[a])
                reach_new = [0] * len(remap)
                for a_old, a_new in remap.items():
                    r = reach2[a_old]
                    rr = 0
                    for b_old, b_new in remap.items():
                        if (r >> b_old) & 1:
                            rr |= 1 << b_new
                    reach_new[a_new] = rr

                newly_paid = {int(srcs[i]) for i in cut_i}
                paid_new = 0
                for j, u in enumerate(new_frontier):
                    if u == v:
                        continue
                    if (st.paid >> pos_of[u]) & 1 or u in newly_paid:
                        paid_new |= 1 << j

                sig = (tuple(labs_new), paid_new, tuple(reach_new))
                acc_new = st.acc + float(delta[si, p])
                cur = new_states.get(sig)
                if cur is None or acc_new < cur.acc:
                    cuts_new = st.cuts.copy()
                    if cut_i:
                        cuts_new[ks[cut_i]] = True
                    new_states[sig] = _DPState(
                        tuple(labs_new), paid_new, tuple(reach_new),
                        acc_new, cuts_new,
                    )
        if not new_states:
            raise InfeasibleBudgetError(
            "no feasible grouping under the SRAM budget"
        )
        if len(new_states) > max_states:
            raise FrontierTooWide(
                f"{len(new_states)} live states exceed the DP cap {max_states}"
            )
        states = new_states

    best = min(states.values(), key=lambda s: s.acc)
    labels = cut_group_labels(g, best.cuts)
    return DPResult(
        cuts=best.cuts,
        group_cost_words=const0 + best.acc,
        n_groups=int(labels.max()) + 1,
        engine="frontier_dp",
    )


@functools.lru_cache(maxsize=32)
def _frontier_dp_cached(g: GraphIR, sram_budget_words: float) -> "DPResult | None":
    """Per-(graph, budget) memo for the dispatch path: repeated searches in
    a flow/fleet are a cache hit, mirroring the `_exhaustive_tables` memo
    the enumeration path enjoys.  Callers get a fresh ``cuts`` copy.
    A :class:`FrontierTooWide` decline is memoised as ``None`` (lru_cache
    does not cache exceptions), so a too-wide graph pays the failed DP
    attempt once, not on every dispatch."""
    try:
        return frontier_dp_min_bw(g, sram_budget_words=sram_budget_words)
    except FrontierTooWide:
        return None


# ---------------------------------------------------------------------------
# Merge search (greedy / beam) — batched engine
# ---------------------------------------------------------------------------


def _merge_pairs(
    esrc: np.ndarray, edst: np.ndarray, labels: np.ndarray
) -> list[tuple[int, int]]:
    """Ordered distinct cross-group (a, b) pairs in edge order — the scalar
    ``_merge_moves`` generation order, so tie-breaking stays bit-identical."""
    la = labels[esrc]
    lb = labels[edst]
    pairs: list[tuple[int, int]] = []
    tried: set[tuple[int, int]] = set()
    for k in range(len(esrc)):
        a, b = int(la[k]), int(lb[k])
        if a == b or (a, b) in tried:
            continue
        tried.add((a, b))
        pairs.append((a, b))
    return pairs


def _merged_label_batch(
    labels: np.ndarray, pairs: list[tuple[int, int]]
) -> np.ndarray:
    """(M, L) label rows: row m relabels group ``pairs[m][1]`` to
    ``pairs[m][0]`` (one single-merge child per candidate pair)."""
    a = np.asarray([p[0] for p in pairs], dtype=labels.dtype)
    b = np.asarray([p[1] for p in pairs], dtype=labels.dtype)
    return np.where(labels[None, :] == b[:, None], a[:, None], labels[None, :])


def _valid_merge_pairs(
    ga: M.GraphArrays, labels: np.ndarray
) -> list[tuple[int, int]]:
    """The convexity-preserving subset of :func:`_merge_pairs`, in order.

    A merge of groups ``a`` and ``b`` (joined by >= 1 arc a->b of the
    current acyclic quotient) closes a cycle iff the quotient has a path
    a ~> b of length >= 2 (the cycle then runs ab -> ... -> ab; conversely
    any cycle of the merged quotient must pass through the merged node and
    lifts to such a path — a b ~> a path would already be a cycle).  The
    reachability matrix of one state's quotient is shared by all of its
    candidate moves: log2(L) boolean matrix squarings replace a Kahn peel
    per move.
    """
    la = labels[ga.esrc]
    lb = labels[ga.edst]
    pairs = _merge_pairs(ga.esrc, ga.edst, labels)
    if not pairs:
        return pairs
    L = len(labels)
    adj = np.zeros((L, L))
    cross = la != lb
    adj[la[cross], lb[cross]] = 1.0
    reach = adj.copy()
    hops = 1
    while hops < L:  # reach: paths of length in [1, 2*hops] each squaring
        reach = np.minimum(reach + reach @ reach, 1.0)
        hops *= 2
    two_plus = adj @ reach  # > 0 iff a path of length >= 2 exists
    return [p for p in pairs if two_plus[p[0], p[1]] == 0.0]


def merge_bandwidth_delta(
    g: GraphIR, labels: np.ndarray, a: int, b: int
) -> float:
    """Exact Eq. (1) bandwidth change from merging groups ``a`` and ``b``.

    Every a<->b edge stops round-tripping DRAM (its consumer read-back
    disappears), and a producer of such an edge also stops writing its
    output frame iff it is not a sink and none of its remaining out-edges
    leave the merged group.  O(boundary degree) per move — the incremental
    fast path of :func:`greedy_merge_cuts` (lock-step with
    ``bandwidth_ref`` differences, asserted in tests; exact because all
    words are integer-valued).
    """
    ga = M.graph_arrays(g)
    la = labels[ga.esrc]
    lb = labels[ga.edst]
    cross = ((la == a) & (lb == b)) | ((la == b) & (lb == a))
    ks = np.flatnonzero(cross)
    delta = -float(ga.ewords[ks].sum())
    for i in np.unique(ga.esrc[ks]):
        if ga.sink_mask[i]:
            continue  # sinks always write their output frame
        gd = lb[ga.out_edges[i]]
        if not np.any((gd != a) & (gd != b)):
            delta -= float(ga.feat[i, M.F_OUT])
    return delta


def _expand_frontier(
    g: GraphIR,
    frontier: list[tuple[float, np.ndarray]],
    sram_budget_words: float,
    seen: set[bytes],
) -> tuple[np.ndarray, np.ndarray, np.ndarray] | None:
    """One batched expansion round over the whole frontier.

    Generates every valid single-merge child of every frontier state as one
    (M, L) label batch (frontier order, then edge order — the scalar
    expansion order), dedups it against ``seen`` (all previously scored
    canonical states, within and across rounds), then runs ONE batched
    feasibility + bandwidth pass.  Returns (labels, cuts, costs) for the
    surviving children in first-occurrence order, or None if there are
    none.  Consistency holds by construction (child cuts are derived from
    labels); convexity is filtered per state by :func:`_valid_merge_pairs`.
    """
    ga = M.graph_arrays(g)
    rows = []
    for _, labels in frontier:
        pairs = _valid_merge_pairs(ga, labels)
        if pairs:
            rows.append(_merged_label_batch(labels, pairs))
    if not rows:
        return None
    merged = np.concatenate(rows, axis=0) if len(rows) > 1 else rows[0]
    keys = canonicalize_labels_batch(merged)
    fresh = []
    for i in range(merged.shape[0]):
        key = keys[i].tobytes()
        if key not in seen:
            seen.add(key)
            fresh.append(i)
    if not fresh:
        return None
    cand = merged[fresh]
    cuts = cand[:, ga.esrc] != cand[:, ga.edst]
    ok = graph_feasible_mask_batch(g, cuts, sram_budget_words)
    if not ok.any():
        return None
    cand, cuts = cand[ok], cuts[ok]
    return cand, cuts, _graph_cost_batch(g, cuts)


def greedy_merge_cuts(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    """Greedy bottom-up merging: start layer-by-layer, repeatedly apply the
    single group merge with the best bandwidth until none improves.

    Each round scores all candidate merges at once: convexity comes from
    one reachability closure of the quotient (:func:`_valid_merge_pairs`),
    feasibility from one batched pass, and costs from the O(degree)
    incremental :func:`merge_bandwidth_delta` fast path (exact, so the
    trajectory is bit-identical to the scalar rescore-everything
    implementation)."""
    g = as_graph(ir)
    ga = M.graph_arrays(g)
    labels = np.arange(len(g.nodes))
    cost = float(
        _graph_cost_batch(g, (labels[ga.esrc] != labels[ga.edst])[None, :])[0]
    )
    while True:
        pairs = _valid_merge_pairs(ga, labels)
        if not pairs:
            break
        merged = _merged_label_batch(labels, pairs)
        cuts = merged[:, ga.esrc] != merged[:, ga.edst]
        ok = graph_feasible_mask_batch(g, cuts, sram_budget_words)
        if not ok.any():
            break
        deltas = np.asarray(
            [
                merge_bandwidth_delta(g, labels, a, b) if o else np.inf
                for (a, b), o in zip(pairs, ok)
            ]
        )
        j = int(np.argmin(deltas))
        if deltas[j] >= 0.0:
            break
        cost, labels = cost + float(deltas[j]), merged[j]
    labels = cut_group_labels(g, cuts_from_labels(g, labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=cost,
        n_groups=int(labels.max()) + 1,
        engine="greedy",
    )


def beam_merge_cuts(
    ir: NetworkIR | GraphIR,
    *,
    beam_width: int = 32,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    """Beam search over merge sequences (greedy with ``beam_width`` frontier
    states).  Keeps the best state ever visited, so it can only improve on
    :func:`greedy_merge_cuts` for the same width >= 1.

    Every round expands the whole frontier into one (M, E) cut batch scored
    by a single batched validity/feasibility/bandwidth pass, and dedups the
    children against every canonical label state already scored — a state
    reached by two merge orders is expanded once, not once per path.  (With
    single-merge moves the group count drops by one per round, so the dedup
    only ever fires within a round; keeping the ``seen`` set across rounds
    makes that invariant explicit and guards any future move type that
    could revisit a partition.)"""
    g = as_graph(ir)
    ga = M.graph_arrays(g)
    start = np.arange(len(g.nodes))
    start_cost = float(
        _graph_cost_batch(g, (start[ga.esrc] != start[ga.edst])[None, :])[0]
    )
    frontier: list[tuple[float, np.ndarray]] = [(start_cost, start)]
    best_cost, best_labels = start_cost, start
    seen: set[bytes] = {canonicalize_labels_batch(start[None, :])[0].tobytes()}
    while frontier:
        expanded = _expand_frontier(g, frontier, sram_budget_words, seen)
        if expanded is None:
            break
        cand, _, costs = expanded
        order = np.argsort(costs, kind="stable")[:beam_width]
        frontier = [(float(costs[o]), cand[o]) for o in order]
        if costs[order[0]] < best_cost:
            best_cost, best_labels = float(costs[order[0]]), cand[order[0]]
    labels = cut_group_labels(g, cuts_from_labels(g, best_labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=best_cost,
        n_groups=int(labels.max()) + 1,
        engine="beam",
    )


# ---------------------------------------------------------------------------
# Merge search — the PR 1 scalar implementations (oracles / bench baseline)
# ---------------------------------------------------------------------------


def _merge_moves(
    g: GraphIR, labels: np.ndarray, sram_budget_words: float
) -> list[tuple[float, np.ndarray]]:
    """All valid, feasible single merges from ``labels`` as (cost, labels)."""
    moves = []
    tried: set[tuple[int, int]] = set()
    for e in g.edges:
        a, b = int(labels[e.src]), int(labels[e.dst])
        if a == b or (a, b) in tried:
            continue
        tried.add((a, b))
        merged = np.where(labels == b, a, labels)
        cuts = cuts_from_labels(g, merged)
        if not _quotient_is_dag(g, merged):
            continue  # merge would make a group non-convex
        if graph_max_intermediate(g, cuts) > sram_budget_words:
            continue
        moves.append((_graph_cost(g, cuts), merged))
    return moves


def _greedy_merge_cuts_scalar(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    g = as_graph(ir)
    labels = np.arange(len(g.nodes))
    cost = _graph_cost(g, cuts_from_labels(g, labels))
    while True:
        moves = _merge_moves(g, labels, sram_budget_words)
        if not moves:
            break
        best_cost, best_labels = min(moves, key=lambda m: m[0])
        if best_cost >= cost:
            break
        cost, labels = best_cost, best_labels
    labels = cut_group_labels(g, cuts_from_labels(g, labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=cost,
        n_groups=int(labels.max()) + 1,
        engine="greedy_scalar",
    )


def _beam_merge_cuts_scalar(
    ir: NetworkIR | GraphIR,
    *,
    beam_width: int = 32,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    g = as_graph(ir)
    start = np.arange(len(g.nodes))
    start_cost = _graph_cost(g, cuts_from_labels(g, start))
    frontier: list[tuple[float, np.ndarray]] = [(start_cost, start)]
    best_cost, best_labels = start_cost, start
    while frontier:
        candidates: dict[tuple[int, ...], tuple[float, np.ndarray]] = {}
        for cost, labels in frontier:
            for mc, ml in _merge_moves(g, labels, sram_budget_words):
                key = tuple(cut_group_labels(g, cuts_from_labels(g, ml)))
                if key not in candidates or mc < candidates[key][0]:
                    candidates[key] = (mc, ml)
        if not candidates:
            break
        ranked = sorted(candidates.values(), key=lambda m: m[0])
        frontier = ranked[:beam_width]
        if ranked[0][0] < best_cost:
            best_cost, best_labels = ranked[0]
    labels = cut_group_labels(g, cuts_from_labels(g, best_labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=best_cost,
        n_groups=int(labels.max()) + 1,
        engine="beam_scalar",
    )


def optimal_cuts(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    beam_width: int = 32,
) -> DPResult:
    """Grouping search dispatch: chain DP fast path; frontier-state DP for
    general DAGs (exact at any edge count, up to a frontier-width cap —
    ResNet-18's 2^38 space included); when the DAG is too wide for the DP,
    small graphs keep their certified optimum via exhaustive enumeration
    and only large-and-wide graphs fall back to beam merge.  The returned
    :class:`DPResult` carries ``engine`` provenance ("chain_dp" /
    "frontier_dp" / "exhaustive" / "beam") and ``exact`` so callers can
    tell a certified optimum from a heuristic answer."""
    g = as_graph(ir)
    if g.is_chain:
        return optimal_cuts_dp(g, sram_budget_words=sram_budget_words)
    res = _frontier_dp_cached(g, float(sram_budget_words))
    if res is not None:
        return dataclasses.replace(res, cuts=res.cuts.copy())
    if (
        g.n_edges <= MAX_EXHAUSTIVE_EDGES
        and len(g.nodes) <= MAX_EXHAUSTIVE_LAYERS
    ):
        return brute_force_min_bw(g, sram_budget_words=sram_budget_words)
    return beam_merge_cuts(
        g, beam_width=beam_width, sram_budget_words=sram_budget_words
    )
