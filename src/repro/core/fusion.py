"""Layer-fusion grouping search over chains and DAGs.

The grouping space over an L-layer *chain* is the 2^(L-1) set of cut
vectors; over a general DAG it is the set of *valid* edge-cut vectors: the
uncut edges must induce groups that are weakly connected (automatic — a
group is a connected component of the uncut subgraph), **consistent**
(every cut edge actually crosses two different groups) and **convex** (no
dataflow may leave a group and re-enter it; equivalently the quotient graph
obtained by contracting every group is acyclic).

Every step of the search runs as a *batched array program* over (C, E) cut
batches — there is no per-candidate Python on any search path:

* component labelling  — min-label propagation + pointer jumping over the
  whole batch (:func:`repro.core.ir.uncut_component_labels_batch`);
* validity             — batched consistency + vectorised Kahn peeling of
  the quotient graphs (:func:`is_valid_cuts_batch`);
* buffer feasibility   — incidence-matrix segment sums/maxes over
  ``F_OUT_PRE`` and internal incoming edge words
  (:func:`graph_max_intermediate_batch`);
* cost                 — batched Eq. (1) bandwidth
  (:func:`repro.core.metrics.bandwidth_batch_graph`), plus an O(degree)
  incremental bandwidth delta for greedy merging.

The scalar functions (``is_valid_cuts``, ``graph_max_intermediate``,
``bandwidth_ref``, the ``_*_scalar`` search variants) are kept as the
oracles; tests assert the batched kernels match them bit-for-bit, and
``benchmarks/bench_search.py`` measures the speedup against them.

Strategies, all returning cut vectors compatible with
:mod:`repro.core.metrics`:

* ``enumerate_cuts`` / ``enumerate_valid_edge_cuts`` — full enumeration as
  a chunked masked pipeline (the paper's predefined-set sweep; chains up to
  2^20 vectors, DAGs up to ``MAX_EXHAUSTIVE_EDGES`` = 22 edges).
* ``pool boundary cuts``  — the paper's Sec. III policy (via
  ``GraphIR.pool_boundary_cuts``).
* ``optimal_cuts_dp``     — O(L^2) chain-partition DP.  Valid because Eq. (1)
  decomposes over groups (weights are grouping-independent; each group
  contributes in_first + out_last), and latency & energy are affine in the
  same per-group quantity, so one DP minimises all three simultaneously;
  buffer feasibility is a per-group predicate.  Tests cross-check DP ==
  brute force on random chains.
* ``greedy_merge_cuts`` / ``beam_merge_cuts`` — bottom-up group merging for
  general DAGs (bandwidth is monotone non-increasing under a valid merge,
  so merging is the natural move; the SRAM budget and convexity are what
  make the problem non-trivial).  Each round expands the whole frontier
  into one (M, E) cut batch, dedups it against every previously seen
  canonical label state, and scores it with one batched validity /
  feasibility / bandwidth pass.  Cross-checked against brute force on
  random DAGs in tests.
* ``optimal_cuts`` — dispatch: chain DP fast path, exhaustive enumeration
  for small DAGs, beam search otherwise.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterator

import numpy as np

from .ir import (
    GraphIR,
    NetworkIR,
    as_graph,
    canonicalize_labels_batch,
    quotient_acyclic_batch,
    scc_labels,
    uncut_component_labels,
    _min_label_reps_batch,
)
from . import metrics as M

MAX_EXHAUSTIVE_LAYERS = 21  # 2^20 cut vectors ~ 1M candidates (vectorised)
# DAG enumeration is a chunked masked array pipeline (batch labelling + Kahn
# peeling), so its cap is within striking distance of the chain cap.
MAX_EXHAUSTIVE_EDGES = 22
# Rows per chunk of the enumeration pipeline — bounds peak memory at
# ~chunk x L for the label/peeling intermediates.
ENUM_CHUNK_ROWS = 1 << 17


def enumerate_cuts(n_layers: int) -> np.ndarray:
    """All 2^(L-1) chain cut vectors, shape (C, L-1), dtype bool."""
    ncuts = n_layers - 1
    if n_layers > MAX_EXHAUSTIVE_LAYERS:
        raise ValueError(
            f"{n_layers} layers -> 2^{ncuts} groupings; use optimal_cuts_dp"
        )
    if ncuts == 0:
        return np.zeros((1, 0), dtype=bool)
    idx = np.arange(2**ncuts, dtype=np.int64)
    bits = (idx[:, None] >> np.arange(ncuts)[None, :]) & 1
    return bits.astype(bool)


def cuts_from_groups(groups: list[list[int]], n_layers: int) -> np.ndarray:
    """Inverse of :func:`repro.core.metrics.groups_from_cuts` (chains)."""
    cuts = np.zeros(n_layers - 1, dtype=bool)
    pos = 0
    for g in groups[:-1]:
        pos += len(g)
        cuts[pos - 1] = True
    return cuts


def layer_by_layer_cuts(n_cuts_or_graph) -> np.ndarray:
    """All-cut vector: every layer its own group.  Accepts a GraphIR (one
    entry per edge) or the legacy chain layer count (L-1 entries)."""
    if isinstance(n_cuts_or_graph, GraphIR):
        return np.ones(n_cuts_or_graph.n_edges, dtype=bool)
    return np.ones(n_cuts_or_graph - 1, dtype=bool)


# ---------------------------------------------------------------------------
# DAG cut validity — scalar oracles
# ---------------------------------------------------------------------------


def cut_group_labels(g: GraphIR, cuts: np.ndarray) -> np.ndarray:
    """(L,) group labels: connected components of the uncut subgraph,
    relabelled to consecutive ints in order of first node appearance."""
    return uncut_component_labels(len(g.nodes), g.edges, cuts)


def groups_from_labels(labels: np.ndarray) -> list[list[int]]:
    groups: list[list[int]] = [[] for _ in range(int(labels.max()) + 1)]
    for i, lab in enumerate(labels):
        groups[int(lab)].append(i)
    return groups


def _quotient_is_dag(g: GraphIR, labels: np.ndarray) -> bool:
    """Convexity <=> the group-contracted graph is acyclic (every strongly
    connected component of the quotient is a singleton)."""
    n = int(labels.max()) + 1
    arcs = {
        (int(labels[e.src]), int(labels[e.dst]))
        for e in g.edges
        if labels[e.src] != labels[e.dst]
    }
    return len(set(scc_labels(n, arcs))) == n


def is_valid_cuts(g: GraphIR, cuts: np.ndarray) -> bool:
    """A cut vector is valid iff every cut edge crosses two different groups
    (consistency) and every group is convex (quotient graph acyclic).
    Weak connectivity is automatic: groups are components of uncut edges.
    On a chain every cut vector is valid.  Scalar oracle for
    :func:`is_valid_cuts_batch`."""
    cuts = np.asarray(cuts, dtype=bool)
    labels = cut_group_labels(g, cuts)
    for k, e in enumerate(g.edges):
        if cuts[k] and labels[e.src] == labels[e.dst]:
            return False  # cut edge internal to a group via another path
    return _quotient_is_dag(g, labels)


def cuts_from_labels(g: GraphIR, labels: np.ndarray) -> np.ndarray:
    """(E,) cut vector: an edge is cut iff its endpoints have different labels."""
    labels = np.asarray(labels)
    return np.asarray(
        [labels[e.src] != labels[e.dst] for e in g.edges], dtype=bool
    )


# ---------------------------------------------------------------------------
# DAG cut validity — batched kernels
# ---------------------------------------------------------------------------


def is_valid_cuts_batch(
    g: GraphIR, cuts_batch: np.ndarray, *, labels: np.ndarray | None = None
) -> np.ndarray:
    """(C,) bool — batched :func:`is_valid_cuts` with no per-candidate Python.

    Consistency is one masked comparison over the (C, E) batch; convexity is
    vectorised Kahn peeling of the quotient graphs (only the consistent rows
    are peeled).  ``labels`` may pass in precomputed component
    representatives to avoid relabelling.
    """
    ga = M.graph_arrays(g)
    cuts_batch = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    C = cuts_batch.shape[0]
    if g.is_chain or g.n_edges == 0:
        return np.ones(C, dtype=bool)
    if labels is None:
        labels = _min_label_reps_batch(len(g.nodes), ga.esrc, ga.edst, cuts_batch)
    lab_s = labels[:, ga.esrc]
    lab_d = labels[:, ga.edst]
    ok = ~np.any(cuts_batch & (lab_s == lab_d), axis=1)  # consistency
    idx = np.flatnonzero(ok)
    if idx.size:
        ok[idx] = quotient_acyclic_batch(
            len(g.nodes), ga.esrc, ga.edst, labels[idx]
        )
    return ok


def _bit_chunks(n_bits: int, chunk_rows: int) -> Iterator[np.ndarray]:
    """Yield the 2^n bit patterns (little-endian, ascending) in row chunks."""
    total = 1 << n_bits
    shifts = np.arange(n_bits)[None, :]
    for lo in range(0, total, chunk_rows):
        idx = np.arange(lo, min(lo + chunk_rows, total), dtype=np.int64)
        yield ((idx[:, None] >> shifts) & 1).astype(bool)


@functools.lru_cache(maxsize=8)
def enumerate_valid_edge_cuts(
    g: GraphIR, *, chunk_rows: int = ENUM_CHUNK_ROWS
) -> np.ndarray:
    """All valid edge-cut vectors, shape (C, E), dtype bool (read-only).

    Chains short-circuit to :func:`enumerate_cuts` (every vector is valid);
    general DAGs push the 2^E bit patterns through the batched validity
    pipeline in chunks of ``chunk_rows`` (ascending pattern order, so the
    output ordering is identical to the per-pattern scalar filter).  The
    result is memoised per graph — the optimisation flow enumerates the
    same graph many times (prefilter, sweep, brute force) — and returned
    read-only so a caller cannot poison the cache; index or copy it before
    mutating.
    """
    if g.is_chain:
        out = enumerate_cuts(len(g.nodes))
    else:
        E = g.n_edges
        if E > MAX_EXHAUSTIVE_EDGES:
            raise ValueError(
                f"{E} edges -> 2^{E} cut patterns; use beam_merge_cuts"
            )
        if E == 0:
            out = np.zeros((1, 0), dtype=bool)
        else:
            out = np.concatenate(
                [
                    bits[is_valid_cuts_batch(g, bits)]
                    for bits in _bit_chunks(E, chunk_rows)
                ],
                axis=0,
            )
    out.setflags(write=False)
    return out


def _enumerate_valid_edge_cuts_scalar(g: GraphIR) -> np.ndarray:
    """The PR 1 per-pattern filter — kept as the enumeration oracle and the
    benchmark baseline (``benchmarks/bench_search.py``)."""
    if g.is_chain:
        return enumerate_cuts(len(g.nodes))
    E = g.n_edges
    if E > MAX_EXHAUSTIVE_EDGES:
        raise ValueError(f"{E} edges -> 2^{E} cut patterns; use beam_merge_cuts")
    if E == 0:
        return np.zeros((1, 0), dtype=bool)
    idx = np.arange(2**E, dtype=np.int64)
    bits = ((idx[:, None] >> np.arange(E)[None, :]) & 1).astype(bool)
    keep = [c for c in bits if is_valid_cuts(g, c)]
    return np.stack(keep)


# ---------------------------------------------------------------------------
# Buffer feasibility
# ---------------------------------------------------------------------------


def group_max_intermediate(feat: np.ndarray, cuts: np.ndarray) -> float:
    """Largest on-chip intermediate implied by a *chain* grouping (words):
    an internal producer holds its **pre-pool** frame (the inline pool only
    reduces the DRAM write-out path) and its fused consumer holds the full
    input operand."""
    end = np.concatenate([cuts, [True]])
    held = np.maximum(feat[:-1, M.F_OUT_PRE], feat[1:, M.F_IN])
    inter = np.where(end[:-1], 0.0, held)
    return float(inter.max(initial=0.0))


def graph_max_intermediate(g: GraphIR, cuts: np.ndarray) -> float:
    """Largest on-chip tensor implied by an edge-cut grouping: the max over
    (a) pre-pool frames of nodes with >= 1 fused consumer and (b) summed
    internal incoming tensors of any node (multi-input nodes hold all fused
    operands at once).  Scalar oracle for
    :func:`graph_max_intermediate_batch`."""
    cuts = np.asarray(cuts, dtype=bool)
    feat = g.node_features()
    internal_in = np.zeros(len(g.nodes))
    internal_out = np.zeros(len(g.nodes), dtype=bool)
    for k, e in enumerate(g.edges):
        if not cuts[k]:
            internal_in[e.dst] += e.words
            internal_out[e.src] = True
    need = np.where(internal_out, feat[:, M.F_OUT_PRE], 0.0)
    return float(max(need.max(initial=0.0), internal_in.max(initial=0.0)))


def graph_max_intermediate_batch(g: GraphIR, cuts_batch: np.ndarray) -> np.ndarray:
    """(C,) batched :func:`graph_max_intermediate` — segment sums/maxes via
    the cached edge incidence matrices (exact: integer-valued words)."""
    ga = M.graph_arrays(g)
    cuts = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    unc = (~cuts).astype(np.float64)
    internal_in = unc @ ga.win_dst  # (C, L) summed internal incoming words
    has_internal_out = (unc @ ga.inc_src) > 0.0
    need = np.where(has_internal_out, ga.feat[None, :, M.F_OUT_PRE], 0.0)
    return np.maximum(
        need.max(axis=1, initial=0.0), internal_in.max(axis=1, initial=0.0)
    )


def graph_feasible_mask_batch(
    g: GraphIR, cuts_batch: np.ndarray, sram_budget_words: float
) -> np.ndarray:
    """(C,) bool — graph analog of :func:`feasible_mask_batch`, used by the
    search strategies and as the SRAM prefilter in
    :func:`repro.core.flow.run_flow`."""
    return graph_max_intermediate_batch(g, cuts_batch) <= sram_budget_words


def padded_max_intermediate_batch(pg, cuts_batch: np.ndarray) -> np.ndarray:
    """(C,) masked :func:`graph_max_intermediate_batch` over a
    :class:`repro.core.ir.PaddedGraph` — padded edges are neither internal
    nor cut, so the result is bit-identical to the unpadded kernel on the
    real rows (locked in tests).  The fleet prefilter scores cut batches
    already padded to the fleet's edge bucket without unpadding them."""
    cuts = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    E_b, L_b = pg.esrc.shape[0], pg.feat.shape[0]
    unc = ((~cuts) & pg.edge_mask[None, :]).astype(np.float64)
    inc_src = np.zeros((E_b, L_b))
    inc_src[np.arange(E_b)[pg.edge_mask], pg.esrc[pg.edge_mask]] = 1.0
    win_dst = np.zeros((E_b, L_b))
    win_dst[np.arange(E_b), pg.edst] = pg.ewords  # padded rows: 0 words at 0
    internal_in = unc @ win_dst  # (C, L_b) summed internal incoming words
    has_internal_out = (unc @ inc_src) > 0.0
    need = np.where(has_internal_out, pg.feat[None, :, M.F_OUT_PRE], 0.0)
    return np.maximum(
        need.max(axis=1, initial=0.0), internal_in.max(axis=1, initial=0.0)
    )


def padded_feasible_mask_batch(
    pg, cuts_batch: np.ndarray, sram_budget_words: float
) -> np.ndarray:
    """(C,) bool — padded-graph analog of :func:`graph_feasible_mask_batch`,
    the SRAM prefilter of :func:`repro.core.flow.run_fleet`."""
    return padded_max_intermediate_batch(pg, cuts_batch) <= sram_budget_words


def buffer_feasible(feat: np.ndarray, cuts: np.ndarray, sram_budget_words: float) -> bool:
    return group_max_intermediate(feat, cuts) <= sram_budget_words


def feasible_mask_batch(
    feat: np.ndarray, cuts_batch: np.ndarray, sram_budget_words: float
) -> np.ndarray:
    """(C,) bool — vectorised chain buffer feasibility for a batch of groupings."""
    held = np.maximum(feat[:-1, M.F_OUT_PRE], feat[1:, M.F_IN])
    inter = np.where(cuts_batch, 0.0, held[None, :])
    return inter.max(axis=1, initial=0.0) <= sram_budget_words


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DPResult:
    cuts: np.ndarray
    group_cost_words: float  # Eq. (1) minus the grouping-independent weights
    n_groups: int


def optimal_cuts_dp(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """Min-bandwidth grouping via chain-partition DP (also min latency/energy).

    dp[j] = min cost of partitioning layers [0..j]; a group [i..j] is feasible
    iff every internal intermediate pre-pool frame fits the SRAM budget and
    the group length is within ``max_group_len``.  Requires a chain.
    """
    g = as_graph(ir)
    if not g.is_chain:
        raise ValueError("optimal_cuts_dp requires a chain; use optimal_cuts")
    feat = g.node_features()
    L = feat.shape[0]
    # A group starting at layer i>0 reads its cut incoming edge's words (==
    # in_words for NetworkIR embeddings, but not for hand-built chain graphs).
    _, _, ewords = g.edge_arrays()
    ins = np.concatenate([feat[:1, M.F_IN], ewords])
    outs = feat[:, M.F_OUT]
    pre = feat[:, M.F_OUT_PRE]
    INF = float("inf")
    dp = np.full(L + 1, INF)
    back = np.full(L + 1, -1, dtype=np.int64)
    dp[0] = 0.0
    for j in range(1, L + 1):  # dp index: first j layers
        max_inter = 0.0
        lo = 0 if max_group_len is None else max(0, j - max_group_len)
        # iterate group starts i (0-based layer index) from j-1 down to lo
        for i in range(j - 1, lo - 1, -1):
            # group = layers [i .. j-1]; fusing edge i holds both the
            # producer's pre-pool frame (OF SRAM) and the edge's words (the
            # consumer's IF operand) on chip — same bound as
            # graph_max_intermediate.
            if i < j - 1:
                max_inter = max(max_inter, pre[i], ewords[i])
            if max_inter > sram_budget_words:
                break  # growing the group further only increases max_inter
            cost = dp[i] + ins[i] + outs[j - 1]
            if cost < dp[j]:
                dp[j] = cost
                back[j] = i
    if not np.isfinite(dp[L]):
        raise ValueError("no feasible grouping under the SRAM budget")
    # Reconstruct groups.
    bounds = []
    j = L
    while j > 0:
        bounds.append((back[j], j))
        j = back[j]
    bounds.reverse()
    groups = [list(range(i, j)) for i, j in bounds]
    cuts = cuts_from_groups(groups, L)
    return DPResult(cuts=cuts, group_cost_words=float(dp[L]), n_groups=len(groups))


def _graph_cost(g: GraphIR, cuts: np.ndarray) -> float:
    """Grouping-dependent part of Eq. (1) (bandwidth minus weight streaming)."""
    return M.bandwidth_ref(g, cuts) - float(g.total_weight_words)


def _graph_cost_batch(g: GraphIR, cuts_batch: np.ndarray) -> np.ndarray:
    """(C,) batched :func:`_graph_cost` (exact: integer-valued words)."""
    return M.bandwidth_batch_graph(g, cuts_batch) - float(g.total_weight_words)


def _max_group_size_batch(labels: np.ndarray) -> np.ndarray:
    """(C,) largest group cardinality per row of a (C, L) label batch."""
    C, L = labels.shape
    rows = np.arange(C)
    cnt = np.zeros((C, L), dtype=np.int16)
    for i in range(L):
        cnt[rows, labels[:, i]] += 1
    return cnt.max(axis=1)


@functools.lru_cache(maxsize=8)
def _exhaustive_tables(g: GraphIR) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-graph (valid cuts, max intermediate, group cost) — every column
    the exhaustive search filters or ranks on, none of which depends on the
    SRAM budget, so repeated searches over the same graph reduce to a mask
    + argmin over these tables."""
    cuts_all = enumerate_valid_edge_cuts(g)
    return (
        cuts_all,
        graph_max_intermediate_batch(g, cuts_all),
        _graph_cost_batch(g, cuts_all),
    )


def brute_force_min_bw(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """Exhaustive min-bandwidth grouping over valid edge cuts.

    One masked array pipeline over the cached per-graph tables: (batched
    enumeration -> batched feasibility -> batched Eq. (1) cost) once per
    graph, then a feasibility mask + first-min argmin per call, in
    ascending pattern order — bit-identical to the scalar per-candidate
    loop it replaced (``_brute_force_min_bw_scalar``, kept as the test
    oracle and benchmark baseline).
    """
    g = as_graph(ir)
    cuts_all, max_int, costs_all = _exhaustive_tables(g)
    feas = max_int <= sram_budget_words
    if max_group_len is not None and feas.any():
        ga = M.graph_arrays(g)
        rows = np.flatnonzero(feas)
        labels = _min_label_reps_batch(
            len(g.nodes), ga.esrc, ga.edst, cuts_all[rows]
        )
        feas = feas.copy()
        feas[rows] = _max_group_size_batch(labels) <= max_group_len
    costs = np.where(feas, costs_all, np.inf)
    j = int(np.argmin(costs))  # first min == the scalar loop's strict-< scan
    if not np.isfinite(costs[j]):
        raise ValueError("no feasible grouping under the SRAM budget")
    best_cuts = cuts_all[j].copy()
    n_groups = int(cut_group_labels(g, best_cuts).max()) + 1
    return DPResult(
        cuts=best_cuts, group_cost_words=float(costs[j]), n_groups=n_groups
    )


def _brute_force_min_bw_scalar(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """The PR 1 per-candidate brute force — test oracle / benchmark baseline."""
    g = as_graph(ir)
    best_cost, best_cuts, best_groups = float("inf"), None, 0
    for cuts in _enumerate_valid_edge_cuts_scalar(g):
        if graph_max_intermediate(g, cuts) > sram_budget_words:
            continue
        labels = cut_group_labels(g, cuts)
        if max_group_len is not None and any(
            len(grp) > max_group_len for grp in groups_from_labels(labels)
        ):
            continue
        cost = _graph_cost(g, cuts)
        if cost < best_cost:
            best_cost, best_cuts = cost, cuts
            best_groups = int(labels.max()) + 1
    if best_cuts is None:
        raise ValueError("no feasible grouping under the SRAM budget")
    return DPResult(cuts=best_cuts, group_cost_words=best_cost, n_groups=best_groups)


# ---------------------------------------------------------------------------
# Merge search (greedy / beam) — batched engine
# ---------------------------------------------------------------------------


def _merge_pairs(
    esrc: np.ndarray, edst: np.ndarray, labels: np.ndarray
) -> list[tuple[int, int]]:
    """Ordered distinct cross-group (a, b) pairs in edge order — the scalar
    ``_merge_moves`` generation order, so tie-breaking stays bit-identical."""
    la = labels[esrc]
    lb = labels[edst]
    pairs: list[tuple[int, int]] = []
    tried: set[tuple[int, int]] = set()
    for k in range(len(esrc)):
        a, b = int(la[k]), int(lb[k])
        if a == b or (a, b) in tried:
            continue
        tried.add((a, b))
        pairs.append((a, b))
    return pairs


def _merged_label_batch(
    labels: np.ndarray, pairs: list[tuple[int, int]]
) -> np.ndarray:
    """(M, L) label rows: row m relabels group ``pairs[m][1]`` to
    ``pairs[m][0]`` (one single-merge child per candidate pair)."""
    a = np.asarray([p[0] for p in pairs], dtype=labels.dtype)
    b = np.asarray([p[1] for p in pairs], dtype=labels.dtype)
    return np.where(labels[None, :] == b[:, None], a[:, None], labels[None, :])


def _valid_merge_pairs(
    ga: M.GraphArrays, labels: np.ndarray
) -> list[tuple[int, int]]:
    """The convexity-preserving subset of :func:`_merge_pairs`, in order.

    A merge of groups ``a`` and ``b`` (joined by >= 1 arc a->b of the
    current acyclic quotient) closes a cycle iff the quotient has a path
    a ~> b of length >= 2 (the cycle then runs ab -> ... -> ab; conversely
    any cycle of the merged quotient must pass through the merged node and
    lifts to such a path — a b ~> a path would already be a cycle).  The
    reachability matrix of one state's quotient is shared by all of its
    candidate moves: log2(L) boolean matrix squarings replace a Kahn peel
    per move.
    """
    la = labels[ga.esrc]
    lb = labels[ga.edst]
    pairs = _merge_pairs(ga.esrc, ga.edst, labels)
    if not pairs:
        return pairs
    L = len(labels)
    adj = np.zeros((L, L))
    cross = la != lb
    adj[la[cross], lb[cross]] = 1.0
    reach = adj.copy()
    hops = 1
    while hops < L:  # reach: paths of length in [1, 2*hops] each squaring
        reach = np.minimum(reach + reach @ reach, 1.0)
        hops *= 2
    two_plus = adj @ reach  # > 0 iff a path of length >= 2 exists
    return [p for p in pairs if two_plus[p[0], p[1]] == 0.0]


def merge_bandwidth_delta(
    g: GraphIR, labels: np.ndarray, a: int, b: int
) -> float:
    """Exact Eq. (1) bandwidth change from merging groups ``a`` and ``b``.

    Every a<->b edge stops round-tripping DRAM (its consumer read-back
    disappears), and a producer of such an edge also stops writing its
    output frame iff it is not a sink and none of its remaining out-edges
    leave the merged group.  O(boundary degree) per move — the incremental
    fast path of :func:`greedy_merge_cuts` (lock-step with
    ``bandwidth_ref`` differences, asserted in tests; exact because all
    words are integer-valued).
    """
    ga = M.graph_arrays(g)
    la = labels[ga.esrc]
    lb = labels[ga.edst]
    cross = ((la == a) & (lb == b)) | ((la == b) & (lb == a))
    ks = np.flatnonzero(cross)
    delta = -float(ga.ewords[ks].sum())
    for i in np.unique(ga.esrc[ks]):
        if ga.sink_mask[i]:
            continue  # sinks always write their output frame
        gd = lb[ga.out_edges[i]]
        if not np.any((gd != a) & (gd != b)):
            delta -= float(ga.feat[i, M.F_OUT])
    return delta


def _expand_frontier(
    g: GraphIR,
    frontier: list[tuple[float, np.ndarray]],
    sram_budget_words: float,
    seen: set[bytes],
) -> tuple[np.ndarray, np.ndarray, np.ndarray] | None:
    """One batched expansion round over the whole frontier.

    Generates every valid single-merge child of every frontier state as one
    (M, L) label batch (frontier order, then edge order — the scalar
    expansion order), dedups it against ``seen`` (all previously scored
    canonical states, within and across rounds), then runs ONE batched
    feasibility + bandwidth pass.  Returns (labels, cuts, costs) for the
    surviving children in first-occurrence order, or None if there are
    none.  Consistency holds by construction (child cuts are derived from
    labels); convexity is filtered per state by :func:`_valid_merge_pairs`.
    """
    ga = M.graph_arrays(g)
    rows = []
    for _, labels in frontier:
        pairs = _valid_merge_pairs(ga, labels)
        if pairs:
            rows.append(_merged_label_batch(labels, pairs))
    if not rows:
        return None
    merged = np.concatenate(rows, axis=0) if len(rows) > 1 else rows[0]
    keys = canonicalize_labels_batch(merged)
    fresh = []
    for i in range(merged.shape[0]):
        key = keys[i].tobytes()
        if key not in seen:
            seen.add(key)
            fresh.append(i)
    if not fresh:
        return None
    cand = merged[fresh]
    cuts = cand[:, ga.esrc] != cand[:, ga.edst]
    ok = graph_feasible_mask_batch(g, cuts, sram_budget_words)
    if not ok.any():
        return None
    cand, cuts = cand[ok], cuts[ok]
    return cand, cuts, _graph_cost_batch(g, cuts)


def greedy_merge_cuts(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    """Greedy bottom-up merging: start layer-by-layer, repeatedly apply the
    single group merge with the best bandwidth until none improves.

    Each round scores all candidate merges at once: convexity comes from
    one reachability closure of the quotient (:func:`_valid_merge_pairs`),
    feasibility from one batched pass, and costs from the O(degree)
    incremental :func:`merge_bandwidth_delta` fast path (exact, so the
    trajectory is bit-identical to the scalar rescore-everything
    implementation)."""
    g = as_graph(ir)
    ga = M.graph_arrays(g)
    labels = np.arange(len(g.nodes))
    cost = float(
        _graph_cost_batch(g, (labels[ga.esrc] != labels[ga.edst])[None, :])[0]
    )
    while True:
        pairs = _valid_merge_pairs(ga, labels)
        if not pairs:
            break
        merged = _merged_label_batch(labels, pairs)
        cuts = merged[:, ga.esrc] != merged[:, ga.edst]
        ok = graph_feasible_mask_batch(g, cuts, sram_budget_words)
        if not ok.any():
            break
        deltas = np.asarray(
            [
                merge_bandwidth_delta(g, labels, a, b) if o else np.inf
                for (a, b), o in zip(pairs, ok)
            ]
        )
        j = int(np.argmin(deltas))
        if deltas[j] >= 0.0:
            break
        cost, labels = cost + float(deltas[j]), merged[j]
    labels = cut_group_labels(g, cuts_from_labels(g, labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=cost,
        n_groups=int(labels.max()) + 1,
    )


def beam_merge_cuts(
    ir: NetworkIR | GraphIR,
    *,
    beam_width: int = 32,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    """Beam search over merge sequences (greedy with ``beam_width`` frontier
    states).  Keeps the best state ever visited, so it can only improve on
    :func:`greedy_merge_cuts` for the same width >= 1.

    Every round expands the whole frontier into one (M, E) cut batch scored
    by a single batched validity/feasibility/bandwidth pass, and dedups the
    children against every canonical label state already scored — a state
    reached by two merge orders is expanded once, not once per path.  (With
    single-merge moves the group count drops by one per round, so the dedup
    only ever fires within a round; keeping the ``seen`` set across rounds
    makes that invariant explicit and guards any future move type that
    could revisit a partition.)"""
    g = as_graph(ir)
    ga = M.graph_arrays(g)
    start = np.arange(len(g.nodes))
    start_cost = float(
        _graph_cost_batch(g, (start[ga.esrc] != start[ga.edst])[None, :])[0]
    )
    frontier: list[tuple[float, np.ndarray]] = [(start_cost, start)]
    best_cost, best_labels = start_cost, start
    seen: set[bytes] = {canonicalize_labels_batch(start[None, :])[0].tobytes()}
    while frontier:
        expanded = _expand_frontier(g, frontier, sram_budget_words, seen)
        if expanded is None:
            break
        cand, _, costs = expanded
        order = np.argsort(costs, kind="stable")[:beam_width]
        frontier = [(float(costs[o]), cand[o]) for o in order]
        if costs[order[0]] < best_cost:
            best_cost, best_labels = float(costs[order[0]]), cand[order[0]]
    labels = cut_group_labels(g, cuts_from_labels(g, best_labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=best_cost,
        n_groups=int(labels.max()) + 1,
    )


# ---------------------------------------------------------------------------
# Merge search — the PR 1 scalar implementations (oracles / bench baseline)
# ---------------------------------------------------------------------------


def _merge_moves(
    g: GraphIR, labels: np.ndarray, sram_budget_words: float
) -> list[tuple[float, np.ndarray]]:
    """All valid, feasible single merges from ``labels`` as (cost, labels)."""
    moves = []
    tried: set[tuple[int, int]] = set()
    for e in g.edges:
        a, b = int(labels[e.src]), int(labels[e.dst])
        if a == b or (a, b) in tried:
            continue
        tried.add((a, b))
        merged = np.where(labels == b, a, labels)
        cuts = cuts_from_labels(g, merged)
        if not _quotient_is_dag(g, merged):
            continue  # merge would make a group non-convex
        if graph_max_intermediate(g, cuts) > sram_budget_words:
            continue
        moves.append((_graph_cost(g, cuts), merged))
    return moves


def _greedy_merge_cuts_scalar(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    g = as_graph(ir)
    labels = np.arange(len(g.nodes))
    cost = _graph_cost(g, cuts_from_labels(g, labels))
    while True:
        moves = _merge_moves(g, labels, sram_budget_words)
        if not moves:
            break
        best_cost, best_labels = min(moves, key=lambda m: m[0])
        if best_cost >= cost:
            break
        cost, labels = best_cost, best_labels
    labels = cut_group_labels(g, cuts_from_labels(g, labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=cost,
        n_groups=int(labels.max()) + 1,
    )


def _beam_merge_cuts_scalar(
    ir: NetworkIR | GraphIR,
    *,
    beam_width: int = 32,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    g = as_graph(ir)
    start = np.arange(len(g.nodes))
    start_cost = _graph_cost(g, cuts_from_labels(g, start))
    frontier: list[tuple[float, np.ndarray]] = [(start_cost, start)]
    best_cost, best_labels = start_cost, start
    while frontier:
        candidates: dict[tuple[int, ...], tuple[float, np.ndarray]] = {}
        for cost, labels in frontier:
            for mc, ml in _merge_moves(g, labels, sram_budget_words):
                key = tuple(cut_group_labels(g, cuts_from_labels(g, ml)))
                if key not in candidates or mc < candidates[key][0]:
                    candidates[key] = (mc, ml)
        if not candidates:
            break
        ranked = sorted(candidates.values(), key=lambda m: m[0])
        frontier = ranked[:beam_width]
        if ranked[0][0] < best_cost:
            best_cost, best_labels = ranked[0]
    labels = cut_group_labels(g, cuts_from_labels(g, best_labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=best_cost,
        n_groups=int(labels.max()) + 1,
    )


def optimal_cuts(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    beam_width: int = 32,
) -> DPResult:
    """Grouping search dispatch: chain DP fast path; exhaustive enumeration
    for small DAGs (up to ``MAX_EXHAUSTIVE_EDGES`` = 22 edges, batched);
    beam merge otherwise."""
    g = as_graph(ir)
    if g.is_chain:
        return optimal_cuts_dp(g, sram_budget_words=sram_budget_words)
    if g.n_edges <= MAX_EXHAUSTIVE_EDGES and len(g.nodes) <= MAX_EXHAUSTIVE_LAYERS:
        return brute_force_min_bw(g, sram_budget_words=sram_budget_words)
    return beam_merge_cuts(
        g, beam_width=beam_width, sram_budget_words=sram_budget_words
    )
