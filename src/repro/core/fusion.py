"""Layer-fusion grouping search over chains and DAGs.

The grouping space over an L-layer *chain* is the 2^(L-1) set of cut
vectors; over a general DAG it is the set of *valid* edge-cut vectors: the
uncut edges must induce groups that are weakly connected (automatic — a
group is a connected component of the uncut subgraph), **consistent**
(every cut edge actually crosses two different groups) and **convex** (no
dataflow may leave a group and re-enter it; equivalently the quotient graph
obtained by contracting every group is acyclic).

Strategies, all returning cut vectors compatible with
:mod:`repro.core.metrics`:

* ``enumerate_cuts`` / ``enumerate_valid_edge_cuts`` — full enumeration
  (the paper's predefined-set sweep; fine for VGG-16's 13-18 layers and for
  DAGs of <= 16 edges).
* ``pool boundary cuts``  — the paper's Sec. III policy (via
  ``GraphIR.pool_boundary_cuts``).
* ``optimal_cuts_dp``     — O(L^2) chain-partition DP.  Valid because Eq. (1)
  decomposes over groups (weights are grouping-independent; each group
  contributes in_first + out_last), and latency & energy are affine in the
  same per-group quantity, so one DP minimises all three simultaneously;
  buffer feasibility is a per-group predicate.  Tests cross-check DP ==
  brute force on random chains.
* ``greedy_merge_cuts`` / ``beam_merge_cuts`` — bottom-up group merging for
  general DAGs (bandwidth is monotone non-increasing under a valid merge,
  so merging is the natural move; the SRAM budget and convexity are what
  make the problem non-trivial).  Cross-checked against brute force on
  random DAGs in tests.
* ``optimal_cuts`` — dispatch: chain DP fast path, exhaustive enumeration
  for small DAGs, beam search otherwise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .ir import GraphIR, NetworkIR, as_graph, scc_labels, uncut_component_labels
from . import metrics as M

MAX_EXHAUSTIVE_LAYERS = 21  # 2^20 cut vectors ~ 1M candidates (vectorised)
# DAG enumeration runs a per-pattern Python validity check, so its cap is
# much lower than the chain cap (2^16 ~ a few seconds; beam covers the rest).
MAX_EXHAUSTIVE_EDGES = 16


def enumerate_cuts(n_layers: int) -> np.ndarray:
    """All 2^(L-1) chain cut vectors, shape (C, L-1), dtype bool."""
    ncuts = n_layers - 1
    if n_layers > MAX_EXHAUSTIVE_LAYERS:
        raise ValueError(
            f"{n_layers} layers -> 2^{ncuts} groupings; use optimal_cuts_dp"
        )
    if ncuts == 0:
        return np.zeros((1, 0), dtype=bool)
    idx = np.arange(2**ncuts, dtype=np.int64)
    bits = (idx[:, None] >> np.arange(ncuts)[None, :]) & 1
    return bits.astype(bool)


def cuts_from_groups(groups: list[list[int]], n_layers: int) -> np.ndarray:
    """Inverse of :func:`repro.core.metrics.groups_from_cuts` (chains)."""
    cuts = np.zeros(n_layers - 1, dtype=bool)
    pos = 0
    for g in groups[:-1]:
        pos += len(g)
        cuts[pos - 1] = True
    return cuts


def layer_by_layer_cuts(n_cuts_or_graph) -> np.ndarray:
    """All-cut vector: every layer its own group.  Accepts a GraphIR (one
    entry per edge) or the legacy chain layer count (L-1 entries)."""
    if isinstance(n_cuts_or_graph, GraphIR):
        return np.ones(n_cuts_or_graph.n_edges, dtype=bool)
    return np.ones(n_cuts_or_graph - 1, dtype=bool)


# ---------------------------------------------------------------------------
# DAG cut validity
# ---------------------------------------------------------------------------


def cut_group_labels(g: GraphIR, cuts: np.ndarray) -> np.ndarray:
    """(L,) group labels: connected components of the uncut subgraph,
    relabelled to consecutive ints in order of first node appearance."""
    return uncut_component_labels(len(g.nodes), g.edges, cuts)


def groups_from_labels(labels: np.ndarray) -> list[list[int]]:
    groups: list[list[int]] = [[] for _ in range(int(labels.max()) + 1)]
    for i, lab in enumerate(labels):
        groups[int(lab)].append(i)
    return groups


def _quotient_is_dag(g: GraphIR, labels: np.ndarray) -> bool:
    """Convexity <=> the group-contracted graph is acyclic (every strongly
    connected component of the quotient is a singleton)."""
    n = int(labels.max()) + 1
    arcs = {
        (int(labels[e.src]), int(labels[e.dst]))
        for e in g.edges
        if labels[e.src] != labels[e.dst]
    }
    return len(set(scc_labels(n, arcs))) == n


def is_valid_cuts(g: GraphIR, cuts: np.ndarray) -> bool:
    """A cut vector is valid iff every cut edge crosses two different groups
    (consistency) and every group is convex (quotient graph acyclic).
    Weak connectivity is automatic: groups are components of uncut edges.
    On a chain every cut vector is valid."""
    cuts = np.asarray(cuts, dtype=bool)
    labels = cut_group_labels(g, cuts)
    for k, e in enumerate(g.edges):
        if cuts[k] and labels[e.src] == labels[e.dst]:
            return False  # cut edge internal to a group via another path
    return _quotient_is_dag(g, labels)


def cuts_from_labels(g: GraphIR, labels: np.ndarray) -> np.ndarray:
    """(E,) cut vector: an edge is cut iff its endpoints have different labels."""
    labels = np.asarray(labels)
    return np.asarray(
        [labels[e.src] != labels[e.dst] for e in g.edges], dtype=bool
    )


def enumerate_valid_edge_cuts(g: GraphIR) -> np.ndarray:
    """All valid edge-cut vectors, shape (C, E), dtype bool.

    Chains short-circuit to :func:`enumerate_cuts` (every vector is valid);
    general DAGs filter the 2^E bit patterns through :func:`is_valid_cuts`.
    """
    if g.is_chain:
        return enumerate_cuts(len(g.nodes))
    E = g.n_edges
    if E > MAX_EXHAUSTIVE_EDGES:
        raise ValueError(
            f"{E} edges -> 2^{E} cut patterns; use beam_merge_cuts"
        )
    if E == 0:
        return np.zeros((1, 0), dtype=bool)
    idx = np.arange(2**E, dtype=np.int64)
    bits = ((idx[:, None] >> np.arange(E)[None, :]) & 1).astype(bool)
    keep = [c for c in bits if is_valid_cuts(g, c)]
    return np.stack(keep)


# ---------------------------------------------------------------------------
# Buffer feasibility
# ---------------------------------------------------------------------------


def group_max_intermediate(feat: np.ndarray, cuts: np.ndarray) -> float:
    """Largest on-chip intermediate implied by a *chain* grouping (words):
    an internal producer holds its **pre-pool** frame (the inline pool only
    reduces the DRAM write-out path) and its fused consumer holds the full
    input operand."""
    end = np.concatenate([cuts, [True]])
    held = np.maximum(feat[:-1, M.F_OUT_PRE], feat[1:, M.F_IN])
    inter = np.where(end[:-1], 0.0, held)
    return float(inter.max(initial=0.0))


def graph_max_intermediate(g: GraphIR, cuts: np.ndarray) -> float:
    """Largest on-chip tensor implied by an edge-cut grouping: the max over
    (a) pre-pool frames of nodes with >= 1 fused consumer and (b) summed
    internal incoming tensors of any node (multi-input nodes hold all fused
    operands at once)."""
    cuts = np.asarray(cuts, dtype=bool)
    feat = g.node_features()
    internal_in = np.zeros(len(g.nodes))
    internal_out = np.zeros(len(g.nodes), dtype=bool)
    for k, e in enumerate(g.edges):
        if not cuts[k]:
            internal_in[e.dst] += e.words
            internal_out[e.src] = True
    need = np.where(internal_out, feat[:, M.F_OUT_PRE], 0.0)
    return float(max(need.max(initial=0.0), internal_in.max(initial=0.0)))


def buffer_feasible(feat: np.ndarray, cuts: np.ndarray, sram_budget_words: float) -> bool:
    return group_max_intermediate(feat, cuts) <= sram_budget_words


def feasible_mask_batch(
    feat: np.ndarray, cuts_batch: np.ndarray, sram_budget_words: float
) -> np.ndarray:
    """(C,) bool — vectorised chain buffer feasibility for a batch of groupings."""
    held = np.maximum(feat[:-1, M.F_OUT_PRE], feat[1:, M.F_IN])
    inter = np.where(cuts_batch, 0.0, held[None, :])
    return inter.max(axis=1, initial=0.0) <= sram_budget_words


# ---------------------------------------------------------------------------
# Search
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DPResult:
    cuts: np.ndarray
    group_cost_words: float  # Eq. (1) minus the grouping-independent weights
    n_groups: int


def optimal_cuts_dp(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """Min-bandwidth grouping via chain-partition DP (also min latency/energy).

    dp[j] = min cost of partitioning layers [0..j]; a group [i..j] is feasible
    iff every internal intermediate pre-pool frame fits the SRAM budget and
    the group length is within ``max_group_len``.  Requires a chain.
    """
    g = as_graph(ir)
    if not g.is_chain:
        raise ValueError("optimal_cuts_dp requires a chain; use optimal_cuts")
    feat = g.node_features()
    L = feat.shape[0]
    # A group starting at layer i>0 reads its cut incoming edge's words (==
    # in_words for NetworkIR embeddings, but not for hand-built chain graphs).
    _, _, ewords = g.edge_arrays()
    ins = np.concatenate([feat[:1, M.F_IN], ewords])
    outs = feat[:, M.F_OUT]
    pre = feat[:, M.F_OUT_PRE]
    INF = float("inf")
    dp = np.full(L + 1, INF)
    back = np.full(L + 1, -1, dtype=np.int64)
    dp[0] = 0.0
    for j in range(1, L + 1):  # dp index: first j layers
        max_inter = 0.0
        lo = 0 if max_group_len is None else max(0, j - max_group_len)
        # iterate group starts i (0-based layer index) from j-1 down to lo
        for i in range(j - 1, lo - 1, -1):
            # group = layers [i .. j-1]; fusing edge i holds both the
            # producer's pre-pool frame (OF SRAM) and the edge's words (the
            # consumer's IF operand) on chip — same bound as
            # graph_max_intermediate.
            if i < j - 1:
                max_inter = max(max_inter, pre[i], ewords[i])
            if max_inter > sram_budget_words:
                break  # growing the group further only increases max_inter
            cost = dp[i] + ins[i] + outs[j - 1]
            if cost < dp[j]:
                dp[j] = cost
                back[j] = i
    if not np.isfinite(dp[L]):
        raise ValueError("no feasible grouping under the SRAM budget")
    # Reconstruct groups.
    bounds = []
    j = L
    while j > 0:
        bounds.append((back[j], j))
        j = back[j]
    bounds.reverse()
    groups = [list(range(i, j)) for i, j in bounds]
    cuts = cuts_from_groups(groups, L)
    return DPResult(cuts=cuts, group_cost_words=float(dp[L]), n_groups=len(groups))


def _graph_cost(g: GraphIR, cuts: np.ndarray) -> float:
    """Grouping-dependent part of Eq. (1) (bandwidth minus weight streaming)."""
    return M.bandwidth_ref(g, cuts) - float(g.total_weight_words)


def brute_force_min_bw(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    max_group_len: int | None = None,
) -> DPResult:
    """Exhaustive min-bandwidth grouping over valid edge cuts (test oracle
    for the DP and for the greedy/beam DAG searches)."""
    g = as_graph(ir)
    best_cost, best_cuts, best_groups = float("inf"), None, 0
    for cuts in enumerate_valid_edge_cuts(g):
        if graph_max_intermediate(g, cuts) > sram_budget_words:
            continue
        labels = cut_group_labels(g, cuts)
        if max_group_len is not None and any(
            len(grp) > max_group_len for grp in groups_from_labels(labels)
        ):
            continue
        cost = _graph_cost(g, cuts)
        if cost < best_cost:
            best_cost, best_cuts = cost, cuts
            best_groups = int(labels.max()) + 1
    if best_cuts is None:
        raise ValueError("no feasible grouping under the SRAM budget")
    return DPResult(cuts=best_cuts, group_cost_words=best_cost, n_groups=best_groups)


def _merge_moves(
    g: GraphIR, labels: np.ndarray, sram_budget_words: float
) -> list[tuple[float, np.ndarray]]:
    """All valid, feasible single merges from ``labels`` as (cost, labels)."""
    moves = []
    tried: set[tuple[int, int]] = set()
    for e in g.edges:
        a, b = int(labels[e.src]), int(labels[e.dst])
        if a == b or (a, b) in tried:
            continue
        tried.add((a, b))
        merged = np.where(labels == b, a, labels)
        cuts = cuts_from_labels(g, merged)
        if not _quotient_is_dag(g, merged):
            continue  # merge would make a group non-convex
        if graph_max_intermediate(g, cuts) > sram_budget_words:
            continue
        moves.append((_graph_cost(g, cuts), merged))
    return moves


def greedy_merge_cuts(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    """Greedy bottom-up merging: start layer-by-layer, repeatedly apply the
    single group merge with the best bandwidth until none improves."""
    g = as_graph(ir)
    labels = np.arange(len(g.nodes))
    cost = _graph_cost(g, cuts_from_labels(g, labels))
    while True:
        moves = _merge_moves(g, labels, sram_budget_words)
        if not moves:
            break
        best_cost, best_labels = min(moves, key=lambda m: m[0])
        if best_cost >= cost:
            break
        cost, labels = best_cost, best_labels
    labels = cut_group_labels(g, cuts_from_labels(g, labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=cost,
        n_groups=int(labels.max()) + 1,
    )


def beam_merge_cuts(
    ir: NetworkIR | GraphIR,
    *,
    beam_width: int = 32,
    sram_budget_words: float = float("inf"),
) -> DPResult:
    """Beam search over merge sequences (greedy with ``beam_width`` frontier
    states).  Keeps the best state ever visited, so it can only improve on
    :func:`greedy_merge_cuts` for the same width >= 1."""
    g = as_graph(ir)
    start = np.arange(len(g.nodes))
    start_cost = _graph_cost(g, cuts_from_labels(g, start))
    frontier: list[tuple[float, np.ndarray]] = [(start_cost, start)]
    best_cost, best_labels = start_cost, start
    while frontier:
        candidates: dict[tuple[int, ...], tuple[float, np.ndarray]] = {}
        for cost, labels in frontier:
            for mc, ml in _merge_moves(g, labels, sram_budget_words):
                key = tuple(cut_group_labels(g, cuts_from_labels(g, ml)))
                if key not in candidates or mc < candidates[key][0]:
                    candidates[key] = (mc, ml)
        if not candidates:
            break
        ranked = sorted(candidates.values(), key=lambda m: m[0])
        frontier = ranked[:beam_width]
        if ranked[0][0] < best_cost:
            best_cost, best_labels = ranked[0]
    labels = cut_group_labels(g, cuts_from_labels(g, best_labels))
    return DPResult(
        cuts=cuts_from_labels(g, labels),
        group_cost_words=best_cost,
        n_groups=int(labels.max()) + 1,
    )


def optimal_cuts(
    ir: NetworkIR | GraphIR,
    *,
    sram_budget_words: float = float("inf"),
    beam_width: int = 32,
) -> DPResult:
    """Grouping search dispatch: chain DP fast path; exhaustive enumeration
    for small DAGs; beam merge otherwise."""
    g = as_graph(ir)
    if g.is_chain:
        return optimal_cuts_dp(g, sram_budget_words=sram_budget_words)
    if g.n_edges <= MAX_EXHAUSTIVE_EDGES and len(g.nodes) <= MAX_EXHAUSTIVE_LAYERS:
        return brute_force_min_bw(g, sram_budget_words=sram_budget_words)
    return beam_merge_cuts(
        g, beam_width=beam_width, sram_budget_words=sram_budget_words
    )
