"""Typed error taxonomy for the evaluator and the planning service.

The evaluator started life as a research script: malformed graphs surfaced
as ``KeyError`` deep inside the tracing frontend, infeasible SRAM budgets
as bare ``ValueError`` strings, and a declined exact search as a silent
fallback.  A planning service admitting millions of (graph, hardware,
budget) queries needs a contract instead: every boundary — IR
construction, tracing, config resolution, the sweep, the grouping search,
service admission — raises exactly one of the classes below, and the
service (:mod:`repro.core.service`) converts them into typed *responses*
so callers always get a valid plan or a typed rejection, never a raw
exception or a silently wrong answer.

Compatibility: each concrete class also inherits the builtin exception the
pre-taxonomy code raised at that boundary (``ValueError`` for validation
and search failures, ``TimeoutError`` for deadlines), so existing
``except ValueError`` call sites and tests keep working while new code can
catch :class:`EvaluatorError` (or a specific subclass) precisely.

Taxonomy::

    EvaluatorError                      # root — nothing else escapes
    +-- GraphValidationError            # malformed GraphIR / LayerSpec / EdgeSpec
    +-- UnsupportedOpError              # frontend cannot lower a jaxpr construct
    +-- ConfigValidationError           # bad DLAConfig / config-space request
    +-- InfeasibleBudgetError           # SRAM budget rejects every candidate
    |     .min_feasible_budget_words    #   smallest budget that would admit one
    +-- InfeasibleConstraintsError      # no swept candidate meets Constraints
    +-- SearchDeclined                  # a search engine refused the instance
    |     +-- fusion.FrontierTooWide    #   (defined next to the DP it guards)
    +-- DeadlineExceeded                # request missed its wall-clock deadline
    +-- ServiceOverloaded               # queue-depth bound shed the request
    +-- TransientFailure                # retries exhausted on a transient fault
    +-- RequestCancelled                # caller cancelled; sweep stopped at a
    |                                   #   chunk boundary
    +-- AuditMismatch                   # online shadow audit: served plan
    |                                   #   diverged from the scalar oracle
    +-- JournalCorrupt                  # write-ahead log failed verification
"""
from __future__ import annotations


class EvaluatorError(Exception):
    """Root of every typed failure the evaluator or service can report."""


class GraphValidationError(EvaluatorError, ValueError):
    """A graph/layer/edge violates the IR invariants (non-positive or
    non-finite dims, edge endpoints out of range, a non-topological edge —
    i.e. a cycle — or a duplicate edge).  The message names the offending
    node or edge."""


class UnsupportedOpError(EvaluatorError, ValueError):
    """The tracing frontend cannot lower a jaxpr construct onto the paper's
    layer abstraction (unknown primitive shape, non-SAME padding, dilated
    or anisotropic convolutions, batch size != 1, ...)."""


class ConfigValidationError(EvaluatorError, ValueError):
    """A hardware configuration or config-space request is malformed
    (unknown style / SRAM-split preset, non-positive PE factors, a config
    space with heterogeneous area constants)."""


class InfeasibleBudgetError(EvaluatorError, ValueError):
    """The SRAM budget rejects every offered grouping candidate.

    ``min_feasible_budget_words`` is the smallest budget under which at
    least one of the rejected candidates would have survived (NaN when the
    failing path cannot compute it cheaply) — the actionable number a
    caller needs to retry, instead of a silently empty candidate set.
    """

    def __init__(self, message: str,
                 min_feasible_budget_words: float = float("nan")):
        """Attach the smallest budget that would have admitted a plan."""
        super().__init__(message)
        self.min_feasible_budget_words = float(min_feasible_budget_words)


class InfeasibleConstraintsError(EvaluatorError, ValueError):
    """The sweep ran, but no (hardware x grouping) candidate meets the
    user constraints."""


class SearchDeclined(EvaluatorError, ValueError):
    """A search engine refused the instance (e.g. the exact frontier DP's
    width/state caps tripped).  Dispatchers absorb this and fall back; it
    only escapes when the caller pinned a specific engine."""


class DeadlineExceeded(EvaluatorError, TimeoutError):
    """The request's wall-clock deadline expired before a plan (even the
    cheapest ladder rung) could be produced."""


class ServiceOverloaded(EvaluatorError):
    """The service's queue-depth bound shed this request instead of
    growing the queue unboundedly."""


class TransientFailure(EvaluatorError):
    """A transient fault (compile error, cache-eviction race) persisted
    through the bounded retry-with-backoff.  ``cause`` keeps the last
    underlying exception; ``attempts`` how many tries were made."""

    def __init__(self, message: str, *, cause: BaseException | None = None,
                 attempts: int = 0):
        """Record the last underlying exception and the attempt count."""
        super().__init__(message)
        self.cause = cause
        self.attempts = int(attempts)


class RequestCancelled(EvaluatorError):
    """The caller cancelled this request.  Cancellation is cooperative: a
    request still queued is answered immediately; one inside a sweep stops
    at the next chunk boundary (:func:`repro.core.flow.run_fleet` with
    ``hw_chunk``), never mid-kernel."""


class AuditMismatch(EvaluatorError):
    """The online shadow audit re-scored a served plan against the scalar
    oracle (``bandwidth_ref`` et al.) and the metrics diverged — the fast
    path produced a silently wrong answer, which must fail loudly."""


class JournalCorrupt(EvaluatorError, IOError):
    """The write-ahead log failed verification beyond what crash-recovery
    tolerates: an interior record with a bad digest, a sequence gap, or a
    snapshot whose digest does not match.  (A *torn tail* — the final
    record cut mid-append — is normal crash damage and silently dropped.)
    Dual-inherits ``IOError`` like the checkpoint layer's corruption
    verdicts."""
