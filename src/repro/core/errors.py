"""Typed error taxonomy for the evaluator and the planning service.

The evaluator started life as a research script: malformed graphs surfaced
as ``KeyError`` deep inside the tracing frontend, infeasible SRAM budgets
as bare ``ValueError`` strings, and a declined exact search as a silent
fallback.  A planning service admitting millions of (graph, hardware,
budget) queries needs a contract instead: every boundary — IR
construction, tracing, config resolution, the sweep, the grouping search,
service admission — raises exactly one of the classes below, and the
service (:mod:`repro.core.service`) converts them into typed *responses*
so callers always get a valid plan or a typed rejection, never a raw
exception or a silently wrong answer.

Compatibility: each concrete class also inherits the builtin exception the
pre-taxonomy code raised at that boundary (``ValueError`` for validation
and search failures, ``TimeoutError`` for deadlines), so existing
``except ValueError`` call sites and tests keep working while new code can
catch :class:`EvaluatorError` (or a specific subclass) precisely.

Taxonomy::

    EvaluatorError                      # root — nothing else escapes
    +-- GraphValidationError            # malformed GraphIR / LayerSpec / EdgeSpec
    +-- UnsupportedOpError              # frontend cannot lower a jaxpr construct
    +-- ConfigValidationError           # bad DLAConfig / config-space request
    +-- InfeasibleBudgetError           # SRAM budget rejects every candidate
    |     .min_feasible_budget_words    #   smallest budget that would admit one
    +-- InfeasibleConstraintsError      # no swept candidate meets Constraints
    +-- SearchDeclined                  # a search engine refused the instance
    |     +-- fusion.FrontierTooWide    #   (defined next to the DP it guards)
    +-- DeadlineExceeded                # request missed its wall-clock deadline
    +-- ServiceOverloaded               # queue-depth bound shed the request
    +-- TransientFailure                # retries exhausted on a transient fault
    +-- RequestCancelled                # caller cancelled; sweep stopped at a
    |                                   #   chunk boundary
    +-- AuditMismatch                   # online shadow audit: served plan
    |                                   #   diverged from the scalar oracle
    +-- PoisonedResultError             # every candidate for a graph was
    |                                   #   quarantined (NaN/Inf/negative/
    |                                   #   overflowed cost rows)
    +-- JournalCorrupt                  # write-ahead log failed verification

:class:`RetryPolicy` lives here too: the one tested retry/backoff
implementation shared by the service's request-level retries and the
fleet sweep's per-chunk salvage, so both layers classify faults the same
way (typed :class:`EvaluatorError` = deterministic, never retried;
anything else = possibly transient, retried with exponential backoff).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable


class EvaluatorError(Exception):
    """Root of every typed failure the evaluator or service can report."""


class GraphValidationError(EvaluatorError, ValueError):
    """A graph/layer/edge violates the IR invariants (non-positive or
    non-finite dims, edge endpoints out of range, a non-topological edge —
    i.e. a cycle — or a duplicate edge).  The message names the offending
    node or edge."""


class UnsupportedOpError(EvaluatorError, ValueError):
    """The tracing frontend cannot lower a jaxpr construct onto the paper's
    layer abstraction (unknown primitive shape, non-SAME padding, dilated
    or anisotropic convolutions, batch size != 1, ...)."""


class ConfigValidationError(EvaluatorError, ValueError):
    """A hardware configuration or config-space request is malformed
    (unknown style / SRAM-split preset, non-positive PE factors, a config
    space with heterogeneous area constants)."""


class InfeasibleBudgetError(EvaluatorError, ValueError):
    """The SRAM budget rejects every offered grouping candidate.

    ``min_feasible_budget_words`` is the smallest budget under which at
    least one of the rejected candidates would have survived (NaN when the
    failing path cannot compute it cheaply) — the actionable number a
    caller needs to retry, instead of a silently empty candidate set.
    """

    def __init__(self, message: str,
                 min_feasible_budget_words: float = float("nan")):
        """Attach the smallest budget that would have admitted a plan."""
        super().__init__(message)
        self.min_feasible_budget_words = float(min_feasible_budget_words)


class InfeasibleConstraintsError(EvaluatorError, ValueError):
    """The sweep ran, but no (hardware x grouping) candidate meets the
    user constraints."""


class SearchDeclined(EvaluatorError, ValueError):
    """A search engine refused the instance (e.g. the exact frontier DP's
    width/state caps tripped).  Dispatchers absorb this and fall back; it
    only escapes when the caller pinned a specific engine."""


class DeadlineExceeded(EvaluatorError, TimeoutError):
    """The request's wall-clock deadline expired before a plan (even the
    cheapest ladder rung) could be produced."""


class ServiceOverloaded(EvaluatorError):
    """The service's queue-depth bound shed this request instead of
    growing the queue unboundedly."""


class TransientFailure(EvaluatorError):
    """A transient fault (compile error, cache-eviction race) persisted
    through the bounded retry-with-backoff.  ``cause`` keeps the last
    underlying exception; ``attempts`` how many tries were made."""

    def __init__(self, message: str, *, cause: BaseException | None = None,
                 attempts: int = 0):
        """Record the last underlying exception and the attempt count."""
        super().__init__(message)
        self.cause = cause
        self.attempts = int(attempts)


class RequestCancelled(EvaluatorError):
    """The caller cancelled this request.  Cancellation is cooperative: a
    request still queued is answered immediately; one inside a sweep stops
    at the next chunk boundary (:func:`repro.core.flow.run_fleet` with
    ``hw_chunk``), never mid-kernel."""


class AuditMismatch(EvaluatorError):
    """The online shadow audit re-scored a served plan against the scalar
    oracle (``bandwidth_ref`` et al.) and the metrics diverged — the fast
    path produced a silently wrong answer, which must fail loudly."""


class PoisonedResultError(EvaluatorError, ArithmeticError):
    """Every candidate cell for a graph was quarantined by the finite
    guard (NaN/Inf, negative, or ``> 2**53`` raw cost rows), so no argmin
    or Pareto front can be composed.  Partial poisoning never raises —
    poisoned cells are excluded and reported via the ``quarantine`` field
    on :class:`~repro.core.flow.FlowResult` — this error is the *total*
    case only.  ``quarantined`` carries the per-cell provenance records."""

    def __init__(self, message: str, *, quarantined: tuple = ()):
        """Attach the quarantined-cell provenance records."""
        super().__init__(message)
        self.quarantined = tuple(quarantined)


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff, shared service-wide.

    One implementation classifies faults for both the request path
    (:meth:`repro.core.service.PlanningService._with_retries`) and the
    compute path (per-chunk salvage in :func:`repro.core.flow.run_fleet`):
    a typed :class:`EvaluatorError` is deterministic — retrying cannot
    change the answer — so it propagates immediately; any other exception
    is treated as transient and retried up to ``max_retries`` times,
    sleeping ``backoff_seconds * multiplier**attempt`` (capped at
    ``max_backoff_seconds``) between attempts.  Exhaustion raises
    :class:`TransientFailure` carrying the last cause and attempt count.
    """

    max_retries: int = 3
    backoff_seconds: float = 0.05
    multiplier: float = 2.0
    max_backoff_seconds: float = 5.0

    def __post_init__(self):
        """Validate the knobs at construction, not first use."""
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_seconds < 0:
            raise ValueError("backoff_seconds must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1")
        if self.max_backoff_seconds < 0:
            raise ValueError("max_backoff_seconds must be >= 0")

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (0-based), capped."""
        return min(self.backoff_seconds * self.multiplier ** attempt,
                   self.max_backoff_seconds)

    def call(self, fn: Callable[[], Any], *,
             sleep: Callable[[float], None] = time.sleep,
             describe: str = "operation",
             on_retry: "Callable[[int, BaseException], None] | None" = None,
             ) -> Any:
        """Run ``fn`` under this policy and return its result.

        ``sleep`` is injectable so tests (and fault harnesses) can run
        with zero wall-clock cost; ``describe`` names the operation in
        the :class:`TransientFailure` message on exhaustion;
        ``on_retry(attempt, exc)`` fires on every caught transient (the
        service counts them).
        """
        last: BaseException | None = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except EvaluatorError:
                raise  # deterministic: retrying cannot change the answer
            except Exception as exc:  # noqa: BLE001 - transient boundary
                last = exc
                if on_retry is not None:
                    on_retry(attempt, exc)
                if attempt < self.max_retries:
                    delay = self.delay(attempt)
                    if delay > 0:
                        sleep(delay)
        raise TransientFailure(
            f"{describe} failed after {self.max_retries + 1} attempts "
            f"({type(last).__name__}: {last})",
            cause=last, attempts=self.max_retries + 1,
        )


class JournalCorrupt(EvaluatorError, IOError):
    """The write-ahead log failed verification beyond what crash-recovery
    tolerates: an interior record with a bad digest, a sequence gap, or a
    snapshot whose digest does not match.  (A *torn tail* — the final
    record cut mid-append — is normal crash damage and silently dropped.)
    Dual-inherits ``IOError`` like the checkpoint layer's corruption
    verdicts."""
