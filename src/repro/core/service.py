"""Deadline-aware planning service over the fleet evaluator.

:func:`repro.core.flow.run_fleet` is a batch engine: hand it a list of
graphs and it sweeps the whole (graph x hardware x grouping) cross-product
in one XLA program.  This module wraps it as a *service*: callers submit
``(graph, config space, SRAM budget, deadline)`` requests one at a time and
always get a typed :class:`PlanResponse` back — a valid plan or a typed
rejection from :mod:`repro.core.errors`, never a raw exception and never a
silently wrong answer.

The serving moves, in the order a request meets them:

1. **Admission** (:meth:`PlanningService.submit`): the graph is
   re-validated (:meth:`repro.core.ir.GraphIR.validate` — corrupt objects
   that dodged ``__post_init__`` are caught here), the budget/deadline
   checked for NaN/negative values, and the config space checked for
   shared area constants.  A full queue sheds the request with
   :class:`~repro.core.errors.ServiceOverloaded` instead of growing
   unboundedly.
2. **Plan cache**: admitted requests first consult a bounded LRU keyed on
   ``(graph, budget, constraints, config space)`` — :class:`GraphIR` is a
   frozen, hashable dataclass, so the graph itself is the key.  Only
   *non-degraded* responses are cached (a degraded plan must not shadow
   the exact plan a later, slacker deadline could afford).
3. **Degradation ladder** (:meth:`PlanningService.tick`): each request's
   grouping search runs at the highest rung its remaining deadline
   affords, estimated by per-rung EWMAs of observed search cost::

       exact   flow.groupings_batch(g, "search")   certified when the
                                                   engine is exact
       beam    fusion.beam_merge_cuts              heuristic, >= greedy
       greedy  fusion.greedy_merge_cuts            heuristic
       lbl     fusion.layer_by_layer_cuts          always feasible

   The exact rung resolves through the same ``groupings_batch`` call
   :func:`~repro.core.flow.run_fleet` uses offline, so a non-degraded
   service plan is **bit-identical** to the offline answer (asserted in
   tests/test_service.py).  Every response stamps the engine provenance,
   ``exact``/``degraded`` flags, and a monotone ``quality_bound``: the
   rung's achieved group cost over the fully-fused lower bound
   (cutting an edge only ever adds a DRAM round-trip, so the all-uncut
   cost is admissible); the ratio is >= 1 and non-decreasing down the
   ladder.
4. **Micro-batched sweep**: the tick coalesces resolved requests by
   ``(budget, constraints, config space)`` and evaluates each group as ONE
   ``run_fleet`` program with per-graph explicit cut batches — the PR 4/6
   shape-bucket amortisation applied to the serving path.  A group member
   whose request is individually infeasible falls back to a singleton
   sweep so it cannot poison its neighbours.
5. **Retry with backoff**: non-evaluator exceptions from the sweep
   (transient compile/cache races, injected faults) are retried up to
   ``max_retries`` with exponential backoff; exhaustion returns a
   :class:`~repro.core.errors.TransientFailure` response.  Typed
   evaluator errors are *not* retried — they are deterministic verdicts.

Fault injection: a duck-typed ``faults`` object (see
:mod:`repro.testing.faults`) may define ``on_tick(n)``,
``before_search(request)`` and ``before_sweep(group_size)`` hooks, called
at the matching points — the same callable-hook idiom as
:mod:`repro.runtime.fault_tolerance`.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from . import flow, fusion
from .arch import Constraints, DLAConfig, default_config_space
from .errors import (
    ConfigValidationError,
    DeadlineExceeded,
    EvaluatorError,
    GraphValidationError,
    ServiceOverloaded,
    TransientFailure,
)
from .ir import GraphIR, NetworkIR, as_graph

# Degradation ladder, most expensive / highest quality first.
RUNGS = ("exact", "beam", "greedy", "lbl")

# Fraction of the remaining deadline a rung's estimated cost may consume;
# the slack absorbs the sweep + bookkeeping that follow the search.
_RUNG_SAFETY = 0.8

# EWMA smoothing for per-rung search-cost estimates (higher = faster
# adaptation to the current workload mix).
_EWMA_ALPHA = 0.3


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """One planning query: find the min-energy (hardware x fusion plan)
    point for ``graph`` under ``sram_budget_words``, within
    ``deadline_seconds`` of submission.  ``config_space``/``constraints``
    default to the service-wide ones."""

    graph: NetworkIR | GraphIR
    sram_budget_words: float = float("inf")
    deadline_seconds: float = float("inf")
    constraints: Constraints | None = None
    config_space: tuple[DLAConfig, ...] | None = None


@dataclasses.dataclass(frozen=True)
class PlanResponse:
    """The service's answer — exactly one of ``plan``/``error`` is set.

    ``engine`` is the grouping-search provenance ("chain_dp",
    "frontier_dp", "exhaustive", "beam", "greedy", "lbl"); ``exact`` says
    the grouping is a certified optimum, ``degraded`` that the deadline
    ladder picked a rung below exact.  ``quality_bound`` is the rung's
    achieved group cost over the fully-fused admissible lower bound
    (>= 1.0, monotone non-decreasing down the ladder; NaN on errors).
    """

    request_id: int
    ok: bool
    plan: flow.FlowResult | None = None
    error: EvaluatorError | None = None
    engine: str = ""
    rung: str = ""
    exact: bool = False
    degraded: bool = False
    quality_bound: float = float("nan")
    from_cache: bool = False
    latency_seconds: float = 0.0

    @property
    def error_type(self) -> str:
        """Class name of the typed rejection, "" on success."""
        return type(self.error).__name__ if self.error is not None else ""


@dataclasses.dataclass
class _Admitted:
    """Internal queue entry: a validated request plus submission state."""

    request_id: int
    g: GraphIR
    budget: float
    deadline: float  # absolute clock() value, inf when unconstrained
    constraints: Constraints
    config_space: tuple[DLAConfig, ...]
    submitted_at: float
    cache_key: tuple


@dataclasses.dataclass
class _Resolved:
    """A queue entry whose grouping search ran: ready to sweep."""

    adm: _Admitted
    cuts: np.ndarray  # (C, E) explicit batch for run_fleet
    engine: str
    rung: str
    exact: bool
    quality_bound: float


def _lower_bound_cost(g: GraphIR) -> float:
    """Fully-fused group cost — admissible: cutting an edge only adds a
    DRAM round-trip, so no grouping costs less."""
    return fusion._graph_cost(g, np.zeros(g.n_edges, dtype=bool))


class PlanningService:
    """Deadline-aware, micro-batching front end over ``run_fleet``.

    Synchronous by design: ``submit()`` enqueues (or answers immediately
    from cache / with a typed rejection) and ``tick()`` drains one
    micro-batch; ``plan()`` is the one-shot convenience.  All shared
    state is touched from the caller's thread — the thread-safety story
    is the executable cache's lock (:mod:`repro.core.flow`), not this
    class.
    """

    def __init__(
        self,
        *,
        config_space: Sequence[DLAConfig] | None = None,
        constraints: Constraints = Constraints(),
        max_queue_depth: int = 256,
        max_batch: int = 16,
        plan_cache_capacity: int = 512,
        max_retries: int = 3,
        backoff_seconds: float = 0.05,
        faults=None,
        clock: Callable[[], float] = time.monotonic,
    ):
        """Service-wide defaults: design space, constraints, queue/batch/
        cache bounds, retry policy, fault hooks, and the clock (injectable
        for deterministic tests)."""
        self.config_space = tuple(
            config_space if config_space is not None else default_config_space()
        )
        self.constraints = constraints
        self.max_queue_depth = int(max_queue_depth)
        self.max_batch = int(max_batch)
        self.max_retries = int(max_retries)
        self.backoff_seconds = float(backoff_seconds)
        self.faults = faults
        self.clock = clock

        self._queue: collections.deque[_Admitted] = collections.deque()
        self._responses: dict[int, PlanResponse] = {}
        self._next_id = 0
        self._ticks = 0

        self._plan_cache: "collections.OrderedDict[tuple, PlanResponse]" = (
            collections.OrderedDict()
        )
        self.plan_cache_capacity = int(plan_cache_capacity)
        self._cache_stats = {"hits": 0, "misses": 0, "evictions": 0}

        # Per-rung EWMA of observed grouping-search seconds, and one for
        # the shared sweep.  Zero-initialised: the first request always
        # tries the exact rung, and real costs take over from there.
        self._rung_ewma = {r: 0.0 for r in RUNGS}
        self._sweep_ewma = 0.0

        self._counters = collections.Counter()

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, request: PlanRequest) -> int:
        """Validate and enqueue one request; returns its request id.

        Invalid requests are *answered*, not raised: the typed rejection
        is recorded immediately and the id returned as usual.  Past the
        queue-depth bound the answer is a ``ServiceOverloaded`` rejection;
        a plan-cache hit is answered immediately without queueing.

        Example — enqueue a batch, then process it with :meth:`tick`::

            >>> from repro.core.service import PlanningService, PlanRequest
            >>> from repro.core.ir import residual_block_ir
            >>> svc = PlanningService()
            >>> rids = [svc.submit(PlanRequest(graph=residual_block_ir(),
            ...                                sram_budget_words=2e6))
            ...         for _ in range(3)]
            >>> svc.queue_depth
            3
            >>> svc.tick()
            3
            >>> svc.collect(rids[0]).ok
            True
        """
        rid = self._next_id
        self._next_id += 1
        self._counters["submitted"] += 1
        t0 = self.clock()
        try:
            adm = self._admit(rid, request, t0)
        except EvaluatorError as e:
            self._reject(rid, e, t0)
            return rid
        except Exception as e:  # malformed request objects, duck-typed junk
            self._reject(
                rid,
                GraphValidationError(
                    f"malformed request ({type(e).__name__}: {e})"
                ),
                t0,
            )
            return rid

        cached = self._cache_get(adm.cache_key)
        if cached is not None:
            self._responses[rid] = dataclasses.replace(
                cached,
                request_id=rid,
                from_cache=True,
                latency_seconds=self.clock() - t0,
            )
            self._counters["cache_hits"] += 1
            return rid

        if len(self._queue) >= self.max_queue_depth:
            self._counters["shed"] += 1
            self._reject(
                rid,
                ServiceOverloaded(
                    f"queue depth {len(self._queue)} at capacity "
                    f"{self.max_queue_depth}"
                ),
                t0,
            )
            return rid

        self._queue.append(adm)
        return rid

    def _admit(self, rid: int, request: PlanRequest, t0: float) -> _Admitted:
        """Validate every field of a request; raises typed errors."""
        if not isinstance(request.graph, (GraphIR, NetworkIR)):
            raise GraphValidationError(
                f"request graph must be GraphIR or NetworkIR, "
                f"got {type(request.graph).__name__}"
            )
        g = as_graph(request.graph)
        g.validate()  # corrupt objects that dodged __post_init__

        budget = float(request.sram_budget_words)
        if np.isnan(budget) or budget <= 0:
            raise GraphValidationError(
                f"sram_budget_words must be positive, got {budget}"
            )

        deadline_s = float(request.deadline_seconds)
        if np.isnan(deadline_s) or deadline_s < 0:
            raise DeadlineExceeded(
                f"deadline_seconds must be non-negative, got {deadline_s}"
            )

        constraints = (
            request.constraints
            if request.constraints is not None
            else self.constraints
        )
        if request.config_space is not None:
            space = tuple(request.config_space)
            if not space or not all(
                isinstance(c, DLAConfig) for c in space
            ):
                raise ConfigValidationError(
                    "config_space must be a non-empty sequence of DLAConfig"
                )
        else:
            space = self.config_space
        # area_consts_of_space raises ConfigValidationError on a space
        # mixing area calibrations — reject at admission, not mid-sweep.
        from . import metrics as M

        M.area_consts_of_space(space)

        return _Admitted(
            request_id=rid,
            g=g,
            budget=budget,
            deadline=t0 + deadline_s if np.isfinite(deadline_s) else float("inf"),
            constraints=constraints,
            config_space=space,
            submitted_at=t0,
            cache_key=(
                g,
                budget,
                constraints.as_row().tobytes(),
                space,
            ),
        )

    def _reject(self, rid: int, err: EvaluatorError, t0: float) -> None:
        self._counters[f"err:{type(err).__name__}"] += 1
        self._responses[rid] = PlanResponse(
            request_id=rid,
            ok=False,
            error=err,
            latency_seconds=self.clock() - t0,
        )

    # ------------------------------------------------------------------
    # plan cache (bounded LRU, same idiom as flow._COMPILED_SWEEPS)
    # ------------------------------------------------------------------

    def _cache_get(self, key: tuple) -> PlanResponse | None:
        resp = self._plan_cache.get(key)
        if resp is not None:
            self._plan_cache.move_to_end(key)
            self._cache_stats["hits"] += 1
        else:
            self._cache_stats["misses"] += 1
        return resp

    def _cache_put(self, key: tuple, resp: PlanResponse) -> None:
        while len(self._plan_cache) >= self.plan_cache_capacity:
            self._plan_cache.popitem(last=False)
            self._cache_stats["evictions"] += 1
        self._plan_cache[key] = resp

    def plan_cache_stats(self) -> dict:
        """Plan-cache accounting: hits/misses/evictions + current size."""
        return dict(self._cache_stats, size=len(self._plan_cache))

    # ------------------------------------------------------------------
    # degradation ladder
    # ------------------------------------------------------------------

    def _pick_rung(self, remaining: float) -> str:
        """Highest rung whose estimated search+sweep cost fits the
        remaining deadline (with safety margin).  Falls through to "lbl"
        as the best-effort floor."""
        if not np.isfinite(remaining):
            return "exact"
        allowance = remaining * _RUNG_SAFETY - self._sweep_ewma
        for rung in RUNGS[:-1]:
            if self._rung_ewma[rung] <= allowance:
                return rung
        return "lbl"

    def _resolve(self, adm: _Admitted) -> _Resolved:
        """Run the grouping search at the deadline-selected rung.

        Raises :class:`DeadlineExceeded` when the deadline expired before
        (or during — e.g. a stalled search) the resolution."""
        now = self.clock()
        if now > adm.deadline:
            raise DeadlineExceeded(
                f"deadline expired {now - adm.deadline:.3f}s before the "
                "grouping search started"
            )
        rung = self._pick_rung(adm.deadline - now)

        if self.faults is not None and hasattr(self.faults, "before_search"):
            self.faults.before_search(adm)

        g, budget = adm.g, adm.budget
        t0 = self.clock()
        lbl = fusion.layer_by_layer_cuts(g)
        if rung == "exact":
            # The SAME resolution run_fleet(groupings="search") performs
            # offline — this is what makes non-degraded service plans
            # bit-identical to the batch answer.
            cuts, engine = flow.groupings_batch(
                g, "search", sram_budget_words=budget, with_provenance=True
            )
            # Re-resolving for the achieved cost is near-free: the
            # frontier DP memoises per (graph, budget), and the chain
            # DP / exhaustive paths are tiny at service graph sizes.
            best = fusion.optimal_cuts(g, sram_budget_words=budget)
            achieved = best.group_cost_words
            exact = best.exact
        else:
            if rung == "beam":
                res = fusion.beam_merge_cuts(g, sram_budget_words=budget)
            elif rung == "greedy":
                res = fusion.greedy_merge_cuts(g, sram_budget_words=budget)
            else:  # lbl — always buffer-minimal, the feasibility floor
                res = fusion.DPResult(
                    cuts=lbl,
                    group_cost_words=fusion._graph_cost(g, lbl),
                    n_groups=g.n_nodes,
                    engine="lbl",
                )
            # The lbl row rides along so the SRAM prefilter can never
            # reject the whole batch when *any* grouping is feasible.
            cuts = np.unique(np.stack([res.cuts, lbl]), axis=0)
            engine, achieved, exact = res.engine, res.group_cost_words, False
        dt = self.clock() - t0
        self._rung_ewma[rung] += _EWMA_ALPHA * (dt - self._rung_ewma[rung])

        now = self.clock()
        if now > adm.deadline:
            raise DeadlineExceeded(
                f"grouping search ({rung}) overran the deadline by "
                f"{now - adm.deadline:.3f}s"
            )
        return _Resolved(
            adm=adm,
            cuts=cuts,
            engine=engine,
            rung=rung,
            exact=exact,
            quality_bound=achieved / _lower_bound_cost(g),
        )

    # ------------------------------------------------------------------
    # micro-batched sweep
    # ------------------------------------------------------------------

    def _with_retries(self, fn: Callable[[], flow.FleetResult]):
        """Bounded retry-with-backoff for transient (non-evaluator)
        failures.  Typed evaluator errors are deterministic verdicts and
        propagate immediately."""
        last: BaseException | None = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except EvaluatorError:
                raise
            except Exception as e:  # transient: injected faults, races
                last = e
                self._counters["transient_retries"] += 1
                if attempt < self.max_retries and self.backoff_seconds > 0:
                    time.sleep(self.backoff_seconds * (2**attempt))
        raise TransientFailure(
            f"sweep failed after {self.max_retries + 1} attempts "
            f"({type(last).__name__}: {last})",
            cause=last,
            attempts=self.max_retries + 1,
        )

    def _sweep_group(self, group: list[_Resolved]) -> None:
        """One run_fleet program for a (budget, constraints, space) group;
        on a group-level typed failure, falls back to singleton sweeps so
        one infeasible request cannot poison its neighbours."""
        adm0 = group[0].adm

        def run() -> flow.FleetResult:
            if self.faults is not None and hasattr(
                self.faults, "before_sweep"
            ):
                self.faults.before_sweep(len(group))
            return flow.run_fleet(
                [r.adm.g for r in group],
                config_space=adm0.config_space,
                constraints=adm0.constraints,
                groupings=[r.cuts for r in group],
                sram_budget_words=adm0.budget,
            )

        t0 = self.clock()
        try:
            fleet = self._with_retries(run)
        except EvaluatorError as e:
            if len(group) == 1:
                self._reject(group[0].adm.request_id, e, group[0].adm.submitted_at)
                return
            for r in group:  # isolate: re-sweep each request alone
                self._sweep_group([r])
            return
        self._sweep_ewma += _EWMA_ALPHA * (
            (self.clock() - t0) - self._sweep_ewma
        )

        for r, fr in zip(group, fleet.results):
            adm = r.adm
            resp = PlanResponse(
                request_id=adm.request_id,
                ok=True,
                # run_fleet reports the explicit batch as "explicit";
                # restore the ladder's true provenance.
                plan=dataclasses.replace(fr, search_engine=r.engine),
                engine=r.engine,
                rung=r.rung,
                exact=r.exact,
                degraded=r.rung != "exact",
                quality_bound=r.quality_bound,
                latency_seconds=self.clock() - adm.submitted_at,
            )
            self._responses[adm.request_id] = resp
            self._counters["completed"] += 1
            if resp.degraded:
                self._counters["degraded"] += 1
            else:
                self._cache_put(adm.cache_key, resp)

    def tick(self) -> int:
        """Process one micro-batch; returns how many responses were
        produced.  Never raises for a request's failure — every outcome
        becomes a typed response.

        One tick dequeues up to ``max_batch`` admitted requests, resolves
        each one's grouping through the deadline ladder, groups the
        resolutions by (budget, constraints, config space), and answers
        each group with ONE coalesced :func:`repro.core.flow.run_fleet`
        program (per-graph explicit cut batches through the shared shape
        buckets).  Deadlines that expire mid-tick become
        ``DeadlineExceeded`` responses; transient sweep failures retry
        with backoff before a ``TransientFailure`` verdict.

        Example — an event loop calling tick until a request resolves::

            >>> from repro.core.service import PlanningService, PlanRequest
            >>> from repro.core.ir import resnet18_ir
            >>> svc = PlanningService()
            >>> rid = svc.submit(PlanRequest(graph=resnet18_ir(),
            ...                              deadline_seconds=0.5))
            >>> resp = None
            >>> while resp is None:          # doctest: +SKIP
            ...     _ = svc.tick()
            ...     resp = svc.collect(rid)  # pops once answered

        (Offline callers can use :meth:`plan` — submit + drain + collect
        in one call — instead of running the loop themselves.)
        """
        self._ticks += 1
        if self.faults is not None and hasattr(self.faults, "on_tick"):
            self.faults.on_tick(self._ticks)

        batch: list[_Admitted] = []
        while self._queue and len(batch) < self.max_batch:
            batch.append(self._queue.popleft())
        if not batch:
            return 0

        groups: dict[tuple, list[_Resolved]] = collections.OrderedDict()
        produced = 0
        for adm in batch:
            try:
                r = self._resolve(adm)
            except EvaluatorError as e:
                self._reject(adm.request_id, e, adm.submitted_at)
                produced += 1
                continue
            except Exception as e:
                self._reject(
                    adm.request_id,
                    TransientFailure(
                        f"grouping search failed "
                        f"({type(e).__name__}: {e})",
                        cause=e,
                        attempts=1,
                    ),
                    adm.submitted_at,
                )
                produced += 1
                continue
            key = (
                adm.budget,
                adm.constraints.as_row().tobytes(),
                adm.config_space,
            )
            groups.setdefault(key, []).append(r)

        for group in groups.values():
            self._sweep_group(group)
            produced += len(group)
        return produced

    # ------------------------------------------------------------------
    # retrieval / convenience
    # ------------------------------------------------------------------

    def collect(self, request_id: int) -> PlanResponse | None:
        """Pop the response for ``request_id`` (None while pending)."""
        return self._responses.pop(request_id, None)

    def drain(self, max_ticks: int = 10_000) -> None:
        """Tick until the queue is empty."""
        while self._queue and max_ticks > 0:
            self.tick()
            max_ticks -= 1

    def plan(self, request: PlanRequest) -> PlanResponse:
        """One-shot convenience: submit, drain, collect."""
        rid = self.submit(request)
        self.drain()
        resp = self.collect(rid)
        assert resp is not None  # drain() guarantees an answer
        return resp

    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet answered by a tick."""
        return len(self._queue)

    def stats(self) -> dict:
        """Service accounting: counters, plan-cache and executable-cache
        stats, ladder EWMAs."""
        return {
            "counters": dict(self._counters),
            "queue_depth": len(self._queue),
            "ticks": self._ticks,
            "plan_cache": self.plan_cache_stats(),
            "sweep_cache": flow.sweep_cache_stats(),
            "rung_ewma_seconds": dict(self._rung_ewma),
            "sweep_ewma_seconds": self._sweep_ewma,
        }
