"""Deadline-aware planning service over the fleet evaluator.

:func:`repro.core.flow.run_fleet` is a batch engine: hand it a list of
graphs and it sweeps the whole (graph x hardware x grouping) cross-product
in one XLA program.  This module wraps it as a *service*: callers submit
``(graph, config space, SRAM budget, deadline)`` requests one at a time and
always get a typed :class:`PlanResponse` back — a valid plan or a typed
rejection from :mod:`repro.core.errors`, never a raw exception and never a
silently wrong answer.

The serving moves, in the order a request meets them:

1. **Admission** (:meth:`PlanningService.submit`): the graph is
   re-validated (:meth:`repro.core.ir.GraphIR.validate` — corrupt objects
   that dodged ``__post_init__`` are caught here), the budget/deadline
   checked for NaN/negative values, and the config space checked for
   shared area constants.  A full queue sheds the request with
   :class:`~repro.core.errors.ServiceOverloaded` instead of growing
   unboundedly.
2. **Plan cache**: admitted requests first consult a bounded LRU keyed on
   ``(graph, budget, constraints, config space)`` — :class:`GraphIR` is a
   frozen, hashable dataclass, so the graph itself is the key.  Only
   *non-degraded* responses are cached (a degraded plan must not shadow
   the exact plan a later, slacker deadline could afford).
3. **Degradation ladder** (:meth:`PlanningService.tick`): each request's
   grouping search runs at the highest rung its remaining deadline
   affords, estimated by per-rung EWMAs of observed search cost::

       exact   flow.groupings_batch(g, "search")   certified when the
                                                   engine is exact
       beam    fusion.beam_merge_cuts              heuristic, >= greedy
       greedy  fusion.greedy_merge_cuts            heuristic
       lbl     fusion.layer_by_layer_cuts          always feasible

   The exact rung resolves through the same ``groupings_batch`` call
   :func:`~repro.core.flow.run_fleet` uses offline, so a non-degraded
   service plan is **bit-identical** to the offline answer (asserted in
   tests/test_service.py).  Every response stamps the engine provenance,
   ``exact``/``degraded`` flags, and a monotone ``quality_bound``: the
   rung's achieved group cost over the fully-fused lower bound
   (cutting an edge only ever adds a DRAM round-trip, so the all-uncut
   cost is admissible); the ratio is >= 1 and non-decreasing down the
   ladder.
4. **Micro-batched sweep**: the tick coalesces resolved requests by
   ``(budget, constraints, config space)`` and evaluates each group as ONE
   ``run_fleet`` program with per-graph explicit cut batches — the PR 4/6
   shape-bucket amortisation applied to the serving path.  A group member
   whose request is individually infeasible falls back to a singleton
   sweep so it cannot poison its neighbours.
5. **Retry with backoff**: non-evaluator exceptions from the sweep
   (transient compile/cache races, injected faults) are retried up to
   ``max_retries`` with exponential backoff; exhaustion returns a
   :class:`~repro.core.errors.TransientFailure` response.  Typed
   evaluator errors are *not* retried — they are deterministic verdicts.

6. **Write-ahead journal** (:mod:`repro.core.journal`): with a
   ``journal_dir`` every admission, tick boundary, response, and
   cancellation is fsync'd to the WAL *before* the in-memory state
   changes, and :meth:`PlanningService.recover` replays snapshot + WAL
   back to the exact pre-crash state — already-served responses are
   restored bit-identically and only in-flight requests re-run
   (kill-point-tested in tests/test_journal*.py).
7. **Cooperative cancellation** (:meth:`PlanningService.cancel`): a
   cancelled request still queued is answered with
   :class:`~repro.core.errors.RequestCancelled` at the next tick; one
   inside a sweep stops at the next ``hw_chunk`` boundary of the chunked
   fleet program — never mid-kernel.  Deadlines are enforced at the same
   chunk granularity.
8. **Circuit breaker**: ``breaker_threshold`` consecutive
   ``TransientFailure`` verdicts trip the breaker OPEN — the ladder is
   forced to its "lbl" floor (cheap, always-feasible plans) for
   ``breaker_cooldown_seconds``, then a HALF_OPEN probe runs at full
   quality and a success re-closes it (:class:`BreakerState`).
9. **Bucket-affinity batching**: the tick's micro-batch is formed from
   the FIFO head plus queued requests sharing its ``(node bucket, edge
   bucket, budget, constraints, config space)`` affinity key, so one tick
   reuses one compiled executable across heterogeneous traffic; the head
   is always served, so no key can starve.
10. **Shadow audit**: a counter-based sample of served plans
    (``shadow_audit_rate``) is re-scored against the scalar oracle
    (:func:`repro.core.metrics.evaluate_ref`); any divergence replaces
    the answer with a typed
    :class:`~repro.core.errors.AuditMismatch` — the fast path is never
    allowed to be silently wrong.

:class:`AsyncPlanningService` wraps all of the above in a worker thread
behind a ``concurrent.futures`` interface with heartbeat/watchdog
liveness (the :mod:`repro.runtime.fault_tolerance` idiom) and
drain-on-shutdown.

Fault injection: a duck-typed ``faults`` object (see
:mod:`repro.testing.faults`) may define ``on_tick(n)``,
``before_search(request)``, ``before_sweep(group_size)`` and
``before_chunk()`` hooks, called at the matching points — the same
callable-hook idiom as :mod:`repro.runtime.fault_tolerance`.
"""
from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import enum
import os
import queue as queue_mod
import threading
import time
from typing import Callable, Sequence

import numpy as np

from . import flow, fusion
from . import journal as journal_mod
from .arch import Constraints, DLAConfig, default_config_space
from .errors import (
    AuditMismatch,
    ConfigValidationError,
    DeadlineExceeded,
    EvaluatorError,
    GraphValidationError,
    RequestCancelled,
    RetryPolicy,
    ServiceOverloaded,
    TransientFailure,
)
from .ir import GraphIR, NetworkIR, as_graph, bucket_size

# Degradation ladder, most expensive / highest quality first.
RUNGS = ("exact", "beam", "greedy", "lbl")

# Fraction of the remaining deadline a rung's estimated cost may consume;
# the slack absorbs the sweep + bookkeeping that follow the search.
_RUNG_SAFETY = 0.8

# EWMA smoothing for per-rung search-cost estimates (higher = faster
# adaptation to the current workload mix).
_EWMA_ALPHA = 0.3


class BreakerState(enum.Enum):
    """Circuit-breaker states (the classic three-state machine).

    CLOSED: normal service.  OPEN: ``breaker_threshold`` consecutive
    ``TransientFailure`` verdicts tripped the breaker — the deadline
    ladder is pinned to its "lbl" floor until the cooldown elapses.
    HALF_OPEN: cooldown elapsed; the next request probes at full quality,
    success re-closes, failure re-opens.
    """

    CLOSED = "closed"
    OPEN = "open"
    HALF_OPEN = "half_open"


class _SweepAborted(EvaluatorError):
    """Internal: the chunked sweep's abort check fired (a group member was
    cancelled or ran out of deadline).  Never escapes the service — the
    tick converts it into per-request RequestCancelled/DeadlineExceeded
    responses and re-sweeps the survivors."""


@dataclasses.dataclass(frozen=True)
class PlanRequest:
    """One planning query: find the min-energy (hardware x fusion plan)
    point for ``graph`` under ``sram_budget_words``, within
    ``deadline_seconds`` of submission.  ``config_space``/``constraints``
    default to the service-wide ones."""

    graph: NetworkIR | GraphIR
    sram_budget_words: float = float("inf")
    deadline_seconds: float = float("inf")
    constraints: Constraints | None = None
    config_space: tuple[DLAConfig, ...] | None = None


@dataclasses.dataclass(frozen=True)
class PlanResponse:
    """The service's answer — exactly one of ``plan``/``error`` is set.

    ``engine`` is the grouping-search provenance ("chain_dp",
    "frontier_dp", "exhaustive", "beam", "greedy", "lbl"); ``exact`` says
    the grouping is a certified optimum, ``degraded`` that the deadline
    ladder picked a rung below exact.  ``quality_bound`` is the rung's
    achieved group cost over the fully-fused admissible lower bound
    (>= 1.0, monotone non-decreasing down the ladder; NaN on errors).
    """

    request_id: int
    ok: bool
    plan: flow.FlowResult | None = None
    error: EvaluatorError | None = None
    engine: str = ""
    rung: str = ""
    exact: bool = False
    degraded: bool = False
    quality_bound: float = float("nan")
    from_cache: bool = False
    latency_seconds: float = 0.0

    @property
    def error_type(self) -> str:
        """Class name of the typed rejection, "" on success."""
        return type(self.error).__name__ if self.error is not None else ""


@dataclasses.dataclass
class _Admitted:
    """Internal queue entry: a validated request plus submission state."""

    request_id: int
    g: GraphIR
    budget: float
    deadline: float  # absolute clock() value, inf when unconstrained
    constraints: Constraints
    config_space: tuple[DLAConfig, ...]
    submitted_at: float
    cache_key: tuple


@dataclasses.dataclass
class _Resolved:
    """A queue entry whose grouping search ran: ready to sweep."""

    adm: _Admitted
    cuts: np.ndarray  # (C, E) explicit batch for run_fleet
    engine: str
    rung: str
    exact: bool
    quality_bound: float


def _lower_bound_cost(g: GraphIR) -> float:
    """Fully-fused group cost — admissible: cutting an edge only adds a
    DRAM round-trip, so no grouping costs less."""
    return fusion._graph_cost(g, np.zeros(g.n_edges, dtype=bool))


class PlanningService:
    """Deadline-aware, micro-batching front end over ``run_fleet``.

    Synchronous by design: ``submit()`` enqueues (or answers immediately
    from cache / with a typed rejection) and ``tick()`` drains one
    micro-batch; ``plan()`` is the one-shot convenience.  All shared
    state is touched from the caller's thread — the thread-safety story
    is the executable cache's lock (:mod:`repro.core.flow`), not this
    class.
    """

    def __init__(
        self,
        *,
        config_space: Sequence[DLAConfig] | None = None,
        constraints: Constraints = Constraints(),
        max_queue_depth: int = 256,
        max_batch: int = 16,
        plan_cache_capacity: int = 512,
        max_retries: int = 3,
        backoff_seconds: float = 0.05,
        retry_policy: RetryPolicy | None = None,
        checkpoint_dir=None,
        faults=None,
        clock: Callable[[], float] = time.monotonic,
        journal_dir=None,
        journal_fsync: bool = True,
        snapshot_every: int = 64,
        hw_chunk: int | None = None,
        affinity_batching: bool = True,
        breaker_threshold: int = 0,
        breaker_cooldown_seconds: float = 1.0,
        shadow_audit_rate: float = 0.0,
    ):
        """Service-wide defaults: design space, constraints, queue/batch/
        cache bounds, retry policy, fault hooks, and the clock (injectable
        for deterministic tests).

        ``journal_dir`` enables the write-ahead log (``journal_fsync``
        trades durability for test speed; a snapshot compacts the WAL
        every ``snapshot_every`` records).  ``hw_chunk`` splits every
        sweep into resumable hardware-axis chunks so cancellation and
        deadlines act between chunks.  ``affinity_batching`` groups the
        tick's micro-batch by shape-bucket affinity.  A positive
        ``breaker_threshold`` arms the circuit breaker;
        ``shadow_audit_rate`` (0..1) re-scores that fraction of served
        plans against the scalar oracle.

        ``retry_policy`` overrides the :class:`RetryPolicy` built from
        ``max_retries``/``backoff_seconds``; the ONE policy governs both
        request-level retries and the sweep's per-chunk salvage.
        ``checkpoint_dir`` (requires ``hw_chunk``) persists completed
        sweep chunks so a killed sweep resumes without recomputing them —
        pair it with ``journal_dir`` and :meth:`recover`."""
        self.config_space = tuple(
            config_space if config_space is not None else default_config_space()
        )
        self.constraints = constraints
        self.max_queue_depth = int(max_queue_depth)
        self.max_batch = int(max_batch)
        self.max_retries = int(max_retries)
        self.backoff_seconds = float(backoff_seconds)
        self.retry_policy = (
            retry_policy
            if retry_policy is not None
            else RetryPolicy(
                max_retries=self.max_retries,
                backoff_seconds=self.backoff_seconds,
            )
        )
        self.faults = faults
        self.clock = clock
        self.hw_chunk = None if hw_chunk is None else int(hw_chunk)
        if checkpoint_dir is not None and self.hw_chunk is None:
            raise ValueError(
                "checkpoint_dir requires hw_chunk: completed hardware-axis "
                "chunks are the checkpoint grain"
            )
        self.checkpoint_dir = checkpoint_dir
        self.affinity_batching = bool(affinity_batching)

        self._queue: collections.deque[_Admitted] = collections.deque()
        self._responses: dict[int, PlanResponse] = {}
        # Every rid ever answered — outlives collect()'s pop so a late
        # cancel() of an already-served request stays a no-op.
        self._done: set[int] = set()
        self._next_id = 0
        self._ticks = 0
        # Cooperative-cancellation flags.  A plain set: adds/discards are
        # atomic under the GIL, and the async transport's caller thread
        # must be able to flag a cancel while the worker is mid-sweep so
        # the chunk-boundary abort check sees it immediately.
        self._cancelled: set[int] = set()

        self._plan_cache: "collections.OrderedDict[tuple, PlanResponse]" = (
            collections.OrderedDict()
        )
        self.plan_cache_capacity = int(plan_cache_capacity)
        self._cache_stats = {"hits": 0, "misses": 0, "evictions": 0}
        # Same lock discipline as flow's executable cache: the async
        # transport reads stats from the caller thread while the worker
        # mutates the LRU, and an unguarded move_to_end/popitem interleave
        # can corrupt the OrderedDict.
        self._plan_cache_lock = threading.Lock()

        # Per-rung EWMA of observed grouping-search seconds, and one for
        # the shared sweep.  Zero-initialised: the first request always
        # tries the exact rung, and real costs take over from there.
        self._rung_ewma = {r: 0.0 for r in RUNGS}
        self._sweep_ewma = 0.0

        self._counters = collections.Counter()

        self.breaker_threshold = int(breaker_threshold)
        self.breaker_cooldown_seconds = float(breaker_cooldown_seconds)
        self._breaker_state = BreakerState.CLOSED
        self._breaker_failures = 0
        self._breaker_open_until = 0.0

        self.shadow_audit_rate = float(shadow_audit_rate)
        self._audit_counter = 0

        self._journal: journal_mod.Journal | None = None
        if journal_dir is not None:
            self._journal = journal_mod.Journal(
                journal_dir, fsync=journal_fsync,
                snapshot_every=snapshot_every,
            )

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------

    def submit(self, request: PlanRequest) -> int:
        """Validate and enqueue one request; returns its request id.

        Invalid requests are *answered*, not raised: the typed rejection
        is recorded immediately and the id returned as usual.  Past the
        queue-depth bound the answer is a ``ServiceOverloaded`` rejection;
        a plan-cache hit is answered immediately without queueing.

        Example — enqueue a batch, then process it with :meth:`tick`::

            >>> from repro.core.service import PlanningService, PlanRequest
            >>> from repro.core.ir import residual_block_ir
            >>> svc = PlanningService()
            >>> rids = [svc.submit(PlanRequest(graph=residual_block_ir(),
            ...                                sram_budget_words=2e6))
            ...         for _ in range(3)]
            >>> svc.queue_depth
            3
            >>> svc.tick()
            3
            >>> svc.collect(rids[0]).ok
            True
        """
        rid = self._next_id
        self._next_id += 1
        self._counters["submitted"] += 1
        t0 = self.clock()
        try:
            adm = self._admit(rid, request, t0)
        except EvaluatorError as e:
            self._reject(rid, e, t0)
            return rid
        except Exception as e:  # malformed request objects, duck-typed junk
            self._reject(
                rid,
                GraphValidationError(
                    f"malformed request ({type(e).__name__}: {e})"
                ),
                t0,
            )
            return rid

        cached = self._cache_get(adm.cache_key)
        if cached is not None:
            resp = dataclasses.replace(
                cached,
                request_id=rid,
                from_cache=True,
                latency_seconds=self.clock() - t0,
            )
            self._record_response(resp)
            self._counters["cache_hits"] += 1
            return rid

        if len(self._queue) >= self.max_queue_depth:
            self._counters["shed"] += 1
            self._reject(
                rid,
                ServiceOverloaded(
                    f"queue depth {len(self._queue)} at capacity "
                    f"{self.max_queue_depth}"
                ),
                t0,
            )
            return rid

        # WAL: the admission is durable BEFORE the queue sees it — a crash
        # after this append re-runs the request, a crash before it means
        # the caller never got an id worth recovering.
        if self._journal is not None:
            self._journal.append("admit", journal_mod.enc_request(adm))
        self._queue.append(adm)
        return rid

    def _admit(self, rid: int, request: PlanRequest, t0: float) -> _Admitted:
        """Validate every field of a request; raises typed errors."""
        if not isinstance(request.graph, (GraphIR, NetworkIR)):
            raise GraphValidationError(
                f"request graph must be GraphIR or NetworkIR, "
                f"got {type(request.graph).__name__}"
            )
        g = as_graph(request.graph)
        g.validate()  # corrupt objects that dodged __post_init__

        budget = float(request.sram_budget_words)
        if np.isnan(budget) or budget <= 0:
            raise GraphValidationError(
                f"sram_budget_words must be positive, got {budget}"
            )

        deadline_s = float(request.deadline_seconds)
        if np.isnan(deadline_s) or deadline_s < 0:
            raise DeadlineExceeded(
                f"deadline_seconds must be non-negative, got {deadline_s}"
            )

        constraints = (
            request.constraints
            if request.constraints is not None
            else self.constraints
        )
        if request.config_space is not None:
            space = tuple(request.config_space)
            if not space or not all(
                isinstance(c, DLAConfig) for c in space
            ):
                raise ConfigValidationError(
                    "config_space must be a non-empty sequence of DLAConfig"
                )
        else:
            space = self.config_space
        # area_consts_of_space raises ConfigValidationError on a space
        # mixing area calibrations — reject at admission, not mid-sweep.
        from . import metrics as M

        M.area_consts_of_space(space)

        return _Admitted(
            request_id=rid,
            g=g,
            budget=budget,
            deadline=t0 + deadline_s if np.isfinite(deadline_s) else float("inf"),
            constraints=constraints,
            config_space=space,
            submitted_at=t0,
            cache_key=(
                g,
                budget,
                constraints.as_row().tobytes(),
                space,
            ),
        )

    def _record_response(self, resp: PlanResponse) -> None:
        """Journal (when enabled) then publish one response — the WAL is
        always at least as advanced as the state a crash destroys."""
        if self._journal is not None:
            self._journal.append("response", journal_mod.enc_response(resp))
        self._responses[resp.request_id] = resp
        self._done.add(resp.request_id)

    def _reject(self, rid: int, err: EvaluatorError, t0: float) -> None:
        self._counters[f"err:{type(err).__name__}"] += 1
        if isinstance(err, TransientFailure):
            self._breaker_on_failure()
        self._record_response(PlanResponse(
            request_id=rid,
            ok=False,
            error=err,
            latency_seconds=self.clock() - t0,
        ))

    # ------------------------------------------------------------------
    # plan cache (bounded LRU, same idiom as flow._COMPILED_SWEEPS)
    # ------------------------------------------------------------------

    def _cache_get(self, key: tuple) -> PlanResponse | None:
        with self._plan_cache_lock:
            resp = self._plan_cache.get(key)
            if resp is not None:
                self._plan_cache.move_to_end(key)
                self._cache_stats["hits"] += 1
            else:
                self._cache_stats["misses"] += 1
            return resp

    def _cache_put(self, key: tuple, resp: PlanResponse) -> None:
        with self._plan_cache_lock:
            while len(self._plan_cache) >= self.plan_cache_capacity:
                self._plan_cache.popitem(last=False)
                self._cache_stats["evictions"] += 1
            self._plan_cache[key] = resp

    def plan_cache_stats(self) -> dict:
        """Plan-cache accounting — same shape as
        :func:`repro.core.flow.sweep_cache_stats`: {hits, misses,
        evictions, size, entries}, where ``entries`` lists each cached
        plan's {graph, budget, engine} in LRU order.  Snapshotted under
        the cache lock, so concurrent readers never see a half-updated
        accounting."""
        with self._plan_cache_lock:
            return dict(
                self._cache_stats,
                size=len(self._plan_cache),
                entries=[
                    {
                        "graph": key[0].name,
                        "budget": float(key[1]),
                        "engine": resp.engine,
                    }
                    for key, resp in self._plan_cache.items()
                ],
            )

    # ------------------------------------------------------------------
    # degradation ladder
    # ------------------------------------------------------------------

    def _breaker_on_failure(self) -> None:
        """A TransientFailure verdict: count it, trip OPEN at threshold
        (a HALF_OPEN probe failure re-opens immediately)."""
        if not self.breaker_threshold:
            return
        self._breaker_failures += 1
        if (
            self._breaker_state is BreakerState.HALF_OPEN
            or self._breaker_failures >= self.breaker_threshold
        ):
            if self._breaker_state is not BreakerState.OPEN:
                self._counters["breaker_trips"] += 1
            self._breaker_state = BreakerState.OPEN
            self._breaker_open_until = (
                self.clock() + self.breaker_cooldown_seconds
            )

    def _breaker_on_success(self) -> None:
        """A served plan: reset the failure streak; a successful HALF_OPEN
        probe re-closes the breaker.  Successes while OPEN do *not* close
        it — the floor rung succeeding says nothing about the tripped
        fast path."""
        if not self.breaker_threshold:
            return
        if self._breaker_state is BreakerState.OPEN:
            return
        if self._breaker_state is BreakerState.HALF_OPEN:
            self._counters["breaker_closes"] += 1
        self._breaker_state = BreakerState.CLOSED
        self._breaker_failures = 0

    @property
    def breaker_state(self) -> BreakerState:
        """Current circuit-breaker state (CLOSED when disarmed)."""
        return self._breaker_state

    def _pick_rung(self, remaining: float) -> str:
        """Highest rung whose estimated search+sweep cost fits the
        remaining deadline (with safety margin).  Falls through to "lbl"
        as the best-effort floor.  An OPEN breaker pins the ladder to
        "lbl" until its cooldown elapses, then HALF_OPEN lets one probe
        through at full quality."""
        if self.breaker_threshold and self._breaker_state is BreakerState.OPEN:
            if self.clock() >= self._breaker_open_until:
                self._breaker_state = BreakerState.HALF_OPEN
            else:
                return "lbl"
        if not np.isfinite(remaining):
            return "exact"
        allowance = remaining * _RUNG_SAFETY - self._sweep_ewma
        for rung in RUNGS[:-1]:
            if self._rung_ewma[rung] <= allowance:
                return rung
        return "lbl"

    def _resolve(self, adm: _Admitted) -> _Resolved:
        """Run the grouping search at the deadline-selected rung.

        Raises :class:`DeadlineExceeded` when the deadline expired before
        (or during — e.g. a stalled search) the resolution, and
        :class:`RequestCancelled` when the request was cancelled while
        queued."""
        if adm.request_id in self._cancelled:
            self._cancelled.discard(adm.request_id)
            raise RequestCancelled("cancelled while queued")
        now = self.clock()
        if now > adm.deadline:
            raise DeadlineExceeded(
                f"deadline expired {now - adm.deadline:.3f}s before the "
                "grouping search started"
            )
        rung = self._pick_rung(adm.deadline - now)

        if self.faults is not None and hasattr(self.faults, "before_search"):
            self.faults.before_search(adm)

        g, budget = adm.g, adm.budget
        t0 = self.clock()
        lbl = fusion.layer_by_layer_cuts(g)
        if rung == "exact":
            # The SAME resolution run_fleet(groupings="search") performs
            # offline — this is what makes non-degraded service plans
            # bit-identical to the batch answer.
            cuts, engine = flow.groupings_batch(
                g, "search", sram_budget_words=budget, with_provenance=True
            )
            # Re-resolving for the achieved cost is near-free: the
            # frontier DP memoises per (graph, budget), and the chain
            # DP / exhaustive paths are tiny at service graph sizes.
            best = fusion.optimal_cuts(g, sram_budget_words=budget)
            achieved = best.group_cost_words
            exact = best.exact
        else:
            if rung == "beam":
                res = fusion.beam_merge_cuts(g, sram_budget_words=budget)
            elif rung == "greedy":
                res = fusion.greedy_merge_cuts(g, sram_budget_words=budget)
            else:  # lbl — always buffer-minimal, the feasibility floor
                res = fusion.DPResult(
                    cuts=lbl,
                    group_cost_words=fusion._graph_cost(g, lbl),
                    n_groups=g.n_nodes,
                    engine="lbl",
                )
            # The lbl row rides along so the SRAM prefilter can never
            # reject the whole batch when *any* grouping is feasible.
            cuts = np.unique(np.stack([res.cuts, lbl]), axis=0)
            engine, achieved, exact = res.engine, res.group_cost_words, False
        dt = self.clock() - t0
        self._rung_ewma[rung] += _EWMA_ALPHA * (dt - self._rung_ewma[rung])

        now = self.clock()
        if now > adm.deadline:
            raise DeadlineExceeded(
                f"grouping search ({rung}) overran the deadline by "
                f"{now - adm.deadline:.3f}s"
            )
        return _Resolved(
            adm=adm,
            cuts=cuts,
            engine=engine,
            rung=rung,
            exact=exact,
            quality_bound=achieved / _lower_bound_cost(g),
        )

    # ------------------------------------------------------------------
    # micro-batched sweep
    # ------------------------------------------------------------------

    def _with_retries(self, fn: Callable[[], flow.FleetResult]):
        """Request-level face of the shared :class:`RetryPolicy`: typed
        evaluator errors are deterministic verdicts and propagate
        immediately; anything else is retried with backoff, counted in
        ``transient_retries``, and exhausts into a typed
        :class:`TransientFailure`."""

        def count(attempt: int, exc: BaseException) -> None:
            self._counters["transient_retries"] += 1

        return self.retry_policy.call(fn, describe="sweep", on_retry=count)

    def _group_abort_check(self, group: list[_Resolved]) -> Callable[[], None]:
        """The chunked sweep's between-chunk preemption point: raises
        :class:`_SweepAborted` when any group member was cancelled or ran
        out of deadline — the sweep stops at the chunk boundary, never
        mid-kernel."""

        def check() -> None:
            if self.faults is not None and hasattr(
                self.faults, "before_chunk"
            ):
                self.faults.before_chunk()
            now = self.clock()
            for r in group:
                if r.adm.request_id in self._cancelled or now > r.adm.deadline:
                    raise _SweepAborted("abort at sweep-chunk boundary")

        return check

    def _maybe_audit(self, adm: _Admitted, resp: PlanResponse) -> PlanResponse:
        """Shadow audit: every ``1/shadow_audit_rate``-th served plan is
        re-scored by the scalar oracle; a divergent answer is replaced
        with a typed :class:`AuditMismatch` rejection (fail loudly, never
        serve a silently wrong plan)."""
        if self.shadow_audit_rate <= 0 or resp.plan is None:
            return resp
        self._audit_counter += 1
        period = max(1, int(round(1.0 / self.shadow_audit_rate)))
        if self._audit_counter % period:
            return resp
        from . import metrics as M

        self._counters["audits"] += 1
        plan = resp.plan
        ref = M.evaluate_ref(adm.g, plan.best_cuts, plan.best_hw)
        if self.faults is not None and hasattr(self.faults, "corrupt_audit"):
            ref = self.faults.corrupt_audit(ref)
        if ref != plan.best_metrics:
            self._counters["audit_mismatches"] += 1
            self._counters["err:AuditMismatch"] += 1
            return dataclasses.replace(
                resp,
                ok=False,
                plan=None,
                error=AuditMismatch(
                    f"request {adm.request_id}: sweep said "
                    f"{plan.best_metrics}, scalar oracle says {ref}"
                ),
                quality_bound=float("nan"),
            )
        return resp

    def _sweep_group(self, group: list[_Resolved]) -> None:
        """One run_fleet program for a (budget, constraints, space) group;
        on a group-level typed failure, falls back to singleton sweeps so
        one infeasible request cannot poison its neighbours.  With
        ``hw_chunk`` the program runs in resumable hardware-axis chunks; a
        cancellation/deadline abort answers the affected members and
        re-sweeps the survivors (cached executables make the restart
        cheap)."""
        adm0 = group[0].adm

        def run() -> flow.FleetResult:
            if self.faults is not None and hasattr(
                self.faults, "before_sweep"
            ):
                self.faults.before_sweep(len(group))
            return flow.run_fleet(
                [r.adm.g for r in group],
                config_space=adm0.config_space,
                constraints=adm0.constraints,
                groupings=[r.cuts for r in group],
                sram_budget_words=adm0.budget,
                hw_chunk=self.hw_chunk,
                abort_check=(
                    self._group_abort_check(group)
                    if self.hw_chunk is not None
                    else None
                ),
                retry_policy=self.retry_policy,
                checkpoint_dir=self.checkpoint_dir,
                hooks=self.faults,
            )

        t0 = self.clock()
        try:
            fleet = self._with_retries(run)
        except _SweepAborted:
            survivors: list[_Resolved] = []
            now = self.clock()
            for r in group:
                rid = r.adm.request_id
                if rid in self._cancelled:
                    self._cancelled.discard(rid)
                    self._counters["cancelled_in_sweep"] += 1
                    self._reject(
                        rid,
                        RequestCancelled(
                            "cancelled mid-sweep; stopped at the chunk "
                            "boundary"
                        ),
                        r.adm.submitted_at,
                    )
                elif now > r.adm.deadline:
                    self._reject(
                        rid,
                        DeadlineExceeded(
                            f"deadline expired mid-sweep "
                            f"({now - r.adm.deadline:.3f}s past)"
                        ),
                        r.adm.submitted_at,
                    )
                else:
                    survivors.append(r)
            if survivors:
                self._sweep_group(survivors)
            return
        except EvaluatorError as e:
            if len(group) == 1:
                self._reject(group[0].adm.request_id, e, group[0].adm.submitted_at)
                return
            for r in group:  # isolate: re-sweep each request alone
                self._sweep_group([r])
            return
        self._sweep_ewma += _EWMA_ALPHA * (
            (self.clock() - t0) - self._sweep_ewma
        )

        for r, fr in zip(group, fleet.results):
            adm = r.adm
            resp = PlanResponse(
                request_id=adm.request_id,
                ok=True,
                # run_fleet reports the explicit batch as "explicit";
                # restore the ladder's true provenance.
                plan=dataclasses.replace(fr, search_engine=r.engine),
                engine=r.engine,
                rung=r.rung,
                exact=r.exact,
                degraded=r.rung != "exact",
                quality_bound=r.quality_bound,
                latency_seconds=self.clock() - adm.submitted_at,
            )
            resp = self._maybe_audit(adm, resp)
            self._record_response(resp)
            if not resp.ok:
                continue
            self._breaker_on_success()
            self._counters["completed"] += 1
            if resp.degraded:
                self._counters["degraded"] += 1
            else:
                self._cache_put(adm.cache_key, resp)

    def tick(self) -> int:
        """Process one micro-batch; returns how many responses were
        produced.  Never raises for a request's failure — every outcome
        becomes a typed response.

        One tick dequeues up to ``max_batch`` admitted requests, resolves
        each one's grouping through the deadline ladder, groups the
        resolutions by (budget, constraints, config space), and answers
        each group with ONE coalesced :func:`repro.core.flow.run_fleet`
        program (per-graph explicit cut batches through the shared shape
        buckets).  Deadlines that expire mid-tick become
        ``DeadlineExceeded`` responses; transient sweep failures retry
        with backoff before a ``TransientFailure`` verdict.

        Example — an event loop calling tick until a request resolves::

            >>> from repro.core.service import PlanningService, PlanRequest
            >>> from repro.core.ir import resnet18_ir
            >>> svc = PlanningService()
            >>> rid = svc.submit(PlanRequest(graph=resnet18_ir(),
            ...                              deadline_seconds=0.5))
            >>> resp = None
            >>> while resp is None:          # doctest: +SKIP
            ...     _ = svc.tick()
            ...     resp = svc.collect(rid)  # pops once answered

        (Offline callers can use :meth:`plan` — submit + drain + collect
        in one call — instead of running the loop themselves.)
        """
        self._ticks += 1
        if self.faults is not None and hasattr(self.faults, "on_tick"):
            self.faults.on_tick(self._ticks)

        batch = self._take_batch()
        if not batch:
            return 0
        # WAL: the tick boundary is durable before any member is resolved,
        # so recovery can tell "queued" from "was inside a tick" (both
        # re-run, but the distinction is visible to the kill-point tests).
        if self._journal is not None:
            self._journal.append(
                "tick",
                {
                    "tick": self._ticks,
                    "rids": [a.request_id for a in batch],
                },
            )

        groups: dict[tuple, list[_Resolved]] = collections.OrderedDict()
        produced = 0
        for adm in batch:
            try:
                r = self._resolve(adm)
            except EvaluatorError as e:
                self._reject(adm.request_id, e, adm.submitted_at)
                produced += 1
                continue
            except Exception as e:
                self._reject(
                    adm.request_id,
                    TransientFailure(
                        f"grouping search failed "
                        f"({type(e).__name__}: {e})",
                        cause=e,
                        attempts=1,
                    ),
                    adm.submitted_at,
                )
                produced += 1
                continue
            key = (
                adm.budget,
                adm.constraints.as_row().tobytes(),
                adm.config_space,
            )
            groups.setdefault(key, []).append(r)

        for group in groups.values():
            self._sweep_group(group)
            produced += len(group)
        if self._journal is not None:
            self._journal.maybe_snapshot(self._snapshot_payload)
        return produced

    def _take_batch(self) -> list[_Admitted]:
        """Form one micro-batch.  Plain FIFO without affinity; with it,
        the FIFO head (always served — no starvation) plus queued requests
        sharing its shape-bucket/budget/constraints/space affinity key, so
        the whole batch sweeps through ONE compiled executable even under
        heterogeneous traffic."""
        batch: list[_Admitted] = []
        if not self._queue:
            return batch
        batch.append(self._queue.popleft())
        if not self.affinity_batching:
            while self._queue and len(batch) < self.max_batch:
                batch.append(self._queue.popleft())
            return batch
        key = self._affinity_key(batch[0])
        kept: collections.deque[_Admitted] = collections.deque()
        while self._queue and len(batch) < self.max_batch:
            adm = self._queue.popleft()
            if self._affinity_key(adm) == key:
                batch.append(adm)
            else:
                kept.append(adm)
        kept.extend(self._queue)  # unexamined tail, original order
        self._queue = kept
        if len(batch) > 1:
            self._counters["affinity_batched"] += len(batch) - 1
        return batch

    def _affinity_key(self, adm: _Admitted) -> tuple:
        """Requests with equal keys share a sweep group AND a compiled
        executable: same (L, E) shape bucket, budget, constraints, and
        config space (the C bucket depends on ladder output, so it cannot
        be part of the admission-time key)."""
        return (
            bucket_size(adm.g.n_nodes, flow.NODE_BUCKET_FLOOR),
            bucket_size(adm.g.n_edges, flow.EDGE_BUCKET_FLOOR),
            adm.budget,
            adm.constraints.as_row().tobytes(),
            adm.config_space,
        )

    # ------------------------------------------------------------------
    # retrieval / convenience
    # ------------------------------------------------------------------

    def cancel(self, request_id: int) -> bool:
        """Request cooperative cancellation of ``request_id``.

        Returns False when the request is unknown or already answered
        (the answer stands — cancellation never un-serves a plan).
        Otherwise the cancellation flag is set (and journaled) and the
        request is answered with
        :class:`~repro.core.errors.RequestCancelled`: at its next tick if
        still queued, or at the next ``hw_chunk`` boundary if its sweep is
        already running.  Safe to call from any thread — this is the
        async transport's mid-flight cancel path.
        """
        if request_id in self._done or request_id >= self._next_id:
            return False
        self._cancelled.add(request_id)
        if self._journal is not None:
            self._journal.append("cancel", {"rid": int(request_id)})
        self._counters["cancel_requested"] += 1
        return True

    def collect(self, request_id: int) -> PlanResponse | None:
        """Pop the response for ``request_id`` (None while pending)."""
        return self._responses.pop(request_id, None)

    def drain(self, max_ticks: int = 10_000) -> None:
        """Tick until the queue is empty."""
        while self._queue and max_ticks > 0:
            self.tick()
            max_ticks -= 1

    def plan(self, request: PlanRequest) -> PlanResponse:
        """One-shot convenience: submit, drain, collect."""
        rid = self.submit(request)
        self.drain()
        resp = self.collect(rid)
        assert resp is not None  # drain() guarantees an answer
        return resp

    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet answered by a tick."""
        return len(self._queue)

    def stats(self) -> dict:
        """Service accounting: counters, plan-cache and executable-cache
        stats, ladder EWMAs, breaker state, and the journal's last durable
        sequence number (0 without a journal)."""
        return {
            "counters": dict(self._counters),
            "queue_depth": len(self._queue),
            "ticks": self._ticks,
            "plan_cache": self.plan_cache_stats(),
            "sweep_cache": flow.sweep_cache_stats(),
            "rung_ewma_seconds": dict(self._rung_ewma),
            "sweep_ewma_seconds": self._sweep_ewma,
            "breaker": self._breaker_state.value,
            "journal_seq": (
                self._journal.seq if self._journal is not None else 0
            ),
        }

    def close(self) -> None:
        """Flush and close the journal (no-op without one)."""
        if self._journal is not None:
            self._journal.close()

    # ------------------------------------------------------------------
    # crash recovery
    # ------------------------------------------------------------------

    def _snapshot_payload(self) -> dict:
        """Full durable state at the current WAL position: everything
        :meth:`recover` needs without replaying records the snapshot
        supersedes."""
        return {
            "next_id": self._next_id,
            "ticks": self._ticks,
            "queue": [journal_mod.enc_request(a) for a in self._queue],
            "responses": {
                str(rid): journal_mod.enc_response(r)
                for rid, r in self._responses.items()
            },
            "cancelled": sorted(self._cancelled),
            "done": sorted(self._done),
            "counters": dict(self._counters),
        }

    @classmethod
    def recover(
        cls,
        journal_dir,
        *,
        journal_fsync: bool = True,
        snapshot_every: int = 64,
        **service_kwargs,
    ) -> "PlanningService":
        """Rebuild a service from its journal after a crash.

        Replays the newest snapshot plus the WAL tail: every journaled
        response is restored **bit-identically** (the journal's hex-float/
        raw-bytes codecs), and every request with a durable admission but
        no response — queued at the crash, or inside an in-flight tick —
        is re-enqueued so the next :meth:`drain` answers it exactly once.
        A request cancelled before the crash is answered with
        ``RequestCancelled`` immediately.  Deadlines restart with the
        budget the request had at admission (monotonic clocks do not
        survive a process).  The journal stays attached, so the recovered
        service keeps appending to the same WAL — recovery composes with
        itself (kill the recovered process, recover again).

        ``service_kwargs`` are the normal constructor arguments (config
        space, ladder/batch bounds, ...); they must match the crashed
        service's for re-runs to be bit-identical.
        """
        state, records = journal_mod.load(journal_dir)
        svc = cls(**service_kwargs)

        pending: "collections.OrderedDict[int, dict]" = (
            collections.OrderedDict()
        )
        cancelled: set[int] = set()
        if state is not None:
            svc._next_id = int(state["next_id"])
            svc._ticks = int(state["ticks"])
            svc._responses = {
                int(rid): journal_mod.dec_response(r)
                for rid, r in state["responses"].items()
            }
            svc._done = set(
                int(r) for r in state.get("done", ())
            ) | set(svc._responses)
            svc._counters = collections.Counter(
                {k: int(v) for k, v in state["counters"].items()}
            )
            for d in state["queue"]:
                q = journal_mod.dec_request(d)
                pending[q["rid"]] = q
            cancelled = set(int(r) for r in state.get("cancelled", ()))

        for rec in records:
            rtype, payload = rec["type"], rec["payload"]
            if rtype == "admit":
                q = journal_mod.dec_request(payload)
                pending[q["rid"]] = q
                svc._next_id = max(svc._next_id, q["rid"] + 1)
            elif rtype == "response":
                resp = journal_mod.dec_response(payload)
                pending.pop(resp.request_id, None)
                cancelled.discard(resp.request_id)
                svc._responses[resp.request_id] = resp
                svc._done.add(resp.request_id)
                svc._next_id = max(svc._next_id, resp.request_id + 1)
            elif rtype == "cancel":
                cancelled.add(int(payload["rid"]))
            elif rtype == "tick":
                # An in-flight tick: its unanswered members stay pending
                # and re-run below — "exactly once" across the crash.
                svc._ticks = max(svc._ticks, int(payload["tick"]))

        # Reattach AFTER replay: replayed records must not be re-appended,
        # while everything the recovered service does next is journaled as
        # usual (the Journal resumes at the last durable sequence number).
        svc._journal = journal_mod.Journal(
            journal_dir, fsync=journal_fsync, snapshot_every=snapshot_every
        )

        now = svc.clock()
        for rid, q in pending.items():  # admission (= rid) order
            if rid in cancelled:
                svc._reject(
                    rid,
                    RequestCancelled("cancelled before the crash"),
                    now,
                )
                continue
            budget_s = q["deadline_budget"]
            svc._queue.append(
                _Admitted(
                    request_id=rid,
                    g=q["graph"],
                    budget=q["budget"],
                    deadline=(
                        now + budget_s
                        if np.isfinite(budget_s)
                        else float("inf")
                    ),
                    constraints=q["constraints"],
                    config_space=q["config_space"],
                    submitted_at=now,
                    cache_key=(
                        q["graph"],
                        q["budget"],
                        q["constraints"].as_row().tobytes(),
                        q["config_space"],
                    ),
                )
            )
            svc._counters["recovered"] += 1
        return svc


class AsyncPlanningService:
    """Asynchronous transport over :class:`PlanningService`.

    One daemon worker thread owns the inner (single-threaded) service:
    callers hand requests to a thread-safe inbox and get a
    ``concurrent.futures.Future`` back immediately; the worker admits,
    ticks, and resolves each future with the typed
    :class:`PlanResponse`.  The division of labour is strict — only the
    worker touches the inner service's queue/responses/journal — except
    for the two operations designed to act mid-tick from any thread:
    cooperative cancellation (:meth:`cancel` flags the request so the
    running sweep stops at its next ``hw_chunk`` boundary) and the
    lock-guarded stats readers.

    Liveness follows the :class:`repro.runtime.fault_tolerance` idiom: the
    worker touches ``heartbeat_path`` every loop, and a watchdog thread
    (armed by ``watchdog_seconds``) calls ``on_stall(age_seconds)`` when
    the heartbeat goes stale — a stalled sweep is *observable* without
    killing it.

    Shutdown is graceful by default: :meth:`shutdown` (or leaving the
    ``with`` block) drains the queue so every accepted future resolves,
    then closes the journal; ``drain=False`` instead cancels everything
    still pending (each future resolves with ``RequestCancelled``).  Used
    as a context manager the transport is Ctrl-C-safe: a
    ``KeyboardInterrupt`` unwinds through ``__exit__``, which still
    drains before the process exits (demonstrated in
    examples/serve_lm.py).

    Example::

        >>> from repro.core.service import AsyncPlanningService, PlanRequest
        >>> from repro.core.ir import residual_block_ir
        >>> with AsyncPlanningService() as svc:
        ...     fut = svc.submit(PlanRequest(graph=residual_block_ir(),
        ...                                  sram_budget_words=2e6))
        ...     resp = fut.result(timeout=120)
        >>> resp.ok
        True
    """

    def __init__(
        self,
        service: PlanningService | None = None,
        *,
        poll_seconds: float = 0.005,
        heartbeat_path=None,
        watchdog_seconds: float = 0.0,
        on_stall: Callable[[float], None] | None = None,
        **service_kwargs,
    ):
        """Wrap ``service`` (or construct one from ``service_kwargs``) and
        start the worker.  ``poll_seconds`` bounds the idle-loop latency;
        ``heartbeat_path``/``watchdog_seconds``/``on_stall`` arm the
        liveness machinery."""
        if service is not None and service_kwargs:
            raise ValueError(
                "pass either a ready service or constructor kwargs, not both"
            )
        self.service = (
            service if service is not None else PlanningService(**service_kwargs)
        )
        self.poll_seconds = float(poll_seconds)
        self.heartbeat_path = heartbeat_path
        self.watchdog_seconds = float(watchdog_seconds)
        self.on_stall = on_stall

        self._inbox: "queue_mod.Queue" = queue_mod.Queue()
        self._futures: dict[int, concurrent.futures.Future] = {}
        self._futures_lock = threading.Lock()
        self._stop = threading.Event()
        self._drain_on_stop = True
        self._last_beat = time.monotonic()
        self._stalls = 0

        self._thread = threading.Thread(
            target=self._run, name="planning-service-worker", daemon=True
        )
        self._thread.start()
        self._watchdog: threading.Thread | None = None
        if self.watchdog_seconds > 0:
            self._watchdog = threading.Thread(
                target=self._watch, name="planning-service-watchdog",
                daemon=True,
            )
            self._watchdog.start()

    # -- caller-side API ------------------------------------------------

    def submit(self, request: PlanRequest) -> concurrent.futures.Future:
        """Enqueue one request; returns a Future resolving to its
        :class:`PlanResponse`.  The future grows a ``request_id``
        attribute once the worker admits it (needed only for debugging —
        :meth:`cancel` takes the future itself)."""
        if self._stop.is_set():
            raise RuntimeError("service is shut down")
        fut: concurrent.futures.Future = concurrent.futures.Future()
        fut.request_id = None
        fut.cancel_requested = False
        self._inbox.put((request, fut))
        return fut

    def cancel(self, fut: concurrent.futures.Future) -> bool:
        """Request cooperative cancellation of a submitted future.

        Effective at any stage: before admission (the worker cancels it
        on arrival), queued (answered at its next tick), or mid-sweep
        (the running chunked sweep aborts at its next chunk boundary).
        The future still *resolves* — with a ``RequestCancelled``
        response — unless the answer had already been served."""
        fut.cancel_requested = True
        rid = getattr(fut, "request_id", None)
        if rid is not None:
            return self.service.cancel(rid)
        return True

    def plan(self, request: PlanRequest, timeout: float | None = None):
        """Synchronous convenience: submit + wait."""
        return self.submit(request).result(timeout=timeout)

    def shutdown(self, *, drain: bool = True, timeout: float | None = None):
        """Stop the worker.  ``drain=True`` answers everything accepted
        first; ``drain=False`` cancels pending requests (their futures
        resolve with ``RequestCancelled``).  Idempotent."""
        self._drain_on_stop = drain
        self._stop.set()
        self._thread.join(timeout)

    def __enter__(self) -> "AsyncPlanningService":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        # Drain even when unwinding from KeyboardInterrupt: accepted
        # requests are answered (and journaled) before the process dies.
        self.shutdown(drain=True)

    def stats(self) -> dict:
        """Inner-service stats plus transport accounting."""
        with self._futures_lock:
            inflight = len(self._futures)
        return dict(
            self.service.stats(),
            transport={
                "inflight": inflight,
                "inbox": self._inbox.qsize(),
                "stalls": self._stalls,
                "heartbeat_age_seconds": time.monotonic() - self._last_beat,
            },
        )

    # -- worker side ----------------------------------------------------

    def _beat(self) -> None:
        self._last_beat = time.monotonic()
        if self.heartbeat_path is not None:
            try:
                with open(self.heartbeat_path, "w") as f:
                    f.write(f"{os.getpid()} {time.time():.3f}\n")
            except OSError:  # liveness reporting must never kill serving
                pass

    def _watch(self) -> None:
        interval = max(self.watchdog_seconds / 4, 0.001)
        while not self._stop.wait(interval):
            age = time.monotonic() - self._last_beat
            if age > self.watchdog_seconds:
                self._stalls += 1
                if self.on_stall is not None:
                    try:
                        self.on_stall(age)
                    except Exception:
                        pass

    def _ingest(self, block: bool) -> None:
        """Move every waiting submission from the inbox into the inner
        service (optionally blocking ``poll_seconds`` for the first)."""
        items = []
        if block:
            try:
                items.append(self._inbox.get(timeout=self.poll_seconds))
            except queue_mod.Empty:
                return
        while True:
            try:
                items.append(self._inbox.get_nowait())
            except queue_mod.Empty:
                break
        for request, fut in items:
            rid = self.service.submit(request)
            fut.request_id = rid
            with self._futures_lock:
                self._futures[rid] = fut
            if fut.cancel_requested:
                self.service.cancel(rid)

    def _deliver(self) -> None:
        with self._futures_lock:
            rids = list(self._futures)
        for rid in rids:
            resp = self.service.collect(rid)
            if resp is not None:
                with self._futures_lock:
                    fut = self._futures.pop(rid)
                if not fut.done():
                    fut.set_result(resp)

    def _run(self) -> None:
        svc = self.service
        while True:
            self._beat()
            self._ingest(block=not self._stop.is_set())
            if svc.queue_depth:
                svc.tick()
            self._deliver()
            if self._stop.is_set() and self._inbox.empty():
                if not self._drain_on_stop:
                    with self._futures_lock:
                        rids = list(self._futures)
                    for rid in rids:
                        svc.cancel(rid)
                while svc.queue_depth:
                    self._beat()
                    svc.tick()
                self._deliver()
                break
        svc.close()
