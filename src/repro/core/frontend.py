"""Tracing frontend — real JAX models to :class:`repro.core.ir.GraphIR`.

Every workload the evaluator scores used to be a hand-written ``*_ir``
builder; this module removes that transcription step.  :func:`trace` runs
``jax.make_jaxpr`` on a model's forward pass (weights may be
``jax.ShapeDtypeStruct`` pytrees — nothing is materialised) and lowers the
jaxpr onto the paper's layer abstraction:

* ``conv_general_dilated``  -> ``conv`` nodes (``feature_group_count`` maps
  to :class:`LayerSpec` ``groups``, so depthwise/grouped convs cost the
  right kernels words and MACs);
* ``dot_general``           -> ``matmul`` nodes (a matmul is the degenerate
  1x1 convolution over ``M`` "pixels"; ``M == 1`` is tagged ``fc``).  A
  ``dot_general`` whose *both* operands are activations becomes ``actmul``
  (attention's QK^T / PV — the "kernel" operand is activation traffic);
  batch dimensions fold into the contraction (``k *= B``) so one actmul
  node prices all heads, and its O(S^2) score matrix is an explicit edge.
  Two-activation products whose operands descend from the *same* dataflow
  source (MoE's combine-weights einsum — a rearrangement of one tensor)
  fold instead of minting a bogus giant actmul.  An activation against a
  *batched weight* stack (MoE's ``(E, d, ff)`` expert einsums) expands
  into ``E`` branch ``matmul`` nodes whose incoming edges carry the routed
  per-expert capacity words — the producer becomes a *tuple* of node ids,
  and downstream elementwise ops over equal-length tuples stay branched
  (per-expert gate nodes) until a two-source product joins them;
* ``scan``                  -> one ``scan`` node (SSM selective scan): a
  weightless recurrent layer whose carry words (``d_state x d_inner``)
  become :class:`LayerSpec` ``state_words`` — SRAM the carry occupies in
  *every* grouping, priced by Eq. (4) and the buffer-feasibility checks.
  The chunk-recurrent form (``repro.models.ssm.selective_scan_chunked``)
  traces to the same node; splitting a model at a chunk boundary
  (:func:`mamba_graph` with ``chunks > 1``) exposes the carry hand-off as
  a real cuttable edge;
* ``reduce_window_{max,sum,min}`` -> ``pool`` nodes, or — with
  ``fold_pool=True`` and a window that equals its stride — absorbed into
  the producing conv's ``pool_after`` (the DLA's inline pool unit, Fig. 1);
* everything else is **folded**: an elementwise op (bias add, ReLU/SiLU,
  BN scale/shift, reshape/transpose/cast plumbing) whose activation
  operands come from a single producer node contributes no node — its
  output is re-attributed to that producer.  An elementwise op that *joins*
  two or more distinct dataflow sources (a residual add, a gated-MLP
  product; every graph input is its own source) becomes an ``elementwise``
  node, which is exactly how fan-in is represented in the hand-built DAGs.
  Operands read straight off a graph input have no producer node to fuse
  over, so a non-source consumer (a join, or an ``actmul``/``matmul`` with
  one produced operand) charges their words as ``LayerSpec.ext_in_words``
  — DRAM traffic in every grouping, counted by Eq. (1)-(3).

Dataflow recovery: a var is an *activation* iff it descends from a
designated activation argument (default: the last positional argument, so
``forward(params, x)`` traces with ``x`` as the input frame); every other
invar/constvar is weight or constant traffic.  Edges follow jaxpr use-def
between surviving nodes and carry the consumed tensor's word count, so
fan-out (a tensor read by several consumers) and skip paths come out as
real DAG edges.

The canonical builders at the bottom (``vgg16_network``,
``resnet18_graph``, ``mobilenet_graph``, ``mlp_block_graph``) trace the
real models in :mod:`repro.models` and rename nodes to the historical
builder names; ``repro.core.ir.vgg16_ir`` / ``resnet18_ir`` are thin
wrappers over them (locked node-and-edge-identical to verbatim
transcriptions of the old hand builders in ``tests/test_frontend.py``).

Geometry is validated as it is derived: the evaluator's ``SAME``-padding
``h_in // stride`` arithmetic must reproduce the traced output shape of
every conv/pool node, otherwise :func:`trace` raises rather than emitting
an IR whose edge words disagree with its node frames.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.extend import core as jex_core

from .errors import GraphValidationError, UnsupportedOpError
from .ir import (
    RESNET18_STAGE_PLAN,
    VGG16_CONV_PLAN,
    EdgeSpec,
    GraphIR,
    LayerSpec,
    NetworkIR,
)

_REDUCE_WINDOW_PRIMS = ("reduce_window_max", "reduce_window_sum", "reduce_window_min")
_SPATIAL_REDUCE_PRIMS = ("reduce_sum", "reduce_max", "reduce_min")


def _words(aval) -> int:
    """Word count of a traced tensor (the paper uses one word per element)."""
    return int(np.prod(aval.shape, dtype=np.int64)) if aval.shape else 1


def _chw(shape: tuple[int, ...]) -> tuple[int, int, int]:
    """(channels, h, w) of an activation tensor: channels-last, leading
    size-1 batch axis dropped, remaining axes flattened into (h, w)."""
    if len(shape) > 2 and shape[0] == 1:
        shape = shape[1:]
    if not shape:
        return 1, 1, 1
    c = shape[-1]
    spatial = shape[:-1]
    if not spatial:
        return c, 1, 1
    if len(spatial) == 1:
        return c, int(spatial[0]), 1
    return c, int(spatial[0]), int(math.prod(spatial[1:]))


@dataclasses.dataclass
class _PendingNode:
    spec: LayerSpec
    inputs: dict[int, int]  # producer node id -> words read from it


class _Tracer:
    """``producer`` maps every activation var to the *dataflow source* it
    descends from: an ``int`` node id, or — for values read straight off a
    graph input — the original input var itself, so two different inputs
    stay two different sources (and two views of one input stay one)."""

    def __init__(self, *, name: str, fold_pool: bool):
        self.name = name
        self.fold_pool = fold_pool
        self.nodes: list[_PendingNode] = []
        self.producer: dict[Any, Any] = {}  # activation var -> source

    # ---- helpers -----------------------------------------------------------
    def _act_inputs(self, eqn) -> list[tuple[Any, Any]]:
        return [
            (v, self.producer[v])
            for v in eqn.invars
            if not isinstance(v, jex_core.Literal) and v in self.producer
        ]

    def _add_node(self, spec: LayerSpec, act_in) -> int:
        node = _PendingNode(spec=spec, inputs={})
        for v, p in act_in:
            if isinstance(p, tuple):
                # Branch fan-in (expert stacks): the consumed tensor is the
                # concatenation of the branch outputs — one edge per branch,
                # words split evenly across the members.
                w = max(1, _words(v.aval) // len(p))
                for member in p:
                    node.inputs[member] = max(node.inputs.get(member, 0), w)
                continue
            if not isinstance(p, int):
                continue  # graph-input operand: no producer node to fuse with
            w = _words(v.aval)
            node.inputs[p] = max(node.inputs.get(p, 0), w)
        self.nodes.append(node)
        return len(self.nodes) - 1

    def _ext_words(self, act_in) -> int:
        """Words of operands read straight off a graph input — DRAM traffic
        in every grouping (deduped per input var: two views of one input
        are one read)."""
        by_src: dict[Any, int] = {}
        for v, p in act_in:
            if not isinstance(p, (int, tuple)):
                by_src[p] = max(by_src.get(p, 0), _words(v.aval))
        return sum(by_src.values())

    def _check_geometry(self, spec: LayerSpec, out_shape, *, what: str) -> None:
        c, h, w = _chw(tuple(out_shape))
        if (spec.n_out, spec.h_out, spec.w_out) != (c, h, w):
            raise UnsupportedOpError(
                f"{self.name}: traced {what} {spec.name} derives "
                f"{spec.n_out}x{spec.h_out}x{spec.w_out} but the jaxpr "
                f"produces {c}x{h}x{w} — only SAME-padding geometry "
                f"(out = in // stride) is representable"
            )

    # ---- primitive lowering ------------------------------------------------
    def eqn_conv(self, eqn, act_in) -> None:
        lhs, rhs = eqn.invars[0], eqn.invars[1]
        if rhs in self.producer:
            raise UnsupportedOpError(
                f"{self.name}: conv with an activation kernel operand is "
                "not supported (use dot_general for activation products)"
            )
        # act_in is non-empty and rhs is not activation, so lhs is.
        p = eqn.params
        dn = p["dimension_numbers"]
        if p["lhs_dilation"] != (1,) * len(p["lhs_dilation"]) or p[
            "rhs_dilation"
        ] != (1,) * len(p["rhs_dilation"]):
            raise UnsupportedOpError(f"{self.name}: dilated convolutions unsupported")
        lshape, rshape = lhs.aval.shape, rhs.aval.shape
        if lshape[dn.lhs_spec[0]] != 1:
            raise UnsupportedOpError(f"{self.name}: trace with batch size 1")
        n_in = int(lshape[dn.lhs_spec[1]])
        spatial = [int(lshape[i]) for i in dn.lhs_spec[2:]]
        h_in, w_in = (spatial + [1])[:2]
        n_out = int(rshape[dn.rhs_spec[0]])
        ks = [int(rshape[i]) for i in dn.rhs_spec[2:]]
        kh, kw = (ks + [1])[:2]
        strides = tuple(int(s) for s in p["window_strides"])
        if len(set(strides)) != 1:
            raise UnsupportedOpError(f"{self.name}: anisotropic conv strides unsupported")
        groups = int(p["feature_group_count"])
        spec = LayerSpec(
            f"conv{len(self.nodes)}", "conv", n_in, n_out, h_in, w_in,
            kh, kw, strides[0], groups=groups,
        )
        out = eqn.outvars[0]
        out_spatial = [int(out.aval.shape[i]) for i in dn.out_spec[2:]]
        oh, ow = (out_spatial + [1])[:2]
        if (spec.h_out, spec.w_out) != (oh, ow):
            raise UnsupportedOpError(
                f"{self.name}: conv {spec.name} derives {spec.h_out}x{spec.w_out} "
                f"but the jaxpr produces {oh}x{ow} — only SAME-padding geometry "
                "(out = in // stride) is representable"
            )
        self.producer[out] = self._add_node(spec, [(lhs, self.producer[lhs])])

    def eqn_dot(self, eqn, act_in) -> None:
        lhs, rhs = eqn.invars[0], eqn.invars[1]
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lshape, rshape = lhs.aval.shape, rhs.aval.shape
        # Batch dims are pairwise equal-sized in both operands (jax checks);
        # B == 1 is the plain unbatched product.
        B = int(math.prod(lshape[d] for d in lb))
        k = int(math.prod(lshape[d] for d in lc))
        l_free = int(
            math.prod(lshape[d] for d in range(len(lshape)) if d not in lc and d not in lb)
        )
        r_free = int(
            math.prod(rshape[d] for d in range(len(rshape)) if d not in rc and d not in rb)
        )
        out = eqn.outvars[0]
        lhs_is_act = lhs in self.producer
        if len(act_in) == 2:
            if self.producer[lhs] == self.producer[rhs] and isinstance(
                self.producer[lhs], (int, tuple)
            ):
                # Both operands are views of ONE dataflow source (MoE's
                # combine-weights einsum: dispatch one-hots x gates, both
                # derived from the router) — a rearrangement, not a compute
                # node.  Minting an actmul here would price B * k bogus
                # MACs per output word.
                self.producer[out] = self.producer[lhs]
                return
            # Attention-style activation product: the batch axes (heads)
            # fold into the contraction/output so one node prices them all.
            kind, k, m, n = "actmul", B * k, l_free, B * r_free
        elif B > 1:
            # One activation against a stacked weight tensor (MoE expert
            # einsums, (E, d, ff)): E independent matmuls — expand into B
            # branch nodes so each expert's routed capacity words become a
            # real edge.  The out producer is the tuple of branch ids.
            av = lhs if lhs_is_act else rhs
            m = l_free if lhs_is_act else r_free
            n = r_free if lhs_is_act else l_free
            kind = "fc" if m == 1 else "matmul"
            if _words(out.aval) != B * m * n:
                raise UnsupportedOpError(
                    f"{self.name}: batched dot_general output has "
                    f"{_words(out.aval)} words, expected {B}*{m}*{n}"
                )
            p_act = self.producer[av]
            if isinstance(p_act, tuple) and len(p_act) != B:
                raise UnsupportedOpError(
                    f"{self.name}: {len(p_act)}-branch operand into a "
                    f"{B}-batched dot_general"
                )
            branch_words = max(1, _words(av.aval) // B)
            ext = 0 if isinstance(p_act, (int, tuple)) else branch_words
            ids = []
            for b in range(B):
                spec = LayerSpec(
                    f"{kind}{len(self.nodes)}", kind, k, n, m, 1,
                    ext_in_words=ext,
                )
                node = _PendingNode(spec=spec, inputs={})
                if isinstance(p_act, tuple):
                    node.inputs[p_act[b]] = branch_words  # branch b feeds b
                elif isinstance(p_act, int):
                    node.inputs[p_act] = branch_words  # fan-out (dispatch)
                self.nodes.append(node)
                ids.append(len(self.nodes) - 1)
            self.producer[out] = tuple(ids)
            return
        else:
            m, n = (l_free, r_free) if lhs_is_act else (r_free, l_free)
            kind = "fc" if m == 1 else "matmul"
        # A graph-input operand of a non-source node (e.g. actmul of a
        # projected query against the raw input) has no edge to fuse over:
        # its words stream from DRAM in every grouping.  Source nodes
        # already count all operands via in_words.
        has_edge = any(isinstance(p, (int, tuple)) for _, p in act_in)
        ext = self._ext_words(act_in) if has_edge else 0
        spec = LayerSpec(
            f"{kind}{len(self.nodes)}", kind, k, n, m, 1, ext_in_words=ext
        )
        if _words(out.aval) != m * n:
            raise UnsupportedOpError(
                f"{self.name}: dot_general output has {_words(out.aval)} words, "
                f"expected {m}*{n}"
            )
        self.producer[out] = self._add_node(spec, act_in)

    def eqn_reduce_window(self, eqn, act_in) -> None:
        (v, p_id) = act_in[0]
        shape = v.aval.shape
        window = tuple(int(d) for d in eqn.params["window_dimensions"])
        strides = tuple(int(s) for s in eqn.params["window_strides"])
        if len(shape) != 4 or window[0] != 1 or window[3] != 1:
            raise UnsupportedOpError(
                f"{self.name}: reduce_window expects NHWC with a spatial "
                f"window, got shape {shape} window {window}"
            )
        if shape[0] != 1:
            raise UnsupportedOpError(f"{self.name}: trace with batch size 1")
        kh, kw = window[1], window[2]
        sh, sw = strides[1], strides[2]
        if sh != sw:
            raise UnsupportedOpError(f"{self.name}: anisotropic pool strides unsupported")
        c, h_in, w_in = int(shape[3]), int(shape[1]), int(shape[2])
        out = eqn.outvars[0]
        if (
            self.fold_pool
            and isinstance(p_id, int)
            and self.nodes[p_id].spec.kind == "conv"
            and self.nodes[p_id].spec.pool_after == 1
            and (kh, kw) == (sh, sw)
            and self._use_count[v] == 1
        ):
            # Absorb into the producing conv's inline pool unit (Fig. 1).
            spec = dataclasses.replace(self.nodes[p_id].spec, pool_after=sh)
            self._check_geometry(spec, out.aval.shape, what="absorbed pool")
            self.nodes[p_id].spec = spec
            self.producer[out] = p_id
            return
        spec = LayerSpec(
            f"pool{len(self.nodes)}", "pool", c, c, h_in, w_in, kh, kw, sh
        )
        self._check_geometry(spec, out.aval.shape, what="pool")
        self.producer[out] = self._add_node(spec, act_in)

    def eqn_spatial_reduce(self, eqn, act_in) -> bool:
        """Global spatial reduction (``jnp.mean(x, (1, 2))``) -> pool node.
        Returns False when the reduction is not spatial-pool shaped (the
        caller then raises: folding a shape-changing reduction would break
        the producer-frame / edge-words consistency)."""
        (v, p_id) = act_in[0]
        shape = v.aval.shape
        axes = tuple(sorted(int(a) for a in eqn.params["axes"]))
        if len(shape) != 4 or axes != (1, 2) or shape[1] != shape[2]:
            return False
        if shape[0] != 1:
            raise UnsupportedOpError(f"{self.name}: trace with batch size 1")
        c, hw = int(shape[3]), int(shape[1])
        spec = LayerSpec(
            f"pool{len(self.nodes)}", "pool", c, c, hw, hw, hw, hw, hw
        )
        self.producer[eqn.outvars[0]] = self._add_node(spec, act_in)
        return True

    def eqn_scan(self, eqn, act_in) -> None:
        """``lax.scan`` -> one recurrent ``scan`` node.  The carry operands'
        words become ``state_words`` (summed over *all* carries by position
        — an initial state built as ``jnp.zeros`` inside the traced fn is a
        constant, not an activation, but still occupies the SRAM).  The node
        frame is the largest stacked output (the per-step ys restacked over
        the scan axis), so edge words stay consistent with consumers."""
        p = eqn.params
        nc, nk = int(p["num_consts"]), int(p["num_carry"])
        state = int(
            sum(_words(v.aval) for v in eqn.invars[nc : nc + nk])
        )
        ys = list(eqn.outvars[nk:]) or list(eqn.outvars[:1])
        big = max(ys, key=lambda o: _words(o.aval))
        c, h, w = _chw(tuple(big.aval.shape))
        has_edge = any(isinstance(pp, (int, tuple)) for _, pp in act_in)
        ext = self._ext_words(act_in) if has_edge else 0
        spec = LayerSpec(
            f"scan{len(self.nodes)}", "scan", c, c, h, w,
            ext_in_words=ext, state_words=state,
        )
        node = self._add_node(spec, act_in)
        for o in eqn.outvars:
            self.producer[o] = node

    def eqn_default(self, eqn, act_in) -> None:
        """Fold, or join >= 2 distinct sources into an ``elementwise`` node
        (the graph input counts as a source, so a residual add of the raw
        input still surfaces as a join).  Operands read straight from the
        graph input have no producer edge to fuse over, so their words
        become the join's ``ext_in_words`` — DRAM traffic in every
        grouping.  An op over >= 2 equal-length *tuple* producers (the
        expert-branch gate: silu(w1_e) * w3_e) stays branched — one
        ``elementwise`` node per member, pairwise — so the expert fan-out
        topology survives until a real combine joins it."""
        distinct = {p for _, p in act_in}
        if len(distinct) >= 2:
            out = eqn.outvars[0]
            c, h, w = _chw(tuple(out.aval.shape))
            if all(isinstance(p, tuple) for p in distinct) and (
                len({len(p) for p in distinct}) == 1
            ):
                branches = sorted(distinct)
                nb = len(branches[0])
                total = _words(out.aval)
                bw = max(1, total // nb)
                hb = max(1, bw // c)
                ids = []
                for b in range(nb):
                    spec = LayerSpec(
                        f"gate{len(self.nodes)}", "elementwise", c, c, hb, 1
                    )
                    node = _PendingNode(spec=spec, inputs={})
                    for t in branches:
                        node.inputs[t[b]] = max(node.inputs.get(t[b], 0), bw)
                    self.nodes.append(node)
                    ids.append(len(self.nodes) - 1)
                for o in eqn.outvars:
                    self.producer[o] = tuple(ids)
                return
            ext = self._ext_words(act_in)
            if not any(isinstance(p, (int, tuple)) for p in distinct):
                # All operands are raw inputs: the node is a *source* and
                # already reads in_words (one frame) — ext carries only the
                # frames beyond that.
                ext = max(0, ext - c * h * w)
            spec = LayerSpec(
                f"join{len(self.nodes)}", "elementwise", c, c, h, w,
                ext_in_words=int(ext),
            )
            node = self._add_node(spec, act_in)
            for o in eqn.outvars:
                self.producer[o] = node
            return
        p = distinct.pop() if distinct else None
        for o in eqn.outvars:
            self.producer[o] = p

    # ---- driver ------------------------------------------------------------
    def run(self, jaxpr) -> GraphIR:
        self._use_count: dict[Any, int] = {}
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if not isinstance(v, jex_core.Literal):
                    self._use_count[v] = self._use_count.get(v, 0) + 1
        for v in jaxpr.outvars:
            if not isinstance(v, jex_core.Literal):
                self._use_count[v] = self._use_count.get(v, 0) + 1
        for eqn in jaxpr.eqns:
            act_in = self._act_inputs(eqn)
            if not act_in:
                continue  # weights/constants only: nothing reaches the IR
            prim = eqn.primitive.name
            if prim == "conv_general_dilated":
                self.eqn_conv(eqn, act_in)
            elif prim == "dot_general":
                self.eqn_dot(eqn, act_in)
            elif prim in _REDUCE_WINDOW_PRIMS:
                self.eqn_reduce_window(eqn, act_in)
            elif prim in _SPATIAL_REDUCE_PRIMS:
                # Only an NHWC reduction over *both* spatial axes is
                # pool-shaped; everything else (softmax / rmsnorm statistics
                # over the channel axis, MoE routing sums over arbitrary
                # axes) is a normalisation-style statistic that folds or
                # joins like any elementwise op.
                shape = eqn.invars[0].aval.shape
                axes = tuple(sorted(int(a) for a in eqn.params["axes"]))
                if len(shape) == 4 and axes == (1, 2):
                    if not self.eqn_spatial_reduce(eqn, act_in):
                        # A rectangular global reduction would emit a pool
                        # whose SAME-geometry frame disagrees with the
                        # traced output — refuse.
                        raise UnsupportedOpError(
                            f"{self.name}: {prim} over axes "
                            f"{tuple(eqn.params['axes'])} on shape "
                            f"{eqn.invars[0].aval.shape} is not representable "
                            "(only square NHWC global spatial reductions map "
                            "to pool nodes)"
                        )
                else:
                    self.eqn_default(eqn, act_in)
            elif prim == "scan":
                self.eqn_scan(eqn, act_in)
            else:
                self.eqn_default(eqn, act_in)
        if not self.nodes:
            raise UnsupportedOpError(f"{self.name}: no layers traced")
        edges = tuple(
            EdgeSpec(src, dst, words)
            for dst, node in enumerate(self.nodes)
            for src, words in sorted(node.inputs.items())
        )
        return GraphIR(self.name, tuple(n.spec for n in self.nodes), edges)


def trace(
    fn: Callable,
    *args,
    name: str = "traced",
    activation_argnums: Sequence[int] | None = None,
    fold_pool: bool = False,
    names: Sequence[str] | None = None,
) -> GraphIR:
    """Trace ``fn(*args)`` into a :class:`GraphIR`.

    ``args`` are pytrees of arrays or ``jax.ShapeDtypeStruct`` (weights are
    never materialised).  ``activation_argnums`` marks which arguments are
    activation inputs (default: the last one, matching ``forward(params,
    x)``); activations must be traced with batch size 1.  ``fold_pool``
    absorbs a window == stride pooling into its producing conv's
    ``pool_after`` when the pooled tensor has no other consumer.  ``names``
    optionally renames the nodes (length-checked).

    Example — a gated MLP, weights as shape structs only::

        >>> import jax, jax.numpy as jnp
        >>> from repro.core import frontend as F
        >>> from repro.models import layers as L
        >>> sds = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)
        >>> params = {"w1": sds(256, 1024), "w3": sds(256, 1024),
        ...           "w2": sds(1024, 256)}
        >>> g = F.trace(lambda p, x: L.mlp_block(p, x, "swiglu"),
        ...             params, sds(128, 256), name="mlp")
        >>> [n.kind for n in g.nodes]
        ['matmul', 'matmul', 'elementwise', 'matmul']
        >>> g.n_edges  # w1 -> gate, w3 -> gate, gate -> w2
        3

    Failures are typed: anything the layer abstraction cannot represent
    raises :class:`repro.core.errors.UnsupportedOpError` (a subclass of
    ``ValueError``), never a raw ``KeyError``/``IndexError`` — the planning
    service's admission path relies on this contract.
    """
    if not args:
        raise UnsupportedOpError("trace() needs at least one example argument")
    nums = (
        {len(args) - 1}
        if activation_argnums is None
        else {a % len(args) for a in activation_argnums}
    )
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except (UnsupportedOpError, GraphValidationError):
        raise
    except Exception as e:
        # jax itself rejected the function (rank/shape errors surface as
        # raw ValueError/IndexError/TypeError while *building* the jaxpr)
        # — the trace boundary converts them to the typed taxonomy.
        raise UnsupportedOpError(
            f"{name}: fn is not traceable to a jaxpr "
            f"({type(e).__name__}: {e})"
        ) from e
    tr = _Tracer(name=name, fold_pool=fold_pool)
    invars = iter(closed.jaxpr.invars)
    for i, arg in enumerate(args):
        leaves = jax.tree_util.tree_leaves(arg)
        for _ in leaves:
            v = next(invars)
            if i in nums:
                tr.producer[v] = v  # each input var is its own source
    # Lowering must fail *typed*: an unlowerable jaxpr is an
    # UnsupportedOpError and a lowered-but-invalid IR a
    # GraphValidationError — never a raw KeyError/IndexError from a
    # degenerate primitive the lowering rules did not anticipate (the
    # contract the service admission path and the fuzz tests rely on).
    try:
        g = tr.run(closed.jaxpr)
    except (GraphValidationError, UnsupportedOpError):
        raise
    except (KeyError, IndexError, AttributeError, TypeError,
            ZeroDivisionError, AssertionError) as e:
        raise UnsupportedOpError(
            f"{name}: jaxpr is not lowerable to the layer abstraction "
            f"({type(e).__name__}: {e})"
        ) from e
    if names is not None:
        g = rename_nodes(g, names)
    return g


def rename_nodes(g: GraphIR, names: Sequence[str]) -> GraphIR:
    """Rename every node (length-checked) — traced graphs get the
    historical hand-builder names this way."""
    if len(names) != len(g.nodes):
        raise UnsupportedOpError(
            f"{g.name}: {len(names)} names for {len(g.nodes)} nodes "
            f"(traced: {[n.name for n in g.nodes]})"
        )
    nodes = tuple(
        dataclasses.replace(n, name=nm) for n, nm in zip(g.nodes, names)
    )
    return GraphIR(g.name, nodes, g.edges)


def to_chain(g: GraphIR, name: str | None = None) -> NetworkIR:
    """Collapse a chain-shaped trace back to the legacy :class:`NetworkIR`."""
    if not g.is_chain:
        raise UnsupportedOpError(f"{g.name} is not a chain ({g.n_edges} edges)")
    return NetworkIR(name or g.name, g.nodes)


# ---------------------------------------------------------------------------
# Canonical model builders (the thin wrappers `repro.core.ir` re-exports)
# ---------------------------------------------------------------------------


def _sds(*shape, dtype=None):
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(tuple(shape), dtype or jnp.float32)


def vgg16_network(
    *, pool_mode: str = "separate", include_fc: bool = False
) -> NetworkIR:
    """VGG-16 traced from :mod:`repro.models.vgg` (the paper's Sec. III
    workload) — ``pool_mode="absorbed"`` folds each 2x2 pool into its conv."""
    from ..models import vgg

    if pool_mode not in ("separate", "absorbed"):
        raise UnsupportedOpError(pool_mode)
    g = trace(
        vgg.forward,
        vgg.param_specs(),
        _sds(1, 224, 224, 3),
        name="vgg16",
        fold_pool=(pool_mode == "absorbed"),
    )
    names: list[str] = []
    for lname, _n_in, _n_out, _hw, pooled in VGG16_CONV_PLAN:
        names.append(lname)
        if pooled and pool_mode == "separate":
            names.append(f"pool{lname[4]}")
    n_feature = len(names)
    names += ["fc6", "fc7", "fc8"]
    net = to_chain(rename_nodes(g, names), "vgg16")
    if not include_fc:
        net = NetworkIR("vgg16", net.layers[:n_feature])
    return net


def resnet18_graph(*, input_hw: int = 224) -> GraphIR:
    """ResNet-18 traced from :mod:`repro.models.resnet` — the skip adds come
    out as real join nodes with two incoming edges."""
    from ..models import resnet

    g = trace(
        resnet.forward,
        resnet.param_specs(),
        _sds(1, input_hw, input_hw, 3),
        name="resnet18",
    )
    names = ["conv1", "pool1"]
    c_in = 64
    for stage, n_blocks, c_out, stride0 in RESNET18_STAGE_PLAN:
        for b in range(n_blocks):
            stride = stride0 if b == 0 else 1
            cin_blk = c_in if b == 0 else c_out
            tag = f"s{stage}b{b}"
            names += [f"{tag}.conv_a", f"{tag}.conv_b"]
            if stride != 1 or cin_blk != c_out:
                names.append(f"{tag}.downsample")
            names.append(f"{tag}.add")
        c_in = c_out
    names += ["avgpool", "fc"]
    return rename_nodes(g, names)


def mobilenet_graph(
    *, input_hw: int = 112, plan: tuple | None = None
) -> GraphIR:
    """MobileNet-style inverted-residual stack traced from
    :mod:`repro.models.mobilenet` — depthwise convs carry ``groups`` and
    stride-1 blocks contribute skip joins."""
    from ..models import mobilenet

    plan = mobilenet.MOBILENET_PLAN if plan is None else plan
    g = trace(
        lambda p, x: mobilenet.forward(p, x, plan=plan),
        mobilenet.param_specs(plan=plan),
        _sds(1, input_hw, input_hw, 3),
        name="mobilenet",
    )
    names = ["stem"]
    for i, (c_in, c_out, stride, expand) in enumerate(plan):
        if expand != 1:
            names.append(f"b{i}.expand")
        names += [f"b{i}.dw", f"b{i}.project"]
        if stride == 1 and c_in == c_out:
            names.append(f"b{i}.add")
    return rename_nodes(g, names)


def mlp_block_graph(
    *,
    d_model: int = 256,
    d_ff: int = 1024,
    seq_len: int = 128,
    act: str = "swiglu",
    name: str = "mlp",
) -> GraphIR:
    """One transformer MLP block traced from
    :func:`repro.models.layers.mlp_block` — gated activations (swiglu/geglu)
    fan the input out to two projections and join them in an elementwise
    product, a topology the chain IR could not express."""
    from ..models import layers as L

    params = {"w1": _sds(d_model, d_ff), "w2": _sds(d_ff, d_model)}
    gated = act in L.GATED_ACTS
    if gated:
        params["w3"] = _sds(d_model, d_ff)
    g = trace(
        lambda p, x: L.mlp_block(p, x, act),
        params,
        _sds(seq_len, d_model),
        name=name,
    )
    names = (
        [f"{name}.w1", f"{name}.w3", f"{name}.gate", f"{name}.w2"]
        if gated
        else [f"{name}.w1", f"{name}.w2"]
    )
    return rename_nodes(g, names)


# ---------------------------------------------------------------------------
# Config-zoo builders: trace the real production-shape model blocks
# ---------------------------------------------------------------------------


def _zoo_seq_len(cfg, seq_len: int) -> int:
    """Clamp/validate a trace sequence length against the config's MoE
    group-limited routing (tokens must tile into routing groups)."""
    if cfg.n_experts > 1:
        sg = min(cfg.moe_group_size, seq_len)
        if seq_len % sg:
            raise UnsupportedOpError(
                f"{cfg.name}: seq_len {seq_len} does not tile into MoE "
                f"routing groups of {sg}"
            )
    return seq_len


def transformer_graph(cfg, *, seq_len: int = 512,
                      n_sublayers: int | None = None,
                      name: str | None = None) -> GraphIR:
    """One superblock (``cfg.pattern_period`` sublayers) of the config's
    decoder trunk, traced from the real :mod:`repro.models.transformer`
    forward pass via :func:`~repro.models.transformer.block_forward`.

    Attention sublayers lower to the actmul pair (QK^T -> folded softmax ->
    PV) with the O(S^2) score matrix as an explicit edge; mamba sublayers
    contribute a recurrent ``scan`` node carrying ``d_inner x d_state``
    ``state_words``; MoE sublayers expand into router + E expert branches +
    combine.  ``n_sublayers`` overrides the traced depth (default: one full
    pattern period, so jamba's 1:7 attn:mamba interleave and llama4's
    alternating dense/MoE both appear once)."""
    from ..configs.base import RunConfig
    from ..models import transformer as T

    count = cfg.pattern_period if n_sublayers is None else n_sublayers
    kinds = cfg.sublayer_kinds(0, count)
    seq_len = _zoo_seq_len(cfg, seq_len)
    params = T.sublayer_param_specs(cfg, kinds)
    rc = RunConfig()
    return trace(
        lambda p, x: T.block_forward(p, x, cfg, kinds, rc=rc,
                                     attn_impl="reference"),
        params,
        _sds(1, seq_len, cfg.d_model),
        name=name or f"{cfg.name}.block",
    )


def mamba_graph(cfg, *, seq_len: int = 512, chunks: int = 1,
                name: str | None = None) -> GraphIR:
    """One mamba mixer block traced from
    :func:`repro.models.ssm.mamba_block` (chunk-recurrent selective scan).

    ``chunks > 1`` splits the sequence and threads the SSM cache between
    the calls — the ``(d_inner, d_state)`` carry hand-off and the
    ``(conv-1)``-token convolution tail both surface as real edges, so the
    fusion search sees the chunk boundary as a cut point."""
    import jax.numpy as jnp

    from ..models import ssm as SSM

    if "mamba" not in cfg.layer_pattern:
        raise UnsupportedOpError(f"{cfg.name}: no mamba sublayers in pattern")
    if chunks < 1 or seq_len % chunks:
        raise UnsupportedOpError(
            f"{cfg.name}: seq_len {seq_len} does not split into "
            f"{chunks} chunks"
        )
    params = SSM.mamba_param_specs(cfg)
    step = seq_len // chunks

    def fn(p, x):
        if chunks == 1:
            return SSM.mamba_block(p, x, cfg, impl="chunked", chunk=step)[0]
        cache = {
            "conv": jnp.zeros((1, cfg.ssm_conv - 1, cfg.d_inner), x.dtype),
            "h": jnp.zeros((1, cfg.d_inner, cfg.ssm_state), jnp.float32),
        }
        outs = []
        for i in range(chunks):
            xi = jax.lax.slice_in_dim(x, i * step, (i + 1) * step, axis=1)
            y, cache = SSM.mamba_block(p, xi, cfg, cache, impl="chunked",
                                       chunk=step)
            outs.append(y)
        return jnp.concatenate(outs, axis=1)

    return trace(
        fn, params, _sds(1, seq_len, cfg.d_model),
        name=name or f"{cfg.name}.mamba",
    )


def moe_block_graph(cfg, *, seq_len: int = 512,
                    name: str | None = None) -> GraphIR:
    """One MoE FFN traced from :func:`repro.models.moe.moe_block`: a router
    ``matmul``, a dispatch ``actmul`` whose routed one-hots descend from the
    router, ``E`` expert branches whose incoming edges carry the routed
    capacity words (``C = moe._capacity`` — ``capacity_factor``-scaled), and
    a combine ``actmul`` joining the branches against the router's combine
    weights (arctic's parallel dense-residual MLP appears alongside)."""
    from ..models import moe as MOE

    if cfg.n_experts <= 1:
        raise UnsupportedOpError(f"{cfg.name}: config has no MoE layers")
    seq_len = _zoo_seq_len(cfg, seq_len)
    params = MOE.moe_param_specs(cfg)
    return trace(
        lambda p, x: MOE.moe_block(p, x, cfg)[0],
        params,
        _sds(1, seq_len, cfg.d_model),
        name=name or f"{cfg.name}.moe",
    )
