"""Tracing frontend — real JAX models to :class:`repro.core.ir.GraphIR`.

Every workload the evaluator scores used to be a hand-written ``*_ir``
builder; this module removes that transcription step.  :func:`trace` runs
``jax.make_jaxpr`` on a model's forward pass (weights may be
``jax.ShapeDtypeStruct`` pytrees — nothing is materialised) and lowers the
jaxpr onto the paper's layer abstraction:

* ``conv_general_dilated``  -> ``conv`` nodes (``feature_group_count`` maps
  to :class:`LayerSpec` ``groups``, so depthwise/grouped convs cost the
  right kernels words and MACs);
* ``dot_general``           -> ``matmul`` nodes (a matmul is the degenerate
  1x1 convolution over ``M`` "pixels"; ``M == 1`` is tagged ``fc``).  A
  ``dot_general`` whose *both* operands are activations becomes ``actmul``
  (attention's QK^T / PV — the "kernel" operand is activation traffic);
* ``reduce_window_{max,sum,min}`` -> ``pool`` nodes, or — with
  ``fold_pool=True`` and a window that equals its stride — absorbed into
  the producing conv's ``pool_after`` (the DLA's inline pool unit, Fig. 1);
* everything else is **folded**: an elementwise op (bias add, ReLU/SiLU,
  BN scale/shift, reshape/transpose/cast plumbing) whose activation
  operands come from a single producer node contributes no node — its
  output is re-attributed to that producer.  An elementwise op that *joins*
  two or more distinct dataflow sources (a residual add, a gated-MLP
  product; every graph input is its own source) becomes an ``elementwise``
  node, which is exactly how fan-in is represented in the hand-built DAGs.
  Operands read straight off a graph input have no producer node to fuse
  over, so a non-source consumer (a join, or an ``actmul``/``matmul`` with
  one produced operand) charges their words as ``LayerSpec.ext_in_words``
  — DRAM traffic in every grouping, counted by Eq. (1)-(3).

Dataflow recovery: a var is an *activation* iff it descends from a
designated activation argument (default: the last positional argument, so
``forward(params, x)`` traces with ``x`` as the input frame); every other
invar/constvar is weight or constant traffic.  Edges follow jaxpr use-def
between surviving nodes and carry the consumed tensor's word count, so
fan-out (a tensor read by several consumers) and skip paths come out as
real DAG edges.

The canonical builders at the bottom (``vgg16_network``,
``resnet18_graph``, ``mobilenet_graph``, ``mlp_block_graph``) trace the
real models in :mod:`repro.models` and rename nodes to the historical
builder names; ``repro.core.ir.vgg16_ir`` / ``resnet18_ir`` are thin
wrappers over them (locked node-and-edge-identical to verbatim
transcriptions of the old hand builders in ``tests/test_frontend.py``).

Geometry is validated as it is derived: the evaluator's ``SAME``-padding
``h_in // stride`` arithmetic must reproduce the traced output shape of
every conv/pool node, otherwise :func:`trace` raises rather than emitting
an IR whose edge words disagree with its node frames.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.extend import core as jex_core

from .errors import GraphValidationError, UnsupportedOpError
from .ir import (
    RESNET18_STAGE_PLAN,
    VGG16_CONV_PLAN,
    EdgeSpec,
    GraphIR,
    LayerSpec,
    NetworkIR,
)

_REDUCE_WINDOW_PRIMS = ("reduce_window_max", "reduce_window_sum", "reduce_window_min")
_SPATIAL_REDUCE_PRIMS = ("reduce_sum", "reduce_max", "reduce_min")


def _words(aval) -> int:
    """Word count of a traced tensor (the paper uses one word per element)."""
    return int(np.prod(aval.shape, dtype=np.int64)) if aval.shape else 1


def _chw(shape: tuple[int, ...]) -> tuple[int, int, int]:
    """(channels, h, w) of an activation tensor: channels-last, leading
    size-1 batch axis dropped, remaining axes flattened into (h, w)."""
    if len(shape) > 2 and shape[0] == 1:
        shape = shape[1:]
    if not shape:
        return 1, 1, 1
    c = shape[-1]
    spatial = shape[:-1]
    if not spatial:
        return c, 1, 1
    if len(spatial) == 1:
        return c, int(spatial[0]), 1
    return c, int(spatial[0]), int(math.prod(spatial[1:]))


@dataclasses.dataclass
class _PendingNode:
    spec: LayerSpec
    inputs: dict[int, int]  # producer node id -> words read from it


class _Tracer:
    """``producer`` maps every activation var to the *dataflow source* it
    descends from: an ``int`` node id, or — for values read straight off a
    graph input — the original input var itself, so two different inputs
    stay two different sources (and two views of one input stay one)."""

    def __init__(self, *, name: str, fold_pool: bool):
        self.name = name
        self.fold_pool = fold_pool
        self.nodes: list[_PendingNode] = []
        self.producer: dict[Any, Any] = {}  # activation var -> source

    # ---- helpers -----------------------------------------------------------
    def _act_inputs(self, eqn) -> list[tuple[Any, Any]]:
        return [
            (v, self.producer[v])
            for v in eqn.invars
            if not isinstance(v, jex_core.Literal) and v in self.producer
        ]

    def _add_node(self, spec: LayerSpec, act_in) -> int:
        node = _PendingNode(spec=spec, inputs={})
        for v, p in act_in:
            if not isinstance(p, int):
                continue  # graph-input operand: no producer node to fuse with
            w = _words(v.aval)
            node.inputs[p] = max(node.inputs.get(p, 0), w)
        self.nodes.append(node)
        return len(self.nodes) - 1

    def _ext_words(self, act_in) -> int:
        """Words of operands read straight off a graph input — DRAM traffic
        in every grouping (deduped per input var: two views of one input
        are one read)."""
        by_src: dict[Any, int] = {}
        for v, p in act_in:
            if not isinstance(p, int):
                by_src[p] = max(by_src.get(p, 0), _words(v.aval))
        return sum(by_src.values())

    def _check_geometry(self, spec: LayerSpec, out_shape, *, what: str) -> None:
        c, h, w = _chw(tuple(out_shape))
        if (spec.n_out, spec.h_out, spec.w_out) != (c, h, w):
            raise UnsupportedOpError(
                f"{self.name}: traced {what} {spec.name} derives "
                f"{spec.n_out}x{spec.h_out}x{spec.w_out} but the jaxpr "
                f"produces {c}x{h}x{w} — only SAME-padding geometry "
                f"(out = in // stride) is representable"
            )

    # ---- primitive lowering ------------------------------------------------
    def eqn_conv(self, eqn, act_in) -> None:
        lhs, rhs = eqn.invars[0], eqn.invars[1]
        if rhs in self.producer:
            raise UnsupportedOpError(
                f"{self.name}: conv with an activation kernel operand is "
                "not supported (use dot_general for activation products)"
            )
        # act_in is non-empty and rhs is not activation, so lhs is.
        p = eqn.params
        dn = p["dimension_numbers"]
        if p["lhs_dilation"] != (1,) * len(p["lhs_dilation"]) or p[
            "rhs_dilation"
        ] != (1,) * len(p["rhs_dilation"]):
            raise UnsupportedOpError(f"{self.name}: dilated convolutions unsupported")
        lshape, rshape = lhs.aval.shape, rhs.aval.shape
        if lshape[dn.lhs_spec[0]] != 1:
            raise UnsupportedOpError(f"{self.name}: trace with batch size 1")
        n_in = int(lshape[dn.lhs_spec[1]])
        spatial = [int(lshape[i]) for i in dn.lhs_spec[2:]]
        h_in, w_in = (spatial + [1])[:2]
        n_out = int(rshape[dn.rhs_spec[0]])
        ks = [int(rshape[i]) for i in dn.rhs_spec[2:]]
        kh, kw = (ks + [1])[:2]
        strides = tuple(int(s) for s in p["window_strides"])
        if len(set(strides)) != 1:
            raise UnsupportedOpError(f"{self.name}: anisotropic conv strides unsupported")
        groups = int(p["feature_group_count"])
        spec = LayerSpec(
            f"conv{len(self.nodes)}", "conv", n_in, n_out, h_in, w_in,
            kh, kw, strides[0], groups=groups,
        )
        out = eqn.outvars[0]
        out_spatial = [int(out.aval.shape[i]) for i in dn.out_spec[2:]]
        oh, ow = (out_spatial + [1])[:2]
        if (spec.h_out, spec.w_out) != (oh, ow):
            raise UnsupportedOpError(
                f"{self.name}: conv {spec.name} derives {spec.h_out}x{spec.w_out} "
                f"but the jaxpr produces {oh}x{ow} — only SAME-padding geometry "
                "(out = in // stride) is representable"
            )
        self.producer[out] = self._add_node(spec, [(lhs, self.producer[lhs])])

    def eqn_dot(self, eqn, act_in) -> None:
        lhs, rhs = eqn.invars[0], eqn.invars[1]
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        lshape, rshape = lhs.aval.shape, rhs.aval.shape
        if any(lshape[d] != 1 for d in lb) or any(rshape[d] != 1 for d in rb):
            raise UnsupportedOpError(f"{self.name}: trace dot_general with batch size 1")
        k = int(math.prod(lshape[d] for d in lc))
        l_free = int(math.prod(lshape[d] for d in range(len(lshape)) if d not in lc))
        r_free = int(math.prod(rshape[d] for d in range(len(rshape)) if d not in rc))
        lhs_is_act = lhs in self.producer
        if len(act_in) == 2:
            kind, m, n = "actmul", l_free, r_free
        else:
            m, n = (l_free, r_free) if lhs_is_act else (r_free, l_free)
            kind = "fc" if m == 1 else "matmul"
        # A graph-input operand of a non-source node (e.g. actmul of a
        # projected query against the raw input) has no edge to fuse over:
        # its words stream from DRAM in every grouping.  Source nodes
        # already count all operands via in_words.
        has_edge = any(isinstance(p, int) for _, p in act_in)
        ext = self._ext_words(act_in) if has_edge else 0
        spec = LayerSpec(
            f"{kind}{len(self.nodes)}", kind, k, n, m, 1, ext_in_words=ext
        )
        out = eqn.outvars[0]
        if _words(out.aval) != m * n:
            raise UnsupportedOpError(
                f"{self.name}: dot_general output has {_words(out.aval)} words, "
                f"expected {m}*{n}"
            )
        self.producer[out] = self._add_node(spec, act_in)

    def eqn_reduce_window(self, eqn, act_in) -> None:
        (v, p_id) = act_in[0]
        shape = v.aval.shape
        window = tuple(int(d) for d in eqn.params["window_dimensions"])
        strides = tuple(int(s) for s in eqn.params["window_strides"])
        if len(shape) != 4 or window[0] != 1 or window[3] != 1:
            raise UnsupportedOpError(
                f"{self.name}: reduce_window expects NHWC with a spatial "
                f"window, got shape {shape} window {window}"
            )
        if shape[0] != 1:
            raise UnsupportedOpError(f"{self.name}: trace with batch size 1")
        kh, kw = window[1], window[2]
        sh, sw = strides[1], strides[2]
        if sh != sw:
            raise UnsupportedOpError(f"{self.name}: anisotropic pool strides unsupported")
        c, h_in, w_in = int(shape[3]), int(shape[1]), int(shape[2])
        out = eqn.outvars[0]
        if (
            self.fold_pool
            and isinstance(p_id, int)
            and self.nodes[p_id].spec.kind == "conv"
            and self.nodes[p_id].spec.pool_after == 1
            and (kh, kw) == (sh, sw)
            and self._use_count[v] == 1
        ):
            # Absorb into the producing conv's inline pool unit (Fig. 1).
            spec = dataclasses.replace(self.nodes[p_id].spec, pool_after=sh)
            self._check_geometry(spec, out.aval.shape, what="absorbed pool")
            self.nodes[p_id].spec = spec
            self.producer[out] = p_id
            return
        spec = LayerSpec(
            f"pool{len(self.nodes)}", "pool", c, c, h_in, w_in, kh, kw, sh
        )
        self._check_geometry(spec, out.aval.shape, what="pool")
        self.producer[out] = self._add_node(spec, act_in)

    def eqn_spatial_reduce(self, eqn, act_in) -> bool:
        """Global spatial reduction (``jnp.mean(x, (1, 2))``) -> pool node.
        Returns False when the reduction is not spatial-pool shaped (the
        caller then raises: folding a shape-changing reduction would break
        the producer-frame / edge-words consistency)."""
        (v, p_id) = act_in[0]
        shape = v.aval.shape
        axes = tuple(sorted(int(a) for a in eqn.params["axes"]))
        if len(shape) != 4 or axes != (1, 2) or shape[1] != shape[2]:
            return False
        if shape[0] != 1:
            raise UnsupportedOpError(f"{self.name}: trace with batch size 1")
        c, hw = int(shape[3]), int(shape[1])
        spec = LayerSpec(
            f"pool{len(self.nodes)}", "pool", c, c, hw, hw, hw, hw, hw
        )
        self.producer[eqn.outvars[0]] = self._add_node(spec, act_in)
        return True

    def eqn_default(self, eqn, act_in) -> None:
        """Fold, or join >= 2 distinct sources into an ``elementwise`` node
        (the graph input counts as a source, so a residual add of the raw
        input still surfaces as a join).  Operands read straight from the
        graph input have no producer edge to fuse over, so their words
        become the join's ``ext_in_words`` — DRAM traffic in every
        grouping."""
        distinct = {p for _, p in act_in}
        if len(distinct) >= 2:
            out = eqn.outvars[0]
            c, h, w = _chw(tuple(out.aval.shape))
            ext = self._ext_words(act_in)
            if not any(isinstance(p, int) for p in distinct):
                # All operands are raw inputs: the node is a *source* and
                # already reads in_words (one frame) — ext carries only the
                # frames beyond that.
                ext = max(0, ext - c * h * w)
            spec = LayerSpec(
                f"join{len(self.nodes)}", "elementwise", c, c, h, w,
                ext_in_words=int(ext),
            )
            node = self._add_node(spec, act_in)
            for o in eqn.outvars:
                self.producer[o] = node
            return
        p = distinct.pop() if distinct else None
        for o in eqn.outvars:
            self.producer[o] = p

    # ---- driver ------------------------------------------------------------
    def run(self, jaxpr) -> GraphIR:
        self._use_count: dict[Any, int] = {}
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if not isinstance(v, jex_core.Literal):
                    self._use_count[v] = self._use_count.get(v, 0) + 1
        for v in jaxpr.outvars:
            if not isinstance(v, jex_core.Literal):
                self._use_count[v] = self._use_count.get(v, 0) + 1
        for eqn in jaxpr.eqns:
            act_in = self._act_inputs(eqn)
            if not act_in:
                continue  # weights/constants only: nothing reaches the IR
            prim = eqn.primitive.name
            if prim == "conv_general_dilated":
                self.eqn_conv(eqn, act_in)
            elif prim == "dot_general":
                self.eqn_dot(eqn, act_in)
            elif prim in _REDUCE_WINDOW_PRIMS:
                self.eqn_reduce_window(eqn, act_in)
            elif prim in _SPATIAL_REDUCE_PRIMS:
                if not self.eqn_spatial_reduce(eqn, act_in):
                    # Folding a reduction would emit a producer frame that
                    # disagrees with its consumer edge words — refuse.
                    raise UnsupportedOpError(
                        f"{self.name}: {prim} over axes "
                        f"{tuple(eqn.params['axes'])} on shape "
                        f"{eqn.invars[0].aval.shape} is not representable "
                        "(only square NHWC global spatial reductions map to "
                        "pool nodes)"
                    )
            else:
                self.eqn_default(eqn, act_in)
        if not self.nodes:
            raise UnsupportedOpError(f"{self.name}: no layers traced")
        edges = tuple(
            EdgeSpec(src, dst, words)
            for dst, node in enumerate(self.nodes)
            for src, words in sorted(node.inputs.items())
        )
        return GraphIR(self.name, tuple(n.spec for n in self.nodes), edges)


def trace(
    fn: Callable,
    *args,
    name: str = "traced",
    activation_argnums: Sequence[int] | None = None,
    fold_pool: bool = False,
    names: Sequence[str] | None = None,
) -> GraphIR:
    """Trace ``fn(*args)`` into a :class:`GraphIR`.

    ``args`` are pytrees of arrays or ``jax.ShapeDtypeStruct`` (weights are
    never materialised).  ``activation_argnums`` marks which arguments are
    activation inputs (default: the last one, matching ``forward(params,
    x)``); activations must be traced with batch size 1.  ``fold_pool``
    absorbs a window == stride pooling into its producing conv's
    ``pool_after`` when the pooled tensor has no other consumer.  ``names``
    optionally renames the nodes (length-checked).
    """
    if not args:
        raise UnsupportedOpError("trace() needs at least one example argument")
    nums = (
        {len(args) - 1}
        if activation_argnums is None
        else {a % len(args) for a in activation_argnums}
    )
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except (UnsupportedOpError, GraphValidationError):
        raise
    except Exception as e:
        # jax itself rejected the function (rank/shape errors surface as
        # raw ValueError/IndexError/TypeError while *building* the jaxpr)
        # — the trace boundary converts them to the typed taxonomy.
        raise UnsupportedOpError(
            f"{name}: fn is not traceable to a jaxpr "
            f"({type(e).__name__}: {e})"
        ) from e
    tr = _Tracer(name=name, fold_pool=fold_pool)
    invars = iter(closed.jaxpr.invars)
    for i, arg in enumerate(args):
        leaves = jax.tree_util.tree_leaves(arg)
        for _ in leaves:
            v = next(invars)
            if i in nums:
                tr.producer[v] = v  # each input var is its own source
    # Lowering must fail *typed*: an unlowerable jaxpr is an
    # UnsupportedOpError and a lowered-but-invalid IR a
    # GraphValidationError — never a raw KeyError/IndexError from a
    # degenerate primitive the lowering rules did not anticipate (the
    # contract the service admission path and the fuzz tests rely on).
    try:
        g = tr.run(closed.jaxpr)
    except (GraphValidationError, UnsupportedOpError):
        raise
    except (KeyError, IndexError, AttributeError, TypeError,
            ZeroDivisionError, AssertionError) as e:
        raise UnsupportedOpError(
            f"{name}: jaxpr is not lowerable to the layer abstraction "
            f"({type(e).__name__}: {e})"
        ) from e
    if names is not None:
        g = rename_nodes(g, names)
    return g


def rename_nodes(g: GraphIR, names: Sequence[str]) -> GraphIR:
    if len(names) != len(g.nodes):
        raise UnsupportedOpError(
            f"{g.name}: {len(names)} names for {len(g.nodes)} nodes "
            f"(traced: {[n.name for n in g.nodes]})"
        )
    nodes = tuple(
        dataclasses.replace(n, name=nm) for n, nm in zip(g.nodes, names)
    )
    return GraphIR(g.name, nodes, g.edges)


def to_chain(g: GraphIR, name: str | None = None) -> NetworkIR:
    """Collapse a chain-shaped trace back to the legacy :class:`NetworkIR`."""
    if not g.is_chain:
        raise UnsupportedOpError(f"{g.name} is not a chain ({g.n_edges} edges)")
    return NetworkIR(name or g.name, g.nodes)


# ---------------------------------------------------------------------------
# Canonical model builders (the thin wrappers `repro.core.ir` re-exports)
# ---------------------------------------------------------------------------


def _sds(*shape, dtype=None):
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(tuple(shape), dtype or jnp.float32)


def vgg16_network(
    *, pool_mode: str = "separate", include_fc: bool = False
) -> NetworkIR:
    """VGG-16 traced from :mod:`repro.models.vgg` (the paper's Sec. III
    workload) — ``pool_mode="absorbed"`` folds each 2x2 pool into its conv."""
    from ..models import vgg

    if pool_mode not in ("separate", "absorbed"):
        raise UnsupportedOpError(pool_mode)
    g = trace(
        vgg.forward,
        vgg.param_specs(),
        _sds(1, 224, 224, 3),
        name="vgg16",
        fold_pool=(pool_mode == "absorbed"),
    )
    names: list[str] = []
    for lname, _n_in, _n_out, _hw, pooled in VGG16_CONV_PLAN:
        names.append(lname)
        if pooled and pool_mode == "separate":
            names.append(f"pool{lname[4]}")
    n_feature = len(names)
    names += ["fc6", "fc7", "fc8"]
    net = to_chain(rename_nodes(g, names), "vgg16")
    if not include_fc:
        net = NetworkIR("vgg16", net.layers[:n_feature])
    return net


def resnet18_graph(*, input_hw: int = 224) -> GraphIR:
    """ResNet-18 traced from :mod:`repro.models.resnet` — the skip adds come
    out as real join nodes with two incoming edges."""
    from ..models import resnet

    g = trace(
        resnet.forward,
        resnet.param_specs(),
        _sds(1, input_hw, input_hw, 3),
        name="resnet18",
    )
    names = ["conv1", "pool1"]
    c_in = 64
    for stage, n_blocks, c_out, stride0 in RESNET18_STAGE_PLAN:
        for b in range(n_blocks):
            stride = stride0 if b == 0 else 1
            cin_blk = c_in if b == 0 else c_out
            tag = f"s{stage}b{b}"
            names += [f"{tag}.conv_a", f"{tag}.conv_b"]
            if stride != 1 or cin_blk != c_out:
                names.append(f"{tag}.downsample")
            names.append(f"{tag}.add")
        c_in = c_out
    names += ["avgpool", "fc"]
    return rename_nodes(g, names)


def mobilenet_graph(
    *, input_hw: int = 112, plan: tuple | None = None
) -> GraphIR:
    """MobileNet-style inverted-residual stack traced from
    :mod:`repro.models.mobilenet` — depthwise convs carry ``groups`` and
    stride-1 blocks contribute skip joins."""
    from ..models import mobilenet

    plan = mobilenet.MOBILENET_PLAN if plan is None else plan
    g = trace(
        lambda p, x: mobilenet.forward(p, x, plan=plan),
        mobilenet.param_specs(plan=plan),
        _sds(1, input_hw, input_hw, 3),
        name="mobilenet",
    )
    names = ["stem"]
    for i, (c_in, c_out, stride, expand) in enumerate(plan):
        if expand != 1:
            names.append(f"b{i}.expand")
        names += [f"b{i}.dw", f"b{i}.project"]
        if stride == 1 and c_in == c_out:
            names.append(f"b{i}.add")
    return rename_nodes(g, names)


def mlp_block_graph(
    *,
    d_model: int = 256,
    d_ff: int = 1024,
    seq_len: int = 128,
    act: str = "swiglu",
    name: str = "mlp",
) -> GraphIR:
    """One transformer MLP block traced from
    :func:`repro.models.layers.mlp_block` — gated activations (swiglu/geglu)
    fan the input out to two projections and join them in an elementwise
    product, a topology the chain IR could not express."""
    from ..models import layers as L

    params = {"w1": _sds(d_model, d_ff), "w2": _sds(d_ff, d_model)}
    gated = act in L.GATED_ACTS
    if gated:
        params["w3"] = _sds(d_model, d_ff)
    g = trace(
        lambda p, x: L.mlp_block(p, x, act),
        params,
        _sds(seq_len, d_model),
        name=name,
    )
    names = (
        [f"{name}.w1", f"{name}.w3", f"{name}.gate", f"{name}.w2"]
        if gated
        else [f"{name}.w1", f"{name}.w2"]
    )
    return rename_nodes(g, names)
