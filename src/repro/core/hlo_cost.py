"""Trip-count-aware cost walker over optimized HLO text.

Why this exists — two defects of ``compiled.cost_analysis()`` for deriving
TPU rooflines from a CPU-backend compile:

1. **Loop blindness**: a ``while`` body is counted once, ignoring
   ``known_trip_count`` — a train step that scans 88 layers x 8
   microbatches under-reports FLOPs by ~3 orders of magnitude.
2. **CPU fusion granularity**: the CPU pipeline materialises elementwise
   chains that the TPU backend would fuse, inflating "bytes accessed" by
   3-10x.

This walker parses the post-SPMD optimized HLO and accumulates:

* ``dot_flops``  — 2 * prod(output dims) * prod(contracting dims), loops
  multiplied by their trip counts;
* ``bytes``      — HBM traffic under a **fusion-group model that is the
  paper's Eq. (1) applied to HLO**: contiguous fusible ops (elementwise /
  convert / reduce / broadcast / existing fusions) form groups billed at
  group-inputs + group-outputs only — exactly how the paper bills a layer
  fusion group at first-input + last-output, with intermediates kept
  on-chip.  Non-fusible ops (dot, copy, collectives, slices) are billed
  individually; operands that are merely sliced (scan xs indexing) are
  billed at their sliced size.
* ``collective_bytes`` — per collective kind, trip-count multiplied.

Validated against ``cost_analysis`` FLOPs on loop-free modules and against
analytic 6*N*D counts (tests/test_hlo_cost.py, EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "u1": 1, "s1": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}\s/*]+?))\s+"
    r"([\w\-]+)\((.*)$"
)
_CALLED_RE = re.compile(r"(?:body|condition|calls|to_apply|inner)=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count\\?":\s*\{\\?"n\\?":\\?"(\d+)')
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*->.*\{\s*$")


def _shape_info(shape_text: str):
    out = []
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((n, n * DTYPE_BYTES[dtype]))
    return out


def _total_bytes(shape_text: str) -> int:
    return sum(b for _, b in _shape_info(shape_text))


def _total_elems(shape_text: str) -> int:
    return sum(n for n, _ in _shape_info(shape_text))


def _dims_of(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Cost:
    """Accumulated FLOP/byte/collective totals for an HLO (sub)tree."""

    dot_flops: float = 0.0
    elem_flops: float = 0.0
    bytes: float = 0.0  # Eq.(1) fusion-group model (upper bound)
    bytes_lo: float = 0.0  # dots/slices/copies/collectives only (TPU-
    # fusion-optimistic lower bound: elementwise fused into epilogues)
    coll: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_count: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        """Accumulate ``other`` scaled by ``mult`` (loop trip counts)."""
        self.dot_flops += other.dot_flops * mult
        self.elem_flops += other.elem_flops * mult
        self.bytes += other.bytes * mult
        self.bytes_lo += other.bytes_lo * mult
        for k, v in other.coll.items():
            self.coll[k] += v * mult
        self.coll_count += other.coll_count * mult


# Pure-metadata ops: no traffic, invalid as traffic producers.
_FREE = {
    "parameter", "constant", "iota", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "reshape", "rng-bit-generator",
}
# Ops the TPU backend fuses into neighbours (group members).
_FUSIBLE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "power", "negate", "abs",
    "and", "or", "xor", "not", "select", "compare", "clamp", "floor", "ceil",
    "sign", "exponential-minus-one", "logistic", "convert", "reduce",
    "broadcast", "transpose", "map", "fusion", "reduce-precision", "pad",
}
# Slice-type ops: traffic ~ 2x output (sliced read + write), not the operand.
_SLICY = {"gather", "dynamic-slice", "slice"}
# Scatter-type: ~3x output-ish (read-modify-write of the touched region).
_SCATTERY = {"dynamic-update-slice", "scatter"}


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    rest: str
    line: str
    operands: list[str]
    is_root: bool


class HloModuleCost:
    """Static FLOP/byte/collective cost model over parsed HLO text."""

    def __init__(self, hlo_text: str):
        """Parse ``hlo_text`` into per-computation op lists."""
        self.computations: dict[str, list[_Op]] = {}
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}
        self._param_cache: dict[str, dict[int, float]] = {}
        self.entry = self._find_entry(hlo_text)

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            stripped = line.strip()
            if stripped.endswith("{") and "->" in stripped:
                m = _COMP_HDR_RE.match(stripped)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                    continue
            if stripped.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            op_name, shape_text, opcode, rest = m.groups()
            operands = _NAME_RE.findall(rest.split(")")[0])
            self.computations[cur].append(
                _Op(op_name, shape_text, opcode, rest, line,
                    operands, stripped.startswith("ROOT") or " ROOT " in line)
            )

    def _find_entry(self, text: str) -> str:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        if m and m.group(1) in self.computations:
            return m.group(1)
        return max(self.computations, key=lambda c: len(self.computations[c]))

    def total(self) -> Cost:
        """Cost of the module's entry computation."""
        return self.comp_cost(self.entry)

    # ------------------------------------------------------------------
    def _param_read_bytes(self, comp: str) -> dict[int, float]:
        """Effective read bytes per parameter of a fused computation:
        billed at the slice size when every consumer slices it."""
        if comp in self._param_cache:
            return self._param_cache[comp]
        params: dict[str, int] = {}
        consumers: dict[str, list[_Op]] = {}
        for op in self.computations.get(comp, ()):
            if op.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", op.line)
                if pm:
                    params[op.name] = int(pm.group(1))
                continue
            for nm in op.operands:
                consumers.setdefault(nm, []).append(op)
        out: dict[int, float] = {}
        for pname, idx in params.items():
            cons = consumers.get(pname, [])
            if cons and all(c.opcode in _SLICY for c in cons):
                out[idx] = sum(_total_bytes(c.shape) for c in cons)
            else:
                out[idx] = -1.0
        self._param_cache[comp] = out
        return out

    def _edge_bytes(self, producer_shape: str, consumer: _Op,
                    operand_index: int) -> float:
        """Bytes a consumer actually pulls from a producer's buffer."""
        full = _total_bytes(producer_shape)
        if consumer.opcode in _SLICY:
            return min(full, _total_bytes(consumer.shape))
        if consumer.opcode == "fusion":
            called = _CALLED_RE.findall(consumer.line)
            if called and called[0] in self.computations:
                eff = self._param_read_bytes(called[0]).get(operand_index, -1.0)
                if eff >= 0:
                    return min(eff, full)
        return full

    # ------------------------------------------------------------------
    def comp_cost(self, name: str) -> Cost:
        """Memoised cost of one named computation (callees included)."""
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        ops = self.computations.get(name, [])
        cost = Cost()
        shapes: dict[str, str] = {o.name: o.shape for o in ops}
        opmap: dict[str, _Op] = {o.name: o for o in ops}

        # ---- FLOPs / collectives / sub-computations (trip-count aware) ----
        for op in ops:
            cost.add(self._compute_cost(op, shapes))

        # ---- traffic under the Eq.(1) fusion-group model -------------------
        # union-find over fusible ops
        parent: dict[str, str] = {}

        def find(x):
            while parent.get(x, x) != x:
                parent[x] = parent.get(parent[x], parent[x])
                x = parent[x]
            return x

        def union(a, b):
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[ra] = rb

        fusible = {o.name for o in ops if o.opcode in _FUSIBLE}
        for op in ops:
            if op.name not in fusible:
                continue
            for nm in op.operands:
                if nm in fusible:
                    union(op.name, nm)

        consumers: dict[str, list[tuple[_Op, int]]] = {}
        for op in ops:
            for i, nm in enumerate(op.operands):
                consumers.setdefault(nm, []).append((op, i))
        # Slice outputs are billed once at the slice op; consumers reading
        # them do not re-bill (on TPU the slice IS the consumer's read —
        # scan-over-weights would otherwise be triple-billed).
        slice_names = {o.name for o in ops if o.opcode in _SLICY}

        traffic = 0.0
        group_inputs: dict[str, dict[str, float]] = {}  # gid -> {producer: bytes}
        group_outputs: dict[str, float] = {}
        for op in ops:
            oc = op.opcode
            if oc in _FREE:
                continue
            if oc == "while":
                continue  # body billed per iteration below
            if oc in ("call", "conditional", "sort", "reduce-window",
                      "select-and-scatter", "custom-call", "rng"):
                traffic += _total_bytes(op.shape)
                continue
            if op.name in fusible:
                gid = find(op.name)
                gin = group_inputs.setdefault(gid, {})
                op_in_eff = 0.0
                for i, nm in enumerate(op.operands):
                    if nm in fusible and find(nm) == gid:
                        continue  # internal edge: on-chip, free (Eq. 1)
                    if nm in slice_names:
                        continue  # billed at the slice op
                    src_op = opmap.get(nm)
                    if src_op is not None and src_op.opcode in _FREE \
                            and src_op.opcode != "parameter" \
                            and src_op.opcode != "get-tuple-element":
                        continue  # constants/iota: no HBM read
                    if nm not in shapes:
                        continue
                    b = self._edge_bytes(shapes[nm], op, i)
                    op_in_eff += b
                    gin[nm] = max(gin.get(nm, 0.0), b)
                out_b = _total_bytes(op.shape)
                # Streaming fusions (matvec decode, cache reads): operands
                # >> output means real HBM traffic a TPU epilogue fusion
                # cannot hide — count it in the optimistic bound too.
                if op_in_eff > 4.0 * max(out_b, 1.0):
                    cost.bytes_lo += op_in_eff + out_b
                ext = op.is_root or any(
                    (c.name not in fusible or find(c.name) != gid)
                    for c, _ in consumers.get(op.name, [])
                )
                if ext:
                    group_outputs[gid] = group_outputs.get(gid, 0.0) + out_b
                continue
            # non-fusible real ops
            out_b = _total_bytes(op.shape)
            if oc in _SLICY:
                traffic += out_b  # one read; consumers don't re-bill
                cost.bytes_lo += out_b
            elif oc in _SCATTERY:
                traffic += 3.0 * out_b
                cost.bytes_lo += 3.0 * out_b
            else:
                opnd = 0.0
                for i, nm in enumerate(op.operands):
                    if nm in shapes and nm not in slice_names:
                        opnd += self._edge_bytes(shapes[nm], op, i)
                traffic += out_b + opnd
                if oc in ("dot", "convolution", "copy") or \
                        oc.replace("-start", "").replace("-done", "") in COLLECTIVES:
                    cost.bytes_lo += out_b + opnd
        for gid, gin in group_inputs.items():
            traffic += sum(gin.values()) + group_outputs.get(gid, 0.0)
        cost.bytes += traffic

        self._memo[name] = cost
        return cost

    # ------------------------------------------------------------------
    def _compute_cost(self, op: _Op, shapes: dict[str, str]) -> Cost:
        """FLOPs, collectives and sub-computation recursion for one op."""
        c = Cost()
        called = _CALLED_RE.findall(op.line)
        br = _BRANCHES_RE.search(op.line)
        if br:
            called += _NAME_RE.findall(br.group(1))

        if op.opcode == "while":
            tc_m = _TRIP_RE.search(op.line)
            tc = float(tc_m.group(1)) if tc_m else 1.0
            for sub in called:
                if sub in self.computations:
                    c.add(self.comp_cost(sub), tc)
            return c

        if op.opcode == "fusion":
            for sub in called:
                if sub in self.computations:
                    inner = self.comp_cost(sub)
                    c.dot_flops += inner.dot_flops
                    c.elem_flops += inner.elem_flops
                    c.coll_count += inner.coll_count
                    for k, v in inner.coll.items():
                        c.coll[k] += v
            return c

        if op.opcode in ("call", "conditional"):
            for sub in called:
                if sub in self.computations:
                    c.add(self.comp_cost(sub))
            return c

        base = op.opcode.replace("-start", "").replace("-done", "")
        if base in COLLECTIVES:
            if not op.opcode.endswith("-done"):
                c.coll[base] += _total_bytes(op.shape)
                c.coll_count += 1
            return c

        if op.opcode == "dot":
            k = 1
            contract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
            if op.operands and op.operands[0] in shapes and contract \
                    and contract.group(1):
                lhs_dims = _dims_of(shapes[op.operands[0]])
                for idx in contract.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        k *= lhs_dims[i]
            c.dot_flops += 2.0 * _total_elems(op.shape) * k
            return c

        if op.opcode == "convolution":
            win = re.findall(r"size=([\dx]+)", op.line)
            ksize = 1
            if win:
                for d in win[0].split("x"):
                    ksize *= int(d)
            cin = 1
            if len(op.operands) >= 2 and op.operands[1] in shapes:
                rdims = _dims_of(shapes[op.operands[1]])
                if len(rdims) >= 2:
                    cin = rdims[-2]
            c.dot_flops += 2.0 * _total_elems(op.shape) * ksize * cin
            return c

        if op.opcode in ("reduce", "map") or op.opcode in _FUSIBLE:
            c.elem_flops += _total_elems(op.shape)
        return c


def module_cost(hlo_text: str) -> Cost:
    """One-shot convenience: parse + entry-computation cost."""
    return HloModuleCost(hlo_text).total()
