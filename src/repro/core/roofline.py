"""Three-term roofline extraction from compiled XLA artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_bw

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are
NOT in cost_analysis, so we parse the post-SPMD optimized HLO
(``compiled.as_text()``) and sum the *output* operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
The SPMD-partitioned module is the per-device program, so all three terms
are per-chip seconds directly.
"""
from __future__ import annotations

import dataclasses
import re

from .arch import TPUSpec, TPU_V5E

# HLO dtype -> bytes.
DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. "bf16[256,4096,5120]{2,1,0}" or "f32[]" — capture dtype + dims.
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# Start-of-op: "  %name = <shape-or-tuple> <opcode>(" ; opcode has dots/digits
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)


def shape_bytes(shape_text: str) -> int:
    """Bytes of an HLO shape string (sum over tuple elements)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-kind output bytes of collective ops in the (per-device) module.

    ``-done`` ops are skipped (their ``-start`` counterpart carries the
    shape) to avoid double counting async collectives.
    """
    out = {k: 0 for k in COLLECTIVE_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_text, kind = m.group(1), m.group(2)
        out[kind] += shape_bytes(shape_text)
        out["count"] += 1
    return out


@dataclasses.dataclass(frozen=True)
class Roofline:
    """Per-device roofline decomposition of one compiled step."""

    flops: float  # per-device HLO FLOPs
    hbm_bytes: float  # per-device bytes, TPU-fusion-optimistic (primary)
    coll_bytes: float  # per-device collective bytes
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_device: float  # 6*N*D / chips (or serve analogue)
    hbm_bytes_upper: float = 0.0  # Eq.(1)-grouped upper bound
    memory_s_upper: float = 0.0

    @property
    def bound(self) -> str:
        """Which resource dominates: compute / memory / collective."""
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_seconds(self) -> float:
        """Lower-bound step time: perfectly-overlapped roofline max."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """Model FLOPs over total executed HLO FLOPs."""
        return self.model_flops_per_device / max(self.flops, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        peak = TPU_V5E.peak_flops
        return self.model_flops_per_device / max(self.step_seconds, 1e-30) / peak

    def row(self) -> dict:
        """Flat dict row for the benchmark CSV/JSON writers."""
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "hbm_bytes_upper": self.hbm_bytes_upper,
            "coll_bytes": self.coll_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_s_upper": self.memory_s_upper,
            "collective_s": self.collective_s,
            "bound": self.bound,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "coll_breakdown": {
                k: v for k, v in self.coll_breakdown.items() if v and k != "count"
            },
        }


def roofline_from_compiled(
    compiled, *, model_flops_total: float, n_chips: int, spec: TPUSpec = TPU_V5E,
    hlo_text: str | None = None,
) -> Roofline:
    """Roofline via the trip-count-aware HLO walker (repro.core.hlo_cost).

    ``compiled.cost_analysis()`` is loop-blind on the CPU backend (while
    bodies counted once), so the walker is the primary source; the raw
    cost_analysis numbers are kept in the breakdown for reference.
    """
    from . import hlo_cost as HC

    text = hlo_text if hlo_text is not None else compiled.as_text()
    walked = HC.module_cost(text)
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        raw_flops = float(cost.get("flops", 0.0))
        raw_bytes = float(cost.get("bytes accessed", 0.0))
    except Exception:  # pragma: no cover
        raw_flops = raw_bytes = 0.0

    flops = walked.dot_flops + walked.elem_flops
    coll = dict(walked.coll)
    coll["count"] = walked.coll_count
    coll["raw_cost_analysis_flops"] = raw_flops
    coll["raw_cost_analysis_bytes"] = raw_bytes
    coll["dot_flops"] = walked.dot_flops
    cbytes = float(sum(walked.coll.values()))
    return Roofline(
        flops=flops,
        hbm_bytes=walked.bytes_lo,
        hbm_bytes_upper=walked.bytes,
        coll_bytes=cbytes,
        coll_breakdown=coll,
        compute_s=flops / spec.peak_flops,
        memory_s=walked.bytes_lo / spec.hbm_bw,
        memory_s_upper=walked.bytes / spec.hbm_bw,
        collective_s=cbytes / spec.ici_bw,
        model_flops_per_device=model_flops_total / n_chips,
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS (the "useful" compute of the cell)
# ---------------------------------------------------------------------------


def model_flops(cfg, shape, *, kind: str) -> float:
    """6*N_active*D for training; 2*N_active*D per forward token for serving."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence; attention reads the KV cache but that
    # is memory-, not FLOP-dominated — 2*N_active*B is the standard count.
    return 2.0 * n_active * shape.global_batch
