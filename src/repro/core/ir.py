"""Layer-level intermediate representation for the pre-RTL evaluator.

The paper (Yang & Chang, ISOCC'21) evaluates networks as chains of layers,
each a convolution with ``N*Nih*Niw`` input frames, ``N*Nkh*Nkw*M`` filter
kernels and ``M*Noh*Now`` output frames (Sec. II-B).  This module defines that
layer abstraction plus builders for:

* VGG-16 (the paper's own experiment, Sec. III),
* transformer blocks (matmuls expressed as 1x1 convolutions over ``seq``
  "pixels"), so the same evaluator / fusion flow runs over every assigned
  architecture.

Everything here is plain Python + numpy features extraction; the vectorised
metric kernels live in :mod:`repro.core.metrics`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

# Layer kinds.  "conv" and "fc" carry weights; "pool" is weightless; "matmul"
# covers transformer projections (weights) and "actmul" covers activation x
# activation products (attention QK^T / PV) whose "weights" are activations
# and therefore count as input traffic, not weight traffic.
KINDS = ("conv", "pool", "fc", "matmul", "actmul", "elementwise")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer in the paper's notation.

    ``n_in``/``n_out`` are N / M (input / output channels); ``h_in``/``w_in``
    are Nih/Niw; ``kh``/``kw`` are Nkh/Nkw; ``h_out``/``w_out`` are Noh/Now.
    ``pool_after`` > 1 means a pooling stage is *absorbed* into this layer's
    write-out path (the DLA's inline ReLU/BN/pool functional unit, Fig. 1).
    """

    name: str
    kind: str
    n_in: int
    n_out: int
    h_in: int
    w_in: int
    kh: int = 1
    kw: int = 1
    stride: int = 1
    pool_after: int = 1
    flops_per_mac: int = 2

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown layer kind {self.kind!r}")
        if min(self.n_in, self.n_out, self.h_in, self.w_in) <= 0:
            raise ValueError(f"non-positive dims in {self.name}")

    # ---- derived geometry (SAME padding; stride then absorbed pool) --------
    @property
    def h_out(self) -> int:
        return max(1, self.h_in // self.stride // self.pool_after)

    @property
    def w_out(self) -> int:
        base = self.w_in // self.stride
        return max(1, base // self.pool_after)

    # ---- paper quantities (in words; the paper uses one word per element) --
    @property
    def weight_words(self) -> int:
        """N*Nkh*Nkw*M for weighted layers; 0 for pool/actmul/elementwise."""
        if self.kind in ("conv", "fc", "matmul"):
            return self.n_in * self.kh * self.kw * self.n_out
        return 0

    @property
    def in_words(self) -> int:
        """N*Nih*Niw (+ the second operand for activation-activation products)."""
        base = self.n_in * self.h_in * self.w_in
        if self.kind == "actmul":
            # QK^T / PV: the "kernel" operand is also an activation tensor.
            base += self.n_in * self.kh * self.kw * self.n_out
        return base

    @property
    def out_words(self) -> int:
        """M*Noh*Now after the absorbed pool (what hits DRAM on write-out)."""
        return self.n_out * self.h_out * self.w_out

    @property
    def out_words_prepool(self) -> int:
        """M*Noh*Now before the absorbed pool (the on-chip intermediate)."""
        return self.n_out * (self.h_in // self.stride) * (self.w_in // self.stride)

    @property
    def macs(self) -> int:
        if self.kind in ("pool", "elementwise"):
            return 0
        return (
            self.n_in
            * self.kh
            * self.kw
            * self.n_out
            * (self.h_in // self.stride)
            * (self.w_in // self.stride)
        )

    @property
    def flops(self) -> int:
        return self.macs * self.flops_per_mac

    def describe(self) -> str:
        return (
            f"{self.name:12s} {self.kind:5s} N={self.n_in:5d} M={self.n_out:5d} "
            f"in={self.h_in}x{self.w_in} k={self.kh}x{self.kw}/{self.stride} "
            f"pool={self.pool_after} W={self.weight_words} MACs={self.macs}"
        )


@dataclasses.dataclass(frozen=True)
class NetworkIR:
    """A chain of layers (the unit the fusion search partitions)."""

    name: str
    layers: tuple[LayerSpec, ...]

    def __post_init__(self):
        if not self.layers:
            raise ValueError("empty network")

    def __len__(self) -> int:
        return len(self.layers)

    def __iter__(self):
        return iter(self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_weight_words(self) -> int:
        return sum(l.weight_words for l in self.layers)

    # ---- feature matrix for the vectorised metric kernels ------------------
    FEATURES = (
        "weight_words",
        "in_words",
        "out_words",
        "out_words_prepool",
        "macs",
        "is_pool",
        "kh",
        "kw",
        "n_in",
        "n_out",
        "pixels_out",
    )

    def feature_matrix(self) -> np.ndarray:
        """(L, F) float64 matrix consumed by :mod:`repro.core.metrics`."""
        rows = []
        for l in self.layers:
            rows.append(
                [
                    l.weight_words,
                    l.in_words,
                    l.out_words,
                    l.out_words_prepool,
                    l.macs,
                    1.0 if l.kind == "pool" else 0.0,
                    l.kh,
                    l.kw,
                    l.n_in,
                    l.n_out,
                    (l.h_in // l.stride) * (l.w_in // l.stride),
                ]
            )
        return np.asarray(rows, dtype=np.float64)

    def pool_boundary_cuts(self) -> np.ndarray:
        """The paper's VGG-16 grouping: cut after every pooling stage.

        Returns a boolean cut vector of length L-1 (cut[i] == True means a
        group boundary between layer i and layer i+1).
        """
        L = len(self.layers)
        cuts = np.zeros(L - 1, dtype=bool)
        for i, l in enumerate(self.layers[:-1]):
            if l.kind == "pool" or l.pool_after > 1:
                cuts[i] = True
        return cuts


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

VGG16_CONV_PLAN = (
    # (name, n_in, n_out, spatial, pool_after_this_layer)
    ("conv1_1", 3, 64, 224, False),
    ("conv1_2", 64, 64, 224, True),
    ("conv2_1", 64, 128, 112, False),
    ("conv2_2", 128, 128, 112, True),
    ("conv3_1", 128, 256, 56, False),
    ("conv3_2", 256, 256, 56, False),
    ("conv3_3", 256, 256, 56, True),
    ("conv4_1", 256, 512, 28, False),
    ("conv4_2", 512, 512, 28, False),
    ("conv4_3", 512, 512, 28, True),
    ("conv5_1", 512, 512, 14, False),
    ("conv5_2", 512, 512, 14, False),
    ("conv5_3", 512, 512, 14, True),
)


def vgg16_ir(*, pool_mode: str = "separate", include_fc: bool = False) -> NetworkIR:
    """VGG-16 feature extractor as used in the paper's Sec. III experiment.

    pool_mode:
      * ``"separate"``  — pooling layers are standalone layers (the naive
        layer-by-layer execution round-trips them through DRAM; fusion absorbs
        them into the group).  This is the accounting that reproduces the
        paper's 55.6 % bandwidth-reduction number.
      * ``"absorbed"``  — pooling runs inside the producing conv's functional
        unit even in layer-by-layer mode (no standalone pool layers).
    """
    if pool_mode not in ("separate", "absorbed"):
        raise ValueError(pool_mode)
    layers: list[LayerSpec] = []
    for name, n_in, n_out, hw, pooled in VGG16_CONV_PLAN:
        if pooled and pool_mode == "absorbed":
            layers.append(
                LayerSpec(name, "conv", n_in, n_out, hw, hw, 3, 3, 1, pool_after=2)
            )
        else:
            layers.append(LayerSpec(name, "conv", n_in, n_out, hw, hw, 3, 3, 1))
            if pooled:
                layers.append(
                    LayerSpec(
                        f"pool{name[4]}", "pool", n_out, n_out, hw, hw, 2, 2, 2
                    )
                )
    if include_fc:
        layers.append(LayerSpec("fc6", "fc", 512 * 7 * 7, 4096, 1, 1))
        layers.append(LayerSpec("fc7", "fc", 4096, 4096, 1, 1))
        layers.append(LayerSpec("fc8", "fc", 4096, 1000, 1, 1))
    return NetworkIR("vgg16", tuple(layers))


def transformer_block_ir(
    *,
    name: str,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    seq_len: int,
    ffn_act: str = "swiglu",
    n_experts: int = 0,
    top_k: int = 1,
) -> NetworkIR:
    """One transformer block as a layer chain for the evaluator.

    Matmuls become 1x1 convolutions over ``seq_len`` pixels (h_in=seq, w_in=1)
    with channels = feature dims.  Attention's QK^T and PV products are
    ``actmul`` layers (both operands are activations).  For MoE blocks the MLP
    matmuls carry the *active* expert weights (top_k experts worth of compute;
    weight traffic scales with the experts actually streamed from DRAM).
    """
    hd = d_model // n_heads
    kv_dim = n_kv_heads * hd
    layers = [
        LayerSpec(f"{name}.q", "matmul", d_model, d_model, seq_len, 1),
        LayerSpec(f"{name}.kv", "matmul", d_model, 2 * kv_dim, seq_len, 1),
        # QK^T: contraction over head_dim, output seq x seq per head.
        LayerSpec(f"{name}.qk", "actmul", d_model, n_heads * seq_len, seq_len, 1),
        # PV: contraction over seq, output seq x d_model.
        LayerSpec(f"{name}.pv", "actmul", n_heads * seq_len, d_model, seq_len, 1),
        LayerSpec(f"{name}.o", "matmul", d_model, d_model, seq_len, 1),
    ]
    mult = 2 if ffn_act == "swiglu" else 1  # gate + up projections
    k = max(1, top_k)
    if n_experts > 1:
        layers.append(
            LayerSpec(f"{name}.moe_w1", "matmul", d_model, mult * d_ff * k, seq_len, 1)
        )
        layers.append(
            LayerSpec(f"{name}.moe_w2", "matmul", d_ff * k, d_model, seq_len, 1)
        )
    else:
        layers.append(LayerSpec(f"{name}.w1", "matmul", d_model, mult * d_ff, seq_len, 1))
        layers.append(LayerSpec(f"{name}.w2", "matmul", d_ff, d_model, seq_len, 1))
    return NetworkIR(name, tuple(layers))


def lm_ir(
    *,
    name: str,
    n_layers: int,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    seq_len: int,
    n_experts: int = 0,
    top_k: int = 1,
    repeat: int = 1,
) -> NetworkIR:
    """A (possibly truncated) LM as one chain; ``repeat`` caps emitted blocks.

    The evaluator's fusion search is per-chain; transformer LMs are periodic,
    so evaluating ``repeat`` blocks and scaling by ``n_layers / repeat`` is
    exact for periodic stacks (validated in tests).
    """
    blocks = []
    for b in range(min(repeat, n_layers)):
        blk = transformer_block_ir(
            name=f"{name}.b{b}",
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv_heads,
            d_ff=d_ff,
            seq_len=seq_len,
            n_experts=n_experts,
            top_k=top_k,
        )
        blocks.extend(blk.layers)
    return NetworkIR(name, tuple(blocks))


def chain_ir(name: str, layers: Iterable[LayerSpec]) -> NetworkIR:
    return NetworkIR(name, tuple(layers))
