"""Layer/graph intermediate representation for the pre-RTL evaluator.

The paper (Yang & Chang, ISOCC'21) evaluates networks as chains of layers,
each a convolution with ``N*Nih*Niw`` input frames, ``N*Nkh*Nkw*M`` filter
kernels and ``M*Noh*Now`` output frames (Sec. II-B).  This module defines that
layer abstraction plus two network representations:

* :class:`NetworkIR` — the paper's original *chain* of layers.
* :class:`GraphIR`   — a DAG of layer nodes joined by explicit tensor edges,
  generalising the fusion-group search to residual / branching networks
  (LoopTree frames fused-layer scheduling as exactly this graph-partitioning
  problem).  A chain is the special case where edge ``i`` connects node ``i``
  to node ``i+1``; :func:`as_graph` performs that embedding losslessly.

Fusion groups on a graph are described by a boolean vector over *edges*: a
cut edge crosses a group boundary (its tensor round-trips through DRAM), an
uncut edge stays inside a group (its tensor lives in on-chip SRAM).  For a
residual basic block the cut space looks like::

        in ──e0──> conv_a ──e1──> conv_b ──e2──> add ──e4──> out
         │                                        ^
         └────────────────e3 (skip)───────────────┘

  cutting {e0,e1,e2,e3,e4}  = layer-by-layer (every tensor hits DRAM);
  cutting {e0,e4} only      = the whole block is one fusion group — the
  skip tensor e3 *and* both conv intermediates stay in SRAM, a grouping a
  chain IR cannot even express (e3 is a second consumer of ``in``'s output).
  A valid group must be weakly connected and convex (no dataflow may leave
  the group and re-enter), which on the quotient graph means acyclicity —
  see :mod:`repro.core.fusion`.

Builders cover VGG-16 (the paper's own experiment, Sec. III), transformer
blocks / LMs (matmuls as 1x1 convolutions over ``seq`` "pixels"), ResNet-18
(residual DAG) and an encoder–decoder block (cross-attention DAG).

Everything here is plain Python + numpy feature extraction; the vectorised
metric kernels live in :mod:`repro.core.metrics`.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, Sequence

import numpy as np

from .errors import GraphValidationError

# Layer kinds.  "conv" and "fc" carry weights; "pool" is weightless; "matmul"
# covers transformer projections (weights) and "actmul" covers activation x
# activation products (attention QK^T / PV) whose "weights" are activations
# and therefore count as input traffic, not weight traffic.  "scan" is a
# recurrent node (SSM selective scan): weightless like elementwise, but its
# ``state_words`` carry occupies SRAM in every grouping.
KINDS = ("conv", "pool", "fc", "matmul", "actmul", "elementwise", "scan")

# Integer-valued LayerSpec fields and the floor each must satisfy.  NaN,
# inf, floats and negative word counts are all rejected here — the
# feature-matrix columns derive from these fields, so validating them at
# construction is what makes every downstream feature word finite and
# non-negative (the service's admission contract).
_LAYER_INT_FIELDS = (
    ("n_in", 1), ("n_out", 1), ("h_in", 1), ("w_in", 1),
    ("kh", 1), ("kw", 1), ("stride", 1), ("pool_after", 1),
    ("flops_per_mac", 1), ("groups", 1), ("ext_in_words", 0),
    ("state_words", 0),
)


def _as_valid_int(value, *, floor: int, what: str) -> int:
    """``value`` as a plain int, or :class:`GraphValidationError` naming the
    offending field — floats (including NaN/inf), bools and anything below
    ``floor`` are corrupt feature words, not layer geometry."""
    if isinstance(value, bool) or not isinstance(value, (int, np.integer)):
        raise GraphValidationError(
            f"{what} = {value!r} is not an integer word count"
        )
    if value < floor:
        raise GraphValidationError(f"{what} = {int(value)} is below {floor}")
    return int(value)


def validate_layer(l: "LayerSpec") -> None:
    """Check every :class:`LayerSpec` invariant, raising
    :class:`GraphValidationError` naming the offending field.  Runs at
    construction (``__post_init__``) and again from
    :meth:`GraphIR.validate` so graphs corrupted *after* construction
    (deserialisation, test fault injection) are still caught at the
    service boundary."""
    if l.kind not in KINDS:
        raise GraphValidationError(
            f"{l.name}: unknown layer kind {l.kind!r} (expected one of {KINDS})"
        )
    for field, floor in _LAYER_INT_FIELDS:
        _as_valid_int(getattr(l, field), floor=floor,
                      what=f"{l.name}: {field}")
    if l.n_in % l.groups or l.n_out % l.groups:
        raise GraphValidationError(
            f"{l.name}: groups={l.groups} must divide "
            f"n_in={l.n_in} and n_out={l.n_out}"
        )


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer in the paper's notation.

    ``n_in``/``n_out`` are N / M (input / output channels); ``h_in``/``w_in``
    are Nih/Niw; ``kh``/``kw`` are Nkh/Nkw; ``h_out``/``w_out`` are Noh/Now.
    ``pool_after`` > 1 means a pooling stage is *absorbed* into this layer's
    write-out path (the DLA's inline ReLU/BN/pool functional unit, Fig. 1).
    ``groups`` > 1 is a grouped convolution: each output channel contracts
    only ``n_in / groups`` input channels (depthwise = ``groups == n_in``),
    which scales the kernel words and MAC count but not the activation
    frames.  ``ext_in_words`` > 0 is activation traffic streamed from DRAM
    *regardless of grouping* — operands not covered by any graph edge (a
    join that consumes the raw network input re-reads it in every
    grouping, because there is no producer node to fuse with).
    ``state_words`` > 0 is a recurrent carry (``d_state x d_inner`` for an
    SSM selective scan): words that live in SRAM for the node's whole
    execution, in *every* grouping, on top of any streamed input frame —
    Eq. (4) and buffer feasibility both charge them.
    """

    name: str
    kind: str
    n_in: int
    n_out: int
    h_in: int
    w_in: int
    kh: int = 1
    kw: int = 1
    stride: int = 1
    pool_after: int = 1
    flops_per_mac: int = 2
    groups: int = 1
    ext_in_words: int = 0
    state_words: int = 0

    def __post_init__(self):
        validate_layer(self)

    # ---- derived geometry (SAME padding; stride then absorbed pool) --------
    @property
    def h_out(self) -> int:
        """Output height: SAME-padding stride then the absorbed pool."""
        return max(1, self.h_in // self.stride // self.pool_after)

    @property
    def w_out(self) -> int:
        """Output width: SAME-padding stride then the absorbed pool."""
        base = self.w_in // self.stride
        return max(1, base // self.pool_after)

    # ---- paper quantities (in words; the paper uses one word per element) --
    @property
    def contracted_channels(self) -> int:
        """Input channels each output channel contracts (N / groups)."""
        return self.n_in // self.groups

    @property
    def weight_words(self) -> int:
        """(N/groups)*Nkh*Nkw*M for weighted layers; 0 for pool/actmul/elementwise."""
        if self.kind in ("conv", "fc", "matmul"):
            return self.contracted_channels * self.kh * self.kw * self.n_out
        return 0

    @property
    def in_words(self) -> int:
        """N*Nih*Niw (+ the second operand for activation-activation products)."""
        base = self.n_in * self.h_in * self.w_in
        if self.kind == "actmul":
            # QK^T / PV: the "kernel" operand is also an activation tensor.
            base += self.n_in * self.kh * self.kw * self.n_out
        return base

    @property
    def out_words(self) -> int:
        """M*Noh*Now after the absorbed pool (what hits DRAM on write-out)."""
        return self.n_out * self.h_out * self.w_out

    @property
    def out_words_prepool(self) -> int:
        """M*Noh*Now before the absorbed pool (the on-chip intermediate)."""
        return self.n_out * (self.h_in // self.stride) * (self.w_in // self.stride)

    @property
    def macs(self) -> int:
        """MAC count of the layer (zero for weightless kinds)."""
        if self.kind in ("pool", "elementwise", "scan"):
            return 0
        return (
            self.contracted_channels
            * self.kh
            * self.kw
            * self.n_out
            * (self.h_in // self.stride)
            * (self.w_in // self.stride)
        )

    @property
    def flops(self) -> int:
        """FLOPs at 2 per MAC."""
        return self.macs * self.flops_per_mac

    def describe(self) -> str:
        """One-line geometry/kernel/weight/MAC summary."""
        grp = f" g={self.groups}" if self.groups > 1 else ""
        return (
            f"{self.name:12s} {self.kind:5s} N={self.n_in:5d} M={self.n_out:5d} "
            f"in={self.h_in}x{self.w_in} k={self.kh}x{self.kw}/{self.stride}{grp} "
            f"pool={self.pool_after} W={self.weight_words} MACs={self.macs}"
        )


def _feature_row(l: LayerSpec) -> list[float]:
    """One feature vector (order = ``NetworkIR.FEATURES``).

    The ``n_in`` column carries the *contracted* channels (N / groups) — the
    input-parallel extent the PE array actually tiles — so grouped/depthwise
    convolutions cost the right t_PB in the vectorised kernels, lock-step
    with the scalar oracles.
    """
    return [
        l.weight_words,
        l.in_words,
        l.out_words,
        l.out_words_prepool,
        l.macs,
        1.0 if l.kind == "pool" else 0.0,
        l.kh,
        l.kw,
        l.contracted_channels,
        l.n_out,
        (l.h_in // l.stride) * (l.w_in // l.stride),
        l.ext_in_words,
        l.state_words,
    ]


@dataclasses.dataclass(frozen=True)
class NetworkIR:
    """A chain of layers (the unit the fusion search partitions)."""

    name: str
    layers: tuple[LayerSpec, ...]

    def __post_init__(self):
        if not self.layers:
            raise ValueError("empty network")

    def __len__(self) -> int:
        return len(self.layers)

    def __iter__(self):
        return iter(self.layers)

    @property
    def total_macs(self) -> int:
        """Network-total MAC count."""
        return sum(l.macs for l in self.layers)

    @property
    def total_weight_words(self) -> int:
        """Network-total weight words (read once per inference, Eq. (1))."""
        return sum(l.weight_words for l in self.layers)

    # ---- feature matrix for the vectorised metric kernels ------------------
    FEATURES = (
        "weight_words",
        "in_words",
        "out_words",
        "out_words_prepool",
        "macs",
        "is_pool",
        "kh",
        "kw",
        "n_in",
        "n_out",
        "pixels_out",
        "ext_in_words",
        "state_words",
    )

    def feature_matrix(self) -> np.ndarray:
        """(L, F) float64 matrix consumed by :mod:`repro.core.metrics`."""
        return np.asarray([_feature_row(l) for l in self.layers], dtype=np.float64)

    def pool_boundary_cuts(self) -> np.ndarray:
        """The paper's VGG-16 grouping: cut after every pooling stage.

        Returns a boolean cut vector of length L-1 (cut[i] == True means a
        group boundary between layer i and layer i+1).
        """
        L = len(self.layers)
        cuts = np.zeros(L - 1, dtype=bool)
        for i, l in enumerate(self.layers[:-1]):
            if l.kind == "pool" or l.pool_after > 1:
                cuts[i] = True
        return cuts


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------

VGG16_CONV_PLAN = (
    # (name, n_in, n_out, spatial, pool_after_this_layer)
    ("conv1_1", 3, 64, 224, False),
    ("conv1_2", 64, 64, 224, True),
    ("conv2_1", 64, 128, 112, False),
    ("conv2_2", 128, 128, 112, True),
    ("conv3_1", 128, 256, 56, False),
    ("conv3_2", 256, 256, 56, False),
    ("conv3_3", 256, 256, 56, True),
    ("conv4_1", 256, 512, 28, False),
    ("conv4_2", 512, 512, 28, False),
    ("conv4_3", 512, 512, 28, True),
    ("conv5_1", 512, 512, 14, False),
    ("conv5_2", 512, 512, 14, False),
    ("conv5_3", 512, 512, 14, True),
)


@functools.lru_cache(maxsize=None)
def vgg16_ir(*, pool_mode: str = "separate", include_fc: bool = False) -> NetworkIR:
    """VGG-16 feature extractor as used in the paper's Sec. III experiment.

    A thin wrapper over the tracing frontend: the chain is traced from the
    real JAX model (:mod:`repro.models.vgg`) by
    :func:`repro.core.frontend.vgg16_network` — locked layer-identical to a
    verbatim transcription of the original hand-built plan in
    ``tests/test_frontend.py``.

    pool_mode:
      * ``"separate"``  — pooling layers are standalone layers (the naive
        layer-by-layer execution round-trips them through DRAM; fusion absorbs
        them into the group).  This is the accounting that reproduces the
        paper's 55.6 % bandwidth-reduction number.
      * ``"absorbed"``  — pooling runs inside the producing conv's functional
        unit even in layer-by-layer mode (no standalone pool layers; the
        frontend folds each window == stride pool into its producer).
    """
    from .frontend import vgg16_network

    return vgg16_network(pool_mode=pool_mode, include_fc=include_fc)


def transformer_block_ir(
    *,
    name: str,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    seq_len: int,
    ffn_act: str = "swiglu",
    n_experts: int = 0,
    top_k: int = 1,
) -> NetworkIR:
    """One transformer block as a layer chain for the evaluator.

    Matmuls become 1x1 convolutions over ``seq_len`` pixels (h_in=seq, w_in=1)
    with channels = feature dims.  Attention's QK^T and PV products are
    ``actmul`` layers (both operands are activations).  For MoE blocks the MLP
    matmuls carry the *active* expert weights (top_k experts worth of compute;
    weight traffic scales with the experts actually streamed from DRAM).
    """
    hd = d_model // n_heads
    kv_dim = n_kv_heads * hd
    layers = [
        LayerSpec(f"{name}.q", "matmul", d_model, d_model, seq_len, 1),
        LayerSpec(f"{name}.kv", "matmul", d_model, 2 * kv_dim, seq_len, 1),
        # QK^T: contraction over head_dim, output seq x seq per head.
        LayerSpec(f"{name}.qk", "actmul", d_model, n_heads * seq_len, seq_len, 1),
        # PV: contraction over seq, output seq x d_model.
        LayerSpec(f"{name}.pv", "actmul", n_heads * seq_len, d_model, seq_len, 1),
        LayerSpec(f"{name}.o", "matmul", d_model, d_model, seq_len, 1),
    ]
    mult = 2 if ffn_act == "swiglu" else 1  # gate + up projections
    k = max(1, top_k)
    if n_experts > 1:
        layers.append(
            LayerSpec(f"{name}.moe_w1", "matmul", d_model, mult * d_ff * k, seq_len, 1)
        )
        layers.append(
            LayerSpec(f"{name}.moe_w2", "matmul", d_ff * k, d_model, seq_len, 1)
        )
    else:
        layers.append(LayerSpec(f"{name}.w1", "matmul", d_model, mult * d_ff, seq_len, 1))
        layers.append(LayerSpec(f"{name}.w2", "matmul", d_ff, d_model, seq_len, 1))
    return NetworkIR(name, tuple(layers))


def lm_ir(
    *,
    name: str,
    n_layers: int,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    d_ff: int,
    seq_len: int,
    n_experts: int = 0,
    top_k: int = 1,
    repeat: int = 1,
) -> NetworkIR:
    """A (possibly truncated) LM as one chain; ``repeat`` caps emitted blocks.

    The evaluator's fusion search is per-chain; transformer LMs are periodic,
    so evaluating ``repeat`` blocks and scaling by ``n_layers / repeat`` is
    exact for periodic stacks (validated in tests).
    """
    blocks = []
    for b in range(min(repeat, n_layers)):
        blk = transformer_block_ir(
            name=f"{name}.b{b}",
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv_heads,
            d_ff=d_ff,
            seq_len=seq_len,
            n_experts=n_experts,
            top_k=top_k,
        )
        blocks.extend(blk.layers)
    return NetworkIR(name, tuple(blocks))


def chain_ir(name: str, layers: Iterable[LayerSpec]) -> NetworkIR:
    """Build a chain ``NetworkIR`` from an iterable of layers."""
    return NetworkIR(name, tuple(layers))


# ---------------------------------------------------------------------------
# Graph IR — DAG of layer nodes with explicit tensor edges
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EdgeSpec:
    """A tensor flowing from node ``src`` to node ``dst``.

    ``words`` is the tensor's word count as *read by the consumer*: if the
    edge is cut (crosses a fusion-group boundary) the consumer streams
    ``words`` from DRAM; if the edge is internal the tensor occupies
    ``words`` of on-chip frame SRAM instead.  For chain embeddings this is
    the consumer layer's ``in_words`` so chain metrics stay bit-identical.
    """

    src: int
    dst: int
    words: int

    def __post_init__(self):
        validate_edge(self)


def validate_edge(e: "EdgeSpec", n_nodes: int | None = None) -> None:
    """Check one :class:`EdgeSpec`, raising :class:`GraphValidationError`
    naming the edge.  ``src < dst`` is the IR's acyclicity invariant (node
    ids are topological); ``n_nodes`` additionally range-checks the
    endpoints against a graph."""
    tag = f"edge ({e.src}->{e.dst})"
    _as_valid_int(e.src, floor=0, what=f"{tag} src")
    _as_valid_int(e.dst, floor=0, what=f"{tag} dst")
    if e.dst <= e.src:
        raise GraphValidationError(
            f"{tag} must be topological (src < dst); a dst <= src edge "
            "would make the graph cyclic"
        )
    _as_valid_int(e.words, floor=1, what=f"{tag} words")
    if n_nodes is not None and e.dst >= n_nodes:
        raise GraphValidationError(f"{tag} out of range (L={n_nodes})")


@dataclasses.dataclass(frozen=True)
class GraphIR:
    """A DAG of layers (the unit the edge-cut fusion search partitions).

    Nodes are :class:`LayerSpec` in topological order; every edge satisfies
    ``src < dst`` and edges are stored sorted by ``(src, dst)``.  Nodes with
    no incoming edge read their input frame from DRAM unconditionally;
    nodes with no outgoing edge write their output frame unconditionally.
    """

    name: str
    nodes: tuple[LayerSpec, ...]
    edges: tuple[EdgeSpec, ...]

    def __post_init__(self):
        self.validate()
        object.__setattr__(
            self, "edges", tuple(sorted(self.edges, key=lambda e: (e.src, e.dst)))
        )

    def validate(self) -> "GraphIR":
        """Re-check every IR invariant — node fields finite/positive, edge
        endpoints in range, topological (acyclic) edges, no duplicates —
        raising :class:`GraphValidationError` naming the offending node or
        edge.  Runs at construction, and again at the planning-service
        admission boundary so graphs corrupted after construction
        (deserialisation bugs, fault injection) are rejected with a typed
        error instead of surfacing as an index error deep in a kernel.
        Returns ``self`` so call sites can chain."""
        if not self.nodes:
            raise GraphValidationError(f"{self.name}: empty graph")
        for i, n in enumerate(self.nodes):
            if not isinstance(n, LayerSpec):
                raise GraphValidationError(
                    f"{self.name}: node {i} is {type(n).__name__}, "
                    "not a LayerSpec"
                )
            validate_layer(n)
        L = len(self.nodes)
        seen = set()
        for e in self.edges:
            if not isinstance(e, EdgeSpec):
                raise GraphValidationError(
                    f"{self.name}: edge {e!r} is not an EdgeSpec"
                )
            validate_edge(e, L)
            if (e.src, e.dst) in seen:
                raise GraphValidationError(
                    f"duplicate edge ({e.src}->{e.dst})"
                )
            seen.add((e.src, e.dst))
        return self

    def __len__(self) -> int:
        return len(self.nodes)

    @property
    def n_nodes(self) -> int:
        """Node count (alias of ``len(graph)``)."""
        return len(self.nodes)

    @property
    def n_edges(self) -> int:
        """Edge count — the grouping space is the 2^n_edges cut vectors."""
        return len(self.edges)

    @property
    def is_chain(self) -> bool:
        """True iff the graph is exactly the chain embedding (edge i: i->i+1)."""
        return len(self.edges) == len(self.nodes) - 1 and all(
            e.src == i and e.dst == i + 1 for i, e in enumerate(self.edges)
        )

    @property
    def total_macs(self) -> int:
        """Graph-total MAC count."""
        return sum(n.macs for n in self.nodes)

    @property
    def total_weight_words(self) -> int:
        """Graph-total weight words (read once per inference, Eq. (1))."""
        return sum(n.weight_words for n in self.nodes)

    # ---- numpy views for the metric kernels --------------------------------
    FEATURES = NetworkIR.FEATURES

    def node_features(self) -> np.ndarray:
        """(L, F) float64 matrix (same columns as ``NetworkIR.feature_matrix``)."""
        return np.asarray([_feature_row(n) for n in self.nodes], dtype=np.float64)

    def edge_arrays(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(src, dst, words) arrays of shape (E,): int64, int64, float64."""
        src = np.asarray([e.src for e in self.edges], dtype=np.int64)
        dst = np.asarray([e.dst for e in self.edges], dtype=np.int64)
        words = np.asarray([e.words for e in self.edges], dtype=np.float64)
        return src, dst, words

    @property
    def in_degree(self) -> np.ndarray:
        """(L,) incoming-edge count per node."""
        deg = np.zeros(len(self.nodes), dtype=np.int64)
        for e in self.edges:
            deg[e.dst] += 1
        return deg

    @property
    def out_degree(self) -> np.ndarray:
        """(L,) outgoing-edge count per node."""
        deg = np.zeros(len(self.nodes), dtype=np.int64)
        for e in self.edges:
            deg[e.src] += 1
        return deg

    @property
    def source_mask(self) -> np.ndarray:
        """(L,) bool — nodes reading their input frame from DRAM."""
        return self.in_degree == 0

    @property
    def sink_mask(self) -> np.ndarray:
        """(L,) bool — nodes whose output always writes to DRAM."""
        return self.out_degree == 0

    def successors(self, i: int) -> list[int]:
        """Consumer node ids of node ``i``."""
        return [e.dst for e in self.edges if e.src == i]

    def predecessors(self, i: int) -> list[int]:
        """Producer node ids of node ``i``."""
        return [e.src for e in self.edges if e.dst == i]

    def pool_boundary_cuts(self) -> np.ndarray:
        """The paper's Sec. III policy lifted to edges: cut every edge whose
        producer ends a pooling stage (standalone pool layer or absorbed
        pool), then repaired to a *valid* partition (a raw per-edge policy
        can cut an edge whose endpoints stay connected through a skip path,
        or leave a non-convex group).  On a chain embedding this equals
        ``NetworkIR.pool_boundary_cuts``."""
        cuts = np.zeros(len(self.edges), dtype=bool)
        for k, e in enumerate(self.edges):
            p = self.nodes[e.src]
            if p.kind == "pool" or p.pool_after > 1:
                cuts[k] = True
        return _repair_partition_cuts(len(self.nodes), self.edges, cuts)

    def describe(self) -> str:
        """Multi-line dump: one row per node with its producer ids."""
        lines = [f"graph {self.name}: {len(self.nodes)} nodes, {len(self.edges)} edges"]
        for i, n in enumerate(self.nodes):
            preds = self.predecessors(i)
            tag = f" <- {preds}" if preds else " <- (DRAM)"
            lines.append(f"  [{i:3d}] {n.describe()}{tag}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Shape buckets — zero-padded views for the bucketed evaluator
# ---------------------------------------------------------------------------


def bucket_size(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — the shape-bucket rounding
    used by :mod:`repro.core.flow` so many graphs share one compiled
    evaluator executable instead of paying XLA compilation per exact
    ``(L, E, C)`` signature."""
    n = max(int(n), int(floor), 1)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class PaddedGraph:
    """Zero-padded numpy views of a :class:`GraphIR` for bucketed evaluation.

    Padded node rows carry all-zero features with ``node_mask`` False and
    ``src_mask``/``sink_mask`` False; padded edges point ``0 -> 0`` with
    ``words == 0`` and ``edge_mask`` False.  The masked metric kernels
    (:func:`repro.core.metrics.evaluate_batch_graph`) make such rows exactly
    inert in Eq. (1)-(4): every padded summand is 0.0 and every padded max
    operand is at or below the unpadded floor, so padded results are
    bit-identical to the unpadded path (all words are integer-valued
    float64, hence exact under any summation order).
    """

    feat: np.ndarray  # (L_b, F) — rows >= n_nodes are all-zero
    esrc: np.ndarray  # (E_b,) int64 — entries >= n_edges are 0
    edst: np.ndarray  # (E_b,) int64 — entries >= n_edges are 0
    ewords: np.ndarray  # (E_b,) float64 — entries >= n_edges are 0.0
    src_mask: np.ndarray  # (L_b,) bool — False on padded rows
    sink_mask: np.ndarray  # (L_b,) bool — False on padded rows
    node_mask: np.ndarray  # (L_b,) bool — True exactly on real nodes
    edge_mask: np.ndarray  # (E_b,) bool — True exactly on real edges
    n_nodes: int  # real node count (L)
    n_edges: int  # real edge count (E)

    @property
    def n_nodes_padded(self) -> int:
        """Bucket node count L_pad (>= n_nodes)."""
        return self.feat.shape[0]

    @property
    def n_edges_padded(self) -> int:
        """Bucket edge count E_pad (>= n_edges)."""
        return self.esrc.shape[0]


def pad_graph(
    g: GraphIR, *, n_nodes: int | None = None, n_edges: int | None = None
) -> PaddedGraph:
    """Zero-pad ``g``'s evaluator arrays to bucket sizes.

    ``n_nodes``/``n_edges`` are the target (padded) sizes and must be >= the
    real counts; they default to the next power of two
    (:func:`bucket_size`).
    """
    L, E = g.n_nodes, g.n_edges
    L_b = bucket_size(L) if n_nodes is None else int(n_nodes)
    E_b = bucket_size(E) if n_edges is None else int(n_edges)
    if L_b < L or E_b < E:
        raise ValueError(
            f"bucket ({L_b}, {E_b}) smaller than graph ({L}, {E})"
        )
    feat = g.node_features()
    esrc, edst, ewords = g.edge_arrays()
    feat_p = np.zeros((L_b, feat.shape[1]), dtype=feat.dtype)
    feat_p[:L] = feat
    esrc_p = np.zeros(E_b, dtype=np.int64)
    esrc_p[:E] = esrc
    edst_p = np.zeros(E_b, dtype=np.int64)
    edst_p[:E] = edst
    ewords_p = np.zeros(E_b, dtype=np.float64)
    ewords_p[:E] = ewords

    def _pad_mask(m: np.ndarray, n: int) -> np.ndarray:
        out = np.zeros(n, dtype=bool)
        out[: m.shape[0]] = m
        return out

    node_mask = np.zeros(L_b, dtype=bool)
    node_mask[:L] = True
    edge_mask = np.zeros(E_b, dtype=bool)
    edge_mask[:E] = True
    return PaddedGraph(
        feat=feat_p,
        esrc=esrc_p,
        edst=edst_p,
        ewords=ewords_p,
        src_mask=_pad_mask(g.source_mask, L_b),
        sink_mask=_pad_mask(g.sink_mask, L_b),
        node_mask=node_mask,
        edge_mask=edge_mask,
        n_nodes=L,
        n_edges=E,
    )


def pad_cuts_batch(
    cuts_batch: np.ndarray, n_edges: int, n_rows: int | None = None
) -> np.ndarray:
    """Pad a (C, E) cut batch to ``(n_rows, n_edges)`` with False.

    Padded edge columns are ignored by the masked kernels (``edge_mask``);
    padded candidate rows evaluate to well-defined but meaningless metrics
    and must be sliced off by the caller (``out[:, :C]``) before any
    feasibility test or argmin.
    """
    cuts = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    C, E = cuts.shape
    C_b = C if n_rows is None else int(n_rows)
    if n_edges < E or C_b < C:
        raise ValueError(
            f"pad target ({C_b}, {n_edges}) smaller than batch ({C}, {E})"
        )
    out = np.zeros((C_b, n_edges), dtype=bool)
    out[:C, :E] = cuts
    return out


def uncut_component_labels(
    n_nodes: int, edges: tuple[EdgeSpec, ...], cuts: np.ndarray
) -> np.ndarray:
    """(L,) group labels: connected components of the uncut subgraph,
    relabelled to consecutive ints in order of first node appearance.
    The single partition-labelling used by both the cut-policy repair here
    and the fusion search (:mod:`repro.core.fusion`)."""
    cuts = np.asarray(cuts, dtype=bool)
    parent = list(range(n_nodes))

    def find(x: int) -> int:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for k, e in enumerate(edges):
        if not cuts[k]:
            ra, rb = find(e.src), find(e.dst)
            if ra != rb:
                parent[rb] = ra
    remap: dict[int, int] = {}
    out = np.empty(n_nodes, dtype=np.int64)
    for i in range(n_nodes):
        r = find(i)
        if r not in remap:
            remap[r] = len(remap)
        out[i] = remap[r]
    return out


def _min_label_reps_batch(
    n_nodes: int,
    esrc: np.ndarray,
    edst: np.ndarray,
    cuts_batch: np.ndarray,
) -> np.ndarray:
    """(C, L) component representatives (min node index per component) of the
    uncut subgraph, for a whole batch of cut vectors at once.

    Min-label propagation: every node starts labelled with its own index;
    each sweep relaxes every uncut edge to the min of its endpoint labels,
    then pointer-jumps (``lab <- lab[lab]``, valid because a label is always
    the index of a node in the same component) until a full sweep changes
    nothing.  Because node ids are topological, minima flow mostly in edge
    order and the loop converges in a handful of sweeps.
    """
    cuts_batch = np.asarray(cuts_batch, dtype=bool)
    C = cuts_batch.shape[0]
    dtype = np.int16 if n_nodes < 2**15 else np.int64
    lab = np.repeat(np.arange(n_nodes, dtype=dtype)[None, :], max(C, 1), axis=0)
    E = len(esrc)
    if E == 0 or C == 0:
        return lab[:C]
    uncut = ~cuts_batch

    def relax(k: int) -> None:
        u = uncut[:, k]
        ls = lab[:, esrc[k]]
        ld = lab[:, edst[k]]
        m = np.minimum(ls, ld)
        lab[:, esrc[k]] = np.where(u, m, ls)
        lab[:, edst[k]] = np.where(u, m, ld)

    while True:
        prev = lab.copy()
        for k in range(E):  # forward: minima flow with the edge order ...
            relax(k)
        for k in range(E - 1, -1, -1):  # ... and backward, against it
            relax(k)
        lab = np.take_along_axis(lab, lab, axis=1)
        if np.array_equal(lab, prev):
            return lab


def canonicalize_labels_batch(labels: np.ndarray) -> np.ndarray:
    """Relabel every row of a (C, L) label batch to consecutive ints in order
    of first appearance — the canonical form :func:`uncut_component_labels`
    returns (and the dedup key the merge searches use)."""
    labels = np.atleast_2d(np.asarray(labels))
    C, L = labels.shape
    if L == 0 or C == 0:
        return labels.astype(np.int16)
    rows = np.arange(C)
    first = np.full((C, L), L, dtype=np.int16)  # first[c, v]: first col of v
    for i in range(L - 1, -1, -1):
        first[rows, labels[:, i]] = i
    fp = np.take_along_axis(first, labels.astype(np.int64), axis=1)
    is_first = fp == np.arange(L, dtype=np.int16)[None, :]
    rank = np.cumsum(is_first, axis=1, dtype=np.int16)
    return np.take_along_axis(rank, fp.astype(np.int64), axis=1) - 1


def uncut_component_labels_batch(
    n_nodes: int, edges: tuple[EdgeSpec, ...], cuts_batch: np.ndarray
) -> np.ndarray:
    """Batched :func:`uncut_component_labels`: (C, E) cut batch -> (C, L)
    canonical group labels, with no per-candidate Python (lock-step with the
    scalar union-find, asserted in tests)."""
    cuts_batch = np.atleast_2d(np.asarray(cuts_batch, dtype=bool))
    esrc = np.asarray([e.src for e in edges], dtype=np.int64)
    edst = np.asarray([e.dst for e in edges], dtype=np.int64)
    return canonicalize_labels_batch(
        _min_label_reps_batch(n_nodes, esrc, edst, cuts_batch)
    )


def quotient_acyclic_batch(
    n_nodes: int,
    esrc: np.ndarray,
    edst: np.ndarray,
    labels: np.ndarray,
) -> np.ndarray:
    """(C,) bool — is each row's group-contracted (quotient) graph acyclic?

    Vectorised Kahn peeling: repeatedly remove every group with no incoming
    arc from a still-alive group; a row is acyclic iff all groups die.  Rows
    are compacted out of the working set as soon as they are decided, so the
    per-iteration cost tracks the undecided population.  ``labels`` may be
    representatives or canonical labels — any values in [0, n_nodes).
    """
    labels = np.atleast_2d(np.asarray(labels))
    C = labels.shape[0]
    out = np.ones(C, dtype=bool)
    E = len(esrc)
    if E == 0 or C == 0:
        return out
    lab_s = labels[:, esrc]  # (C, E) group of each arc tail
    lab_d = labels[:, edst]
    cross = lab_s != lab_d
    ids = np.flatnonzero(cross.any(axis=1))  # rows with >= 1 quotient arc
    if ids.size == 0:
        return out
    lab_s, lab_d, cross = lab_s[ids], lab_d[ids], cross[ids]
    alive = np.zeros((ids.size, n_nodes), dtype=bool)
    np.put_along_axis(alive, labels[ids].astype(np.int64), True, axis=1)
    while ids.size:
        rows = np.arange(ids.size)
        in_any = np.zeros((ids.size, n_nodes), dtype=bool)
        for k in range(E):
            act = cross[:, k] & alive[rows, lab_s[:, k]]
            in_any[rows, lab_d[:, k]] |= act
        removable = alive & ~in_any
        progressed = removable.any(axis=1)
        alive &= ~removable
        alive_left = alive.any(axis=1)
        out[ids[alive_left & ~progressed]] = False  # stuck -> cyclic
        keep = alive_left & progressed
        if not keep.any():
            return out
        ids, alive = ids[keep], alive[keep]
        lab_s, lab_d, cross = lab_s[keep], lab_d[keep], cross[keep]
    return out


# ---------------------------------------------------------------------------
# Topological elimination orders and frontier width (for the frontier DP)
# ---------------------------------------------------------------------------
#
# The frontier-state fusion DP (:func:`repro.core.fusion.frontier_dp_min_bw`)
# sweeps nodes in a topological order; its state space is governed by the
# *frontier width* — the largest number of already-processed nodes that still
# have an edge into the unprocessed suffix at any point of the sweep.  Any
# topological order yields the same optimum (cost accounting is
# order-independent); a narrower order just keeps the DP small, so the
# search picks the better of the natural node order and a greedy
# width-minimising order.


def topo_frontier_sets(
    g: GraphIR, order: Sequence[int] | None = None
) -> list[list[int]]:
    """Frontier after each step of a topological sweep.

    ``out[t]`` lists (ascending node ids) the nodes among ``order[: t + 1]``
    that still have >= 1 edge to a node outside that prefix — exactly the
    nodes whose pending out-edges the frontier DP has yet to decide.  The
    last entry is always empty.  ``order`` defaults to the natural node
    order (topological by construction: every edge has ``src < dst``) and
    must itself be topological.
    """
    L = len(g.nodes)
    order = list(range(L)) if order is None else [int(i) for i in order]
    if sorted(order) != list(range(L)):
        raise ValueError("order must be a permutation of the node ids")
    pos = [0] * L
    for t, v in enumerate(order):
        pos[v] = t
    succs: list[list[int]] = [[] for _ in range(L)]
    for e in g.edges:
        if pos[e.src] >= pos[e.dst]:
            raise ValueError(
                f"order is not topological: edge {e.src}->{e.dst}"
            )
        succs[e.src].append(e.dst)
    out: list[list[int]] = []
    for t in range(L):
        frontier = [
            u
            for u in sorted(order[: t + 1])
            if any(pos[w] > t for w in succs[u])
        ]
        out.append(frontier)
    return out


def topo_frontier_width(g: GraphIR, order: Sequence[int] | None = None) -> int:
    """Largest frontier of a topological sweep (0 for a single node)."""
    return max((len(f) for f in topo_frontier_sets(g, order)), default=0)


def min_width_topo_order(g: GraphIR) -> list[int]:
    """Greedy width-minimising topological order.

    At each step, among the ready nodes (all predecessors processed), pick
    the one whose processing leaves the smallest frontier, tie-broken by
    node id — deterministic, and never worse than fanning out breadth-first.
    A heuristic (minimum-width elimination ordering is NP-hard); callers
    compare its width against the natural order and keep the narrower.
    """
    L = len(g.nodes)
    succs: list[list[int]] = [[] for _ in range(L)]
    n_pred = [0] * L
    for e in g.edges:
        succs[e.src].append(e.dst)
        n_pred[e.dst] += 1
    ready = sorted(i for i in range(L) if n_pred[i] == 0)
    pending_out = [len(s) for s in succs]  # edges into the unprocessed suffix
    frontier: set[int] = set()
    order: list[int] = []
    preds: list[list[int]] = [[] for _ in range(L)]
    for e in g.edges:
        preds[e.dst].append(e.src)

    def width_after(v: int) -> int:
        w = len(frontier) + (1 if pending_out[v] else 0)
        for u in preds[v]:
            if pending_out[u] == 1:  # (u, v) was u's last pending edge
                w -= 1
        return w

    while ready:
        v = min(ready, key=lambda u: (width_after(u), u))
        ready.remove(v)
        order.append(v)
        for u in preds[v]:
            pending_out[u] -= 1
            if pending_out[u] == 0:
                frontier.discard(u)
        if pending_out[v]:
            frontier.add(v)
        for w in succs[v]:
            n_pred[w] -= 1
            if n_pred[w] == 0:
                ready.append(w)
    return order


def scc_labels(n: int, arcs: set[tuple[int, int]]) -> list[int]:
    """Strongly-connected-component id per vertex (iterative Kosaraju)."""
    adj: list[list[int]] = [[] for _ in range(n)]
    radj: list[list[int]] = [[] for _ in range(n)]
    for a, b in arcs:
        adj[a].append(b)
        radj[b].append(a)
    order: list[int] = []
    seen = [False] * n
    for s in range(n):
        if seen[s]:
            continue
        seen[s] = True
        stack = [(s, 0)]
        while stack:
            u, i = stack[-1]
            if i < len(adj[u]):
                stack[-1] = (u, i + 1)
                v = adj[u][i]
                if not seen[v]:
                    seen[v] = True
                    stack.append((v, 0))
            else:
                order.append(u)
                stack.pop()
    comp = [-1] * n
    c = 0
    for s in reversed(order):
        if comp[s] != -1:
            continue
        comp[s] = c
        stack2 = [s]
        while stack2:
            u = stack2.pop()
            for v in radj[u]:
                if comp[v] == -1:
                    comp[v] = c
                    stack2.append(v)
        c += 1
    return comp


def _repair_partition_cuts(
    n_nodes: int, edges: tuple[EdgeSpec, ...], cuts: np.ndarray
) -> np.ndarray:
    """Round an arbitrary per-edge cut policy to the nearest valid partition.

    Groups become the connected components of the uncut subgraph (fixes cut
    edges that are internal via another path), then any directed cycle among
    groups is contracted (fixes non-convex groups; the condensation of the
    quotient graph is acyclic by construction).
    """
    labels = uncut_component_labels(n_nodes, edges, cuts)
    arcs = {
        (int(labels[e.src]), int(labels[e.dst]))
        for e in edges
        if labels[e.src] != labels[e.dst]
    }
    comp = scc_labels(int(labels.max()) + 1, arcs)
    final = [comp[labels[i]] for i in range(n_nodes)]
    return np.asarray(
        [final[e.src] != final[e.dst] for e in edges], dtype=bool
    )


def as_graph(ir: "NetworkIR | GraphIR") -> GraphIR:
    """Embed a chain as a GraphIR (identity on GraphIR inputs).

    Chain edge ``i`` connects node ``i`` to node ``i+1`` and carries the
    consumer's ``in_words`` so that edge-cut metrics reproduce the chain
    metrics bit-for-bit (cut edge k  <=>  group boundary after layer k).
    """
    if isinstance(ir, GraphIR):
        return ir
    edges = tuple(
        EdgeSpec(i, i + 1, ir.layers[i + 1].in_words)
        for i in range(len(ir.layers) - 1)
    )
    return GraphIR(ir.name, tuple(ir.layers), edges)


def graph_ir(
    name: str,
    nodes: Sequence[LayerSpec],
    edges: Iterable[tuple[int, int] | tuple[int, int, int] | EdgeSpec],
) -> GraphIR:
    """Build a GraphIR; 2-tuple edges default to the producer's out_words."""
    nodes = tuple(nodes)
    specs = []
    for e in edges:
        if isinstance(e, EdgeSpec):
            specs.append(e)
        elif len(e) == 2:
            specs.append(EdgeSpec(e[0], e[1], nodes[e[0]].out_words))
        else:
            specs.append(EdgeSpec(e[0], e[1], e[2]))
    return GraphIR(name, nodes, tuple(specs))


# ---------------------------------------------------------------------------
# DAG builders
# ---------------------------------------------------------------------------

RESNET18_STAGE_PLAN = (
    # (stage, n_blocks, channels, first_block_stride)
    (1, 2, 64, 1),
    (2, 2, 128, 2),
    (3, 2, 256, 2),
    (4, 2, 512, 2),
)


@functools.lru_cache(maxsize=None)
def resnet18_ir(*, input_hw: int = 224) -> GraphIR:
    """ResNet-18 as a residual DAG (He et al., 2016; ImageNet geometry).

    A thin wrapper over the tracing frontend: the DAG is traced from the
    real JAX model (:mod:`repro.models.resnet`) by
    :func:`repro.core.frontend.resnet18_graph`, which recovers every skip
    edge from the jaxpr's use-def chains — locked node-and-edge-identical
    to a verbatim transcription of the original hand-built DAG in
    ``tests/test_frontend.py``.

    Each basic block is ``conv3x3 -> conv3x3 -> add`` with a skip edge from
    the block input to the add node; stride-2 blocks project the skip
    through a 1x1 conv.  The skip edges are exactly what the chain IR could
    not represent: fusing a whole block keeps the skip tensor on-chip,
    which the edge-cut metrics reward with one saved store+load pair.
    """
    from .frontend import resnet18_graph

    return resnet18_graph(input_hw=input_hw)


def residual_block_ir(
    *, channels: int = 128, hw: int = 28, name: str = "resblock"
) -> GraphIR:
    """One ResNet basic block (identity skip) — the minimal DAG exhibiting a
    fusion group the chain IR cannot express (see the module docstring)."""
    nodes = (
        LayerSpec(f"{name}.in", "conv", channels, channels, hw, hw, 1, 1, 1),
        LayerSpec(f"{name}.conv_a", "conv", channels, channels, hw, hw, 3, 3, 1),
        LayerSpec(f"{name}.conv_b", "conv", channels, channels, hw, hw, 3, 3, 1),
        LayerSpec(f"{name}.add", "elementwise", channels, channels, hw, hw),
    )
    edges = (
        EdgeSpec(0, 1, nodes[0].out_words),
        EdgeSpec(1, 2, nodes[1].out_words),
        EdgeSpec(2, 3, nodes[2].out_words),
        EdgeSpec(0, 3, nodes[0].out_words),  # skip
    )
    return GraphIR(name, nodes, edges)


def encoder_decoder_ir(
    *,
    name: str = "encdec",
    d_model: int = 512,
    n_heads: int = 8,
    d_ff: int = 2048,
    seq_enc: int = 512,
    seq_dec: int = 128,
) -> GraphIR:
    """One encoder layer + one decoder layer with cross-attention.

    The encoder output ("memory") fans out to the decoder's cross-attention
    K/V projection — a long-range branch the chain IR cannot express.  If
    the memory edge is left uncut, the encoder output never round-trips
    through DRAM between the encoder and the decoder's cross-attention.
    """
    nodes: list[LayerSpec] = []
    edges: list[EdgeSpec] = []

    def add_node(spec: LayerSpec) -> int:
        nodes.append(spec)
        return len(nodes) - 1

    def connect(src: int, dst: int, words: int | None = None):
        edges.append(EdgeSpec(src, dst, nodes[src].out_words if words is None else words))

    def attn_chain(prefix: str, seq: int, prev: int | None) -> int:
        q = add_node(LayerSpec(f"{prefix}.q", "matmul", d_model, d_model, seq, 1))
        if prev is not None:
            connect(prev, q)
        kv = add_node(LayerSpec(f"{prefix}.kv", "matmul", d_model, 2 * d_model, seq, 1))
        if prev is not None:
            connect(prev, kv)
        qk = add_node(
            LayerSpec(f"{prefix}.qk", "actmul", d_model, n_heads * seq, seq, 1)
        )
        connect(q, qk)
        connect(kv, qk)
        pv = add_node(
            LayerSpec(f"{prefix}.pv", "actmul", n_heads * seq, d_model, seq, 1)
        )
        connect(qk, pv)
        connect(kv, pv)
        o = add_node(LayerSpec(f"{prefix}.o", "matmul", d_model, d_model, seq, 1))
        connect(pv, o)
        return o

    def ffn(prefix: str, seq: int, prev: int) -> int:
        w1 = add_node(LayerSpec(f"{prefix}.w1", "matmul", d_model, d_ff, seq, 1))
        connect(prev, w1)
        w2 = add_node(LayerSpec(f"{prefix}.w2", "matmul", d_ff, d_model, seq, 1))
        connect(w1, w2)
        return w2

    # Encoder layer: self-attention + FFN; w2 output is the memory.
    enc_o = attn_chain(f"{name}.enc.self", seq_enc, None)
    memory = ffn(f"{name}.enc", seq_enc, enc_o)

    # Decoder layer: self-attention over seq_dec ...
    dec_o = attn_chain(f"{name}.dec.self", seq_dec, None)
    # ... then cross-attention: Q from the decoder, K/V from the encoder memory.
    xq = add_node(LayerSpec(f"{name}.dec.xq", "matmul", d_model, d_model, seq_dec, 1))
    connect(dec_o, xq)
    xkv = add_node(LayerSpec(f"{name}.dec.xkv", "matmul", d_model, 2 * d_model, seq_enc, 1))
    connect(memory, xkv)  # the cross-link branch
    xqk = add_node(
        LayerSpec(f"{name}.dec.xqk", "actmul", d_model, n_heads * seq_enc, seq_dec, 1)
    )
    connect(xq, xqk)
    connect(xkv, xqk)
    xpv = add_node(
        LayerSpec(f"{name}.dec.xpv", "actmul", n_heads * seq_enc, d_model, seq_dec, 1)
    )
    connect(xqk, xpv)
    connect(xkv, xpv)
    xo = add_node(LayerSpec(f"{name}.dec.xo", "matmul", d_model, d_model, seq_dec, 1))
    connect(xpv, xo)
    ffn(f"{name}.dec", seq_dec, xo)
    return GraphIR(name, tuple(nodes), tuple(edges))
